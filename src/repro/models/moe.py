"""Mixture-of-Experts MLP (top-k router) — grok-1, mixtral.

Two dispatch modes (``cfg.moe_dispatch``):

  * ``dense``    — every token is run through EVERY expert and combined with
                   top-k gate weights (zeros elsewhere).  Simple, sharding-
                   friendly, but wastes E/k× the expert FLOPs.  This is the
                   baseline the §Perf hillclimb starts from.
  * ``capacity`` — GSPMD/Switch-style: each expert processes at most
                   C = ceil(T·k·cf/E) tokens, selected by one-hot dispatch
                   einsums.  FLOPs ∝ k·cf instead of E.  Tokens overflowing
                   an expert's capacity are dropped (standard behaviour);
                   the combine weights renormalize over surviving routes.

Router: softmax over expert logits, top-k, weights renormalized among the
selected experts (mixtral convention).  An auxiliary load-balance loss
(Switch §2.2) is returned for the training objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import act_fn


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(dtype),
        "up": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
        "gate": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
        "down": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
    }


def _route(params, x, cfg):
    """x: (T, D) → gate weights (T, E) (zeros off top-k), probs, topk idx."""
    logits = (x @ params["router"]).astype(jnp.float32)    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.moe_top_k)     # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(x.shape[0])[:, None], top_i].set(top_w)
    return gates, probs, top_i


def _expert_mlp(params, x, cfg):
    """x: (E, C, D) → (E, C, D), batched over experts."""
    h = jnp.einsum("ecd,edf->ecf", x, params["up"])
    g = jnp.einsum("ecd,edf->ecf", x, params["gate"])
    h = act_fn(cfg.mlp_act)(g) * h
    return jnp.einsum("ecf,efd->ecd", h, params["down"])


def load_balance_loss(probs, gates, n_experts: int):
    """Switch-style auxiliary loss: E · Σ_e f_e · P_e."""
    frac_tokens = jnp.mean((gates > 0).astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)


def moe_dense(params, x, cfg):
    """x: (B, S, D).  All experts on all tokens, gate-combined."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    gates, probs, _ = _route(params, xt, cfg)
    # (E, T, D): every expert sees every token
    h = jnp.einsum("td,edf->etf", xt, params["up"])
    g = jnp.einsum("td,edf->etf", xt, params["gate"])
    h = act_fn(cfg.mlp_act)(g) * h
    out = jnp.einsum("etf,efd->etd", h, params["down"])
    out = jnp.einsum("etd,te->td", out, gates.astype(out.dtype))
    aux = load_balance_loss(probs, gates, cfg.n_experts)
    return out.reshape(b, s, d), aux


# tokens per dispatch group: bounds the (G, E, C) one-hot tensors — their
# size per token is E·C = E·(G·k·cf/E) = G·k·cf, so SMALLER groups mean
# proportionally smaller dispatch/combine tensors (and their gradients,
# which all-reduce over the model axis).  256 ⇒ 640 slots/token at k=2.
MOE_GROUP = 256


def moe_capacity(params, x, cfg):
    """GSPMD/Switch-style capacity dispatch with token groups.

    Tokens are partitioned into groups of G; within each group every expert
    accepts at most C = ceil(G·k·cf/E) tokens (overflow dropped, standard).
    The dispatch/combine one-hots are (n_g, G, E, C) — linear in T, unlike a
    flat (T, E, T·k·cf/E) layout which is quadratic.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k

    def wsc(t, *spec):
        """Keep tokens sharded through the group reshapes (GSPMD otherwise
        gathers the full token tensor at every reshape boundary)."""
        ax = getattr(cfg, "act_batch_axis", None)
        if ax is None:
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            t, P(*[(ax if s == "b" else None) for s in spec]))

    xt = x.reshape(-1, d)
    t = xt.shape[0]
    g = min(MOE_GROUP, t)
    pad = (-t) % g
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xt = wsc(xt, "b", "d")
    n_g = xt.shape[0] // g
    cap = max(1, int(np.ceil(g * k * cfg.capacity_factor / e)))
    cap = min(cap, g)

    gates, probs, top_i = _route(params, xt, cfg)           # (T', E), (T', k)
    gates_g = wsc(gates.reshape(n_g, g, e), "b", None, None)
    top_g = wsc(top_i.reshape(n_g, g, k), "b", None, None)

    combine = jnp.zeros((n_g, g, e, cap), jnp.float32)
    dispatch = jnp.zeros((n_g, g, e, cap), bool)
    used = jnp.zeros((n_g, e), jnp.float32)
    for c in range(k):
        onehot = jax.nn.one_hot(top_g[..., c], e, dtype=jnp.float32)  # (n_g,G,E)
        pos = jnp.cumsum(onehot, axis=1) - 1.0 + used[:, None, :]
        pos_tok = jnp.sum(pos * onehot, axis=-1)            # (n_g, G)
        keep = pos_tok < cap
        w = jnp.sum(gates_g * onehot, axis=-1) * keep
        pos_oh = jax.nn.one_hot(pos_tok.astype(jnp.int32), cap,
                                dtype=jnp.float32)          # (n_g, G, C)
        sel = onehot[..., None] * pos_oh[..., None, :]      # (n_g, G, E, C)
        combine = combine + w[..., None, None] * sel
        dispatch = dispatch | ((sel > 0) & keep[..., None, None])
        used = used + jnp.sum(onehot * keep[..., None], axis=1)

    # dispatch: (n_g, E, C, D) → experts run on (E, n_g·C, D)
    xg = wsc(xt.reshape(n_g, g, d), "b", None, "d")
    dispatch = wsc(dispatch, "b", None, None, None)
    combine = wsc(combine, "b", None, None, None)
    # hard routing: no gradient flows through the dispatch one-hot (kills
    # the (G,E,C)-shaped backward einsum + its cross-model all-reduce)
    disp_f = jax.lax.stop_gradient(dispatch.astype(xt.dtype))
    xe = jnp.einsum("gtec,gtd->gecd", disp_f, xg)
    xe = wsc(xe, "b", None, None, "d")
    xe = jnp.transpose(xe, (1, 0, 2, 3)).reshape(e, n_g * cap, d)
    xe = wsc(xe, None, "b", "d")
    ye = _expert_mlp(params, xe, cfg)
    ye = wsc(ye, None, "b", "d")
    ye = jnp.transpose(ye.reshape(e, n_g, cap, d), (1, 0, 2, 3))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(ye.dtype), ye)
    out = wsc(out, "b", None, "d")
    out = out.reshape(-1, d)[:t]
    aux = load_balance_loss(probs, gates, e)
    return out.reshape(b, s, d), aux


def moe(params, x, cfg):
    if cfg.moe_dispatch == "capacity":
        return moe_capacity(params, x, cfg)
    return moe_dense(params, x, cfg)
