"""Attention: GQA/MQA/MHA with RoPE/M-RoPE, sliding window, KV cache.

Two backends:
  * ``xla``     — plain einsum attention (small shapes, smoke tests, oracle)
  * ``chunked`` — flash-style streaming over KV chunks with running
                  max/denominator (``lax.scan``), never materializing the
                  (S × S) score matrix.  Used by the big dry-run shapes; for
                  sliding-window layers only the in-window band of chunks is
                  visited, making the cost O(S·W) instead of O(S²).

The Pallas TPU kernel (kernels/flash_attention.py) implements the same
contract; `repro.kernels.ops.attention` dispatches to it when enabled.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope, rms_norm

NEG_INF = -1e30


def init_attention(key, cfg, dtype):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(qd)
    p = {
        "wq": (jax.random.normal(ks[0], (d, qd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kvd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kvd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (qd, d)) * so).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,))
        p["k_norm"] = jnp.zeros((cfg.head_dim,))
    return p


def _mask_value(scores, q_pos, k_pos, window: Optional[int]):
    """Causal (+ optional sliding-window) mask, positions broadcastable."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, scores, NEG_INF)


def _softcap(scores, cap: Optional[float]):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


def attention_xla(q, k, v, q_pos, k_pos, *, window=None, softcap=None):
    """q: (B,Sq,H,D), k/v: (B,Sk,Hkv,D); returns (B,Sq,H,D)."""
    n_rep = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = _softcap(scores, softcap)
    scores = _mask_value(scores, q_pos, k_pos, window)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def attention_chunked_unrolled(q, k, v, q_pos, k_pos, *, window=None,
                               softcap=None, chunk_q: int = 2048,
                               chunk_k: int = 2048):
    """Flash-style attention with a PYTHON loop over (q-chunk, kv-chunk)
    pairs, visiting only causally/within-window reachable pairs.

    Used by the dry-run (cfg.scan_unroll): every chunk body appears in the
    HLO, so ``cost_analysis`` FLOP/byte totals are exact (XLA counts scan
    bodies once).  Assumes q and k positions are aligned ranges (training /
    prefill), which holds for every dry-run shape.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    n_rep = h // k.shape[2]
    scale = 1.0 / np.sqrt(d)
    pad_q, pad_k = (-sq) % chunk_q, (-sk) % chunk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=2 ** 30)
    nq, nk = q.shape[1] // chunk_q, k.shape[1] // chunk_k
    out_chunks = []
    for qi in range(nq):
        q_blk = q[:, qi * chunk_q:(qi + 1) * chunk_q]
        qp = q_pos[qi * chunk_q:(qi + 1) * chunk_q]
        acc = jnp.zeros((b, h, chunk_q, d), jnp.float32)
        m = jnp.full((b, h, chunk_q), NEG_INF, jnp.float32)
        l = jnp.zeros((b, h, chunk_q), jnp.float32)
        # causal: kv chunk start ≤ q chunk end; window: within band
        hi = min(((qi + 1) * chunk_q + chunk_k - 1) // chunk_k, nk)
        lo = 0
        if window is not None:
            lo = max(0, (qi * chunk_q - window) // chunk_k)
        for kj in range(lo, hi):
            k_blk = _repeat_kv(k[:, kj * chunk_k:(kj + 1) * chunk_k], n_rep)
            v_blk = _repeat_kv(v[:, kj * chunk_k:(kj + 1) * chunk_k], n_rep)
            kp = k_pos[kj * chunk_k:(kj + 1) * chunk_k]
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk).astype(
                jnp.float32) * scale
            s = _softcap(s, softcap)
            s = _mask_value(s, qp, kp, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk).astype(jnp.float32)
            m = m_new
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out_chunks.append(jnp.transpose(out, (0, 2, 1, 3)))
    out = jnp.concatenate(out_chunks, axis=1)
    return out[:, :sq].astype(q.dtype)


def attention_chunked(q, k, v, q_pos, k_pos, *, window=None, softcap=None,
                      chunk_q: int = 256, chunk_k: int = 256):
    """Flash-style attention, O(chunk_q·chunk_k) live scores.

    For sliding-window layers only the band of KV chunks that can intersect
    the window is visited per query chunk (static band width).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    n_rep = h // hkv
    scale = 1.0 / np.sqrt(d)

    # pad to chunk multiples
    pad_q = (-sq) % chunk_q
    pad_k = (-sk) % chunk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=2 ** 30)
    nq, nk = q.shape[1] // chunk_q, k.shape[1] // chunk_k

    qc = q.reshape(b, nq, chunk_q, h, d)
    kc = k.reshape(b, nk, chunk_k, hkv, d)
    vc = v.reshape(b, nk, chunk_k, hkv, d)
    qpc = q_pos.reshape(nq, chunk_q)
    kpc = k_pos.reshape(nk, chunk_k)

    # band of kv chunks per query chunk (static count)
    if window is not None:
        n_band = min(nk, (window + chunk_q) // chunk_k + 2)
    else:
        n_band = nk

    def per_qchunk(qi, q_blk, qp_blk):
        # kv chunk indices to visit: last n_band chunks ending at qi's end
        # (causal ⇒ kv chunk index ≤ roughly qi·chunk_q/chunk_k)
        hi = jnp.minimum((qi + 1) * chunk_q // chunk_k, nk)  # exclusive
        start = jnp.maximum(hi - n_band, 0)

        def inner(carry, j):
            acc, m, l = carry
            kj = jnp.clip(start + j, 0, nk - 1)
            k_blk = jax.lax.dynamic_index_in_dim(kc, kj, axis=1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, kj, axis=1, keepdims=False)
            kp_blk = jax.lax.dynamic_index_in_dim(kpc, kj, axis=0, keepdims=False)
            k_r = _repeat_kv(k_blk, n_rep)
            v_r = _repeat_kv(v_blk, n_rep)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_r).astype(jnp.float32) * scale
            s = _softcap(s, softcap)
            s = _mask_value(s, qp_blk, kp_blk, window)
            # mask out-of-range chunk visits entirely
            s = jnp.where((start + j) < hi, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_r.dtype), v_r).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, chunk_q, d), jnp.float32)
        m0 = jnp.full((b, h, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, chunk_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(inner, (acc0, m0, l0), jnp.arange(n_band))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.transpose(out, (0, 2, 1, 3))  # (b, chunk_q, h, d)

    out = jax.lax.map(
        lambda args: per_qchunk(*args),
        (jnp.arange(nq), jnp.moveaxis(qc, 1, 0), qpc))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * chunk_q, h, d)
    return out[:, :sq].astype(q.dtype)


class KVCache(NamedTuple):
    """KV cache; for sliding-window layers S_max = window and the buffer is
    a ring (absolute positions tracked in ``pos``)."""
    k: jnp.ndarray       # (B, S_max, Hkv, D)
    v: jnp.ndarray
    pos: jnp.ndarray     # (S_max,) absolute position of each slot (−big = empty)
    length: jnp.ndarray  # scalar int32 — total tokens seen


class QuantKVCache(NamedTuple):
    """int8 KV cache (per-token-per-head symmetric scales) — halves the
    decode working set vs bf16; the paper's compression idea applied to the
    serving state (beyond-paper §Perf iteration)."""
    k: jnp.ndarray        # int8 (B, S_max, Hkv, D)
    v: jnp.ndarray
    k_scale: jnp.ndarray  # f32 (B, S_max, Hkv)
    v_scale: jnp.ndarray
    pos: jnp.ndarray
    length: jnp.ndarray


def _kv_quant(x):
    """x (B,S,H,D) → int8 codes + per-(B,S,H) scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / safe[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale.astype(jnp.float32)


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_kv_cache(batch: int, s_max: int, n_kv: int, head_dim: int, dtype,
                  quantized: bool = False):
    # empty slots carry a far-future sentinel so the causal mask hides them
    pos = jnp.full((s_max,), 2 ** 30, jnp.int32)
    length = jnp.zeros((), jnp.int32)
    if quantized:
        return QuantKVCache(
            k=jnp.zeros((batch, s_max, n_kv, head_dim), jnp.int8),
            v=jnp.zeros((batch, s_max, n_kv, head_dim), jnp.int8),
            k_scale=jnp.zeros((batch, s_max, n_kv), jnp.float32),
            v_scale=jnp.zeros((batch, s_max, n_kv), jnp.float32),
            pos=pos, length=length)
    return KVCache(
        k=jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
        pos=pos, length=length)


def attention_block(params, cfg, x, *, rope_cs=None, positions=None,
                    window=None, cache: Optional[KVCache] = None,
                    backend: str = "chunked"):
    """Full attention sub-block: qkv proj → rope → attend → out proj.

    Training / prefill: x is (B, S, D), cache is None (train) or an empty
    cache to fill (prefill).  Decode: x is (B, 1, D) and cache holds history.
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if rope_cs is not None:
        cos, sin = rope_cs
        q = apply_rope(q, cos, sin, cfg.rotary_pct)
        k = apply_rope(k, cos, sin, cfg.rotary_pct)

    if cache is None:
        q_pos = k_pos = positions
        k_all, v_all = k, v
        new_cache = None
    else:
        quant = isinstance(cache, QuantKVCache)
        s_max = cache.k.shape[1]
        start = cache.length
        q_pos = start + jnp.arange(s)
        if s > s_max:
            # prefill longer than a sliding-window ring: keep last s_max
            k_w, v_w = k[:, -s_max:], v[:, -s_max:]
            pos_w = q_pos[-s_max:].astype(jnp.int32)
            if quant:
                kq, ks = _kv_quant(k_w)
                vq, vs = _kv_quant(v_w)
                new_cache = QuantKVCache(k=kq, v=vq, k_scale=ks, v_scale=vs,
                                         pos=pos_w, length=start + s)
            else:
                new_cache = KVCache(k=k_w.astype(cache.k.dtype),
                                    v=v_w.astype(cache.v.dtype),
                                    pos=pos_w, length=start + s)
            # attention over the full fresh sequence (chunked-banded below)
            k_all, v_all, k_pos = k, v, q_pos
        else:
            idx = start % s_max if s == 1 else start  # ring writes for decode
            upd = lambda buf, new, ax=1: jax.lax.dynamic_update_slice_in_dim(
                buf, new, idx, axis=ax)
            pos_all = upd(cache.pos, q_pos.astype(jnp.int32), 0)
            if quant:
                kq, ks = _kv_quant(k)
                vq, vs = _kv_quant(v)
                new_cache = QuantKVCache(
                    k=upd(cache.k, kq), v=upd(cache.v, vq),
                    k_scale=upd(cache.k_scale, ks),
                    v_scale=upd(cache.v_scale, vs),
                    pos=pos_all, length=start + s)
                k_all = _kv_dequant(new_cache.k, new_cache.k_scale, q.dtype)
                v_all = _kv_dequant(new_cache.v, new_cache.v_scale, q.dtype)
            else:
                k_all = upd(cache.k, k.astype(cache.k.dtype))
                v_all = upd(cache.v, v.astype(cache.v.dtype))
                new_cache = KVCache(k=k_all, v=v_all, pos=pos_all,
                                    length=start + s)
                k_all = k_all.astype(q.dtype)
                v_all = v_all.astype(q.dtype)
            k_pos = pos_all

    if backend == "xla":
        fn = attention_xla
    elif cfg.scan_unroll:  # dry-run costing: exact, loop-free HLO
        fn = partial(attention_chunked_unrolled, chunk_q=2048, chunk_k=2048)
    else:
        fn = partial(attention_chunked, chunk_q=min(cfg.chunk_size, max(s, 16)),
                     chunk_k=cfg.chunk_size)
    if s == 1 and cache is not None:
        # decode: single query — use streaming over the cache (no q chunking)
        out = _decode_attention(q, k_all, v_all, q_pos, k_pos, window=window,
                                softcap=cfg.attn_logit_softcap)
    else:
        out = fn(q, k_all, v_all, q_pos, k_pos, window=window,
                 softcap=cfg.attn_logit_softcap)
    out = out.reshape(b, s, cfg.q_dim) @ params["wo"]
    return out, new_cache


def _decode_attention(q, k, v, q_pos, k_pos, *, window=None, softcap=None):
    """One-token decode: q (B,1,H,D) vs full cache (B,S,Hkv,D) — O(S)."""
    n_rep = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = _softcap(scores, softcap)
    scores = _mask_value(scores, q_pos, k_pos, window)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
