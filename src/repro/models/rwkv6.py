"""RWKV-6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Per head (key/value dim P), state S ∈ ℝ^{P×P}:

    o_t = r_tᵀ (S_{t−1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t−1} + k_t v_tᵀ,     w_t = exp(−exp(ŵ_t)) ∈ (0,1)

with data-dependent ŵ_t (low-rank LoRA on the token-shifted input) and a
learned per-channel bonus u.  Training uses the chunked factorized form
(per-channel decay cumsum; q̃ = r·e^{cw}, k̃ = k·e^{−cw}) analogous to the
Mamba-2 SSD path; decode is the O(1) recurrence.

Simplifications vs the reference implementation (documented in DESIGN.md):
single data-dependent lerp for the receptance/key/value/gate token-shift
(RWKV-6 uses five separate LoRA lerps), and the decay LoRA rank is fixed at
64.  The recurrence itself is exact.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import rms_norm


LORA_RANK = 64
RWKV_CHUNK = 64   # chunk for the wkv scan (bounds the exp-split dynamic range)


def init_rwkv6(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    s = 1.0 / np.sqrt(d)
    return {
        # time-mix
        "mix_base": jnp.full((4, d), 0.5, dtype),   # r,k,v,g static lerp
        "mix_lora_a": (jax.random.normal(ks[0], (d, 32)) * s).astype(dtype),
        "mix_lora_b": (jax.random.normal(ks[1], (32, 4 * d)) * 0.1 / np.sqrt(32)).astype(dtype),
        "wr": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[6], (d, d)) * s).astype(dtype),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "decay_lora_a": (jax.random.normal(ks[7], (d, LORA_RANK)) * s).astype(dtype),
        "decay_lora_b": (jax.random.normal(ks[8], (LORA_RANK, d)) * 0.1 / np.sqrt(LORA_RANK)).astype(dtype),
        "bonus_u": jnp.zeros((d,), jnp.float32),
        "ln_x": jnp.zeros((d,)),
        # channel-mix
        "ck": (jax.random.normal(ks[9], (d, cfg.d_ff)) * s).astype(dtype),
        "cv": (jax.random.normal(jax.random.fold_in(key, 11), (cfg.d_ff, d))
               / np.sqrt(cfg.d_ff)).astype(dtype),
        "cr": (jax.random.normal(jax.random.fold_in(key, 12), (d, d)) * s).astype(dtype),
        "cmix_r": jnp.full((d,), 0.5, dtype),
        "cmix_k": jnp.full((d,), 0.5, dtype),
    }


class RWKVCache(NamedTuple):
    state: jnp.ndarray    # (B, H, P, P) float32 — wkv state
    last_t: jnp.ndarray   # (B, D) — previous token's time-mix input
    last_c: jnp.ndarray   # (B, D) — previous token's channel-mix input


def init_rwkv_cache(batch, cfg, dtype):
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    p = cfg.rwkv_head_dim
    return RWKVCache(
        state=jnp.zeros((batch, h, p, p), jnp.float32),
        last_t=jnp.zeros((batch, d), dtype),
        last_c=jnp.zeros((batch, d), dtype),
    )


def _token_shift(x, last):
    """shifted[t] = x[t−1]; shifted[0] = last (zeros at seq start)."""
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev


def _chunked_wkv(r, k, v, logw, u, chunk: int, state0=None):
    """Chunked linear attention with per-channel decay.

    r,k,v: (B,S,H,P); logw (B,S,H,P) = log decay ∈ (−∞, 0); u (H,P).
    o_t = r_t·(S_{t−1} + diag(u) k_t v_tᵀ);  S_t = diag(w_t)S_{t−1} + k_t v_tᵀ.
    """
    b, s, h, p = r.shape
    pad = (-s) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = z(r), z(k), z(v), z(logw)
    nc, q = r.shape[1] // chunk, chunk

    def to_c(t):
        return t.reshape(b, nc, q, h, p)

    rc, kc, vc, lw = map(to_c, (r, k, v, logw))
    lw = lw.astype(jnp.float32)
    cw = jnp.cumsum(lw, axis=2)                 # inclusive cumsum within chunk
    total = cw[:, :, -1]                        # (B,nc,H,P)

    # intra-chunk: for j < t: factor exp(cw_{t−1} − cw_j) = exp(cw_t − lw_t − cw_j)
    rt = rc.astype(jnp.float32) * jnp.exp(cw - lw)
    kt = kc.astype(jnp.float32) * jnp.exp(-cw)

    def intra(rb, kb, vb):
        scores = jnp.einsum("bthp,bjhp->bhtj", rb, kb)
        mask = jnp.tril(jnp.ones((q, q), bool), k=-1)   # strictly lower
        scores = jnp.where(mask, scores, 0.0)
        return jnp.einsum("bhtj,bjhp->bthp", scores, vb.astype(jnp.float32))

    y_intra = jax.vmap(intra, in_axes=(1, 1, 1), out_axes=1)(rt, kt, vc)
    # diagonal (bonus) term: o_t += (r_t ⊙ u · k_t) v_t
    diag = jnp.einsum("bcqhp,bcqhp->bcqh",
                      rc.astype(jnp.float32) * u[None, None, None],
                      kc.astype(jnp.float32))
    y_diag = diag[..., None] * vc.astype(jnp.float32)

    # chunk state: S_chunk = Σ_j diag(exp(total − cw_j)) k_j v_jᵀ
    k_dec = kc.astype(jnp.float32) * jnp.exp(total[:, :, None] - cw)
    s_chunk = jnp.einsum("bcqhp,bcqhn->bchpn", k_dec, vc.astype(jnp.float32))

    def scan_fn(S, inp):
        tot_c, s_c = inp
        S_in = S
        S = jnp.exp(tot_c)[..., None] * S + s_c
        return S, S_in

    S0 = jnp.zeros((b, h, p, p), jnp.float32) if state0 is None else state0
    S_final, S_ins = jax.lax.scan(
        scan_fn, S0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    S_in = jnp.moveaxis(S_ins, 0, 1)            # (B,nc,H,P,P)

    # inter-chunk: o_t += (r_t ⊙ exp(cw_{t−1})) · S_in
    r_dec = rc.astype(jnp.float32) * jnp.exp(cw - lw)
    y_inter = jnp.einsum("bcqhp,bchpn->bcqhn", r_dec, S_in)

    y = (y_intra + y_diag + y_inter).reshape(b, nc * q, h, p)[:, :s]
    return y, S_final


def rwkv6_time_mix(params, cfg, x, cache: Optional[RWKVCache] = None):
    b, s, d = x.shape
    h, p = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    last = cache.last_t if cache is not None else jnp.zeros((b, d), x.dtype)
    prev = _token_shift(x, last)
    delta = prev - x

    # data-dependent lerp (single shared LoRA, split 4 ways)
    lora = jnp.tanh(x @ params["mix_lora_a"]) @ params["mix_lora_b"]
    mixes = params["mix_base"][:, None, None] + lora.reshape(b, s, 4, d).transpose(2, 0, 1, 3)
    xr, xk, xv, xg = (x + delta * m for m in mixes)

    r = (xr @ params["wr"]).reshape(b, s, h, p)
    k = (xk @ params["wk"]).reshape(b, s, h, p)
    v = (xv @ params["wv"]).reshape(b, s, h, p)
    g = jax.nn.silu(xg @ params["wg"])

    dec = params["decay_base"] + (jnp.tanh(xk @ params["decay_lora_a"])
                                  @ params["decay_lora_b"]).astype(jnp.float32)
    logw = -jnp.exp(dec.astype(jnp.float32))            # log w_t ∈ (−∞, 0)
    # clamp so the chunked exp-split factors stay inside float32 range
    # (exp(RWKV_CHUNK·|logw|) ≤ e^80); applied in BOTH train and decode paths
    # so the recurrence semantics stay identical.
    logw = jnp.maximum(logw, -80.0 / RWKV_CHUNK)
    logw = logw.reshape(b, s, h, p)
    u = params["bonus_u"].reshape(h, p)

    if cache is None or s > 1:
        state0 = None if cache is None else cache.state
        y, S = _chunked_wkv(r, k, v, logw, u, RWKV_CHUNK, state0)
    else:
        kv = jnp.einsum("bhp,bhn->bhpn", k[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32))
        o = jnp.einsum("bhp,bhpn->bhn", r[:, 0].astype(jnp.float32),
                       cache.state + u[None, :, :, None] * kv)
        S = jnp.exp(logw[:, 0])[..., None] * cache.state + kv
        y = o[:, None]

    y = y.reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, params["ln_x"], cfg.norm_eps) * g
    out = y @ params["wo"]
    new_cache = None
    if cache is not None:
        new_cache = cache._replace(state=S, last_t=x[:, -1])
    return out, new_cache


def rwkv6_channel_mix(params, cfg, x, cache: Optional[RWKVCache] = None):
    b, s, d = x.shape
    last = cache.last_c if cache is not None else jnp.zeros((b, d), x.dtype)
    prev = _token_shift(x, last)
    xk = x + (prev - x) * params["cmix_k"]
    xr = x + (prev - x) * params["cmix_r"]
    kk = jnp.square(jax.nn.relu(xk @ params["ck"]))
    out = jax.nn.sigmoid(xr @ params["cr"]) * (kk @ params["cv"])
    new_cache = cache._replace(last_c=x[:, -1]) if cache is not None else None
    return out, new_cache
