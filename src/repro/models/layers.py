"""Basic layers: norms, MLPs, embeddings, positional encodings (pure JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, weight, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d):
    return jnp.zeros((d,))


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    p = {
        "up": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "down": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if gated:
        p["gate"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


def mlp(params, x, act: str, gated: bool):
    h = x @ params["up"]
    if gated:
        h = act_fn(act)(x @ params["gate"]) * h
    else:
        h = act_fn(act)(h)
    return h @ params["down"]


# ---------------------------------------------------------------------------
# Embeddings / positions
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def sinusoidal_positions(positions, d_model: int, dtype=jnp.float32):
    """positions: (...,) int → (..., d_model) sinusoidal encoding."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def rope_angles(positions, rot_dim: int, theta: float):
    """positions (...,) int → cos,sin (..., rot_dim//2)."""
    half = rot_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rotary_pct: float = 1.0):
    """x: (B, S, H, D); cos/sin: (B, S, rot_dim//2) or (B, S, H, rot_dim//2)."""
    d = x.shape[-1]
    rot = int(d * rotary_pct)
    if rot % 2:
        rot -= 1
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2:]
    if cos.ndim == x.ndim - 1:  # broadcast over heads
        cos, sin = cos[..., None, :], sin[..., None, :]
    cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < d else out


def mrope_angles(positions3, rot_dim: int, theta: float, sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE: positions3 (3, B, S) = (temporal, height, width).

    The rotary spectrum is partitioned into three sections, each rotated by
    its own position stream; section sizes are in half-dim units and must sum
    to rot_dim//2 (scaled automatically).
    """
    half = rot_dim // 2
    sec = np.array(sections, dtype=np.float64)
    sec = np.round(sec / sec.sum() * half).astype(int)
    sec[2] = half - sec[0] - sec[1]
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    # which position stream drives each frequency band
    stream_idx = jnp.asarray(
        np.concatenate([np.full(s, i) for i, s in enumerate(sec)]))
    p = positions3.astype(jnp.float32)            # (3, B, S)
    p_sel = p[stream_idx]                          # (half, B, S)
    ang = jnp.moveaxis(p_sel, 0, -1) * freqs       # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)
