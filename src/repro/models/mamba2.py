"""Mamba-2 block (SSD — state space duality), chunked for training.

Recurrence per head h (state S ∈ ℝ^{P×N}, P = head dim, N = ssm_state):

    S_t = a_t · S_{t−1} + (Δ_t x_t) ⊗ B_t          a_t = exp(−Δ_t·A_h)
    y_t = S_t C_tᵀ + D_h · x_t

Training uses the chunked form: within a chunk of Q tokens the quadratic
"attention" form with decay mask  exp(cum_t − cum_j)  is factorized as
(q̃ = C·e^{cum}) (k̃ = B·e^{−cum}) so only Q×Q per-head scores materialize;
chunk-final states are carried with a ``lax.scan`` (n_chunks steps).
Decode is the O(1) recurrent update.

This is the TPU adaptation: MXU-friendly chunk matmuls instead of the CUDA
selective-scan kernel; numerics kept in float32 inside the scan.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import rms_norm


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_inner
    h, p, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_n_groups
    conv_dim = d_in + 2 * g * n
    ks = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(d)
    return {
        # fused input projection: [x (d_in), z (d_in), B (g·n), C (g·n), dt (h)]
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_in + 2 * g * n + h)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.zeros((d_in,)),
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) / np.sqrt(d_in)).astype(dtype),
    }


class SSMCache(NamedTuple):
    state: jnp.ndarray       # (B, H, P, N) float32
    conv: jnp.ndarray        # (B, conv_w − 1, conv_dim)


def init_ssm_cache(batch, cfg, dtype):
    h, p, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_n_groups
    conv_dim = cfg.ssm_inner + 2 * g * n
    return SSMCache(
        state=jnp.zeros((batch, h, p, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    )


def _split_proj(cfg, proj):
    d_in, g, n, h = cfg.ssm_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    xz, rest = proj[..., : 2 * d_in], proj[..., 2 * d_in:]
    x, z = xz[..., :d_in], xz[..., d_in:]
    bc, dt = rest[..., : 2 * g * n], rest[..., 2 * g * n:]
    return x, z, bc, dt


def _causal_conv(u, w, b, carry=None):
    """u: (B, S, C); depthwise causal conv width K. carry: (B, K−1, C)."""
    kw = w.shape[0]
    if carry is None:
        pad = jnp.zeros((u.shape[0], kw - 1, u.shape[-1]), u.dtype)
    else:
        pad = carry.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i: i + u.shape[1]] * w[i] for i in range(kw))
    new_carry = full[:, -(kw - 1):] if kw > 1 else None
    return jax.nn.silu(out + b), new_carry


def _ssd_chunked(xh, dt, a, Bm, Cm, d_skip, chunk: int, state0=None):
    """Chunked SSD scan.

    xh (B,S,H,P), dt (B,S,H), a = exp(A_log) (H,), Bm/Cm (B,S,G,N).
    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    b, s, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = xh.shape[1] // chunk
    q = chunk

    def to_chunks(t):
        return t.reshape((b, nc, q) + t.shape[2:])

    xc, dtc = to_chunks(xh), to_chunks(dt)
    Bc = jnp.repeat(to_chunks(Bm), rep, axis=3)        # (B,nc,Q,H,N)
    Cc = jnp.repeat(to_chunks(Cm), rep, axis=3)

    loga = -dtc.astype(jnp.float32) * a                # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(loga, axis=2)                     # inclusive
    total = cum[:, :, -1]                              # (B,nc,H)

    # intra-chunk: scores[t,j] = (C_t·B_j)·exp(cum_t − cum_j)·dt_j, j ≤ t
    def intra(xb, dtb, Bb, Cb, cumb):
        # shapes: (B,Q,H,*) for one chunk — vmapped over chunk axis
        scores = jnp.einsum("bthn,bjhn->bhtj", Cb, Bb).astype(jnp.float32)
        decay = cumb[:, :, None, :] - cumb[:, None, :, :]       # (B,t,j,H)
        decay = jnp.transpose(decay, (0, 3, 1, 2))              # (B,H,t,j)
        mask = jnp.tril(jnp.ones((q, q), bool))
        # mask BEFORE exp: upper-triangle decays are positive and would
        # overflow, poisoning the backward pass with inf·0 = NaN.
        w = jnp.exp(jnp.where(mask, decay, -jnp.inf)) * scores
        w = w * dtb.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        return jnp.einsum("bhtj,bjhp->bthp", w.astype(xb.dtype), xb)

    y_intra = jax.vmap(intra, in_axes=(1, 1, 1, 1, 1), out_axes=1)(
        xc, dtc, Bc, Cc, cum)

    # chunk-final contributions: S_chunk = Σ_j exp(total − cum_j)·dt_j·x_j⊗B_j
    k_dec = jnp.exp(total[:, :, None] - cum) * dtc.astype(jnp.float32)  # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn",
                         k_dec, xc.astype(jnp.float32), Bc.astype(jnp.float32))

    # inter-chunk scan over chunk states
    def scan_fn(S, inp):
        tot_c, s_c = inp                                 # (B,H), (B,H,P,N)
        S_in = S
        S = jnp.exp(tot_c)[:, :, None, None] * S + s_c
        return S, S_in

    S0 = jnp.zeros((b, h, p, n), jnp.float32) if state0 is None else state0
    S_final, S_in_per_chunk = jax.lax.scan(
        scan_fn, S0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    S_in = jnp.moveaxis(S_in_per_chunk, 0, 1)            # (B,nc,H,P,N)

    # inter-chunk output: y_t += C_t · (exp(cum_t) · S_in)
    q_dec = jnp.exp(cum)                                  # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Cc.astype(jnp.float32), S_in, q_dec)

    y = y_intra.astype(jnp.float32) + y_inter + d_skip[None, None, :, None] \
        * xc.astype(jnp.float32)
    y = y.reshape(b, nc * q, h, p)[:, :s]
    return y, S_final


def mamba2_block(params, cfg, x, cache: Optional[SSMCache] = None):
    """x: (B, S, D) → (B, S, D); cache for decode. Returns (y, new_cache)."""
    b, s, d = x.shape
    h, p, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_n_groups
    proj = x @ params["in_proj"]
    xi, z, bc, dt_raw = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xi, bc], axis=-1)
    conv_out, conv_carry = _causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"],
                                        None if cache is None else cache.conv)
    xi = conv_out[..., : cfg.ssm_inner]
    bc = conv_out[..., cfg.ssm_inner:]
    Bm = bc[..., : g * n].reshape(b, s, g, n)
    Cm = bc[..., g * n:].reshape(b, s, g, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = jnp.exp(params["a_log"])                                          # (H,)
    xh = xi.reshape(b, s, h, p)

    if cache is None or s > 1:
        state0 = None if cache is None else cache.state
        y, S = _ssd_chunked(xh, dt, a, Bm, Cm, params["d_skip"],
                            cfg.chunk_size, state0)
    else:
        # decode: one recurrent step
        a_t = jnp.exp(-dt[:, 0] * a)                                      # (B,H)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, 0],
                         xh[:, 0].astype(jnp.float32),
                         jnp.repeat(Bm[:, 0], h // g, axis=1).astype(jnp.float32))
        S = a_t[:, :, None, None] * cache.state + upd
        y = jnp.einsum("bhpn,bhn->bhp", S,
                       jnp.repeat(Cm[:, 0], h // g, axis=1).astype(jnp.float32))
        y = y + params["d_skip"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y[:, None]

    y = y.reshape(b, s, cfg.ssm_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)                       # gated
    y = rms_norm(y, params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = SSMCache(state=S, conv=conv_carry)
    return out, new_cache
