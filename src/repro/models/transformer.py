"""Decoder assembly: embeddings → scanned layer stack → head, + LM loss.

The layer stack is ``scan_unit × scan_repeats`` lowered as ONE ``lax.scan``
over stacked parameters (compact HLO even for 62-layer models), plus an
optional non-repeating ``tail``.  "shared_attn" blocks (Zamba2) read their
weights from a single shared parameter set closed over by the scan body —
weight sharing is real, per-invocation KV caches are separate.

Modes:
  * train   — ``forward(params, cfg, batch)``                → logits, aux
  * prefill — ``forward(..., cache=empty_cache(...))``       → logits, cache
  * decode  — ``forward(..., cache=filled)`` with S=1 tokens → logits, cache
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attention_block, init_attention, init_kv_cache
from .config import ModelConfig
from .layers import (embed, init_embed, init_mlp, init_rms_norm, mlp,
                     mrope_angles, rms_norm, rope_angles, sinusoidal_positions)
from .mamba2 import init_mamba2, init_ssm_cache, mamba2_block
from .moe import init_moe, moe
from .rwkv6 import (init_rwkv6, init_rwkv_cache, rwkv6_channel_mix,
                    rwkv6_time_mix)

ATTN_KINDS = ("attn", "attn_local", "shared_attn")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str, dtype):
    if kind in ("attn", "attn_local"):
        k1, k2 = jax.random.split(key)
        p = {"ln1": init_rms_norm(cfg.d_model),
             "attn": init_attention(k1, cfg, dtype),
             "ln2": init_rms_norm(cfg.d_model)}
        if cfg.n_experts:
            p["moe"] = init_moe(k2, cfg, dtype)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype)
        return p
    if kind == "mamba2":
        return {"ln1": init_rms_norm(cfg.d_model),
                "mamba": init_mamba2(key, cfg, dtype)}
    if kind == "rwkv6":
        return {"ln1": init_rms_norm(cfg.d_model),
                "ln2": init_rms_norm(cfg.d_model),
                "rwkv": init_rwkv6(key, cfg, dtype)}
    if kind == "shared_attn":
        return None  # parameters live in params["shared_attn"]
    raise ValueError(kind)


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: dict = {"embed": init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype)}

    # scanned unit: stack each slot's params over repeats
    unit_params = []
    for slot, kind in enumerate(cfg.scan_unit):
        if kind == "shared_attn":
            unit_params.append({})
            continue
        ks = jax.random.split(jax.random.fold_in(keys[1], slot), cfg.scan_repeats)
        unit_params.append(jax.vmap(
            lambda k: _init_block(k, cfg, kind, dtype))(ks))
    params["scan"] = tuple(unit_params)

    params["tail"] = tuple(
        _init_block(jax.random.fold_in(keys[2], i), cfg, kind, dtype)
        for i, kind in enumerate(cfg.tail))

    if "shared_attn" in cfg.scan_unit or "shared_attn" in cfg.tail:
        params["shared_attn"] = {
            "ln1": init_rms_norm(cfg.d_model),
            "attn": init_attention(keys[3], cfg, dtype),
        }

    if cfg.pos_embed == "learned":
        params["pos_table"] = (jax.random.normal(
            keys[4], (cfg.max_seq, cfg.d_model)) * 0.02).astype(dtype)

    params["final_norm"] = init_rms_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[5], (cfg.d_model, cfg.vocab_size)) / np.sqrt(cfg.d_model)
        ).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, s_max: int, dtype):
    if kind in ("attn", "shared_attn"):
        return init_kv_cache(batch, s_max, cfg.n_kv_heads, cfg.head_dim, dtype,
                             quantized=cfg.kv_cache_int8)
    if kind == "attn_local":
        w = min(cfg.sliding_window or s_max, s_max)
        return init_kv_cache(batch, w, cfg.n_kv_heads, cfg.head_dim, dtype,
                             quantized=cfg.kv_cache_int8)
    if kind == "mamba2":
        return init_ssm_cache(batch, cfg, dtype)
    if kind == "rwkv6":
        return init_rwkv_cache(batch, cfg, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=None):
    """Cache pytree: per scan slot stacked over repeats, plus tail list."""
    dtype = dtype or jnp.dtype(cfg.dtype)

    def stacked(kind):
        one = _init_block_cache(cfg, kind, batch, s_max, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.scan_repeats,) + a.shape).copy(), one)

    return {
        "scan": tuple(stacked(k) for k in cfg.scan_unit),
        "tail": tuple(_init_block_cache(cfg, k, batch, s_max, dtype)
                      for k in cfg.tail),
        "length": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_block(params, cfg, kind, x, rope_cs, rope_cs_local, positions,
                 cache, shared_params, backend):
    """One layer. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local", "shared_attn"):
        p = shared_params if kind == "shared_attn" else params
        window = cfg.sliding_window if kind == "attn_local" else None
        cs = rope_cs_local if (kind == "attn_local" and rope_cs_local
                               is not None) else rope_cs
        h, new_cache = attention_block(
            p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
            rope_cs=cs, positions=positions, window=window, cache=cache,
            backend=backend)
        x = x + h
        if kind != "shared_attn":
            h2 = rms_norm(x, params["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                h2, aux = moe(params["moe"], h2, cfg)
            else:
                h2 = mlp(params["mlp"], h2, cfg.mlp_act, cfg.mlp_gated)
            x = x + h2
        return x, new_cache, aux
    if kind == "mamba2":
        h, new_cache = mamba2_block(
            params["mamba"], cfg, rms_norm(x, params["ln1"], cfg.norm_eps), cache)
        return x + h, new_cache, aux
    if kind == "rwkv6":
        h, new_cache = rwkv6_time_mix(
            params["rwkv"], cfg, rms_norm(x, params["ln1"], cfg.norm_eps), cache)
        x = x + h
        h2, new_cache = rwkv6_channel_mix(
            params["rwkv"], cfg, rms_norm(x, params["ln2"], cfg.norm_eps), new_cache)
        return x + h2, new_cache, aux
    raise ValueError(kind)


class ForwardOut(NamedTuple):
    logits: jnp.ndarray
    cache: Any
    aux_loss: jnp.ndarray


def forward(params, cfg: ModelConfig, batch, cache=None,
            backend: str = "chunked", remat: bool = True) -> ForwardOut:
    """batch keys: "tokens" (B,S) int32 and/or "extra_embeds" (B,S_e,D)
    prepended (VLM/audio stubs); optional "positions" (3,B,S) for M-RoPE."""
    tokens = batch.get("tokens")
    x_parts = []
    if batch.get("extra_embeds") is not None:
        x_parts.append(batch["extra_embeds"])
    if tokens is not None:
        x_parts.append(embed(params["embed"], tokens))
    x = x_parts[0] if len(x_parts) == 1 else jnp.concatenate(x_parts, axis=1)
    b, s, _ = x.shape

    start = jnp.zeros((), jnp.int32)
    if cache is not None:
        start = cache["length"]
    positions = start + jnp.arange(s)

    # positional encodings
    rope_cs = rope_cs_local = None
    if cfg.pos_embed == "rope":
        rot = int(cfg.head_dim * cfg.rotary_pct) // 2 * 2
        pos_b = jnp.broadcast_to(positions[None], (b, s))
        rope_cs = rope_angles(pos_b, rot, cfg.rope_theta)
        if getattr(cfg, "rope_theta_local", None):
            rope_cs_local = rope_angles(pos_b, rot, cfg.rope_theta_local)
    elif cfg.pos_embed == "mrope":
        rot = int(cfg.head_dim * cfg.rotary_pct) // 2 * 2
        pos3 = batch.get("positions")
        if pos3 is None:
            pos3 = jnp.broadcast_to(positions[None, None], (3, b, s))
        rope_cs = mrope_angles(pos3, rot, cfg.rope_theta)
    elif cfg.pos_embed == "learned":
        pos_emb = jnp.take(params["pos_table"],
                           jnp.clip(positions, 0, cfg.max_seq - 1), axis=0)
        x = x + pos_emb[None]
    elif cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model, x.dtype)[None]

    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)

    # ---- scanned unit ----
    def unit_fn(x, slot_params, slot_caches):
        new_caches = []
        aux_sum = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.scan_unit):
            c = None if slot_caches is None else slot_caches[i]
            x, nc, aux = _apply_block(
                None if kind == "shared_attn" else slot_params[i], cfg, kind,
                x, rope_cs, rope_cs_local, positions, c, shared, backend)
            new_caches.append(nc)
            aux_sum += aux
        return x, (tuple(new_caches) if slot_caches is not None else None), aux_sum

    if cfg.scan_repeats > 0:
        if cfg.scan_unroll:
            # dry-run costing: python loop — forward AND backward fully
            # unrolled in the HLO (scan's transpose is a loop that XLA's
            # cost analysis would count once, hiding (R−1)× of the backward)
            body = lambda x, p: unit_fn(x, p, None)
            if remat and cache is None:
                body = jax.checkpoint(body)
            new_scan_caches = [] if cache is not None else None
            for i in range(cfg.scan_repeats):
                p_i = jax.tree_util.tree_map(lambda a: a[i], params["scan"])
                if cache is None:
                    x, _, a = body(x, p_i)
                else:
                    c_i = jax.tree_util.tree_map(lambda a: a[i], cache["scan"])
                    x, nc, a = unit_fn(x, p_i, c_i)
                    new_scan_caches.append(nc)
                aux_total += a
            if cache is not None:
                new_scan_caches = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *new_scan_caches)
        elif cache is None:
            body = lambda x, p: unit_fn(x, p, None)
            g = max(1, min(cfg.remat_group, cfg.scan_repeats))
            if g > 1 and cfg.scan_repeats % g == 0:
                # two-level remat: checkpoint once per group of g units
                def group_body(x, pg):
                    def inner(carry, p):
                        xx, aux = carry
                        xx, _, a = body(xx, p)
                        return (xx, aux + a), None
                    return jax.lax.scan(inner, x, pg, unroll=cfg.scan_unroll)

                group_body = jax.checkpoint(group_body) if remat else group_body
                grouped = jax.tree_util.tree_map(
                    lambda a: a.reshape((cfg.scan_repeats // g, g)
                                        + a.shape[1:]), params["scan"])

                def outer(carry, pg):
                    carry, _ = group_body(carry, pg)
                    return carry, None

                (x, aux_total), _ = jax.lax.scan(
                    outer, (x, aux_total), grouped, unroll=cfg.scan_unroll)
            else:
                if remat:
                    body = jax.checkpoint(body)

                def scan_body(carry, p):
                    x, aux = carry
                    x, _, a = body(x, p)
                    return (x, aux + a), None

                (x, aux_total), _ = jax.lax.scan(
                    scan_body, (x, aux_total), params["scan"],
                    unroll=cfg.scan_unroll)
        else:
            def scan_body(carry, pc):
                x, aux = carry
                p, c = pc
                x, nc, a = unit_fn(x, p, c)
                return (x, aux + a), nc

            (x, aux_total), new_scan_caches = jax.lax.scan(
                scan_body, (x, aux_total), (params["scan"], cache["scan"]),
                unroll=cfg.scan_unroll)

    # ---- tail ----
    new_tail = []
    for i, kind in enumerate(cfg.tail):
        c = None if cache is None else cache["tail"][i]
        x, nc, aux = _apply_block(params["tail"][i], cfg, kind, x, rope_cs,
                                  rope_cs_local, positions, c, shared, backend)
        new_tail.append(nc)
        aux_total += aux

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"]["table"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head

    new_cache = None
    if cache is not None:
        new_cache = {"scan": new_scan_caches if cfg.scan_repeats else (),
                     "tail": tuple(new_tail),
                     "length": start + s}
    return ForwardOut(logits=logits, cache=new_cache, aux_loss=aux_total)


def lm_loss(params, cfg: ModelConfig, batch, backend: str = "chunked",
            aux_coeff: float = 0.01):
    """Next-token cross-entropy; labels −1 are ignored."""
    out = forward(params, cfg, batch, backend=backend)
    logits = out.logits[:, :-1].astype(jnp.float32)
    labels = batch["labels"][:, 1:]
    valid = labels >= 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - picked, 0.0)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
    return loss + aux_coeff * out.aux_loss
