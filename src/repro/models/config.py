"""Model configuration for all assigned architectures.

One :class:`ModelConfig` describes any of the six architecture families
(dense / MoE / SSM / hybrid / VLM / audio).  The layer stack is expressed as
a repeating ``scan_unit`` (lowered as one ``lax.scan`` over stacked params)
plus an optional non-repeating ``tail`` — this keeps the HLO compact for
62-layer models while supporting heterogeneous patterns (gemma-3's 5 local :
1 global, zamba2's Mamba2 blocks with a *weight-shared* attention block
every 6 layers).

Layer kinds:
  "attn"        full causal self-attention
  "attn_local"  sliding-window self-attention (width = sliding_window)
  "shared_attn" full attention with parameters shared across occurrences
  "mamba2"      Mamba-2 SSD block
  "rwkv6"       RWKV-6 time-mix + channel-mix block
Every attention/ssm kind is followed by its MLP (or MoE) inside the block,
except "rwkv6" which uses its own channel-mix, and "mamba2" which is a
standalone block (Zamba2-style backbones alternate pure Mamba2 blocks).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // n_heads

    # layer stack: scan_unit × scan_repeats, then tail
    scan_unit: Tuple[str, ...] = ("attn",)
    scan_repeats: int = 0             # 0 → n_layers (homogeneous)
    tail: Tuple[str, ...] = ()

    # attention
    pos_embed: str = "rope"           # rope|mrope|learned|sinusoidal
    rope_theta: float = 1e4
    rope_theta_local: Optional[float] = None   # separate θ for attn_local
    rotary_pct: float = 1.0
    sliding_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None
    qk_norm: bool = False

    # mlp
    mlp_gated: bool = True
    mlp_act: str = "silu"             # silu|gelu

    # moe
    n_experts: int = 0
    moe_top_k: int = 2
    moe_dispatch: str = "dense"       # dense|capacity  (perf iteration)
    capacity_factor: float = 1.25

    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_n_groups: int = 1
    # rwkv6
    rwkv_head_dim: int = 64

    # embeddings / misc
    tie_embeddings: bool = True
    max_seq: int = 32768
    norm_eps: float = 1e-5
    dtype: str = "float32"
    # sub-quadratic attention available? (gates long_500k)
    subquadratic: bool = False
    # chunk size for chunked attention / ssm scans
    chunk_size: int = 128
    # unroll the layer scan (dry-run costing: XLA cost analysis counts loop
    # bodies once, so unrolling makes FLOP/byte totals exact)
    scan_unroll: bool = False
    # two-level remat: group G scan units per checkpoint boundary; saved
    # residuals drop from R·act to (R/G)·act (+G transient recompute).
    # 1 = checkpoint every unit (baseline); √R is the memory-optimal choice.
    remat_group: int = 1
    # quantize the KV cache to int8 (per-entry affine, scale from config)
    kv_cache_int8: bool = False
    # mesh axis carrying the (per-agent) batch/token dim — when set, MoE
    # dispatch applies explicit sharding constraints so GSPMD keeps tokens
    # sharded through the group reshapes (otherwise it all-gathers the full
    # token tensor per layer; see EXPERIMENTS.md §Perf iteration 2)
    act_batch_axis: Optional[str] = None

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.scan_repeats == 0:
            n_unit = len(self.scan_unit)
            reps = (self.n_layers - len(self.tail)) // n_unit
            object.__setattr__(self, "scan_repeats", reps)
        total = len(self.scan_unit) * self.scan_repeats + len(self.tail)
        if total != self.n_layers:
            raise ValueError(
                f"{self.name}: scan_unit×{self.scan_repeats} + tail = {total} "
                f"!= n_layers {self.n_layers}")

    # -- derived ----------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        kinds = list(self.scan_unit) * self.scan_repeats + list(self.tail)
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        shared_counted = False
        for kind in kinds:
            if kind in ("attn", "attn_local", "shared_attn"):
                if kind == "shared_attn":
                    if shared_counted:
                        continue
                    shared_counted = True
                a = self.d_model * self.q_dim + 2 * self.d_model * self.kv_dim \
                    + self.q_dim * self.d_model
                if kind == "shared_attn":
                    n += a + 2 * self.d_model  # no MLP after shared block
                    continue
                mlp = (3 if self.mlp_gated else 2) * self.d_model * self.d_ff
                if self.n_experts:
                    mlp = mlp * self.n_experts + self.d_model * self.n_experts
                n += a + mlp + 2 * self.d_model
            elif kind == "mamba2":
                d_in = self.ssm_inner
                conv_dim = d_in + 2 * self.ssm_n_groups * self.ssm_state
                n += self.d_model * (2 * d_in + 2 * self.ssm_n_groups * self.ssm_state
                                     + self.ssm_heads)
                n += conv_dim * self.ssm_conv
                n += d_in * self.d_model + 3 * self.ssm_heads + d_in + self.d_model
            elif kind == "rwkv6":
                d = self.d_model
                n += 4 * d * d + d * self.d_ff * 2 + d * self.d_ff  # time+channel mix
                n += 2 * d
        n += self.d_model  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        kinds = list(self.scan_unit) * self.scan_repeats + list(self.tail)
        n_moe_layers = sum(1 for k in kinds if k in ("attn", "attn_local"))
        expert_p = (3 if self.mlp_gated else 2) * self.d_model * self.d_ff
        inactive = n_moe_layers * (self.n_experts - self.moe_top_k) * expert_p
        return self.param_count() - inactive
