"""The unified experiment facade — ``repro.api.Experiment``.

One object owns what used to be a four-step constructor sprawl
(``Engine(get_scenario(...))`` → channel install → ``SpaceRunner(...)``
→ ``tracing(...)`` bookkeeping):

    from repro.api import Experiment

    exp = Experiment.from_scenario(
        "plane-agg-walker", algorithm=alg, compressor=quant,
        topology="plane")            # optional override of the scenario's
    state = exp.init(x0, n_agents)   # delegate to the algorithm
    result = exp.run(state, data, n_rounds=60, key=key,
                     error_fn=err, trace=True)
    result.ingest("runs/ledger.jsonl")

The facade resolves the scenario (by registry name or instance), applies
a ``topology`` override via ``dataclasses.replace``, builds the engine
(or reuses a caller-supplied one — the sweep idiom where a shared engine
amortizes contact plans and cached ARQ plans across arms), installs the
channel through :meth:`repro.sim.engine.Engine.install_channel` (which
invalidates the fast path's memoized channel state — the historical
direct-mutation footgun), and wires tracing with self-describing ledger
meta (scenario / algorithm / compressor / channel / topology / mode).

The old constructors keep working — :class:`Experiment` is thin
delegation over :class:`repro.core.fedlt_sat.SpaceRunner`, not a
replacement; anything not yet surfaced here can still be done by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Union

from .core.fedlt_sat import RoundLog, SpaceRunner
from .faults import describe_faults
from .sim import Engine, Scenario, get_scenario, make_topology


def describe_compressor(c) -> str:
    """Short ledger label for a compressor (``quant10``, ``topk0.1``,
    ``rand0.2``, class name fallback, ``none``)."""
    if c is None:
        return "none"
    name = type(c).__name__
    if name == "UniformQuantizer":
        return f"quant{c.levels}"
    if name == "TopK":
        return f"topk{c.fraction:g}"
    if name == "RandD":
        return f"rand{c.fraction:g}"
    if name == "Identity":
        return "identity"
    return name


def describe_channel(ch) -> str:
    """Short ledger label for a channel (``lossless``, ``flat-0.1``,
    ``budget``)."""
    if ch is None:
        return "lossless"
    if getattr(ch, "budget", None) is not None:
        return "budget"
    return f"flat-{getattr(ch, 'loss', '?')}"


@dataclasses.dataclass
class ExperimentResult:
    """What one :meth:`Experiment.run` produced: the final algorithm
    state, the per-round logs, and (when tracing was on) the trace
    records plus the ledger id if they were ingested."""
    state: Any
    logs: List[RoundLog]
    records: Optional[List[dict]] = None
    run_id: Optional[str] = None

    @property
    def final(self) -> Optional[RoundLog]:
        return self.logs[-1] if self.logs else None

    def ingest(self, ledger_path: str) -> dict:
        """Fold this run's trace into a ledger; returns the entry."""
        if self.records is None:
            raise ValueError(
                "no trace records to ingest — call run(..., trace=True) "
                "(or pass ledger=... to run, which implies it)")
        from .obs.ledger import ingest as _ingest
        entry, _ = _ingest(self.records, ledger_path)
        self.run_id = entry["run_id"]
        return entry


class Experiment:
    """A configured (scenario × algorithm × compression × channel ×
    topology × mode) federated experiment.  See the module docstring."""

    def __init__(self, scenario: Union[str, Scenario, None], algorithm, *,
                 compressor=None, channel=None,
                 topology: Optional[object] = None,
                 mode: str = "sync", measure: str = "probe",
                 loss_robust: bool = True, buffer_size: int = 8,
                 staleness_alpha: float = 0.5, wire_bits: float = 32.0,
                 seed: int = 0, fast: bool = True,
                 faults: Optional[object] = None,
                 deadline: Optional[float] = None, quorum: float = 0.0,
                 engine: Optional[Engine] = None,
                 meta: Optional[Dict[str, Any]] = None):
        if engine is not None:
            # shared-engine sweeps: the engine's scenario wins; a
            # conflicting topology request would silently not apply
            scenario = engine.scenario
            if (topology is not None
                    and make_topology(topology) != engine.topology):
                raise ValueError(
                    f"engine= carries topology "
                    f"{engine.topology.name!r} but topology="
                    f"{make_topology(topology).name!r} was requested — "
                    f"build the engine from the right scenario instead")
        else:
            if scenario is None:
                raise ValueError("pass a scenario (name or Scenario) or "
                                 "a prebuilt engine=")
            if isinstance(scenario, str):
                scenario = get_scenario(scenario)
            if topology is not None:
                scenario = dataclasses.replace(scenario, topology=topology)
            engine = Engine(scenario, seed=seed, fast=fast)
        self.scenario = scenario
        self.algorithm = algorithm
        self.meta = dict(meta or {})
        self.runner = SpaceRunner(
            engine, compressor=compressor, channel=channel, mode=mode,
            measure=measure, loss_robust=loss_robust,
            buffer_size=buffer_size, staleness_alpha=staleness_alpha,
            wire_bits=wire_bits, faults=faults, deadline=deadline,
            quorum=quorum)

    @classmethod
    def from_scenario(cls, name: Union[str, Scenario], *, algorithm,
                      **kwargs) -> "Experiment":
        """The canonical constructor spelling:
        ``Experiment.from_scenario("mega-1000", algorithm=alg, ...)``."""
        return cls(name, algorithm, **kwargs)

    # -- convenience delegation -------------------------------------------
    @property
    def engine(self) -> Engine:
        return self.runner.engine

    @property
    def topology_name(self) -> str:
        return self.engine.topology.name

    def init(self, x0, n_agents: int):
        """Delegate to the algorithm's state constructor."""
        return self.algorithm.init(x0, n_agents)

    def ledger_meta(self) -> Dict[str, Any]:
        """The self-describing trace/ledger meta this experiment stamps
        on its runs (caller ``meta=`` entries win)."""
        out = dict(scenario=self.scenario.name,
                   algorithm=type(self.algorithm).__name__,
                   compressor=describe_compressor(self.runner.compressor),
                   channel=describe_channel(
                       self.runner.channel
                       if self.runner.channel is not None
                       else getattr(self.engine, "channel", None)),
                   topology=self.topology_name,
                   mode=self.runner.mode,
                   faults=describe_faults(
                       getattr(self.engine, "faults", None)
                       or self.runner.faults))
        if self.runner.deadline is not None:
            out["deadline"] = self.runner.deadline
            out["quorum"] = self.runner.quorum
        out.update(self.meta)
        return out

    def run(self, state, data, n_rounds: int, key, *,
            error_fn: Optional[Callable] = None, log_every: int = 10,
            trace: Union[bool, str] = False,
            ledger: Optional[str] = None,
            checkpoint: Optional[str] = None, checkpoint_every: int = 1,
            resume: bool = False) -> ExperimentResult:
        """Drive the algorithm ``n_rounds`` through the engine.

        ``trace=True`` records an in-memory obs trace (``trace="path"``
        streams it to a file as well); ``ledger="runs/x.jsonl"`` implies
        tracing and ingests the finished trace.  ``checkpoint="dir"``
        saves an atomic per-round checkpoint every ``checkpoint_every``
        sync rounds; ``resume=True`` restarts from the newest intact one
        (crash-consistent: the resumed run's ``e_K`` / ``bytes_up``
        curves are bit-identical to the uninterrupted run).  Returns an
        :class:`ExperimentResult`."""
        from .obs import active as _active
        from .obs import tracing
        ckpt = None
        if checkpoint is not None:
            from .checkpoint.run import RunCheckpoint
            ckpt = RunCheckpoint(checkpoint)
        elif resume:
            raise ValueError("resume=True needs checkpoint=<dir>")
        if not trace and ledger is not None:
            trace = True
        if not trace or _active() is not None:
            # no tracing requested, or the caller already opened a tracer
            # (nested tracing() scopes don't stack) — run under it as-is
            state, logs = self.runner.run(self.algorithm, state, data,
                                          n_rounds, key,
                                          error_fn=error_fn,
                                          log_every=log_every, ckpt=ckpt,
                                          ckpt_every=checkpoint_every,
                                          resume=resume)
            return ExperimentResult(state, logs)
        path = trace if isinstance(trace, str) else None
        with tracing(path, **self.ledger_meta()) as trc:
            state, logs = self.runner.run(self.algorithm, state, data,
                                          n_rounds, key,
                                          error_fn=error_fn,
                                          log_every=log_every, ckpt=ckpt,
                                          ckpt_every=checkpoint_every,
                                          resume=resume)
            records = trc.records()
        result = ExperimentResult(state, logs, records)
        if ledger is not None:
            result.ingest(ledger)
        return result
