"""Round-time-minimizing active-set selection (Kim et al., 2025 style).

Given the constellation state at time t, pick which satellites participate
in the next round:

  * `k_direct` satellites with the soonest GS windows connect directly
    (cost = wait-until-window + uplink transmission time);
  * each direct satellite can additionally relay up to `n_relay` in-plane
    neighbours through ISLs (cost += ISL hop + forwarded transmission) —
    the paper's "space-ification": more participants per round without more
    sat-to-ground links.

Returns the active set S_k, the per-satellite completion times, and the
round duration (max over the active set — the coordinator aggregates when
the last scheduled update lands).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Set, Tuple

import numpy as np

from .links import LinkModel
from .orbits import GroundStation, Walker, in_plane_neighbors, next_window


@dataclasses.dataclass(frozen=True)
class Scheduler:
    walker: Walker
    gs: GroundStation
    link: LinkModel = LinkModel()
    k_direct: int = 4
    n_relay: int = 2           # forwarded neighbours per direct satellite
    compute_time: float = 30.0  # on-board local-training time per round

    def select(self, t0: float, msg_bytes: float,
               rng: Optional[np.random.Generator] = None
               ) -> Tuple[np.ndarray, float]:
        """Returns (active bool (n_sats,), round_duration_seconds)."""
        n = self.walker.n_sats
        # one propagation for all satellites over the lookahead horizon
        ts = t0 + np.arange(0.0, 7200.0, 10.0)
        from .orbits import visible
        vis = visible(self.walker, self.gs, ts)          # (T, S)
        first = np.argmax(vis, axis=0)                    # first True index
        has = vis[first, np.arange(n)]
        waits = np.where(has, first * 10.0, np.inf)
        order = np.argsort(waits)
        direct = [s for s in order[: self.k_direct] if np.isfinite(waits[s])]
        active: Set[int] = set(direct)
        completion = {}
        for s in direct:
            tx = self.link.gs_time(msg_bytes)
            completion[s] = self.compute_time + waits[s] + tx
            # relay neighbours through ISL, forwarded over the same GS link
            nbrs = in_plane_neighbors(self.walker, s)
            for i, nb in enumerate(nbrs[: self.n_relay]):
                if nb in active:
                    continue
                active.add(nb)
                completion[nb] = (self.compute_time + waits[s]
                                  + self.link.isl_time(msg_bytes)
                                  + (i + 2) * self.link.gs_time(msg_bytes))
        mask = np.zeros(n, bool)
        for s in active:
            mask[s] = True
        duration = max(completion.values()) if completion else self.compute_time
        return mask, float(duration)
