"""Round-time-minimizing scheduling policy (Kim et al., 2025 style).

Refactored into a *policy object* plugged into the discrete-event engine
(``repro.sim.engine.Engine``):

  * :meth:`Scheduler.assign` picks the round's participants from the
    precomputed contact plan — ``k_direct`` satellites with the soonest
    usable GS windows become gateways, and each gateway pulls up to
    ``n_relay`` additional satellites over multi-hop ISL routes (nearest
    first, ≤ ``max_hops`` hops) — the paper's "space-ification": more
    participants per round without more sat-to-ground links.
  * :meth:`Scheduler.select` keeps the seed's ``(mask, duration)`` API by
    executing one engine round — completion times come from explicit
    event-level GS-link serialization, which fixes two seed bugs: relays
    are no longer silently capped at 2 (the seed sliced a 2-tuple of
    in-plane neighbours), and no transmission phase is double-counted
    (the seed charged ``isl + (i + 2) · gs_time`` per relay even though
    the ISL transfer overlaps the wait for the window).

Unlike the seed — which re-propagated a 720-step visibility grid on every
``select`` call — windows come from a :class:`~repro.sim.contacts.ContactPlan`
computed once over the whole horizon (``legacy_select`` below preserves the
seed path as the benchmark baseline and regression reference).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .links import LinkModel
from .orbits import GroundStation, Walker


@dataclasses.dataclass
class Assignment:
    """One round's schedule, produced by a policy for the engine."""
    gateways: List[int]                        # direct-uplink sats, by window
    windows: Dict[int, Tuple[float, float, int]]  # gateway → (start, end, gs)
    relays: Dict[int, object]                  # sat → routing.Route


@dataclasses.dataclass(frozen=True)
class Scheduler:
    walker: Walker
    gs: object                   # GroundStation or tuple of GroundStations
    link: LinkModel = LinkModel()
    k_direct: int = 4
    n_relay: int = 2             # forwarded satellites per gateway
    compute_time: object = 30.0  # scalar or (S,) on-board training seconds
    lookahead: float = 7200.0
    dt: float = 10.0
    max_hops: int = 4
    _cache: dict = dataclasses.field(default_factory=dict, compare=False,
                                     repr=False)

    @property
    def stations(self) -> tuple:
        return tuple(self.gs) if isinstance(self.gs, (tuple, list)) else (self.gs,)

    # -- policy interface (called by the engine) ---------------------------
    def assign(self, t0: float, msg_bytes: float, engine) -> Assignment:
        sc = engine.scenario
        n = sc.walker.n_sats
        compute = np.broadcast_to(
            np.asarray(sc.compute_time, dtype=np.float64), (n,))
        t_ready = t0 + compute
        start, end, station = engine.usable_windows_all(t_ready)
        cand = np.where(np.isfinite(start) & (start <= t0 + self.lookahead))[0]
        order = cand[np.argsort(start[cand], kind="stable")]
        gateways = [int(s) for s in order[: self.k_direct]]
        if not gateways:
            return Assignment([], {}, {})
        windows = {g: (float(start[g]), float(end[g]), int(station[g]))
                   for g in gateways}
        routes = engine.router.routes_to_gateways(gateways, msg_bytes,
                                                  max_hops=self.max_hops)
        gw_set = set(gateways)
        load = {g: 0 for g in gateways}
        relays: Dict[int, object] = {}
        for sat in sorted(routes,
                          key=lambda s: (routes[s].time, routes[s].hops, s)):
            r = routes[sat]
            if sat in gw_set or r.hops == 0:
                continue
            if load[r.gateway] < self.n_relay:
                relays[sat] = r
                load[r.gateway] += 1
        return Assignment(gateways, windows, relays)

    # -- seed-compatible API ----------------------------------------------
    def _engine(self):
        eng = self._cache.get("engine")
        if eng is None:
            from ..sim.engine import Engine, Scenario  # lazy: breaks cycle
            sc = Scenario(name="scheduler", walker=self.walker,
                          stations=self.stations, link=self.link,
                          compute_time=self.compute_time,
                          k_direct=self.k_direct, n_relay=self.n_relay,
                          max_hops=self.max_hops, lookahead=self.lookahead,
                          dt=self.dt)
            eng = Engine(sc, policy=self)
            self._cache["engine"] = eng
        return eng

    def select(self, t0: float, msg_bytes: float,
               rng: Optional[np.random.Generator] = None
               ) -> Tuple[np.ndarray, float]:
        """Returns (active bool (n_sats,), round_duration_seconds)."""
        res = self._engine().run_round(t0, msg_bytes)
        return res.mask, float(res.duration)


def legacy_select(walker: Walker, gs: GroundStation, link: LinkModel,
                  t0: float, msg_bytes: float, k_direct: int = 4,
                  n_relay: int = 2, compute_time: float = 30.0
                  ) -> Tuple[np.ndarray, float]:
    """The seed scheduler, verbatim: re-propagates the whole visibility grid
    on every call and relays only the two in-plane neighbours, with the
    known accounting bugs (relay cap at 2, double-counted uplink term).
    Kept as the benchmark baseline and as the parity/regression reference.
    """
    from .orbits import in_plane_neighbors, visible

    n = walker.n_sats
    ts = t0 + np.arange(0.0, 7200.0, 10.0)
    vis = visible(walker, gs, ts)                    # (T, S)
    first = np.argmax(vis, axis=0)
    has = vis[first, np.arange(n)]
    waits = np.where(has, first * 10.0, np.inf)
    order = np.argsort(waits)
    direct = [s for s in order[:k_direct] if np.isfinite(waits[s])]
    active = set(direct)
    completion = {}
    for s in direct:
        tx = link.gs_time(msg_bytes)
        completion[s] = compute_time + waits[s] + tx
        nbrs = in_plane_neighbors(walker, s)
        for i, nb in enumerate(nbrs[:n_relay]):
            if nb in active:
                continue
            active.add(nb)
            completion[nb] = (compute_time + waits[s]
                              + link.isl_time(msg_bytes)
                              + (i + 2) * link.gs_time(msg_bytes))
    mask = np.zeros(n, bool)
    for s in active:
        mask[s] = True
    duration = max(completion.values()) if completion else compute_time
    return mask, float(duration)
