"""Link budget / transmission-time model for GS and inter-satellite links.

Transmission times are pure functions of on-wire bytes.  Two ways to get
the byte count:

* :func:`message_bytes` — *nominal* estimate from a compressor's
  ``wire_bits_per_scalar`` (payload only, no headers);
* a measured :class:`repro.wire.WireMessage` — pass its exact ``nbytes``
  into :meth:`LinkModel.gs_time` / :meth:`LinkModel.isl_time`.

The simulator (``repro.sim.engine``) and :class:`repro.core.fedlt_sat.
SpaceRunner` use measured bytes whenever the compressor has a wire codec.

These rates are *fixed* — an elevation-dependent profile (slant-range
link budget, SNR → BER → erasure probability) lives in
:mod:`repro.channel.budget`; a :class:`repro.channel.ChannelModel` with
``budget=None`` falls back to this fixed-rate model exactly.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Transmission times for model updates (bytes / rate + latency)."""
    gs_rate: float = 100e6 / 8        # 100 Mbit/s sat↔GS → bytes/s
    isl_rate: float = 1e9 / 8         # 1 Gbit/s optical ISL
    gs_latency: float = 0.02          # s (LEO slant range)
    isl_latency: float = 0.005

    def gs_time(self, nbytes: float) -> float:
        return self.gs_latency + nbytes / self.gs_rate

    def isl_time(self, nbytes: float, hops: int = 1) -> float:
        return hops * (self.isl_latency + nbytes / self.isl_rate)


def message_bytes(n_params: int, bits_per_scalar: float) -> float:
    """Nominal on-wire size of one model update under a given compressor
    (payload-only estimate; exact sizes come from ``repro.wire``)."""
    return n_params * bits_per_scalar / 8.0
