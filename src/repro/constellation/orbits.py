"""Walker constellation propagation + ground-station visibility windows.

FLySTacK-fidelity orbital model (Kim et al., 2024): circular LEO orbits,
spherical Earth, Walker-delta phasing.  Positions are propagated
analytically; a satellite can talk to the ground station when its elevation
above the GS horizon exceeds a mask angle.  NumPy only — this is host-side
scheduling substrate, not device compute.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

R_EARTH = 6371.0e3           # m
MU = 3.986004418e14          # m³/s²
OMEGA_EARTH = 7.2921159e-5   # rad/s


@dataclasses.dataclass(frozen=True)
class Walker:
    """Walker-delta constellation i:t/p/f."""
    n_sats: int = 100
    n_planes: int = 10
    altitude: float = 550e3
    inclination: float = 97.6        # degrees (sun-synchronous — polar GS)
    phasing: int = 1                 # relative spacing factor f

    @property
    def sats_per_plane(self) -> int:
        return self.n_sats // self.n_planes

    @property
    def radius(self) -> float:
        return R_EARTH + self.altitude

    @property
    def period(self) -> float:
        return 2 * np.pi * np.sqrt(self.radius ** 3 / MU)

    def positions(self, t: np.ndarray) -> np.ndarray:
        """ECI positions (…, n_sats, 3) at times t (seconds, array)."""
        t = np.asarray(t, dtype=np.float64)
        inc = np.radians(self.inclination)
        n = 2 * np.pi / self.period                       # mean motion
        spp = self.sats_per_plane
        plane = np.arange(self.n_sats) // spp             # (S,)
        slot = np.arange(self.n_sats) % spp
        raan = 2 * np.pi * plane / self.n_planes
        phase = (2 * np.pi * slot / spp
                 + 2 * np.pi * self.phasing * plane / self.n_sats)
        u = phase + n * t[..., None]                      # argument of latitude
        # orbital plane → ECI
        x_orb = self.radius * np.cos(u)
        y_orb = self.radius * np.sin(u)
        cos_r, sin_r = np.cos(raan), np.sin(raan)
        cos_i, sin_i = np.cos(inc), np.sin(inc)
        x = x_orb * cos_r - y_orb * cos_i * sin_r
        y = x_orb * sin_r + y_orb * cos_i * cos_r
        z = y_orb * sin_i
        return np.stack([x, y, z], axis=-1)


@dataclasses.dataclass(frozen=True)
class GroundStation:
    lat: float = 67.86     # Kiruna, a common polar LEO downlink site
    lon: float = 20.22
    mask_angle: float = 10.0  # degrees above horizon

    def position(self, t: np.ndarray) -> np.ndarray:
        """ECI position of the GS at times t (Earth rotation included)."""
        t = np.asarray(t, dtype=np.float64)
        lat, lon0 = np.radians(self.lat), np.radians(self.lon)
        lon = lon0 + OMEGA_EARTH * t
        return R_EARTH * np.stack(
            [np.cos(lat) * np.cos(lon), np.cos(lat) * np.sin(lon),
             np.full_like(lon, np.sin(lat))], axis=-1)


def elevation(sat_pos: np.ndarray, gs_pos: np.ndarray) -> np.ndarray:
    """Elevation (degrees) of satellites above the GS local horizon.

    sat_pos: (..., S, 3); gs_pos: (..., 3)."""
    rel = sat_pos - gs_pos[..., None, :]
    zen = gs_pos / np.linalg.norm(gs_pos, axis=-1, keepdims=True)
    proj = np.einsum("...sk,...k->...s", rel, zen)
    dist = np.linalg.norm(rel, axis=-1)
    return np.degrees(np.arcsin(np.clip(proj / dist, -1, 1)))


def visible(walker: Walker, gs: GroundStation, t: np.ndarray) -> np.ndarray:
    """Bool (…, n_sats): GS link available at times t."""
    return elevation(walker.positions(t), gs.position(t)) > gs.mask_angle


def visibility_grid(walker: Walker, gs: GroundStation, ts: np.ndarray,
                    chunk: int = 64) -> np.ndarray:
    """Fused, chunked :func:`visible` for large (T, S) grids.

    Same spherical geometry as ``visible`` but with the elevation
    threshold evaluated in place — no (T, S, 3) position/relative-vector
    temporaries are ever materialized, peak memory is O(chunk · S), and
    the per-sample trig collapses to four multiply-adds via the angle sum
    ``u = phase + n·t`` (trig is evaluated once per satellite phase and
    once per time sample, not per (satellite, time) pair).  This is the
    contact-plan builder's hot loop: at mega-constellation scale the
    naive path moves gigabytes of float64 through memory per horizon
    doubling.

    The visibility decision ``el > mask`` is taken as the equivalent
    monotone comparison ``proj·|proj| > sin(mask)·|sin(mask)|·dist²``
    (sign-preserving squares avoid the sqrt/arcsin of the reference
    path).  Agreement with ``visible`` is exact unless a grid sample's
    elevation sits within ~1 ulp of the mask angle — regression-tested
    against the reference on every built-in scenario geometry.
    """
    ts = np.asarray(ts, dtype=np.float64)
    inc = np.radians(walker.inclination)
    n = 2.0 * np.pi / walker.period
    spp = walker.sats_per_plane
    plane = np.arange(walker.n_sats) // spp
    slot = np.arange(walker.n_sats) % spp
    raan = 2.0 * np.pi * plane / walker.n_planes
    phase = (2.0 * np.pi * slot / spp
             + 2.0 * np.pi * walker.phasing * plane / walker.n_sats)
    cos_p, sin_p = np.cos(phase), np.sin(phase)
    # pos(t, s) = R · (cos_u · A + sin_u · B); the basis vectors depend
    # only on the orbital PLANE (raan, inclination), so the station-frame
    # dot products contract at (T, n_planes) and gather out to (T, S)
    # ragged constellations can spill into plane index n_planes — cover
    # every plane value `sat // spp` actually produces
    raan_p = (2.0 * np.pi * np.arange(int(plane.max()) + 1)
              / walker.n_planes)
    cos_r, sin_r = np.cos(raan_p), np.sin(raan_p)
    cos_i, sin_i = np.cos(inc), np.sin(inc)
    A = np.stack([cos_r, sin_r, np.zeros_like(raan_p)], axis=-1)     # (P, 3)
    B = np.stack([-cos_i * sin_r, cos_i * cos_r,
                  np.full_like(raan_p, sin_i)], axis=-1)             # (P, 3)
    R = walker.radius
    s_mask = np.sin(np.radians(gs.mask_angle))
    thr = s_mask * abs(s_mask)
    out = np.empty((len(ts), walker.n_sats), dtype=bool)
    # fold the per-sat phase into the basis: pos·zen = R·(cos(nt)·P1 +
    # sin(nt)·P2) with P1 = cosφ·(A·zen) + sinφ·(B·zen) and
    # P2 = cosφ·(B·zen) − sinφ·(A·zen) — the angle sum absorbed into two
    # (T, S) fused multiply-adds instead of materializing cos_u/sin_u
    for i in range(0, len(ts), chunk):
        t = ts[i:i + chunk]
        g = gs.position(t)                                           # (T, 3)
        gn = np.linalg.norm(g, axis=-1)                              # (T,)
        zen = g / gn[:, None]
        az = np.einsum("tk,pk->tp", zen, A)[:, plane]                # (T, S)
        bz = np.einsum("tk,pk->tp", zen, B)[:, plane]
        p1 = cos_p[None, :] * az + sin_p[None, :] * bz
        p2 = cos_p[None, :] * bz - sin_p[None, :] * az
        cu, su = np.cos(n * t), np.sin(n * t)
        # pos·zen; then pos·g = |g|·(pos·zen), so both the horizon
        # projection and the slant range fold into this one matrix
        pz = R * (cu[:, None] * p1 + su[:, None] * p2)
        proj = pz - gn[:, None]                                      # rel·zen
        dist2 = R * R + gn[:, None] ** 2 - 2.0 * gn[:, None] * pz
        out[i:i + chunk] = proj * np.abs(proj) > thr * dist2
    return out


def next_window(walker: Walker, gs: GroundStation, t0: float, sat: int,
                horizon: float = 7200.0, dt: float = 10.0) -> Optional[float]:
    """Seconds from t0 until satellite `sat` next sees the GS (None if not
    within `horizon`)."""
    ts = t0 + np.arange(0.0, horizon, dt)
    vis = visible(walker, gs, ts)[:, sat]
    idx = np.argmax(vis)
    if not vis[idx]:
        return None
    return float(ts[idx] - t0)


def in_plane_neighbors(walker: Walker, sat: int) -> tuple:
    """The two ring neighbours of `sat` within its orbital plane (ISL)."""
    spp = walker.sats_per_plane
    plane, slot = sat // spp, sat % spp
    return (plane * spp + (slot - 1) % spp,
            plane * spp + (slot + 1) % spp)


def isl_neighbors(walker: Walker, sat: int, cross_plane: bool = True) -> tuple:
    """+grid ISL topology: the in-plane ring pair plus (optionally) the
    same-slot satellites in the two adjacent planes, wrapping across the
    seam (last plane ↔ plane 0).  Duplicates collapse for degenerate
    constellations (≤ 2 planes or ≤ 2 slots per plane)."""
    spp = walker.sats_per_plane
    plane, slot = sat // spp, sat % spp
    nbrs = list(in_plane_neighbors(walker, sat))
    if cross_plane and walker.n_planes > 1:
        nbrs.append(((plane - 1) % walker.n_planes) * spp + slot)
        nbrs.append(((plane + 1) % walker.n_planes) * spp + slot)
    out = []
    for nb in nbrs:
        if nb != sat and nb not in out:
            out.append(nb)
    return tuple(out)
