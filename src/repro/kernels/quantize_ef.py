"""Fused quantize + error-feedback Pallas TPU kernel.

The per-round uplink (paper Alg. 2 lines 15–16) touches every parameter
three times when written naively: read (z+c), write the wire ints, write the
new cache.  Fusing them into one VMEM pass makes the op strictly
memory-bound at its floor: read msg + read cache → write wire + write cache
in a single tile sweep (2 reads + 2 writes, no intermediate HBM traffic).

TPU adaptation: tiles are (BLOCK_M, 128)-shaped to match the VPU lane width;
the quantization is pure element-wise VPU work (no MXU), so the kernel's
roofline is the HBM bandwidth — exactly what the fusion minimizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 256
BLOCK_N = 128


def _kernel(msg_ref, cache_ref, wire_ref, newc_ref, *, levels, vmin, vmax):
    msg = msg_ref[...].astype(jnp.float32)
    cache = cache_ref[...].astype(jnp.float32)
    delta = (vmax - vmin) / levels
    corrected = msg + cache
    idx = jnp.floor((jnp.clip(corrected, vmin, vmax) - vmin) / delta + 0.5)
    idx = jnp.clip(idx, 0.0, float(levels))
    decoded = idx * delta + vmin
    wire_ref[...] = idx.astype(wire_ref.dtype)
    newc_ref[...] = (corrected - decoded).astype(newc_ref.dtype)


@functools.partial(jax.jit, static_argnames=("levels", "vmin", "vmax",
                                             "interpret"))
def quantize_ef(msg, cache, *, levels: int = 255, vmin: float = -0.25,
                vmax: float = 0.25, interpret: bool = True):
    """msg/cache: same-shape float arrays → (wire uint8/16, new_cache).

    Arbitrary shapes are flattened and padded to the (BLOCK_M, BLOCK_N) tile
    grid; interpret=True runs the kernel body in Python on CPU (validation),
    interpret=False targets the TPU backend.
    """
    shape, dtype = msg.shape, msg.dtype
    n = msg.size
    flat_m = msg.reshape(-1)
    flat_c = cache.reshape(-1)
    tile = BLOCK_M * BLOCK_N
    pad = (-n) % tile
    if pad:
        flat_m = jnp.pad(flat_m, (0, pad))
        flat_c = jnp.pad(flat_c, (0, pad))
    rows = flat_m.size // BLOCK_N
    m2 = flat_m.reshape(rows, BLOCK_N)
    c2 = flat_c.reshape(rows, BLOCK_N)
    wire_dtype = jnp.uint8 if levels <= 255 else jnp.uint16

    grid = (rows // BLOCK_M,)
    out = pl.pallas_call(
        functools.partial(_kernel, levels=levels, vmin=vmin, vmax=vmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(m2.shape, wire_dtype),
            jax.ShapeDtypeStruct(m2.shape, dtype),
        ],
        interpret=interpret,
    )(m2, c2)
    wire, newc = out
    wire = wire.reshape(-1)[:n].reshape(shape)
    newc = newc.reshape(-1)[:n].reshape(shape)
    return wire, newc
