"""Fused compress → error-feedback → bit-pack Pallas pipeline kernel.

The per-round uplink of the paper's Algorithm 2 is a three-stage chain:

    corrected = msg + cache            (error feedback, §2.2)
    wire      = C(corrected)           (compression, §2.4)
    words     = pack(wire)             (on-wire serialization, repro.wire)
    new_cache = corrected − wire

Run separately (``quantize_ef`` then ``pack_bits``, or the jnp
``quantize_encode`` chain in ``core.deploy``) every parameter makes two
round trips through HBM: the intermediate integer tensor is written by the
quantizer and re-read by the packer.  This kernel chains all three stages
inside one VMEM tile sweep: read msg + cache → write packed words + new
cache.  The intermediate indices never leave VMEM, so the op hits its
memory floor (2 reads + ~1.03 writes per element for 8-bit wire vs
2 reads + 2 writes unfused — and one kernel dispatch instead of two).

Tiling matches :mod:`repro.kernels.pack_bits` exactly — values in
``(GROUP·R, LANES)`` tiles, words in ``(bits·R, LANES)`` tiles with the
transposed bit-plane layout (bit j of value i at bit position i of word j)
— so fused output words are bit-identical to
``pack_bits(quantize_encode(msg + cache))`` and both ends of the wire
interoperate freely with the unfused path.

Modes
-----
``quant_pipeline``
    b-bit uniform quantization (paper Definition 2, clip=True): the wire
    is ``ceil(log2(levels+1))``-bit level indices.
``sign_pipeline``
    1-bit scaled sign (ScaledSign, sign(0) := +1): the wire is one bit
    per coordinate plus one f32 scale = mean |corrected|.  The scale is a
    global reduction, computed as a read-only jnp pass before the kernel
    (no extra HBM writes); masking, EF update, and packing still fuse.

Top-k / rand-d sparsification is NOT fused: selecting the k-th largest
magnitude of ``msg + cache`` is a cross-tile reduction over the corrected
signal, and compacting survivors into the sparse index+value wire format
is a gather — neither fits a single elementwise tile sweep.  Those codecs
keep the :class:`repro.wire.codecs.SparseCodec` path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pack_bits import GROUP, LANES, R, _TILE_VALS, _check_bits

__all__ = ["quant_pipeline", "sign_pipeline", "pipeline_tile_values"]

#: values per kernel tile (same tile as pack_bits: (32·R, 128) = 32768)
pipeline_tile_values = _TILE_VALS


def _pack_planes(v, words_ref, bits):
    """Write uint32 values ``v`` (GROUP·R, LANES) as transposed bit planes."""
    for j in range(bits):
        w = jnp.zeros((R, LANES), jnp.uint32)
        for i in range(GROUP):
            w = w | (((v[i * R:(i + 1) * R, :] >> j) & 1) << i)
        words_ref[j * R:(j + 1) * R, :] = w


def _quant_kernel(msg_ref, cache_ref, words_ref, newc_ref, *,
                  bits, levels, vmin, vmax):
    msg = msg_ref[...].astype(jnp.float32)
    cache = cache_ref[...].astype(jnp.float32)
    delta = (vmax - vmin) / levels
    corrected = msg + cache
    idx = jnp.floor((jnp.clip(corrected, vmin, vmax) - vmin) / delta + 0.5)
    idx = jnp.clip(idx, 0.0, float(levels))
    decoded = idx * delta + vmin
    newc_ref[...] = (corrected - decoded).astype(newc_ref.dtype)
    _pack_planes(idx.astype(jnp.uint32), words_ref, bits)


def _sign_kernel(msg_ref, cache_ref, scale_ref, words_ref, newc_ref):
    msg = msg_ref[...].astype(jnp.float32)
    cache = cache_ref[...].astype(jnp.float32)
    scale = scale_ref[0, 0]
    corrected = msg + cache
    bit = (corrected >= 0.0)
    decoded = jnp.where(bit, scale, -scale)
    newc_ref[...] = (corrected - decoded).astype(newc_ref.dtype)
    _pack_planes(bit.astype(jnp.uint32), words_ref, 1)


def _tile(x, fill=0.0):
    """Flatten + pad to whole (GROUP·R, LANES) tiles; returns
    (2-D array, n, tiles).

    ``fill`` is the pad value for the tail.  The quant path pads ``msg``
    with ``vmin`` (and ``cache`` with 0) so padded slots quantize to index
    0 and the packed words match the unfused ``pack_bits`` zero-padding
    bit-for-bit; the sign path pads with −1 for the same reason (bit 0).
    """
    n = x.size
    flat = x.reshape(-1)
    tiles = max(1, -(-n // _TILE_VALS))
    pad = tiles * _TILE_VALS - n
    if pad:
        flat = jnp.pad(flat, (0, pad), constant_values=fill)
    return flat.reshape(tiles * GROUP * R, LANES), n, tiles


@functools.partial(jax.jit, static_argnames=("levels", "vmin", "vmax",
                                             "interpret"))
def quant_pipeline(msg, cache, *, levels: int = 255, vmin: float = -1.0,
                   vmax: float = 1.0, interpret: bool = True):
    """Fused quantize + EF + pack: (msg, cache) → (wire words, new cache).

    ``words`` is a flat uint32 array of ``tiles·bits·R·LANES`` packed
    words, bit-identical to
    ``pack_bits(quantize_encode(msg + cache, levels, vmin, vmax), bits)``
    with ``bits = wire_index_bits(levels)``; ``new_cache`` has the shape
    and dtype of ``msg`` and equals ``(msg + cache) − decode(words)``.
    interpret=True runs the kernel body in Python on CPU (validation),
    interpret=False targets the TPU backend.
    """
    from ..core.compression import wire_index_bits  # lazy: core imports us
    bits = wire_index_bits(levels)
    _check_bits(bits)
    shape, dtype = msg.shape, msg.dtype
    m2, n, tiles = _tile(msg, fill=vmin)   # pad quantizes to index 0
    c2, _, _ = _tile(cache)
    words, newc = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits, levels=levels,
                          vmin=vmin, vmax=vmax),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((GROUP * R, LANES), lambda i: (i, 0)),
            pl.BlockSpec((GROUP * R, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bits * R, LANES), lambda i: (i, 0)),
            pl.BlockSpec((GROUP * R, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles * bits * R, LANES), jnp.uint32),
            jax.ShapeDtypeStruct(m2.shape, dtype),
        ],
        interpret=interpret,
    )(m2, c2)
    return words.reshape(-1), newc.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_pipeline(msg, cache, *, interpret: bool = True):
    """Fused scaled-sign + EF + 1-bit pack: → (words, scale, new cache).

    ``scale = mean |msg + cache|`` (one read-only reduction pass);
    ``words`` packs ``corrected >= 0`` bits in the repro.wire layout and
    ``new_cache = corrected − (±scale)``.
    """
    shape, dtype = msg.shape, msg.dtype
    m2, n, tiles = _tile(msg, fill=-1.0)   # pad signs negative → bit 0
    c2, _, _ = _tile(cache)
    corrected_flat = (msg.reshape(-1).astype(jnp.float32)
                      + cache.reshape(-1).astype(jnp.float32))
    scale = jnp.mean(jnp.abs(corrected_flat)).astype(jnp.float32)
    words, newc = pl.pallas_call(
        _sign_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((GROUP * R, LANES), lambda i: (i, 0)),
            pl.BlockSpec((GROUP * R, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1 * R, LANES), lambda i: (i, 0)),
            pl.BlockSpec((GROUP * R, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles * 1 * R, LANES), jnp.uint32),
            jax.ShapeDtypeStruct(m2.shape, dtype),
        ],
        interpret=interpret,
    )(m2, c2, scale.reshape(1, 1))
    return words.reshape(-1), scale, newc.reshape(-1)[:n].reshape(shape)
