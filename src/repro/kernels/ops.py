"""jit'd public wrappers around the Pallas kernels.

``use_pallas`` selects the kernel path; interpret mode is chosen
automatically (CPU → interpret=True for validation, TPU → compiled kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .quantize_ef import quantize_ef as _quant_ef


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_ef(msg, cache, *, levels=255, vmin=-0.25, vmax=0.25,
                use_pallas: bool = True):
    if not use_pallas:
        return ref.quantize_ef_ref(msg, cache, levels=levels, vmin=vmin,
                                   vmax=vmax)
    return _quant_ef(msg, cache, levels=levels, vmin=vmin, vmax=vmax,
                     interpret=_interpret())


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              use_pallas: bool = True, block_q: int = 128, block_k: int = 128):
    """(B,S,H,D) attention; kv heads must be pre-expanded to match q."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap)
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=_interpret())
