"""jit'd public wrappers around the Pallas kernels.

``use_pallas`` selects the kernel path; interpret mode is chosen
automatically (CPU → interpret=True for validation, TPU → compiled kernel).

Every wrapper is wrapped in a dispatch hook (:func:`_traced`): with an
active :mod:`repro.obs` tracer each call runs under a
``jax.profiler.TraceAnnotation`` (so the dispatch shows up named inside
``jax.profiler.trace`` captures) and records a host-side ``kernel`` span
(dispatch time — device compute is async and belongs to the profiler).
Disabled, the hook is one module attribute read and a ``None`` check.
"""
from __future__ import annotations

import functools
import time

import jax

from ..obs.trace import active as _obs_active
from . import ref
from .compress_pipeline import quant_pipeline as _quant_pipeline
from .compress_pipeline import sign_pipeline as _sign_pipeline
from .erasure_mask import erasure_mask as _erasure_mask
from .flash_attention import flash_attention as _flash
from .pack_bits import pack_bits as _pack_bits
from .pack_bits import unpack_bits as _unpack_bits
from .quantize_ef import quantize_ef as _quant_ef


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _traced(fn):
    """Kernel-dispatch trace hook (zero-cost with no active tracer)."""
    name = fn.__name__

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        trc = _obs_active()
        if trc is None:
            return fn(*args, **kwargs)
        with jax.profiler.TraceAnnotation(f"repro.kernels.{name}"):
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            dur = time.perf_counter() - t0
        # same record Tracer.span would emit, but timed manually so the
        # duration can also feed the phase profiler (kernel.<name>)
        trc.raw({"kind": "kernel", "name": name,
                 "t_host": t0 - trc._t0_host, "dur_host": dur})
        trc.prof.add("kernel." + name, dur)
        if trc.prof.sync_device:
            # honest host/device split: the dispatch above only measures
            # trace + launch time under JAX's async dispatch; this extra
            # (opt-in, prof_sync meta) wait attributes device compute
            t1 = time.perf_counter()
            jax.block_until_ready(out)
            dur_sync = time.perf_counter() - t1
            trc.raw({"kind": "kernel", "name": name + "[device]",
                     "t_host": t1 - trc._t0_host, "dur_host": dur_sync})
            trc.prof.add("kernel." + name + "[device]", dur_sync)
        trc.metrics.counter("kernel_dispatches").add(1.0, name=name)
        return out

    return wrapper


@_traced
def pack_bits(x, bits: int, *, use_pallas: bool = True):
    """Pack b-bit values into uint32 wire words (repro.wire layout)."""
    if not use_pallas:
        return ref.pack_bits_ref(x, bits)
    return _pack_bits(x, bits, interpret=_interpret())


@_traced
def unpack_bits(words, bits: int, n: int, *, use_pallas: bool = True):
    """Inverse of :func:`pack_bits`: first ``n`` values, flat uint32."""
    if not use_pallas:
        return ref.unpack_bits_ref(words, bits, n)
    return _unpack_bits(words, bits, n, interpret=_interpret())


@_traced
def quantize_ef(msg, cache, *, levels=255, vmin=-0.25, vmax=0.25,
                use_pallas: bool = True):
    if not use_pallas:
        return ref.quantize_ef_ref(msg, cache, levels=levels, vmin=vmin,
                                   vmax=vmax)
    return _quant_ef(msg, cache, levels=levels, vmin=vmin, vmax=vmax,
                     interpret=_interpret())


@_traced
def quant_pipeline(msg, cache, *, levels=255, vmin=-1.0, vmax=1.0,
                   use_pallas: bool = True):
    """Fused quantize→EF→pack sweep: (msg, cache) → (wire words, new cache).

    One kernel dispatch replacing the separate quantize_ef → pack_bits
    chain; output words are bit-identical to the unfused path.
    """
    if not use_pallas:
        return ref.quant_pipeline_ref(msg, cache, levels=levels, vmin=vmin,
                                      vmax=vmax)
    return _quant_pipeline(msg, cache, levels=levels, vmin=vmin, vmax=vmax,
                           interpret=_interpret())


@_traced
def sign_pipeline(msg, cache, *, use_pallas: bool = True):
    """Fused scaled-sign→EF→1-bit-pack sweep → (words, scale, new cache)."""
    if not use_pallas:
        return ref.sign_pipeline_ref(msg, cache)
    return _sign_pipeline(msg, cache, interpret=_interpret())


@_traced
def erasure_mask(words, *, p: float, seed: int = 0, segment_words: int = 32,
                 use_pallas: bool = True):
    """Counter-based segment erasure over packed wire words → (masked,
    keep mask).  Lossy transport of the fused uplink, on-device."""
    if not use_pallas:
        return ref.erasure_mask_ref(words, p=p, seed=seed,
                                    segment_words=segment_words)
    return _erasure_mask(words, p=p, seed=seed, segment_words=segment_words,
                         interpret=_interpret())


@_traced
def attention(q, k, v, *, causal=True, window=None, softcap=None,
              use_pallas: bool = True, block_q: int = 128, block_k: int = 128):
    """(B,S,H,D) attention; kv heads must be pre-expanded to match q."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap)
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, interpret=_interpret())
