"""Blocked flash-attention Pallas TPU kernel (causal + sliding window).

TPU adaptation of the prefill hot spot: Q/K/V tiles live in VMEM, the score
block (BLOCK_Q × BLOCK_K) stays on-chip, and the running max/denominator
(online softmax) are carried across the KV-block loop, so HBM traffic is
O(S·D) instead of O(S²).  Block shapes are MXU-aligned (multiples of 128 on
the contraction/lane dims).  Sliding-window layers visit only the in-window
band of KV blocks via the grid's kv range, the same banding the pure-JAX
chunked path uses.

Grid: (batch·heads, n_q_blocks, n_kv_blocks), kv innermost so the
accumulators in VMEM scratch carry across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale, block_q, block_k, n_kv, causal, window, softcap,
                 seq_q, seq_k):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # skip blocks fully outside the causal/window band
    first_q = qi * block_q
    last_q = first_q + block_q - 1
    first_k = kj * block_k
    run = True
    if causal:
        run = jnp.asarray(first_k <= last_q)
    if window is not None:
        run = jnp.logical_and(run, jnp.asarray(first_k + block_k
                                               > first_q - window))

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        ok &= (q_pos < seq_q) & (k_pos < seq_k)   # padding
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q,k,v: (B, S, H, D) with equal H (GQA expansion by the caller).
    Returns (B, S, H, D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)

    pad_q, pad_k = (-sq) % block_q, (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = q.shape[1], k.shape[1]

    # (B·H, S, D) layout
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq_p, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, sk_p, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, sk_p, d)

    n_q, n_kv = sq_p // block_q, sk_p // block_k
    grid = (b * h, n_q, n_kv)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv=n_kv, causal=causal, window=window, softcap=softcap,
        seq_q=sq, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, kj: (bh, kj, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, kj: (bh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # running max
            pltpu.VMEM((block_q,), jnp.float32),     # running denom
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(b, h, sq_p, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
