"""Pallas counter-based-RNG erasure-mask kernel over packed wire words.

Device-side sibling of the host ARQ model (:mod:`repro.channel`): given
the uint32 word stream produced by the fused compress→EF→pack pipeline
(:mod:`repro.kernels.compress_pipeline` / :mod:`repro.kernels.pack_bits`),
decide — per *segment* of ``segment_words`` consecutive words — whether
the channel erased it, and zero the erased words in one VMEM sweep.  The
whole lossy transport of a cohort's stacked uplink therefore stays
on-device: compress → EF → pack → erase, no host round-trip.

Counter-based RNG
-----------------
The fate of word ``i`` depends only on ``(seed, i // segment_words)``:
a murmur3-style 32-bit finalizer hashes the segment counter, and the
segment is erased when ``hash < ⌊p·2³²⌋``.  No state, no key threading —
the same (seed, counter) always gives the same decision, on any backend,
for any grid/tile decomposition, which is exactly the property the
host-side :func:`repro.channel.outage.counter_uniform` draws rely on.
The kernel is pure element-wise VPU work: an iota over flat word indices,
integer mixing, one compare, one select.

Outputs are the masked words plus the per-word keep mask (uint32 0/1) so
callers can reduce per-satellite survival (`all segments kept?`) without
re-deriving the hash.  ``ref.erasure_mask_ref`` is the pure-jnp oracle;
the kernel must match it word-for-word.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 256
LANES = 128

_GOLD = 0x9E3779B9          # 2³²/φ — decorrelates consecutive counters


def drop_threshold(p: float) -> int:
    """uint32 threshold: segment erased iff hash < threshold."""
    return min(max(int(round(float(p) * 4294967296.0)), 0), 4294967295)


def _mix32(x):
    """murmur3 fmix32 finalizer (uint32 avalanche)."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def segment_hash(idx, seed: int):
    """Counter hash of flat word indices ``idx`` (uint32) under ``seed``."""
    h = idx * jnp.uint32(_GOLD) + jnp.uint32(seed & 0xFFFFFFFF)
    return _mix32(_mix32(h) ^ jnp.uint32((seed >> 32) & 0xFFFFFFFF))


def _erasure_kernel(words_ref, out_ref, keep_ref, *, seed, thresh,
                    segment_words):
    i = pl.program_id(0)
    row = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_M, LANES), 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_M, LANES), 1)
    flat = (jnp.uint32(i) * jnp.uint32(BLOCK_M) + row) * jnp.uint32(LANES) \
        + lane
    seg = flat // jnp.uint32(segment_words)
    keep = (segment_hash(seg, seed) >= jnp.uint32(thresh)).astype(jnp.uint32)
    out_ref[...] = words_ref[...] * keep
    keep_ref[...] = keep


@functools.partial(jax.jit, static_argnames=("p", "seed", "segment_words",
                                             "interpret"))
def erasure_mask(words, *, p: float, seed: int = 0, segment_words: int = 32,
                 interpret: bool = True):
    """Erase segments of a packed word stream → (masked words, keep mask).

    ``words``: any-shape uint32 array, flattened in C order; segment ``s``
    covers flat words ``[s·segment_words, (s+1)·segment_words)``.  Each
    segment is independently erased with probability ``p`` (decision =
    counter hash of the segment index under ``seed``); erased words are
    zeroed.  Returns ``(masked, keep)`` with ``keep`` uint32 0/1 per word,
    both in the input's shape.
    """
    if segment_words < 1:
        raise ValueError(f"segment_words must be >= 1, got {segment_words}")
    shape = words.shape
    n = words.size
    flat = words.reshape(-1).astype(jnp.uint32)
    tile = BLOCK_M * LANES
    pad = (-n) % tile
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.size // LANES
    w2 = flat.reshape(rows, LANES)
    grid = (rows // BLOCK_M,)
    masked, keep = pl.pallas_call(
        functools.partial(_erasure_kernel, seed=seed,
                          thresh=drop_threshold(p),
                          segment_words=segment_words),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_M, LANES), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCK_M, LANES), lambda i: (i, 0)),
                   pl.BlockSpec((BLOCK_M, LANES), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(w2.shape, jnp.uint32),
                   jax.ShapeDtypeStruct(w2.shape, jnp.uint32)],
        interpret=interpret,
    )(w2)
    return (masked.reshape(-1)[:n].reshape(shape),
            keep.reshape(-1)[:n].reshape(shape))
