"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def quantize_ef_ref(msg, cache, *, levels: int, vmin: float, vmax: float):
    """Fused uplink step (paper Alg. 2 lines 15–16):

        corrected = msg + cache
        wire      = level_index(clip(corrected))      (uint8/uint16)
        new_cache = corrected − decode(wire)

    Returns (wire, new_cache).
    """
    delta = (vmax - vmin) / levels
    # accumulate in f32 (matches the kernel: VMEM compute is f32)
    corrected = msg.astype(jnp.float32) + cache.astype(jnp.float32)
    idx = jnp.floor((jnp.clip(corrected, vmin, vmax) - vmin) / delta + 0.5)
    idx = jnp.clip(idx, 0, levels)
    dtype = jnp.uint8 if levels <= 255 else jnp.uint16
    decoded = idx * delta + vmin
    new_cache = (corrected - decoded).astype(msg.dtype)
    return idx.astype(dtype), new_cache


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        softcap=None):
    """q,k,v: (B, S, H, D) (same kv heads — GQA expansion done by caller).
    Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
