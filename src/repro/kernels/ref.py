"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def quantize_ef_ref(msg, cache, *, levels: int, vmin: float, vmax: float):
    """Fused uplink step (paper Alg. 2 lines 15–16):

        corrected = msg + cache
        wire      = level_index(clip(corrected))      (uint8/uint16)
        new_cache = corrected − decode(wire)

    Returns (wire, new_cache).
    """
    delta = (vmax - vmin) / levels
    # accumulate in f32 (matches the kernel: VMEM compute is f32)
    corrected = msg.astype(jnp.float32) + cache.astype(jnp.float32)
    idx = jnp.floor((jnp.clip(corrected, vmin, vmax) - vmin) / delta + 0.5)
    idx = jnp.clip(idx, 0, levels)
    dtype = jnp.uint8 if levels <= 255 else jnp.uint16
    decoded = idx * delta + vmin
    new_cache = (corrected - decoded).astype(msg.dtype)
    return idx.astype(dtype), new_cache


def pack_bits_ref(x, bits: int):
    """Pure-jnp oracle for :func:`repro.kernels.pack_bits.pack_bits`.

    Implements the identical transposed bit-plane layout (value ``i`` of
    group ``(r, lane)`` at row ``i·R + r``; its word ``j`` at row
    ``j·R + r``) so the kernel must match it word-for-word.
    """
    from .pack_bits import GROUP, LANES, R, _TILE_VALS, _check_bits
    _check_bits(bits)
    n = x.size
    flat = x.reshape(-1).astype(jnp.uint32)
    tiles = max(1, -(-n // _TILE_VALS))
    flat = jnp.pad(flat, (0, tiles * _TILE_VALS - n))
    v = flat.reshape(tiles, GROUP, R, LANES)
    j = jnp.arange(bits, dtype=jnp.uint32)[None, None, :, None, None]
    i = jnp.arange(GROUP, dtype=jnp.uint32)[None, :, None, None, None]
    planes = ((v[:, :, None] >> j) & 1) << i        # (T, 32, b, R, LANES)
    return jnp.sum(planes, axis=1, dtype=jnp.uint32).reshape(-1)


def unpack_bits_ref(words, bits: int, n: int):
    """Pure-jnp oracle for :func:`repro.kernels.pack_bits.unpack_bits`."""
    from .pack_bits import GROUP, LANES, R, _check_bits
    _check_bits(bits)
    tiles = words.size // (bits * R * LANES)
    w = words.reshape(tiles, bits, R, LANES)
    i = jnp.arange(GROUP, dtype=jnp.uint32)[None, :, None, None, None]
    j = jnp.arange(bits, dtype=jnp.uint32)[None, None, :, None, None]
    planes = ((w[:, None] >> i) & 1) << j           # (T, 32, b, R, LANES)
    vals = jnp.sum(planes, axis=2, dtype=jnp.uint32)
    return vals.reshape(-1)[:n]


def quant_pipeline_ref(msg, cache, *, levels: int, vmin: float, vmax: float):
    """Pure-jnp oracle for
    :func:`repro.kernels.compress_pipeline.quant_pipeline`.

    Composes the two existing oracles — quantize+EF then transposed
    bit-plane packing — so the fused kernel must reproduce the separate
    path word-for-word: ``words == pack_bits_ref(wire)`` and
    ``new_cache == (msg + cache) − decode(wire)``.
    """
    wire, new_cache = quantize_ef_ref(msg, cache, levels=levels,
                                      vmin=vmin, vmax=vmax)
    bits = max(1, int(np.ceil(np.log2(levels + 1))))
    words = pack_bits_ref(wire.astype(jnp.uint32), bits)
    return words, new_cache


def sign_pipeline_ref(msg, cache):
    """Pure-jnp oracle for
    :func:`repro.kernels.compress_pipeline.sign_pipeline`."""
    corrected = msg.astype(jnp.float32) + cache.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(corrected.reshape(-1))).astype(jnp.float32)
    bit = (corrected >= 0.0)
    decoded = jnp.where(bit, scale, -scale)
    new_cache = (corrected - decoded).astype(msg.dtype)
    words = pack_bits_ref(bit.astype(jnp.uint32), 1)
    return words, scale, new_cache


def erasure_mask_ref(words, *, p: float, seed: int = 0,
                     segment_words: int = 32):
    """Pure-jnp oracle for :func:`repro.kernels.erasure_mask.erasure_mask`.

    Same counter hash (murmur3 fmix32 of the segment index under the
    seed), same ``⌊p·2³²⌋`` threshold — the kernel must reproduce the
    masked words and the keep mask bit-for-bit.
    """
    from .erasure_mask import drop_threshold, segment_hash
    shape = words.shape
    flat = words.reshape(-1).astype(jnp.uint32)
    idx = jnp.arange(flat.size, dtype=jnp.uint32)
    seg = idx // jnp.uint32(segment_words)
    keep = (segment_hash(seg, seed)
            >= jnp.uint32(drop_threshold(p))).astype(jnp.uint32)
    return (flat * keep).reshape(shape), keep.reshape(shape)


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        softcap=None):
    """q,k,v: (B, S, H, D) (same kv heads — GQA expansion done by caller).
    Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
