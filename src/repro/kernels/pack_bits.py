"""Pallas bit-pack / bit-unpack kernels for the on-wire codec layer.

The wire subsystem (``repro.wire``, paper §2.4) serializes compressor
outputs into the exact bytes that cross a sat↔GS link: b-bit quantization
indices, 1-bit signs, and sparse coordinate indices are all packed into
dense ``uint32`` words.  These kernels are the hot path of that layer —
companions to :mod:`repro.kernels.quantize_ef` — and run the packing as a
single VMEM sweep (read values, write words; strictly memory-bound).

Wire word layout (transposed bit-plane packing)
-----------------------------------------------
Values are processed in groups of 32; a group of 32 b-bit values packs
into exactly b ``uint32`` words, with **bit j of value i stored at bit
position i of word j**.  This layout

  * supports ANY bit width 1 ≤ b ≤ 32 with no value ever straddling a
    word boundary,
  * is pure element-wise shift/mask VPU work (no gathers, no cross-lane
    shuffles): the reduction over the 32 group members runs along the
    sublane axis of a (32·R, 128) tile.

Within one grid step the kernel sees a ``(32·R, LANES)`` value tile and
writes a ``(b·R, LANES)`` word tile; value ``i`` of group ``(r, lane)``
lives at row ``i·R + r`` and its word ``j`` at row ``j·R + r``.  The flat
padded value index is therefore

    v_idx = ((tile·32 + i)·R + r)·LANES + lane

Both ends of the wire use the same layout, so the interleaving is
invisible to callers: ``unpack_bits(pack_bits(x, b), b, n) == x`` exactly
whenever ``x < 2**b``.  Tile padding is memory-layout only — the logical
on-wire size is ``ceil(n/32)·b`` words, which is what
:class:`repro.wire.message.WireMessage` accounts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128     # VPU lane width
GROUP = 32      # values per packed group (= bits per uint32 word)
R = 8           # groups stacked per sublane block (tile rows = 32·R)

_TILE_VALS = GROUP * R * LANES


def _check_bits(bits: int) -> None:
    if not (1 <= int(bits) <= 32):
        raise ValueError(f"bit width must be in [1, 32], got {bits}")


def logical_words(n: int, bits: int) -> int:
    """On-wire ``uint32`` word count for ``n`` b-bit values (no tile pad)."""
    _check_bits(bits)
    return -(-n // GROUP) * bits


def _pack_kernel(vals_ref, words_ref, *, bits):
    v = vals_ref[...]                                  # (32·R, LANES) uint32
    for j in range(bits):
        w = jnp.zeros((R, LANES), jnp.uint32)
        for i in range(GROUP):
            w = w | (((v[i * R:(i + 1) * R, :] >> j) & 1) << i)
        words_ref[j * R:(j + 1) * R, :] = w


def _unpack_kernel(words_ref, vals_ref, *, bits):
    w = words_ref[...]                                 # (b·R, LANES) uint32
    for i in range(GROUP):
        v = jnp.zeros((R, LANES), jnp.uint32)
        for j in range(bits):
            v = v | (((w[j * R:(j + 1) * R, :] >> i) & 1) << j)
        vals_ref[i * R:(i + 1) * R, :] = v


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def pack_bits(x, bits: int, *, interpret: bool = True):
    """Pack ``x`` (any shape, values < 2**bits) into uint32 wire words.

    Returns a flat uint32 array of ``tiles·bits·R·LANES`` words — tile-
    padded; the first ``logical_words(x.size, bits)`` carry information
    under the documented layout.  interpret=True runs the kernel body in
    Python on CPU (validation); interpret=False targets the TPU backend.
    """
    _check_bits(bits)
    n = x.size
    flat = x.reshape(-1).astype(jnp.uint32)
    tiles = max(1, -(-n // _TILE_VALS))
    pad = tiles * _TILE_VALS - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    v2 = flat.reshape(tiles * GROUP * R, LANES)
    return pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits),
        grid=(tiles,),
        in_specs=[pl.BlockSpec((GROUP * R, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bits * R, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles * bits * R, LANES), jnp.uint32),
        interpret=interpret,
    )(v2).reshape(-1)


@functools.partial(jax.jit, static_argnames=("bits", "n", "interpret"))
def unpack_bits(words, bits: int, n: int, *, interpret: bool = True):
    """Inverse of :func:`pack_bits`: first ``n`` values as flat uint32."""
    _check_bits(bits)
    tiles = words.size // (bits * R * LANES)
    if tiles * bits * R * LANES != words.size:
        raise ValueError(f"word buffer size {words.size} is not a whole "
                         f"number of ({bits}·{R}·{LANES})-word tiles")
    if n > tiles * _TILE_VALS:
        raise ValueError(f"cannot unpack {n} values from {tiles} tile(s)")
    w2 = words.reshape(tiles * bits * R, LANES)
    vals = pl.pallas_call(
        functools.partial(_unpack_kernel, bits=bits),
        grid=(tiles,),
        in_specs=[pl.BlockSpec((bits * R, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((GROUP * R, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles * GROUP * R, LANES),
                                       jnp.uint32),
        interpret=interpret,
    )(w2)
    return vals.reshape(-1)[:n]
