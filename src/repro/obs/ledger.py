"""Run ledger: fold obs traces into an append-only cross-run record.

A *ledger* is a JSONL file (default ``runs/ledger.jsonl``; ``.gz`` ok)
with one record per ingested run — the durable, comparable residue of an
experiment that a single-run trace file is not:

    {"kind": "run", "ledger_schema": 1, "run_id": "…12 hex…",
     "git_sha": "…", "scenario": …, "algorithm": …, "compressor": …,
     "channel": …, "mode": …, "meta": {…header extras…},
     "final": {"e_K": …, "bytes_up": …, "rounds": …, …},
     "series": {"e_K": {"steps": […], "values": […]}, …}}

``run_id`` is a content hash (sha1 over the canonical JSON of meta +
final + series), so ingest is idempotent — re-ingesting the same trace
into the same ledger appends nothing — and deterministic: the same run
always gets the same id on any machine, which keeps the rewritten
``benchmarks/table_lossy_ef.py`` byte-reproducible from ledger data.

The descriptive fields (scenario/algorithm/compressor/channel/mode) are
read from the trace header's meta — pass them at ``obs.tracing(...,
scenario="mega-1000", algorithm="FedLT", ...)`` time, or override at
ingest with keyword args / ``repro.obs ingest --meta k=v``.

Consumers: ``repro.obs report`` (cross-run tables + the bytes-to-ground
vs e_K frontier, :mod:`repro.obs.report`) and ``repro.obs convgate``
(the CI convergence gate).
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
from typing import List, Optional, Sequence, Tuple, Union

from .summary import summarize_dict
from .trace import _open, load

LEDGER_SCHEMA = 1
DEFAULT_LEDGER = os.path.join("runs", "ledger.jsonl")

# header-meta keys promoted to top-level ledger fields
_PROMOTED = ("scenario", "algorithm", "compressor", "channel", "mode",
             "topology", "faults")


def git_sha() -> str:
    """The current commit (``REPRO_GIT_SHA`` env override for CI /
    detached checkouts; ``unknown`` outside a git repo)."""
    sha = os.environ.get("REPRO_GIT_SHA")
    if sha:
        return sha
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def run_id(entry: dict) -> str:
    """Deterministic 12-hex content hash over meta + final + series."""
    core = {k: entry.get(k) for k in
            _PROMOTED + ("meta", "final", "series")}
    blob = json.dumps(core, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def entry_from_records(records: Sequence[dict], *,
                       sha: Optional[str] = None, **meta_overrides) -> dict:
    """Build one ledger entry from a trace's record list."""
    s = summarize_dict(records)
    meta = dict(s["meta"])
    meta.update({k: v for k, v in meta_overrides.items() if v is not None})
    entry = {"kind": "run", "ledger_schema": LEDGER_SCHEMA,
             "trace_schema": s["schema"]}
    for key in _PROMOTED:
        entry[key] = meta.pop(key, None)
    if entry["mode"] is None:
        entry["mode"] = s["final"].get("mode")
    entry["meta"] = meta
    entry["final"] = {k: v for k, v in s["final"].items() if k != "mode"}
    entry["series"] = s["series"]
    entry["run_id"] = run_id(entry)
    entry["git_sha"] = sha if sha is not None else git_sha()
    return entry


def load_ledger(path: str) -> List[dict]:
    """Read a ledger file into its run-entry list (missing file → [])."""
    if not os.path.exists(path):
        return []
    out = []
    with _open(path, "rt") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return [e for e in out if e.get("kind") == "run"]


def append_entry(entry: dict, path: str) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with _open(path, "at") as f:
        f.write(json.dumps(entry, sort_keys=True, allow_nan=False) + "\n")


def ingest(trace: Union[str, Sequence[dict]],
           ledger_path: str = DEFAULT_LEDGER, *,
           sha: Optional[str] = None,
           **meta_overrides) -> Tuple[dict, bool]:
    """Fold one trace (path or record list) into the ledger.

    Returns ``(entry, appended)`` — ``appended=False`` when a run with
    the identical content hash is already present (idempotent
    re-ingest)."""
    records = load(trace) if isinstance(trace, str) else trace
    entry = entry_from_records(records, sha=sha, **meta_overrides)
    existing = {e["run_id"] for e in load_ledger(ledger_path)}
    if entry["run_id"] in existing:
        return entry, False
    append_entry(entry, ledger_path)
    return entry, True
