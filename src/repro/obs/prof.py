"""Phase-attribution profiler: deterministic per-phase wall-time rollups.

The perf story before this module was binary — a ±20% gate over
point-in-time ``BENCH_*.json`` snapshots could say *that* something got
slower, never *which stage*.  This module rides the existing
:class:`repro.obs.trace.Tracer` to answer the second question:

* **accumulation** (:class:`PhaseAcc`) — every Tracer owns one.  Both
  engines (the heapq oracle and the vectorized fast path) bracket their
  real stages with ``prof.begin(name)`` / ``prof.end()`` pairs:
  contact-plan extension (``plan_extend``), sync scheduling
  (``assign``), per-engine caches (``state_build``), the event loop
  (``event_loop``) and its hot interior — window-fit searches
  (``window_fit``), channel/ARQ commits (``tx_commit``), batched async
  routing (``dispatch`` / ``window_query``, fast path) and per-dispatch
  route choice (``route``, oracle) — plus kernel dispatches
  (``kernel.<name>`` leaves via :mod:`repro.kernels.ops`, with an
  optional ``kernel.<name>[device]`` block-until-ready split when
  ``sync_device`` is set, so host dispatch and device compute are
  separated honestly).  Nesting is tracked with an explicit stack, so
  each occurrence lands on its full *path* (``event_loop/window_fit``);
  the per-call cost is two ``perf_counter`` reads and a dict update,
  which keeps the whole layer inside the <5% ``sim.trace_overhead``
  budget at mega-1000;
* **emission** — :meth:`PhaseAcc.flush` runs once per round / async run
  (from the ``Engine.run_round`` / ``run_async`` wrappers): one
  ``phase`` record per path (count + summed seconds) and one
  ``phase_total`` record carrying the measured round wall time, plus a
  per-path ``phase:<path>`` histogram of per-round totals (p50/p99 via
  :meth:`repro.obs.metrics.Histogram.percentile`).  Host timings are
  nondeterministic, so neither kind is a trace-diff kind — fast and
  oracle traces still diff clean;
* **rollup** (:func:`collect` / :func:`render_profile`) — per-phase
  count / total / self (total minus direct children) / %wall /
  p50 / p99, with the *unattributed residual* (wall minus top-level
  engine phases) reported explicitly — the ≥90%-attribution gate CI
  enforces with ``repro.obs prof --min-attribution 0.9``.  ``kernel.*``
  top-level paths are excluded from the attributed sum: on federated
  traces kernel dispatches can run *between* engine rounds, and the
  attribution claim is about round-wall coverage by engine stages;
* **flame** (:func:`folded`) — Brendan-Gregg folded-stacks text
  (``path;leaf self_µs`` per line) that speedscope / inferno /
  flamegraph.pl all read; ``repro.obs chrome`` renders the same records
  as a synthetic-timeline icicle track;
* **perfdiff** (:func:`perfdiff` / :func:`render_perfdiff`) — aligns two
  profiles by path, normalizes per round, and names the top regressed
  phases with deltas.  ``repro.bench.compare`` calls this when a gate
  trips and matching traces exist, so a failed ±20% gate prints *which
  phase* moved;
* **bench history** (:func:`ingest_bench` / :func:`render_history`) —
  folds successive ``BENCH_*.json`` emissions into an append-only
  ``runs/bench_history.jsonl`` (content-hashed entries, idempotent like
  the run ledger) and renders per-metric trajectories with
  regression-onset localization (first entry that degrades beyond
  tolerance against the best value seen before it).

CLI::

    python -m repro.obs prof TRACE.jsonl [--flame F] [--min-attribution Q]
    python -m repro.obs perfdiff A.jsonl B.jsonl [--top N] [--tol T]
    python -m repro.obs bench-history [BENCH_*.json ...] [--history H]
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import PHASE_BOUNDS, Histogram

DEFAULT_HISTORY = os.path.join("runs", "bench_history.jsonl")

# record kinds emitted by PhaseAcc.flush (host timing — NOT diff kinds)
PHASE_KINDS = ("phase", "phase_total")


class PhaseAcc:
    """Per-tracer phase accumulator (stack-based, reset every flush).

    Hot-path contract: ``begin``/``end`` cost two ``perf_counter`` reads
    plus one dict update — no allocation beyond a short tuple — and the
    engines only call them with an active tracer (the disabled path
    stays one module attribute read per round).  The stack is cleared on
    :meth:`flush`, so an exception that escapes mid-round cannot poison
    the next round's nesting.
    """

    __slots__ = ("_stack", "_acc", "sync_device")

    def __init__(self, sync_device: bool = False):
        self._stack: List[tuple] = []     # (path_tuple, t0) frames
        self._acc: Dict[tuple, list] = {}  # path -> [count, total_s]
        self.sync_device = bool(sync_device)

    def begin(self, name: str) -> None:
        st = self._stack
        path = (st[-1][0] + (name,)) if st else (name,)
        st.append((path, time.perf_counter()))

    def end(self) -> None:
        t1 = time.perf_counter()
        path, t0 = self._stack.pop()
        e = self._acc.get(path)
        if e is None:
            self._acc[path] = [1, t1 - t0]
        else:
            e[0] += 1
            e[1] += t1 - t0

    def add(self, name: str, dur: float) -> None:
        """Record one externally-timed occurrence (kernel dispatches)."""
        st = self._stack
        path = (st[-1][0] + (name,)) if st else (name,)
        e = self._acc.get(path)
        if e is None:
            self._acc[path] = [1, dur]
        else:
            e[0] += 1
            e[1] += dur

    def add_many(self, path: Tuple[str, ...], count: int,
                 total: float) -> None:
        """Fold an externally-accumulated (count, total) into an explicit
        path.  The fast engine's hot interior (window fits, channel
        commits — thousands of occurrences per mega round) accumulates
        inline with two ``perf_counter`` reads and two float adds per
        occurrence, then folds here once per round: ~4x cheaper per
        occurrence than a begin/end pair, which is what keeps the phase
        layer inside the 1.05x ``sim.trace_overhead`` gate."""
        if count:
            e = self._acc.get(path)
            if e is None:
                self._acc[path] = [count, total]
            else:
                e[0] += count
                e[1] += total

    def flush(self, trc, *, engine: str, mode: str, wall: float,
              round: Optional[int] = None, run: Optional[int] = None
              ) -> None:
        """Emit the accumulated phases as trace records and reset.

        One ``phase`` record per path plus one ``phase_total`` with the
        measured wall; per-path per-round totals feed ``phase:<path>``
        histograms for the rollup's p50/p99 columns."""
        acc = self._acc
        key = "round" if round is not None else "run"
        idx = round if round is not None else run
        mtr = trc.metrics
        for path in sorted(acc):
            cnt, tot = acc[path]
            p = "/".join(path)
            trc.raw({"kind": "phase", "engine": engine, "mode": mode,
                     key: idx, "path": p, "count": cnt, "total": tot})
            mtr.histogram("phase:" + p, bounds=PHASE_BOUNDS,
                          lo=0.0).observe(tot)
        trc.raw({"kind": "phase_total", "engine": engine, "mode": mode,
                 key: idx, "wall": wall})
        acc.clear()
        self._stack.clear()


# ---------------------------------------------------------------------------
# rollup
# ---------------------------------------------------------------------------

def collect(records: Sequence[dict]) -> dict:
    """Aggregate a trace's phase records into one profile.

    Returns ``{"phases": {path: {count, total, units}}, "wall": s,
    "units": n, "hists": {path: snapshot}, "engines": [...],
    "modes": [...]}`` — ``units`` counts rounds + async runs."""
    phases: Dict[str, dict] = {}
    wall = 0.0
    units = 0
    engines: set = set()
    modes: set = set()
    hists: Dict[str, dict] = {}
    for r in records:
        kind = r.get("kind")
        if kind == "phase":
            e = phases.setdefault(r["path"],
                                  {"count": 0, "total": 0.0, "units": 0})
            e["count"] += r["count"]
            e["total"] += r["total"]
            e["units"] += 1
        elif kind == "phase_total":
            wall += r["wall"]
            units += 1
            engines.add(r.get("engine"))
            modes.add(r.get("mode"))
        elif kind == "metrics":
            for name, h in r.get("histograms", {}).items():
                if name.startswith("phase:"):
                    hists[name[len("phase:"):]] = h
    return {"phases": phases, "wall": wall, "units": units, "hists": hists,
            "engines": sorted(e for e in engines if e),
            "modes": sorted(m for m in modes if m)}


def _children(phases: Dict[str, dict], path: str) -> List[str]:
    pre = path + "/"
    return [p for p in phases if p.startswith(pre)
            and "/" not in p[len(pre):]]


def self_times(phases: Dict[str, dict]) -> Dict[str, float]:
    """Per-path self time: total minus the sum of direct children."""
    return {p: e["total"] - sum(phases[c]["total"]
                                for c in _children(phases, p))
            for p, e in phases.items()}


def attribution(profile: dict) -> Tuple[float, float]:
    """(attributed_seconds, fraction-of-wall) over top-level engine
    phases.  ``kernel.*`` roots are excluded — on federated traces they
    can run between rounds, and the claim is round-wall coverage."""
    att = sum(e["total"] for p, e in profile["phases"].items()
              if "/" not in p and not p.startswith("kernel."))
    wall = profile["wall"]
    return att, (att / wall if wall > 0 else 0.0)


def _pctl(hist_dict: Optional[dict], q: float) -> Optional[float]:
    if not hist_dict or not hist_dict.get("count"):
        return None
    return Histogram.from_dict(hist_dict).percentile(q)


def render_profile(profile: dict, title: str = "") -> str:
    """Human table: per-phase count/total/self/%wall/p50/p99 plus the
    explicit unattributed residual."""
    phases = profile["phases"]
    wall = profile["wall"]
    selfs = self_times(phases)
    lines = []
    if title:
        lines.append(title)
    lines.append(f"{'phase':40s} {'count':>8s} {'total_s':>10s} "
                 f"{'self_s':>10s} {'%wall':>6s} {'p50_ms':>8s} "
                 f"{'p99_ms':>8s}")
    for path in sorted(phases):    # lexicographic = children after parent
        e = phases[path]
        depth = path.count("/")
        name = "  " * depth + path.split("/")[-1]
        pct = 100.0 * e["total"] / wall if wall > 0 else 0.0
        h = profile["hists"].get(path)
        p50, p99 = _pctl(h, 50), _pctl(h, 99)
        lines.append(
            f"{name:40s} {e['count']:8d} {e['total']:10.4f} "
            f"{selfs[path]:10.4f} {pct:5.1f}% "
            f"{(p50 or 0.0) * 1e3:8.3f} {(p99 or 0.0) * 1e3:8.3f}")
    att, frac = attribution(profile)
    residual = wall - att
    pct = 100.0 * residual / wall if wall > 0 else 0.0
    lines.append(f"{'(unattributed residual)':40s} {'':8s} "
                 f"{residual:10.4f} {'':10s} {pct:5.1f}%")
    units = profile["units"]
    lines.append(
        f"wall {wall:.4f}s over {units} unit(s) "
        f"[engine={'+'.join(profile['engines']) or '?'}, "
        f"mode={'+'.join(profile['modes']) or '?'}]; "
        f"attributed {100.0 * frac:.1f}%")
    return "\n".join(lines)


def folded(profile: dict) -> str:
    """Brendan-Gregg folded stacks (``a;b;c self_µs`` lines) — feed to
    speedscope, inferno, or flamegraph.pl."""
    phases = profile["phases"]
    selfs = self_times(phases)
    out = []
    for path in sorted(phases):
        us = int(round(max(selfs[path], 0.0) * 1e6))
        if us > 0:
            out.append(path.replace("/", ";") + f" {us}")
    att, _ = attribution(profile)
    res_us = int(round(max(profile["wall"] - att, 0.0) * 1e6))
    if res_us > 0:
        out.append(f"(unattributed) {res_us}")
    return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# perfdiff
# ---------------------------------------------------------------------------

def perfdiff(records_a: Sequence[dict], records_b: Sequence[dict],
             tol: float = 0.2, top: int = 8) -> dict:
    """Diff two phase profiles (A = reference, B = fresh).

    Totals are normalized per unit (round / async run) so profiles with
    different round counts compare fairly.  Returns ``{"rows": [...],
    "offenders": [...], ...}``.  Offenders are ranked by *self*-time
    growth beyond ``tol`` (worst absolute self delta first): a slowdown
    inside a nested phase inflates every enclosing parent's total too,
    and ranking by totals would name ``event_loop`` when the regression
    lives in ``event_loop/tx_commit``."""
    pa, pb = collect(records_a), collect(records_b)
    sa, sb = self_times(pa["phases"]), self_times(pb["phases"])
    ua = max(pa["units"], 1)
    ub = max(pb["units"], 1)
    rows = []
    for path in sorted(set(pa["phases"]) | set(pb["phases"])):
        ta = pa["phases"].get(path, {}).get("total", 0.0) / ua
        tb = pb["phases"].get(path, {}).get("total", 0.0) / ub
        fa = sa.get(path, 0.0) / ua
        fb = sb.get(path, 0.0) / ub
        ratio = tb / ta if ta > 0 else (float("inf") if tb > 0 else 1.0)
        sratio = fb / fa if fa > 0 else (float("inf") if fb > 0 else 1.0)
        rows.append({"path": path, "a": ta, "b": tb, "delta": tb - ta,
                     "ratio": ratio, "self_a": fa, "self_b": fb,
                     "self_delta": fb - fa, "self_ratio": sratio})
    rows.sort(key=lambda r: -abs(r["delta"]))
    offenders = sorted(
        (r for r in rows
         if r["self_delta"] > 0 and r["self_ratio"] > 1.0 + tol),
        key=lambda r: -r["self_delta"])[:top]
    return {"rows": rows, "offenders": offenders,
            "wall_a": pa["wall"] / ua, "wall_b": pb["wall"] / ub,
            "units_a": pa["units"], "units_b": pb["units"]}


def render_perfdiff(d: dict, top: int = 8) -> str:
    lines = [f"per-unit wall: A {d['wall_a']:.4f}s ({d['units_a']} units) "
             f"vs B {d['wall_b']:.4f}s ({d['units_b']} units)",
             f"{'phase':40s} {'A_s/unit':>10s} {'B_s/unit':>10s} "
             f"{'delta_s':>10s} {'ratio':>7s}"]
    for r in d["rows"][:top]:
        ratio = (f"{r['ratio']:7.2f}" if r["ratio"] != float("inf")
                 else "    new")
        lines.append(f"{r['path']:40s} {r['a']:10.4f} {r['b']:10.4f} "
                     f"{r['delta']:+10.4f} {ratio}")
    if d["offenders"]:
        lines.append("top regressed phases (by self time): " + ", ".join(
            f"{o['path']} (+{o['self_delta'] * 1e3:.2f}ms/unit, "
            + ("new" if o["self_ratio"] == float("inf")
               else f"{o['self_ratio']:.2f}x") + ")"
            for o in d["offenders"]))
    else:
        lines.append("no phase regressed beyond tolerance")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# bench history
# ---------------------------------------------------------------------------

def bench_id(benchmarks: dict) -> str:
    """Deterministic 12-hex content hash over the benchmark metrics —
    the same idiom as the run ledger's ``run_id``, so re-ingesting an
    identical emission appends nothing."""
    blob = json.dumps(benchmarks, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def load_history(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return [e for e in out if e.get("kind") == "bench"]


def ingest_bench(path: str, history_path: str = DEFAULT_HISTORY, *,
                 sha: Optional[str] = None) -> Tuple[dict, bool]:
    """Fold one ``BENCH_<group>.json`` into the append-only history.

    Returns ``(entry, appended)`` — idempotent on the content hash."""
    from .ledger import git_sha          # lazy: keeps prof import-light
    with open(path) as f:
        doc = json.load(f)
    group = os.path.basename(path)
    if group.startswith("BENCH_") and group.endswith(".json"):
        group = group[len("BENCH_"):-len(".json")]
    entry = {"kind": "bench", "group": group,
             "tiny": bool(doc.get("tiny", False)),
             "bench_id": bench_id(doc.get("benchmarks", {})),
             "git_sha": sha if sha is not None else git_sha(),
             "benchmarks": doc.get("benchmarks", {})}
    existing = {(e["group"], e["bench_id"]) for e in
                load_history(history_path)}
    if (entry["group"], entry["bench_id"]) in existing:
        return entry, False
    d = os.path.dirname(history_path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(history_path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True, allow_nan=False) + "\n")
    return entry, True


def _onset(values: List[float], hib: bool, tol: float) -> Optional[int]:
    """First index whose value degrades beyond ``tol`` against the best
    value seen before it (direction-aware); None when clean."""
    best = None
    for i, v in enumerate(values):
        if best is not None:
            if hib and v < best * (1.0 - tol):
                return i
            if not hib and v > best * (1.0 + tol):
                return i
        if best is None or (hib and v > best) or (not hib and v < best):
            best = v
    return None


def render_history(entries: Sequence[dict], tol: float = 0.2) -> str:
    """Per-metric trajectories across ingested emissions, localizing the
    regression-onset entry (index + git sha) for any gated metric that
    degraded beyond ``tol``."""
    if not entries:
        return "(empty bench history)"
    series: Dict[Tuple[str, str, str], dict] = {}
    for i, e in enumerate(entries):
        for bench, metrics in e.get("benchmarks", {}).items():
            for m, md in metrics.items():
                s = series.setdefault(
                    (e["group"], bench, m),
                    {"values": [], "idx": [], "shas": [], "meta": md})
                s["values"].append(md["value"])
                s["idx"].append(i)
                s["shas"].append(e.get("git_sha", "?"))
                s["meta"] = md          # latest flags win
    lines = [f"bench history: {len(entries)} emission(s)"]
    n_reg = 0
    for (group, bench, m) in sorted(series):
        s = series[(group, bench, m)]
        md = s["meta"]
        gated = md.get("gate", False)
        traj = " -> ".join(f"{v:.4g}" for v in s["values"][-8:])
        tag = " [gate]" if gated else ""
        line = f"  {bench}.{m}{tag}: {traj}"
        onset = _onset(s["values"], md.get("higher_is_better", True), tol)
        if onset is not None and gated:
            n_reg += 1
            prev_best = (max if md.get("higher_is_better", True)
                         else min)(s["values"][:onset])
            line += (f"\n    REGRESSION ONSET at emission "
                     f"#{s['idx'][onset]} (git {s['shas'][onset]}): "
                     f"{s['values'][onset]:.4g} vs best {prev_best:.4g} "
                     f"(tol {tol:.0%})")
        lines.append(line)
    lines.append(f"gated regressions localized: {n_reg}")
    return "\n".join(lines)
