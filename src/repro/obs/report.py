"""Cross-run reporting, live trace watch, and the CI convergence gate.

Three consumers of the run ledger (:mod:`repro.obs.ledger`):

* :func:`render_report` / :func:`render_frontier` — cross-run comparison
  tables and the paper's central curve, the **bytes-to-ground vs e_K
  frontier** (``repro.obs report``).  ``benchmarks/table_lossy_ef.py``
  renders its rows exclusively through :func:`lossy_ef_rows` — from
  ledger entries, never recomputed from in-memory logs;
* :func:`watch` — tail a live trace (reader-side only: the traced
  process is untouched) with the per-round table, round rate, and ETA —
  the long-mega-run progress view (``repro.obs watch``);
* :func:`convgate` — the convergence analogue of the BENCH ±20% perf
  gate: committed reference e_K curves for three canonical scenarios
  (``CONV_reference.json``), compared round-by-round against a fresh
  run; degradation beyond tolerance exits 1 naming the scenario, round,
  and metric (``repro.obs convgate``).

The canonical scenarios (:data:`CANONICAL`) are deterministic
small-problem runs of the federated stack — lossless sync, lossy-uplink
sync with loss-robust EF, and buffered-async on mega-1000 — sized so the
three runs finish in CI minutes while still separating a real
convergence regression (e.g. EF silently disabled) from float noise.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

from . import ledger as _ledger
from .summary import (ENG_HEADER, FL_HEADER, eng_row, extract_series,
                      fl_row)
from .trace import load

REFERENCE_PATH = "CONV_reference.json"
REF_SCHEMA = 1
DEFAULT_TOL = 0.25        # e_K may degrade by at most 25% at any round
DEFAULT_TOL_BYTES = 0.01  # byte accounting is deterministic: ±1% only


# ---------------------------------------------------------------------------
# cross-run report + frontier
# ---------------------------------------------------------------------------

def _label(e: dict) -> str:
    """Human row label: the meta ``arm`` when present (sweep tables),
    else algorithm@scenario."""
    arm = e.get("meta", {}).get("arm")
    if arm:
        return str(arm)
    alg = e.get("algorithm") or "?"
    sc = e.get("scenario") or "?"
    return f"{alg}@{sc}"


def render_report(entries: Sequence[dict]) -> str:
    """Cross-run comparison table over ledger entries."""
    if not entries:
        return "(empty ledger)"
    lines = [f"{'run_id':>12s} {'sha':>9s} {'scenario':>18s} "
             f"{'label':>20s} {'mode':>5s} {'rounds':>6s} "
             f"{'bytes_up':>12s} {'e_K':>12s} {'lost':>6s}"]
    for e in entries:
        f = e.get("final", {})
        ek = f.get("e_K")
        bu = f.get("bytes_up")
        lines.append(
            f"{e['run_id']:>12s} {str(e.get('git_sha'))[:9]:>9s} "
            f"{str(e.get('scenario'))[:18]:>18s} "
            f"{_label(e)[:20]:>20s} {str(e.get('mode'))[:5]:>5s} "
            f"{f.get('rounds', 0):6d} "
            + (f"{bu:12.0f} " if bu is not None else f"{'—':>12s} ")
            + (f"{ek:12.6f} " if ek is not None else f"{'—':>12s} ")
            + f"{f.get('n_lost', 0) or 0:6d}")
    return "\n".join(lines)


def frontier_points(entries: Sequence[dict]) -> List[dict]:
    """Accuracy-vs-communication points: entries with both a final e_K
    and a bytes_up ledger value, bytes-ascending, Pareto members marked.

    A point is on the frontier when no cheaper-or-equal-bytes run
    achieves a strictly lower e_K — the curve the paper's central claim
    lives on (and the one the ROADMAP's in-orbit-aggregation comparison
    will extend)."""
    pts = [{"run_id": e["run_id"], "label": _label(e),
            "scenario": e.get("scenario"),
            "bytes_up": e["final"]["bytes_up"], "e_K": e["final"]["e_K"]}
           for e in entries
           if e.get("final", {}).get("e_K") is not None
           and e.get("final", {}).get("bytes_up") is not None]
    pts.sort(key=lambda p: (p["bytes_up"], p["e_K"]))
    best = math.inf
    for p in pts:
        p["pareto"] = p["e_K"] < best
        best = min(best, p["e_K"])
    return pts


def render_frontier(entries: Sequence[dict]) -> str:
    """The bytes-to-ground vs e_K frontier as a table (``*`` = Pareto)."""
    pts = frontier_points(entries)
    if not pts:
        return "(no runs with both e_K and bytes_up in the ledger)"
    lines = [f"{'':2s}{'bytes_up_kB':>12s} {'e_K':>12s}  label"]
    for p in pts:
        mark = "* " if p["pareto"] else "  "
        lines.append(f"{mark}{p['bytes_up'] / 1e3:12.1f} "
                     f"{p['e_K']:12.6f}  {p['label']}")
    return "\n".join(lines)


def lossy_ef_rows(entries: Sequence[dict]) -> List[dict]:
    """The ``benchmarks/table_lossy_ef.py`` row dicts, rebuilt purely
    from ledger entries (meta: ``loss_rate``/``arm``; final: e_K /
    n_lost / n_active / bytes_up) — the no-recomputation reporting
    path."""
    rows = []
    for e in entries:
        meta, f = e.get("meta", {}), e.get("final", {})
        if "loss_rate" not in meta or "arm" not in meta:
            continue
        rows.append(dict(loss_rate=meta["loss_rate"], arm=meta["arm"],
                         error=f.get("e_K"), lost=f.get("n_lost", 0),
                         received=f.get("n_active", 0),
                         bytes_up=f.get("bytes_up")))
    return rows


def plane_agg_rows(entries: Sequence[dict]) -> List[dict]:
    """The ``benchmarks/table_plane_agg.py`` row dicts, rebuilt purely
    from ledger entries (promoted ``topology`` + meta ``arm``; final:
    e_K / bytes_up / n_active; series: ``bytes_isl_cum``) — same
    no-recomputation contract as :func:`lossy_ef_rows`.

    ``bytes_gs`` is the final cumulative GS air-byte count,
    ``bytes_isl`` the final cumulative ISL wire bytes (0 for direct
    arms), and ``updates`` the total updates the coordinator
    incorporated across the run — the denominator of the per-update
    incast metric the table reports."""
    rows = []
    for e in entries:
        meta, f = e.get("meta", {}), e.get("final", {})
        if "arm" not in meta or e.get("topology") is None:
            continue
        isl = e.get("series", {}).get("bytes_isl_cum",
                                      {"values": []})["values"]
        rows.append(dict(arm=meta["arm"], topology=e.get("topology"),
                         scenario=e.get("scenario"),
                         rounds=f.get("rounds"), error=f.get("e_K"),
                         bytes_gs=f.get("bytes_up"),
                         bytes_isl=isl[-1] if isl else 0.0,
                         updates=f.get("n_active", 0) or 0,
                         lost=f.get("n_lost", 0) or 0))
    return rows


def fault_tolerance_rows(entries: Sequence[dict]) -> List[dict]:
    """The ``benchmarks/table_fault_tolerance.py`` row dicts, rebuilt
    purely from ledger entries (meta: ``crash_rate``/``arm``/``quorum``;
    promoted ``faults``; final: e_K / bytes_up / n_lost; series:
    ``survivors``/``quorum_frac``) — same no-recomputation contract as
    :func:`lossy_ef_rows`."""
    rows = []
    for e in entries:
        meta, f = e.get("meta", {}), e.get("final", {})
        if "crash_rate" not in meta or "arm" not in meta:
            continue
        qf = e.get("series", {}).get("quorum_frac", {"values": []})["values"]
        rows.append(dict(crash_rate=meta["crash_rate"], arm=meta["arm"],
                         quorum=meta.get("quorum", 0.0),
                         faults=e.get("faults"),
                         error=f.get("e_K"), bytes_up=f.get("bytes_up"),
                         lost=f.get("n_lost", 0),
                         t_sim=f.get("t"),
                         quorum_frac=(sum(qf) / len(qf)) if qf else None))
    return rows


# ---------------------------------------------------------------------------
# live watch (reader-side tail of a growing trace)
# ---------------------------------------------------------------------------

class TraceTail:
    """Incremental JSONL reader over a growing trace file.

    Plain files are tailed by byte offset (only complete lines are
    consumed; a partially-written last line waits for the next poll).
    ``.gz`` traces are re-read whole each poll — gzip streams aren't
    seekable mid-write — which stays correct, just not O(new records).
    """

    def __init__(self, path: str):
        self.path = path
        self._pos = 0
        self._partial = ""
        self._gz_seen = 0

    def poll(self) -> List[dict]:
        """All complete records that appeared since the last poll."""
        if self.path.endswith(".gz"):
            try:
                records = load(self.path)
            except (OSError, EOFError, json.JSONDecodeError):
                return []          # mid-write: try again next poll
            new = records[self._gz_seen:]
            self._gz_seen = len(records)
            return new
        if not os.path.exists(self.path):
            return []
        with open(self.path) as f:
            f.seek(self._pos)
            chunk = f.read()
            self._pos = f.tell()
        if not chunk:
            return []
        text = self._partial + chunk
        lines = text.split("\n")
        self._partial = lines.pop()
        out = []
        for ln in lines:
            ln = ln.strip()
            if ln:
                out.append(json.loads(ln))
        return out


def _eta_str(seconds: float) -> str:
    seconds = int(seconds)
    return f"{seconds // 3600:d}:{seconds % 3600 // 60:02d}:{seconds % 60:02d}"


def watch(path: str, total: Optional[int] = None, interval: float = 0.5,
          follow: bool = True, max_wait: Optional[float] = None,
          out=None) -> int:
    """Tail a live trace: per-round table rows as they land, plus round
    rate and ETA (when ``total`` is known).

    Purely reader-side — the traced process never sees the watcher.
    Returns once the trace closes (its metrics snapshot appears), after
    one pass with ``follow=False``, or after ``max_wait`` seconds
    without new records."""
    out = sys.stdout if out is None else out
    tail = TraceTail(path)
    t_start = time.perf_counter()
    t_last_new = t_start
    n_rounds = 0
    printed_header = False
    while True:
        new = tail.poll()
        now = time.perf_counter()
        if new:
            t_last_new = now
        for r in new:
            kind = r.get("kind")
            if kind == "header":
                meta = {k: v for k, v in r.items()
                        if k not in ("kind", "schema", "n_events",
                                     "streamed")}
                out.write(f"watching {path}  schema={r.get('schema')}"
                          + (f"  {meta}" if meta else "") + "\n")
            elif kind in ("fl_round", "round"):
                if not printed_header:
                    out.write((FL_HEADER if kind == "fl_round"
                               else ENG_HEADER) + "\n")
                    printed_header = True
                n_rounds += 1
                row = fl_row(r) if kind == "fl_round" else eng_row(r)
                elapsed = now - t_start
                if elapsed > 0 and n_rounds > 1:
                    rate = n_rounds / elapsed
                    row += f"  | {rate * 60.0:6.1f} r/min"
                    if total:
                        left = max(total - n_rounds, 0)
                        row += f"  ETA {_eta_str(left / rate)}"
                out.write(row + "\n")
            elif kind == "metrics":
                if n_rounds == 0:
                    out.write("no rounds recorded\n")
                out.write(f"trace closed: {n_rounds} rounds in "
                          f"{now - t_start:.1f}s\n")
                return 0
        if not follow:
            if n_rounds == 0:
                out.write("no rounds recorded\n")
            return 0
        if max_wait is not None and now - t_last_new > max_wait:
            if n_rounds == 0:
                out.write("no rounds recorded\n")
            out.write(f"no new records for {max_wait:.0f}s; stopping "
                      f"({n_rounds} rounds seen)\n")
            return 0
        time.sleep(interval)


# ---------------------------------------------------------------------------
# convergence gate
# ---------------------------------------------------------------------------

# the three canonical convergence scenarios the committed
# CONV_reference.json pins (name → runner config).  Deterministic: fixed
# seeds, fixed problem sizes, deterministic engine timelines.  The
# FedLT hyperparameters sit in the regime where error feedback visibly
# drives convergence under the coarse 10-level quantizer (EF silently
# disabled ⇒ the e_K curve stalls ~30% above the reference — exactly the
# regression class the gate exists to catch, well past the 25%
# tolerance).
CANONICAL: Dict[str, dict] = {
    "sync-lossless": dict(
        scenario="walker-kiruna", mode="sync", rounds=30, loss=None,
        gamma=0.02, rho=2.0),
    "sync-lossy-robust-ef": dict(
        scenario="walker-kiruna", mode="sync", rounds=60, loss=0.3,
        gamma=0.02, rho=2.0),
    "async-mega-1000": dict(
        scenario="mega-1000", mode="async", rounds=8, loss=None,
        n_agents=1000, dim=8, m=16, buffer_size=64,
        gamma=0.02, rho=2.0),
    # the chaos gate (ISSUE 10): scale + erasures + radiation-upset
    # crashes + station blackouts, rounds closed by a quorum deadline —
    # drifting fault draws, broken residual re-sync, or a changed quorum
    # policy all move this curve
    "sync-mega-chaos": dict(
        scenario="mega-1000-chaos", mode="sync", rounds=8, loss=None,
        n_agents=1000, dim=8, m=16, deadline=45.0, quorum=0.7,
        gamma=0.02, rho=2.0),
}
CANONICAL_SEED = 7


def run_canonical(name: str, *, ef: bool = True, loss_robust: bool = True,
                  rounds: Optional[int] = None) -> List[dict]:
    """Run one canonical convergence scenario under a fresh in-memory
    trace; returns the trace records.

    ``ef=False`` / ``loss_robust=False`` exist for regression-injection
    tests: they reproduce exactly the silent failure modes the gate is
    meant to catch (compression error accumulating without error
    feedback; EF residuals discharged into lost wires)."""
    import jax
    import jax.numpy as jnp

    from ..api import Experiment
    from ..core.compression import UniformQuantizer
    from ..core.error_feedback import EFChannel
    from ..core.fedlt import FedLT, optimality_error
    from ..data.logistic import generate, make_local_loss, solve_global

    cfg = CANONICAL[name]
    n_agents = cfg.get("n_agents", 100)
    dim, m = cfg.get("dim", 32), cfg.get("m", 40)
    rounds = rounds if rounds is not None else cfg["rounds"]
    data, _ = generate(jax.random.PRNGKey(CANONICAL_SEED),
                       n_agents=n_agents, m=m, dim=dim)
    loss_fn = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)
    quant = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    alg = FedLT(loss=loss_fn, n_epochs=10, gamma=cfg["gamma"],
                rho=cfg["rho"],
                uplink=EFChannel(quant, enabled=ef),
                downlink=EFChannel(quant, enabled=ef))
    channel = None
    if cfg["loss"] is not None:
        from ..channel import ChannelModel, SelectiveRepeatARQ
        channel = ChannelModel(
            loss=cfg["loss"],
            arq=SelectiveRepeatARQ(seg_bytes=4096, max_rounds=1))
    runner_kw: dict = dict(compressor=quant, channel=channel,
                           loss_robust=loss_robust)
    if cfg.get("deadline") is not None:
        runner_kw.update(deadline=cfg["deadline"],
                         quorum=cfg.get("quorum", 0.0))
    if cfg["mode"] == "async":
        runner_kw.update(mode="async", buffer_size=cfg["buffer_size"],
                         staleness_alpha=0.5)
    exp = Experiment(cfg["scenario"], alg, seed=CANONICAL_SEED,
                     meta=dict(canonical=name), **runner_kw)
    st = exp.init(jnp.zeros((dim,)), n_agents)
    err = lambda s: float(optimality_error(s.x, x_star))  # noqa: E731
    return exp.run(st, data, rounds,
                   jax.random.PRNGKey(100 + CANONICAL_SEED),
                   error_fn=err, log_every=1, trace=True).records


def gate_records(name: str, records: Sequence[dict], reference: dict,
                 tol: Optional[float] = None,
                 tol_bytes: Optional[float] = None) -> List[str]:
    """Compare one run's curves to the committed reference; returns
    failure messages (empty = gate passes), each localized to the
    scenario, round, and metric that regressed."""
    ref = reference["scenarios"].get(name)
    if ref is None:
        return [f"{name}: no reference curve in the reference file "
                f"(known: {sorted(reference['scenarios'])})"]
    tol = reference.get("tol", DEFAULT_TOL) if tol is None else tol
    tol_bytes = (reference.get("tol_bytes", DEFAULT_TOL_BYTES)
                 if tol_bytes is None else tol_bytes)
    series = extract_series(records)
    fresh = series.get("e_K", {"steps": [], "values": []})
    fresh_at = dict(zip(fresh["steps"], fresh["values"]))
    bad: List[str] = []
    for step, rv in zip(ref["e_K"]["steps"], ref["e_K"]["values"]):
        fv = fresh_at.get(step)
        if fv is None:
            bad.append(f"{name}: e_K sample missing at round {step} "
                       f"(reference has one)")
        elif fv > rv * (1.0 + tol):
            bad.append(f"{name}: e_K degraded at round {step}: "
                       f"{fv:.6g} > reference {rv:.6g} × (1+{tol:g})")
    bu = series.get("bytes_up", {"values": []})["values"]
    fresh_bytes = bu[-1] if bu else None
    ref_bytes = ref.get("bytes_up")
    if ref_bytes is not None:
        if fresh_bytes is None:
            bad.append(f"{name}: bytes_up series missing")
        elif abs(fresh_bytes - ref_bytes) > ref_bytes * tol_bytes:
            bad.append(f"{name}: bytes_up drifted: {fresh_bytes:.0f} vs "
                       f"reference {ref_bytes:.0f} (±{tol_bytes:.0%})")
    return bad


def reference_entry(records: Sequence[dict], rounds: int) -> dict:
    series = extract_series(records)
    bu = series.get("bytes_up", {"values": []})["values"]
    return {"rounds": rounds, "seed": CANONICAL_SEED,
            "e_K": series.get("e_K", {"steps": [], "values": []}),
            "bytes_up": bu[-1] if bu else None}


def update_reference(path: str = REFERENCE_PATH,
                     names: Optional[Sequence[str]] = None,
                     tol: float = DEFAULT_TOL,
                     tol_bytes: float = DEFAULT_TOL_BYTES) -> dict:
    """Re-run the canonical scenarios and (re)write the reference file."""
    names = list(CANONICAL) if names is None else list(names)
    scenarios = {}
    for name in names:
        records = run_canonical(name)
        scenarios[name] = reference_entry(records, CANONICAL[name]["rounds"])
    doc = {"schema": REF_SCHEMA, "tol": tol, "tol_bytes": tol_bytes,
           "seed": CANONICAL_SEED, "scenarios": scenarios}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def load_reference(path: str = REFERENCE_PATH) -> dict:
    with open(path) as f:
        return json.load(f)


def convgate(reference_path: str = REFERENCE_PATH,
             traces: Optional[Sequence[str]] = None,
             scenario: Optional[str] = None,
             ledger_path: Optional[str] = None,
             tol: Optional[float] = None,
             tol_bytes: Optional[float] = None,
             out=None) -> int:
    """The CI convergence gate.  Without ``traces``, runs every
    canonical scenario fresh and gates each against the reference
    (optionally ingesting the fresh runs into ``ledger_path``); with
    trace paths, gates those existing traces (scenario taken from each
    trace's ``canonical`` header meta unless ``scenario`` is given).
    Returns the exit code (1 on any failure)."""
    out = sys.stdout if out is None else out
    reference = load_reference(reference_path)
    runs: List[Tuple[str, Sequence[dict]]] = []
    if traces:
        for path in traces:
            records = load(path)
            header = records[0] if records else {}
            name = scenario or header.get("canonical")
            if name is None:
                out.write(f"{path}: no canonical scenario in the trace "
                          f"header; pass --scenario\n")
                return 2
            runs.append((name, records))
    else:
        for name in CANONICAL:
            out.write(f"running canonical scenario {name} "
                      f"({CANONICAL[name]['rounds']} rounds)...\n")
            records = run_canonical(name)
            runs.append((name, records))
            if ledger_path:
                entry, added = _ledger.ingest(records, ledger_path)
                out.write(f"  ingested as {entry['run_id']}"
                          + ("" if added else " (already present)") + "\n")
    rc = 0
    for name, records in runs:
        bad = gate_records(name, records, reference,
                           tol=tol, tol_bytes=tol_bytes)
        if bad:
            rc = 1
            out.write(f"CONVGATE FAIL {name}: {len(bad)} violation(s)\n")
            for msg in bad:
                out.write(f"  {msg}\n")
        else:
            ref = reference["scenarios"][name]
            n = len(ref["e_K"]["steps"])
            out.write(f"CONVGATE OK {name}: {n} e_K samples within "
                      f"tolerance\n")
    return rc
