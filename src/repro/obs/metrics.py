"""Counters and histograms for the sim / federated stack.

A :class:`Metrics` registry is owned by each :class:`repro.obs.trace.
Tracer`; the instrumented layers bump it alongside event emission:

    bytes_air{station=g}      uplink bytes put on the air per GS link
    bytes_retx                retransmitted / truncated-attempt bytes
    bytes_down                nominal coordinator broadcast bytes
    deliveries{status=...}    delivered / lost counts
    delivery_latency          histogram of t_done − t_start (seconds)
    staleness                 histogram of aggregation staleness (async)
    lost_frac                 histogram of per-round lost fraction

Everything is plain-python (no numpy in the hot increment path) and
serializes through :meth:`Metrics.to_dict` into the trace's final JSONL
record.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

# default histogram bucket upper bounds: ~log-spaced, generous range so
# one set covers seconds-scale latencies, staleness counts, and fractions
DEFAULT_BOUNDS = (0.01, 0.1, 0.5, 1.0, 5.0, 10.0, 60.0, 300.0, 1800.0,
                  7200.0, 43200.0)

# phase-profiler bounds (repro.obs.prof): per-round phase totals span
# microseconds (a window-fit pass at mega-1000) to whole-round seconds
PHASE_BOUNDS = (1e-6, 3e-6, 1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2,
                3e-2, 0.1, 0.3, 1.0, 3.0, 10.0)


class Counter:
    """Labelled monotone counter: ``add(v, station=3)`` accumulates into
    the ``(("station", 3),)`` cell; unlabelled adds use the ``()`` cell."""

    __slots__ = ("cells",)

    def __init__(self):
        self.cells: Dict[Tuple, float] = {}

    def add(self, value: float = 1.0, **labels) -> None:
        key = tuple(sorted(labels.items()))
        self.cells[key] = self.cells.get(key, 0.0) + value

    @property
    def total(self) -> float:
        return sum(self.cells.values())

    def to_dict(self) -> dict:
        out = {"total": self.total}
        labelled = {",".join(f"{k}={v}" for k, v in key): val
                    for key, val in sorted(self.cells.items()) if key}
        if labelled:
            out["cells"] = labelled
        return out


class Histogram:
    """Fixed-bound histogram with count/sum/min/max sidecar stats.

    Out-of-range samples are never silently dropped: values above the
    last bound land in the overflow bucket (``counts[-1]``, surfaced as
    an explicit ``overflow`` count in the snapshot), and — with an
    optional lower bound ``lo`` — values below it are tallied as
    ``underflow`` instead of distorting the first bucket.  Under- and
    overflowing samples still contribute to count/sum/min/max, so the
    sidecar stats always describe every observation.
    """

    __slots__ = ("bounds", "lo", "counts", "underflow", "count", "sum",
                 "min", "max")

    def __init__(self, bounds: Optional[Sequence[float]] = None,
                 lo: Optional[float] = None):
        self.bounds = tuple(bounds) if bounds is not None else DEFAULT_BOUNDS
        self.lo = lo
        self.counts = [0] * (len(self.bounds) + 1)   # +1: overflow bucket
        self.underflow = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        if self.lo is not None and value < self.lo:
            self.underflow += 1
        else:
            i = 0
            for b in self.bounds:
                if value <= b:
                    break
                i += 1
            self.counts[i] += 1
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    @property
    def overflow(self) -> int:
        return self.counts[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> Optional[float]:
        """Interpolated ``q``-th percentile (``q`` in [0, 100]) from the
        bucket counts.

        Linear interpolation inside the containing bucket, with exact
        edges everywhere a sidecar stat pins one: the underflow bucket
        spans ``[min, lo)``, the first regular bucket starts at ``lo``
        (or ``min`` without a lower bound), and the overflow bucket
        spans ``(bounds[-1], max]``.  The result is clamped to
        ``[min, max]``, so p0 → ``min`` and p100 → ``max`` hold
        regardless of bucket geometry.  Returns ``None`` when empty."""
        if not self.count:
            return None
        q = min(max(float(q), 0.0), 100.0)
        target = q / 100.0 * self.count
        buckets = []                       # (count, lower_edge, upper_edge)
        if self.underflow:
            buckets.append((self.underflow, self.min, self.lo))
        lo_edge = self.lo if self.lo is not None else self.min
        for i, b in enumerate(self.bounds):
            if self.counts[i]:
                buckets.append((self.counts[i], lo_edge, b))
            lo_edge = b
        if self.counts[-1]:
            buckets.append((self.counts[-1], self.bounds[-1], self.max))
        cum = 0
        for c, e0, e1 in buckets:
            if target <= cum + c:
                frac = (target - cum) / c
                return min(max(e0 + (e1 - e0) * frac, self.min), self.max)
            cum += c
        return self.max

    def to_dict(self) -> dict:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "bounds": list(self.bounds), "counts": list(self.counts),
                "lo": self.lo, "underflow": self.underflow,
                "overflow": self.overflow}

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        """Rebuild a histogram from a :meth:`to_dict` snapshot (what a
        trace's final ``metrics`` record carries) — lets the profiler
        rollup compute percentiles from a loaded trace."""
        h = cls(d["bounds"], lo=d.get("lo"))
        h.counts = list(d["counts"])
        h.underflow = int(d.get("underflow", 0))
        h.count = int(d["count"])
        h.sum = float(d["sum"])
        h.min = d["min"] if d.get("min") is not None else math.inf
        h.max = d["max"] if d.get("max") is not None else -math.inf
        return h


class Metrics:
    """Name → Counter/Histogram registry (created on first touch)."""

    __slots__ = ("counters", "histograms")

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None,
                  lo: Optional[float] = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(bounds, lo=lo)
        return h

    def to_dict(self) -> dict:
        return {"counters": {k: c.to_dict()
                             for k, c in sorted(self.counters.items())},
                "histograms": {k: h.to_dict()
                               for k, h in sorted(self.histograms.items())}}
