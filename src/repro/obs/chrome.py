"""Chrome-trace (Perfetto-loadable) exporter for obs trace records.

Maps the JSONL event schema onto the Chrome Trace Event Format so a
mega-1000 round is visually inspectable in https://ui.perfetto.dev (or
chrome://tracing): open the exported ``.json`` and every delivery shows
as a slice on its ground-station track, rounds as slices on a rounds
track, ARQ losses as instants, and host-side stage/kernel spans on their
own process.

Two clock domains map onto the single trace timeline:

* sim-time events (deliveries, rounds, cohorts) use simulated seconds
  scaled to µs — pids ``1`` (deliveries, one thread per ground station),
  ``2`` (engine rounds), ``4`` (federated rounds);
* host-time spans (kernel dispatches, runner stages) use wall seconds
  since tracer start — pid ``3``;
* phase rollups (:mod:`repro.obs.prof`) are per-round *sums*, not
  timestamped spans, so pid ``5`` renders them as a synthetic-timeline
  icicle: each round/run lays its phases out sequentially from the
  previous round's end (children inside their parents), which preserves
  relative widths — the thing a flame view is for — without pretending
  the rollup knows real start times;
* ``series`` samples (schema v2) map to counter tracks on pid ``6``
  keyed by step (not time); non-finite values are skipped so the JSON
  stays loadable (Perfetto rejects NaN).

They share an origin but not a rate; the pid split keeps them on
separate tracks so the mismatch can't mislead.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List

_US = 1e6    # seconds → microseconds

PID_DELIVERIES = 1
PID_ROUNDS = 2
PID_HOST = 3
PID_FL = 4
PID_PROF = 5
PID_SERIES = 6

_PROCESS_NAMES = {
    PID_DELIVERIES: "sim: deliveries (per ground station)",
    PID_ROUNDS: "sim: engine rounds",
    PID_HOST: "host: stages & kernel dispatches",
    PID_FL: "federated rounds (SpaceRunner)",
    PID_PROF: "prof: phase rollups (synthetic timeline)",
    PID_SERIES: "series (x-axis = step, not time)",
}


def _phase_unit_events(pending: List[dict], wall: float, label: str,
                       offset: float) -> List[dict]:
    """Icicle layout for one flushed unit's phase records: depth-1
    phases sequential from the unit's start, children recursively from
    their parent's start — widths are the measured totals."""
    totals = {r["path"]: r for r in pending}
    ev = [{"ph": "X", "pid": PID_PROF, "tid": 0, "ts": offset * _US,
           "dur": wall * _US, "name": label, "cat": "phase_total",
           "args": {"wall_s": wall}}]

    def lay(paths: List[str], t0: float, depth: int) -> None:
        cursor = t0
        for p in paths:
            r = totals[p]
            ev.append({"ph": "X", "pid": PID_PROF, "tid": 0,
                       "ts": cursor * _US, "dur": r["total"] * _US,
                       "name": p.split("/")[-1], "cat": "phase",
                       "args": {"path": p, "count": r["count"],
                                "total_s": r["total"]}})
            kids = sorted(q for q in totals
                          if q.startswith(p + "/")
                          and "/" not in q[len(p) + 1:])
            if kids:
                lay(kids, cursor, depth + 1)
            cursor += r["total"]

    lay(sorted(p for p in totals if "/" not in p), offset, 0)
    return ev


def chrome_trace(records: List[dict]) -> dict:
    """Convert obs records (``Tracer.records()`` / ``trace.load``) into a
    Chrome Trace Event Format dict (``json.dump`` it for Perfetto)."""
    ev: List[dict] = []
    for pid, name in _PROCESS_NAMES.items():
        ev.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                   "args": {"name": name}})
    bytes_cum = 0.0
    prof_pending: List[dict] = []
    prof_offset = 0.0
    series_tids: Dict[str, int] = {}
    for r in records:
        kind = r.get("kind")
        if kind == "delivery":
            t0, t1 = r["t_start"], r["t_done"]
            ev.append({
                "ph": "X", "pid": PID_DELIVERIES, "tid": r["station"],
                "ts": t0 * _US, "dur": max(t1 - t0, 0.0) * _US,
                "name": f"sat {r['sat']}" + ("" if r["delivered"]
                                             else " (LOST)"),
                "cat": "delivery",
                "args": {k: r[k] for k in ("sat", "gateway", "hops",
                                           "nbytes", "nbytes_attempted",
                                           "retries", "delivered")
                         if k in r},
            })
        elif kind == "arq":
            ev.append({
                "ph": "i", "pid": PID_DELIVERIES, "tid": r["station"],
                "ts": r["t_done"] * _US, "s": "t", "cat": "arq",
                "name": (f"arq sat {r['sat']}: {r['retries']} retx"
                         + ("" if r["delivered"] else ", lost")),
            })
        elif kind == "round":
            ev.append({
                "ph": "X", "pid": PID_ROUNDS, "tid": 0,
                "ts": r["t0"] * _US, "dur": r["duration"] * _US,
                "name": f"round {r['round']}", "cat": "round",
                "args": {k: r[k] for k in ("n_scheduled", "n_delivered",
                                           "n_lost", "bytes_air", "engine")
                         if k in r},
            })
            bytes_cum += r.get("bytes_air", 0.0)
            ev.append({"ph": "C", "pid": PID_ROUNDS, "tid": 0,
                       "ts": (r["t0"] + r["duration"]) * _US,
                       "name": "bytes_air (cumulative)",
                       "args": {"bytes": bytes_cum}})
        elif kind == "cohort":
            ev.append({
                "ph": "X", "pid": PID_ROUNDS, "tid": 1 + r["station"],
                "ts": r["t_first"] * _US,
                "dur": max(r["t_last"] - r["t_first"], 0.0) * _US,
                "name": f"cohort gs{r['station']} ({r['n_sats']} sats)",
                "cat": "cohort", "args": {"nbytes": r.get("nbytes")},
            })
        elif kind == "fl_round":
            args = {k: r[k] for k in ("bytes_up", "n_active", "error",
                                      "staleness", "n_lost") if k in r
                    and r[k] is not None}
            ev.append({
                "ph": "X", "pid": PID_FL, "tid": 0,
                "ts": r.get("t0", 0.0) * _US,
                "dur": max(r.get("t", 0.0) - r.get("t0", 0.0), 0.0) * _US,
                "name": f"fl_round {r['round']}", "cat": "fl_round",
                "args": args,
            })
        elif kind == "phase":
            prof_pending.append(r)
        elif kind == "phase_total":
            unit = ("round" if "round" in r else "run",
                    r.get("round", r.get("run")))
            label = (f"{r.get('engine', '?')} {r.get('mode', '?')} "
                     f"{unit[0]} {unit[1]}")
            ev.extend(_phase_unit_events(prof_pending, r["wall"], label,
                                         prof_offset))
            prof_offset += r["wall"]
            prof_pending = []
        elif kind == "series":
            v = r["value"]
            if not math.isfinite(v):
                continue            # Perfetto rejects NaN/inf JSON
            tid = series_tids.setdefault(r["name"], len(series_tids))
            ev.append({"ph": "C", "pid": PID_SERIES, "tid": tid,
                       "ts": r["step"] * _US, "name": r["name"],
                       "args": {"value": v}})
        elif "t_host" in r and "dur_host" in r:       # kernel / span / …
            ev.append({
                "ph": "X", "pid": PID_HOST, "tid": 0,
                "ts": r["t_host"] * _US, "dur": r["dur_host"] * _US,
                "name": r.get("name", kind), "cat": kind,
                "args": {k: v for k, v in r.items()
                         if k not in ("kind", "name", "t_host", "dur_host")},
            })
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_chrome_trace(records: List[dict], path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(records), f)
    return path
