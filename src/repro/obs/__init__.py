"""Observability: structured tracing + metrics for the constellation sim
and the federated stack.

The paper's claims are about *communication* — fewer uplinks, smaller
wires, error feedback recovering compression loss — so this subsystem
makes the communication visible: where bytes, retries, and staleness
accumulate inside a round, per link and per contact window, instead of
end-of-run aggregates only.

Three layers:

* :mod:`repro.obs.trace` — a zero-overhead-when-disabled :class:`Tracer`
  of typed event records (round, delivery, ARQ retransmission, cohort,
  EF revert, kernel dispatch, link-budget sample), emitted by the
  instrumented engine (both the heapq oracle and the fast batch engine —
  same schema), ``SpaceRunner``, the channel stack, and
  :mod:`repro.kernels.ops`;
* :mod:`repro.obs.metrics` — counters/histograms (bytes per link,
  retransmitted bytes, delivery latency, staleness, lost fraction)
  snapshotted into every trace;
* :mod:`repro.obs.summary` / :mod:`repro.obs.chrome` — summarize
  (human table or ``--json`` machine form), diff (localize the first
  fast-vs-oracle divergence), check invariants (bytes conservation —
  the CI smoke), and export Chrome/Perfetto traces;
* :mod:`repro.obs.ledger` / :mod:`repro.obs.report` — cross-run
  experiment tracking: every run's per-round ``series`` curves (e_K,
  bytes_up/down/air, EF-residual norm, staleness, lost fraction) fold
  into an append-only run ledger keyed by content-hash run ids; the
  report renders cross-run tables and the bytes-to-ground vs e_K
  frontier, ``watch`` tails a live trace (reader-side only), and
  ``convgate`` gates fresh convergence curves against the committed
  ``CONV_reference.json`` in CI;
* :mod:`repro.obs.prof` — the phase-attribution profiler: both engines
  bracket their real stages (plan extension, assignment, window fits,
  channel commits, batched routing, kernel dispatches) so ``prof``
  renders per-phase self/total/p50/p99 with an explicit unattributed
  residual, ``perfdiff`` names the phases behind a perf regression, and
  ``bench-history`` tracks ``BENCH_*.json`` emissions over time with
  regression-onset localization.

Quickstart::

    from repro import obs
    with obs.tracing("run.jsonl", scenario="mega-1000"):
        runner.run(alg, state, data, n_rounds=50, key=key)
    # then:  python -m repro.obs summarize run.jsonl [--json]
    #        python -m repro.obs ingest run.jsonl --ledger runs/ledger.jsonl
    #        python -m repro.obs report --ledger runs/ledger.jsonl
    #        python -m repro.obs watch run.jsonl --total 50   # live runs
    #        python -m repro.obs convgate                     # CI gate
    #        python -m repro.obs diff fast.jsonl oracle.jsonl
    #        python -m repro.obs check run.jsonl
    #        python -m repro.obs chrome run.jsonl -o run.perfetto.json
    #        python -m repro.obs prof run.jsonl --flame run.folded
    #        python -m repro.obs perfdiff old.jsonl new.jsonl
    #        python -m repro.obs bench-history bench_out/BENCH_sim.json

Paths ending in ``.gz`` read and write gzip-compressed; long runs can
stream with bounded memory (``obs.tracing(path, stream_every=N)``).

Disabled (the default) the only cost anywhere in the stack is a module
attribute read per round / per kernel dispatch — enforced by the gated
``sim.trace_overhead`` benchmark (<5% enabled, parity disabled).
"""
from .chrome import chrome_trace, write_chrome_trace
from .ledger import ingest, load_ledger
from .metrics import Counter, Histogram, Metrics
from .prof import (PhaseAcc, attribution, collect, folded, ingest_bench,
                   perfdiff, render_history, render_perfdiff,
                   render_profile)
from .report import convgate, render_frontier, render_report, watch
from .summary import (check, diff, extract_series, render_rounds,
                      summarize, summarize_dict)
from .trace import (Tracer, active, disable, enable, load, tracing)

__all__ = [
    "Tracer", "active", "enable", "disable", "tracing", "load",
    "Metrics", "Counter", "Histogram",
    "summarize", "summarize_dict", "extract_series", "render_rounds",
    "diff", "check",
    "ingest", "load_ledger", "render_report", "render_frontier",
    "watch", "convgate",
    "chrome_trace", "write_chrome_trace",
    "PhaseAcc", "collect", "render_profile", "folded", "attribution",
    "perfdiff", "render_perfdiff", "ingest_bench", "render_history",
]
