"""Observability: structured tracing + metrics for the constellation sim
and the federated stack.

The paper's claims are about *communication* — fewer uplinks, smaller
wires, error feedback recovering compression loss — so this subsystem
makes the communication visible: where bytes, retries, and staleness
accumulate inside a round, per link and per contact window, instead of
end-of-run aggregates only.

Three layers:

* :mod:`repro.obs.trace` — a zero-overhead-when-disabled :class:`Tracer`
  of typed event records (round, delivery, ARQ retransmission, cohort,
  EF revert, kernel dispatch, link-budget sample), emitted by the
  instrumented engine (both the heapq oracle and the fast batch engine —
  same schema), ``SpaceRunner``, the channel stack, and
  :mod:`repro.kernels.ops`;
* :mod:`repro.obs.metrics` — counters/histograms (bytes per link,
  retransmitted bytes, delivery latency, staleness, lost fraction)
  snapshotted into every trace;
* :mod:`repro.obs.summary` / :mod:`repro.obs.chrome` — summarize, diff
  (localize the first fast-vs-oracle divergence), check invariants
  (bytes conservation — the CI smoke), and export Chrome/Perfetto
  traces.

Quickstart::

    from repro import obs
    with obs.tracing("run.jsonl", scenario="mega-1000"):
        runner.run(alg, state, data, n_rounds=50, key=key)
    # then:  python -m repro.obs summarize run.jsonl
    #        python -m repro.obs diff fast.jsonl oracle.jsonl
    #        python -m repro.obs check run.jsonl
    #        python -m repro.obs chrome run.jsonl -o run.perfetto.json

Disabled (the default) the only cost anywhere in the stack is a module
attribute read per round / per kernel dispatch — enforced by the gated
``sim.trace_overhead`` benchmark (<5% enabled, parity disabled).
"""
from .chrome import chrome_trace, write_chrome_trace
from .metrics import Counter, Histogram, Metrics
from .summary import check, diff, render_rounds, summarize
from .trace import (Tracer, active, disable, enable, load, tracing)

__all__ = [
    "Tracer", "active", "enable", "disable", "tracing", "load",
    "Metrics", "Counter", "Histogram",
    "summarize", "render_rounds", "diff", "check",
    "chrome_trace", "write_chrome_trace",
]
