"""CLI: summarize, diff, check, export, and cross-run-track obs traces.

    python -m repro.obs summarize TRACE.jsonl [--json]
    python -m repro.obs diff FAST.jsonl ORACLE.jsonl [--kinds delivery round]
    python -m repro.obs check TRACE.jsonl [MORE.jsonl ...]
    python -m repro.obs chrome TRACE.jsonl -o TRACE.perfetto.json
    python -m repro.obs ingest TRACE.jsonl [--ledger runs/ledger.jsonl]
    python -m repro.obs report [--ledger runs/ledger.jsonl] [--frontier]
    python -m repro.obs watch TRACE.jsonl [--total N] [--max-wait S]
    python -m repro.obs convgate [--reference CONV_reference.json]
    python -m repro.obs prof TRACE.jsonl [--flame F] [--min-attribution Q]
    python -m repro.obs perfdiff A.jsonl B.jsonl [--top N] [--tol T]
    python -m repro.obs bench-history [BENCH_*.json ...] [--history H]
    python -m repro.obs --check TRACE.jsonl          # alias for `check`

All subcommands read ``.gz`` traces transparently.  ``diff`` exits 1 on
the first divergence (printing the record index and field delta),
``check`` exits 1 on any violated invariant, ``convgate`` exits 1 when a
fresh convergence curve degrades past the committed reference tolerance
(naming the scenario, round, and metric) — all three are CI primitives:
the perf gate runs ``check`` on the emitted mega-1000 trace, ``ingest``s
it into the uploaded ledger artifact, and runs ``convgate`` against
``CONV_reference.json``.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import prof as _prof
from .chrome import write_chrome_trace
from .ledger import DEFAULT_LEDGER, ingest, load_ledger
from .report import (REFERENCE_PATH, convgate, render_frontier,
                     render_report, update_reference, watch)
from .summary import DIFF_KINDS, check, diff, summarize, summarize_dict
from .trace import load


def _parse_meta(pairs) -> dict:
    out = {}
    for p in pairs or ():
        if "=" not in p:
            raise SystemExit(f"--meta wants key=value, got {p!r}")
        k, v = p.split("=", 1)
        out[k] = v
    return out


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "--check":       # `repro.obs --check F` alias
        argv[0] = "check"
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="per-round summary table")
    p.add_argument("trace")
    p.add_argument("--json", action="store_true",
                   help="machine-readable summary (what ingest/report "
                        "consume) instead of the table")

    p = sub.add_parser("diff", help="localize the first divergence "
                                    "between two traces")
    p.add_argument("trace_a")
    p.add_argument("trace_b")
    p.add_argument("--kinds", nargs="*", default=None,
                   help=f"event kinds to compare (default: "
                        f"{' '.join(DIFF_KINDS)})")

    p = sub.add_parser("check", help="assert trace invariants "
                                     "(bytes conservation, ordering)")
    p.add_argument("traces", nargs="+")

    p = sub.add_parser("chrome", help="export a Perfetto-loadable "
                                      "Chrome trace")
    p.add_argument("trace")
    p.add_argument("-o", "--out", default=None,
                   help="output path (default: <trace>.perfetto.json)")

    p = sub.add_parser("ingest", help="fold traces into the run ledger")
    p.add_argument("traces", nargs="+")
    p.add_argument("--ledger", default=DEFAULT_LEDGER)
    p.add_argument("--sha", default=None,
                   help="git sha override (default: REPRO_GIT_SHA env "
                        "or `git rev-parse --short HEAD`)")
    p.add_argument("--meta", nargs="*", default=None, metavar="K=V",
                   help="header-meta overrides, e.g. scenario=mega-1000")

    p = sub.add_parser("report", help="cross-run comparison table + "
                                      "bytes-vs-e_K frontier")
    p.add_argument("--ledger", default=DEFAULT_LEDGER)
    p.add_argument("--frontier", action="store_true",
                   help="only the bytes-to-ground vs e_K frontier")

    p = sub.add_parser("watch", help="tail a live trace (per-round "
                                     "table, rate, ETA)")
    p.add_argument("trace")
    p.add_argument("--total", type=int, default=None,
                   help="expected total rounds (enables ETA)")
    p.add_argument("--interval", type=float, default=0.5)
    p.add_argument("--max-wait", type=float, default=None,
                   help="stop after this many idle seconds")
    p.add_argument("--no-follow", action="store_true",
                   help="one pass over what exists now, then exit")

    p = sub.add_parser("convgate", help="CI convergence gate vs the "
                                        "committed reference curves")
    p.add_argument("traces", nargs="*",
                   help="existing traces to gate (default: run the "
                        "canonical scenarios fresh)")
    p.add_argument("--reference", default=REFERENCE_PATH)
    p.add_argument("--scenario", default=None,
                   help="canonical scenario name for the given traces "
                        "(default: from each trace's header meta)")
    p.add_argument("--ledger", default=None,
                   help="also ingest fresh canonical runs here")
    p.add_argument("--tol", type=float, default=None)
    p.add_argument("--tol-bytes", type=float, default=None)
    p.add_argument("--update", action="store_true",
                   help="re-run the canonical scenarios and REWRITE the "
                        "reference file instead of gating")

    p = sub.add_parser("prof", help="phase-attribution profile of a "
                                    "trace's phase records")
    p.add_argument("trace")
    p.add_argument("--flame", default=None, metavar="FILE",
                   help="also write folded stacks (speedscope/"
                        "flamegraph.pl input) here")
    p.add_argument("--out", default=None, metavar="FILE",
                   help="also write the table here")
    p.add_argument("--min-attribution", type=float, default=None,
                   metavar="FRAC",
                   help="exit 1 if less than this fraction of wall time "
                        "is attributed (CI gate, e.g. 0.9)")

    p = sub.add_parser("perfdiff", help="diff two phase profiles and "
                                        "name the top regressed phases")
    p.add_argument("trace_a", help="reference trace")
    p.add_argument("trace_b", help="fresh trace")
    p.add_argument("--top", type=int, default=8)
    p.add_argument("--tol", type=float, default=0.2,
                   help="per-phase regression tolerance (default 0.2)")

    p = sub.add_parser("bench-history",
                       help="ingest BENCH_*.json emissions into the "
                            "append-only history and render per-metric "
                            "trajectories with regression onsets")
    p.add_argument("bench_json", nargs="*",
                   help="BENCH_*.json files to ingest (none: render "
                        "the existing history)")
    p.add_argument("--history", default=_prof.DEFAULT_HISTORY)
    p.add_argument("--tol", type=float, default=0.2)
    p.add_argument("--sha", default=None,
                   help="git sha override for the ingested entries")

    args = ap.parse_args(argv)

    if args.cmd == "summarize":
        records = load(args.trace)
        if args.json:
            print(json.dumps(summarize_dict(records), sort_keys=True))
        else:
            print(summarize(records))
        return 0
    if args.cmd == "diff":
        equal, report = diff(load(args.trace_a), load(args.trace_b),
                             kinds=args.kinds)
        print(report)
        return 0 if equal else 1
    if args.cmd == "check":
        rc = 0
        for path in args.traces:
            bad = check(load(path))
            if bad:
                rc = 1
                print(f"{path}: {len(bad)} invariant violation(s)")
                for msg in bad:
                    print(f"  {msg}")
            else:
                print(f"{path}: all invariants hold")
        return rc
    if args.cmd == "chrome":
        out = args.out or args.trace + ".perfetto.json"
        write_chrome_trace(load(args.trace), out)
        print(f"wrote {out} — open in https://ui.perfetto.dev")
        return 0
    if args.cmd == "ingest":
        meta = _parse_meta(args.meta)
        for path in args.traces:
            entry, added = ingest(path, args.ledger, sha=args.sha, **meta)
            print(f"{path}: {'ingested' if added else 'already present'} "
                  f"as {entry['run_id']} "
                  f"(scenario={entry['scenario']}, "
                  f"e_K={entry['final'].get('e_K')})")
        return 0
    if args.cmd == "report":
        entries = load_ledger(args.ledger)
        if args.frontier:
            print(render_frontier(entries))
        else:
            print(render_report(entries))
            print()
            print("bytes-to-ground vs e_K frontier (* = Pareto):")
            print(render_frontier(entries))
        return 0
    if args.cmd == "watch":
        return watch(args.trace, total=args.total, interval=args.interval,
                     follow=not args.no_follow, max_wait=args.max_wait)
    if args.cmd == "convgate":
        if args.update:
            doc = update_reference(args.reference)
            print(f"wrote {args.reference}: "
                  f"{sorted(doc['scenarios'])} (tol={doc['tol']})")
            return 0
        return convgate(args.reference, traces=args.traces or None,
                        scenario=args.scenario, ledger_path=args.ledger,
                        tol=args.tol, tol_bytes=args.tol_bytes)
    if args.cmd == "prof":
        profile = _prof.collect(load(args.trace))
        table = _prof.render_profile(profile, title=args.trace)
        print(table)
        if args.out:
            with open(args.out, "w") as f:
                f.write(table + "\n")
            print(f"wrote {args.out}")
        if args.flame:
            with open(args.flame, "w") as f:
                f.write(_prof.folded(profile))
            print(f"wrote {args.flame} (folded stacks — load in "
                  f"https://speedscope.app)")
        if args.min_attribution is not None:
            _, frac = _prof.attribution(profile)
            if frac < args.min_attribution:
                print(f"ATTRIBUTION GATE FAILED: {frac:.1%} < "
                      f"{args.min_attribution:.1%} of wall attributed")
                return 1
        return 0
    if args.cmd == "perfdiff":
        d = _prof.perfdiff(load(args.trace_a), load(args.trace_b),
                           tol=args.tol, top=args.top)
        print(_prof.render_perfdiff(d, top=args.top))
        return 0
    if args.cmd == "bench-history":
        for path in args.bench_json:
            entry, added = _prof.ingest_bench(path, args.history,
                                              sha=args.sha)
            print(f"{path}: {'ingested' if added else 'already present'} "
                  f"as {entry['group']}/{entry['bench_id']}")
        print(_prof.render_history(_prof.load_history(args.history),
                                   tol=args.tol))
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())
