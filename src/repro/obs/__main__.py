"""CLI: summarize, diff, check, and export obs traces.

    python -m repro.obs summarize TRACE.jsonl
    python -m repro.obs diff FAST.jsonl ORACLE.jsonl [--kinds delivery round]
    python -m repro.obs check TRACE.jsonl [MORE.jsonl ...]
    python -m repro.obs chrome TRACE.jsonl -o TRACE.perfetto.json
    python -m repro.obs --check TRACE.jsonl          # alias for `check`

``diff`` exits 1 on the first divergence (printing the record index and
field delta), ``check`` exits 1 on any violated invariant — both are CI
primitives: the perf gate runs ``check`` on the trace the bench harness
emits next to BENCH_*.json (bytes conservation), and equivalence tests
run ``diff`` over fast-vs-oracle traces.
"""
from __future__ import annotations

import argparse
import sys

from .chrome import write_chrome_trace
from .summary import DIFF_KINDS, check, diff, summarize
from .trace import load


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "--check":       # `repro.obs --check F` alias
        argv[0] = "check"
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="per-round summary table")
    p.add_argument("trace")

    p = sub.add_parser("diff", help="localize the first divergence "
                                    "between two traces")
    p.add_argument("trace_a")
    p.add_argument("trace_b")
    p.add_argument("--kinds", nargs="*", default=None,
                   help=f"event kinds to compare (default: "
                        f"{' '.join(DIFF_KINDS)})")

    p = sub.add_parser("check", help="assert trace invariants "
                                     "(bytes conservation, ordering)")
    p.add_argument("traces", nargs="+")

    p = sub.add_parser("chrome", help="export a Perfetto-loadable "
                                      "Chrome trace")
    p.add_argument("trace")
    p.add_argument("-o", "--out", default=None,
                   help="output path (default: <trace>.perfetto.json)")

    args = ap.parse_args(argv)

    if args.cmd == "summarize":
        print(summarize(load(args.trace)))
        return 0
    if args.cmd == "diff":
        equal, report = diff(load(args.trace_a), load(args.trace_b),
                             kinds=args.kinds)
        print(report)
        return 0 if equal else 1
    if args.cmd == "check":
        rc = 0
        for path in args.traces:
            bad = check(load(path))
            if bad:
                rc = 1
                print(f"{path}: {len(bad)} invariant violation(s)")
                for msg in bad:
                    print(f"  {msg}")
            else:
                print(f"{path}: all invariants hold")
        return rc
    if args.cmd == "chrome":
        out = args.out or args.trace + ".perfetto.json"
        write_chrome_trace(load(args.trace), out)
        print(f"wrote {out} — open in https://ui.perfetto.dev")
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())
