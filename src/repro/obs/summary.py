"""Summarize, diff, and check obs traces (the ``repro.obs`` CLI core).

* :func:`summarize` — per-round table (engine rounds or federated
  ``fl_round`` records, whichever the trace carries) plus delivery and
  metrics totals; :func:`summarize_dict` is the machine-readable
  counterpart (``repro.obs summarize --json``) that the ledger ingest
  and the report renderer build on, so scripts never screen-scrape the
  rendered table;
* :func:`diff` — ordered comparison of the deterministic sim-schema
  events of two traces; localizes the FIRST diverging record, replacing
  the hand-diffing of Delivery lists that fast-vs-oracle equivalence
  debugging used to need;
* :func:`check` — trace invariants (bytes conservation, delivery
  ordering, count consistency); the CI perf-gate smoke.

All three operate on record lists (``trace.load(path)`` or
``Tracer.records()``), so tests and examples can run them in memory.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .trace import HOST_FIELDS

# the deterministic engine-emitted kinds: identical for any two engines
# that produced the same Delivery timeline, regardless of fast/oracle
# internals, host timing, or channel implementation details
# (head_elect: per-plane cluster-head elections under in-orbit
# aggregation topologies — a pure function of the contact plan, so fast
# and oracle must agree on it too; fault/head_failover: injected faults
# are counter-based draws on the shared delivery timeline, so the fault
# streams of equivalent engines must also be bit-identical)
DIFF_KINDS = ("round", "delivery", "arq", "cohort", "async_run",
              "head_elect", "fault", "head_failover")

# fields legitimately differing between equivalent traces: host clocks
# and the engine tag ("fast"/"oracle") on round records
DIFF_IGNORE = HOST_FIELDS + ("engine",)


def of_kind(records: Iterable[dict], *kinds: str) -> List[dict]:
    return [r for r in records if r.get("kind") in kinds]


# ---------------------------------------------------------------------------
# summarize
# ---------------------------------------------------------------------------

def _fmt(v, width: int, prec: int = 1) -> str:
    if v is None:
        return " " * (width - 1) + "—"
    if isinstance(v, float):
        return f"{v:{width}.{prec}f}"
    return f"{v:{width}d}"


FL_HEADER = (f"{'round':>5s} {'t_sim':>10s} {'bytes_up':>12s} "
             f"{'active':>6s} {'lost':>5s} {'stale':>6s} "
             f"{'error':>12s}")
ENG_HEADER = (f"{'round':>5s} {'t0':>10s} {'duration':>10s} "
              f"{'sched':>6s} {'deliv':>6s} {'lost':>5s} "
              f"{'bytes_air':>12s} {'engine':>7s}")


def fl_row(r: dict) -> str:
    """One ``fl_round`` record as a table row (shared with ``watch``)."""
    err = r.get("error")
    return (f"{r['round']:5d} {_fmt(r.get('t'), 10)} "
            f"{_fmt(r.get('bytes_up'), 12, 0)} "
            f"{_fmt(r.get('n_active'), 6)} {_fmt(r.get('n_lost', 0), 5)} "
            f"{_fmt(r.get('staleness'), 6, 2)} "
            + (f"{err:12.6f}" if err is not None else f"{'—':>12s}"))


def eng_row(r: dict) -> str:
    """One engine ``round`` record as a table row (shared with ``watch``)."""
    return (f"{r['round']:5d} {r['t0']:10.1f} {r['duration']:10.1f} "
            f"{r['n_scheduled']:6d} {r['n_delivered']:6d} "
            f"{r['n_lost']:5d} {r['bytes_air']:12.0f} "
            f"{r.get('engine', '?'):>7s}")


def render_rounds(records: Sequence[dict]) -> str:
    """Per-round summary table: federated ``fl_round`` records when the
    trace has them (bytes/error/staleness), engine ``round`` records
    otherwise."""
    fl = of_kind(records, "fl_round")
    if fl:
        return "\n".join([FL_HEADER] + [fl_row(r) for r in fl])
    rounds = of_kind(records, "round")
    if not rounds:
        return "(no rounds recorded)"
    return "\n".join([ENG_HEADER] + [eng_row(r) for r in rounds])


# ---------------------------------------------------------------------------
# series extraction (schema v2) + machine-readable summary
# ---------------------------------------------------------------------------

def extract_series(records: Sequence[dict]) -> Dict[str, dict]:
    """Group ``series`` records into ``{name: {"steps": [...],
    "values": [...]}}`` curves, step-ordered.

    Schema-v1 traces predate the ``series`` kind; for those the
    federated curves are synthesized from the ``fl_round`` records
    (``e_K`` from non-null errors, ``bytes_up``, ``staleness``), so the
    ledger and the convergence gate read old and new traces alike.
    """
    out: Dict[str, dict] = {}
    for r in records:
        if r.get("kind") != "series":
            continue
        s = out.setdefault(r["name"], {"steps": [], "values": []})
        s["steps"].append(r["step"])
        s["values"].append(r["value"])
    if not out:      # v1 fallback: derive the federated curves
        for r in of_kind(records, "fl_round"):
            for name, val in (("e_K", r.get("error")),
                              ("bytes_up", r.get("bytes_up")),
                              ("staleness", r.get("staleness"))):
                if val is None:
                    continue
                s = out.setdefault(name, {"steps": [], "values": []})
                s["steps"].append(r["round"])
                s["values"].append(val)
    for s in out.values():
        order = sorted(range(len(s["steps"])), key=s["steps"].__getitem__)
        s["steps"] = [s["steps"][i] for i in order]
        s["values"] = [s["values"][i] for i in order]
    return out


def summarize_dict(records: Sequence[dict]) -> dict:
    """Machine-readable trace summary (``repro.obs summarize --json``).

    The single structured view of a trace: header meta, per-round
    records, delivery/async totals, extracted series curves, and the
    metrics snapshot.  :mod:`repro.obs.ledger` ingests exactly this
    (plus a run id), and :mod:`repro.obs.report` renders from it — no
    screen-scraping of the human table anywhere.
    """
    header = records[0] if records and records[0].get("kind") == "header" \
        else {}
    meta = {k: v for k, v in header.items()
            if k not in ("kind", "schema", "n_events", "streamed")}
    fl = of_kind(records, "fl_round")
    eng = of_kind(records, "round")
    rounds = fl or eng
    deliveries = of_kind(records, "delivery")
    out = {
        "schema": header.get("schema"),
        "meta": meta,
        "round_kind": "fl_round" if fl else ("round" if eng else None),
        "n_rounds": len(rounds),
        "rounds": [dict(r) for r in rounds],
        "series": extract_series(records),
        "async_runs": [dict(r) for r in of_kind(records, "async_run")],
        "counters": {}, "histograms": {},
    }
    if deliveries:
        lat = [d["t_done"] - d["t_start"] for d in deliveries]
        out["deliveries"] = {
            "n": len(deliveries),
            "lost": sum(not d["delivered"] for d in deliveries),
            "retx_rounds": sum(d["retries"] for d in deliveries),
            "bytes_air": sum(d["nbytes_attempted"] for d in deliveries),
            "latency_min": min(lat), "latency_max": max(lat),
            "latency_mean": sum(lat) / len(lat),
        }
    else:
        out["deliveries"] = None
    for r in records:
        if r.get("kind") == "metrics":
            out["counters"] = r.get("counters", {})
            out["histograms"] = r.get("histograms", {})
    # final-state convenience block: what the run ledger keys on
    final: dict = {"rounds": len(rounds)}
    if fl:
        last = fl[-1]
        errs = [r["error"] for r in fl if r.get("error") is not None]
        final.update(
            e_K=errs[-1] if errs else None,
            bytes_up=last.get("bytes_up"),
            t=last.get("t"),
            n_lost=sum(r.get("n_lost", 0) or 0 for r in fl),
            n_active=sum(r.get("n_active", 0) or 0 for r in fl),
            mode=last.get("mode"))
    elif eng:
        final.update(
            bytes_air=sum(r["bytes_air"] for r in eng),
            n_delivered=sum(r["n_delivered"] for r in eng),
            n_lost=sum(r["n_lost"] for r in eng))
    out["final"] = final
    return out


def summarize(records: Sequence[dict]) -> str:
    """Full human-readable trace summary."""
    out = [render_rounds(records)]
    deliveries = of_kind(records, "delivery")
    if deliveries:
        lost = sum(not d["delivered"] for d in deliveries)
        retx = sum(d["retries"] for d in deliveries)
        air = sum(d["nbytes_attempted"] for d in deliveries)
        lat = [d["t_done"] - d["t_start"] for d in deliveries]
        out.append(
            f"deliveries: {len(deliveries)} ({lost} lost, {retx} retx "
            f"rounds)  air bytes: {air:.0f}  "
            f"latency s: min {min(lat):.1f} / mean "
            f"{sum(lat) / len(lat):.1f} / max {max(lat):.1f}")
    runs = of_kind(records, "async_run")
    for r in runs:
        out.append(f"async run: {r['n_ok']}/{r['n_deliveries']} delivered "
                   f"ok, air bytes {r['bytes_air']:.0f}, "
                   f"t_end {r['t_end']:.1f}s")
    series = {r["name"] for r in records if r.get("kind") == "series"}
    if series:
        named = extract_series(records)
        out.append("series: " + "  ".join(
            f"{n}[{len(named[n]['steps'])}]"
            f"→{named[n]['values'][-1]:.6g}" for n in sorted(series)))
    kernels = of_kind(records, "kernel")
    if kernels:
        per: dict = {}
        for k in kernels:
            n, s = per.get(k["name"], (0, 0.0))
            per[k["name"]] = (n + 1, s + k["dur_host"])
        out.append("kernel dispatches: " + "  ".join(
            f"{name}×{n} ({s * 1e3:.1f}ms)"
            for name, (n, s) in sorted(per.items())))
    for r in records:
        if r.get("kind") == "metrics":
            cs = r.get("counters", {})
            if cs:
                out.append("counters: " + "  ".join(
                    f"{k}={v['total']:.0f}" for k, v in sorted(cs.items())))
            hs = r.get("histograms", {})
            if hs:
                out.append("histograms: " + "  ".join(
                    f"{k}(n={v['count']}, mean={v['mean']:.2f})"
                    for k, v in sorted(hs.items())))
    return "\n".join(out)


# ---------------------------------------------------------------------------
# diff
# ---------------------------------------------------------------------------

def _strip(r: dict, ignore: Tuple[str, ...]) -> dict:
    return {k: v for k, v in r.items() if k not in ignore}


def diff(a: Sequence[dict], b: Sequence[dict],
         kinds: Optional[Sequence[str]] = None,
         ignore: Tuple[str, ...] = DIFF_IGNORE) -> Tuple[bool, str]:
    """Ordered comparison of the selected event kinds of two traces.

    Returns ``(equal, report)``; on divergence the report names the first
    differing record index (within the filtered stream), its kind, and
    the field-level delta — the trace-level replacement for hand-diffing
    Delivery lists when the fast engine and the heapq oracle disagree.
    """
    kinds = tuple(kinds) if kinds is not None else DIFF_KINDS
    ra = of_kind(a, *kinds)
    rb = of_kind(b, *kinds)
    for i, (x, y) in enumerate(zip(ra, rb)):
        sx, sy = _strip(x, ignore), _strip(y, ignore)
        if sx == sy:
            continue
        fields = sorted(set(sx) | set(sy))
        delta = [f"    {f}: {sx.get(f, '<absent>')!r} != "
                 f"{sy.get(f, '<absent>')!r}"
                 for f in fields if sx.get(f) != sy.get(f)]
        return False, (
            f"DIVERGED at record {i} (kind={x.get('kind')}"
            + (f", round={x.get('round')}" if x.get("round") is not None
               else "") + "):\n" + "\n".join(delta))
    if len(ra) != len(rb):
        longer = "A" if len(ra) > len(rb) else "B"
        extra = (ra if len(ra) > len(rb) else rb)[min(len(ra), len(rb))]
        return False, (
            f"DIVERGED: record counts differ ({len(ra)} vs {len(rb)}); "
            f"first extra record in {longer} is kind={extra.get('kind')!r}")
    return True, f"identical: {len(ra)} records across kinds {list(kinds)}"


# ---------------------------------------------------------------------------
# check
# ---------------------------------------------------------------------------

def check(records: Sequence[dict]) -> List[str]:
    """Trace invariants; returns violation messages (empty = clean).

    1. **bytes conservation** — each engine ``round`` record's
       ``bytes_air`` equals the sum of its delivery records'
       ``nbytes_attempted`` (likewise ``async_run``);
    2. delivery/round count consistency (``n_delivered``/``n_lost``);
    3. deliveries are time-ordered and fit inside their round;
    4. a failed delivery carries zero payload bytes.
    """
    bad: List[str] = []
    by_round: dict = {}
    async_dlv: List[dict] = []
    for d in of_kind(records, "delivery"):
        if d.get("round") is None:
            async_dlv.append(d)
        else:
            by_round.setdefault(d["round"], []).append(d)

    def close(a: float, b: float) -> bool:
        return a == b or math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-6)

    for r in of_kind(records, "round"):
        k = r["round"]
        dlv = by_round.get(k, [])
        air = sum(d["nbytes_attempted"] for d in dlv)
        if not close(air, r["bytes_air"]):
            bad.append(f"round {k}: bytes conservation violated — "
                       f"sum(delivery nbytes_attempted)={air!r} != "
                       f"round bytes_air={r['bytes_air']!r}")
        n_ok = sum(d["delivered"] for d in dlv)
        n_lost = sum(not d["delivered"] for d in dlv)
        if n_ok != r["n_delivered"] or n_lost != r["n_lost"]:
            bad.append(f"round {k}: delivery counts inconsistent — "
                       f"{n_ok} ok/{n_lost} lost in records vs "
                       f"n_delivered={r['n_delivered']}/"
                       f"n_lost={r['n_lost']}")
        t_end = r["t0"] + r["duration"]
        prev = -math.inf
        for d in dlv:
            if d["t_done"] < prev:
                bad.append(f"round {k}: deliveries out of time order "
                           f"(sat {d['sat']} at {d['t_done']})")
            prev = d["t_done"]
            if d["t_done"] > t_end + 1e-6:
                bad.append(f"round {k}: delivery of sat {d['sat']} at "
                           f"{d['t_done']} past round end {t_end}")
            if d["t_done"] < d["t_start"]:
                bad.append(f"round {k}: sat {d['sat']} delivered before "
                           f"it started training")
    for r in of_kind(records, "async_run"):
        air = sum(d["nbytes_attempted"] for d in async_dlv)
        if not close(air, r["bytes_air"]):
            bad.append(f"async run: bytes conservation violated — "
                       f"{air!r} != {r['bytes_air']!r}")
        n_ok = sum(d["delivered"] for d in async_dlv)
        if n_ok != r["n_ok"]:
            bad.append(f"async run: {n_ok} delivered in records vs "
                       f"n_ok={r['n_ok']}")
    for d in of_kind(records, "delivery"):
        if not d["delivered"] and d["nbytes"] != 0.0:
            bad.append(f"delivery sat {d['sat']} failed but carries "
                       f"nbytes={d['nbytes']}")
    prev_up = -math.inf
    for r in of_kind(records, "fl_round"):
        if r["bytes_up"] < prev_up:
            bad.append(f"fl_round {r['round']}: cumulative bytes_up "
                       f"decreased ({r['bytes_up']} < {prev_up})")
        prev_up = r["bytes_up"]
    return bad
