"""Structured tracer: typed event records with a zero-cost disabled path.

One :class:`Tracer` is active at a time (module global ``TRACER``); hot
paths read it ONCE per round into a local and branch on ``None`` — the
entire disabled-mode cost is that attribute read, which is why the
``sim.trace_overhead`` bench can show tracing-disabled rounds at parity
with the pre-instrumentation engine (the existing ``sim.fast_round``
gates double as the disabled-overhead regression gate: they time the
instrumented engine with the tracer off against the committed baseline).

Events are plain dicts with a ``kind`` field, buffered in memory and
flushed as JSONL (first record is a schema header, last is the
:class:`~repro.obs.metrics.Metrics` snapshot).  Two clocks coexist:

* **sim time** — event fields named ``t``/``t0``/``t_done`` carry
  simulated seconds (the engine's clock);
* **host time** — :meth:`Tracer.span` records wall-clock begin/duration
  (``t_host``/``dur_host`` seconds since tracer start) for stage timings
  (uplink encode, aggregation, kernel dispatches).

Event kinds emitted by the instrumented stack:

    ``round``      one engine sync round (t0, duration, counts, air bytes)
    ``delivery``   one :class:`repro.sim.engine.Delivery` (``to_dict``)
    ``arq``        a delivery that needed retransmissions or was lost
    ``cohort``     one contact-window delivery cohort
    ``async_run``  summary of one ``Engine.run_async`` stream
    ``fl_round``   one federated round (SpaceRunner: bytes, error, staleness)
    ``ef_revert``  loss-robust EF revert (lost sats + residual norm)
    ``kernel``     one kernel-dispatch span (repro.kernels.ops)
    ``span``       generic host-time stage span
    ``link``       channel link-budget sample (elevation, fade, p_seg)
    ``outage``     blocked-window refresh summary per station

``trace-diff`` (:mod:`repro.obs.summary`) compares the deterministic
sim-schema kinds (round/delivery/arq/cohort) and ignores host-timing
fields, so fast-vs-oracle engine traces diff clean whenever the Delivery
timelines agree — and localize the FIRST diverging record when they
don't.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import List, Optional

from .metrics import Metrics

SCHEMA_VERSION = 1

# the active tracer; hot paths read this once per round via active()
TRACER: Optional["Tracer"] = None
_STACK: List["Tracer"] = []

# host-timing fields trace-diff must ignore (nondeterministic wall clock)
HOST_FIELDS = ("t_host", "dur_host")


class Tracer:
    """In-memory event buffer + metrics registry with JSONL flush.

    ``path=None`` keeps everything in memory (tests, overhead benches);
    a path writes JSONL on :meth:`flush` / :meth:`close`.
    """

    __slots__ = ("events", "metrics", "path", "meta", "_t0_host", "_closed")

    def __init__(self, path: Optional[str] = None, **meta):
        self.events: List[dict] = []
        self.metrics = Metrics()
        self.path = path
        self.meta = meta
        self._t0_host = time.perf_counter()
        self._closed = False

    # -- emission ----------------------------------------------------------
    def event(self, kind: str, **fields) -> None:
        """Record one typed event (fields must be JSON-serializable)."""
        fields["kind"] = kind
        self.events.append(fields)

    def raw(self, record: dict) -> None:
        """Record a pre-built event dict (must carry ``kind``)."""
        self.events.append(record)

    def host_now(self) -> float:
        return time.perf_counter() - self._t0_host

    @contextlib.contextmanager
    def span(self, kind: str, **fields):
        """Host-time stage span: records begin + duration on exit."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            fields["kind"] = kind
            fields["t_host"] = t0 - self._t0_host
            fields["dur_host"] = time.perf_counter() - t0
            self.events.append(fields)

    # -- output ------------------------------------------------------------
    def records(self) -> List[dict]:
        """Header + events + metrics snapshot — what :meth:`flush` writes,
        and what :mod:`repro.obs.summary` consumes directly in-memory."""
        header = {"kind": "header", "schema": SCHEMA_VERSION,
                  "n_events": len(self.events)}
        header.update(self.meta)
        out = [header]
        out.extend(self.events)
        m = self.metrics.to_dict()
        if m["counters"] or m["histograms"]:
            out.append({"kind": "metrics", **m})
        return out

    def flush(self) -> Optional[str]:
        """Write the JSONL file (no-op without a path); returns the path."""
        if self.path is None:
            return None
        with open(self.path, "w") as f:
            for rec in self.records():
                f.write(json.dumps(rec, sort_keys=True,
                                   allow_nan=False) + "\n")
        return self.path

    def close(self) -> Optional[str]:
        if self._closed:
            return self.path
        self._closed = True
        return self.flush()


def active() -> Optional[Tracer]:
    """The active tracer, or None (read once per round, not per event)."""
    return TRACER


def enable(path: Optional[str] = None, **meta) -> Tracer:
    """Install a fresh tracer as the active one (stackable: ``disable``
    restores whatever was active before)."""
    global TRACER
    t = Tracer(path, **meta)
    _STACK.append(t)
    TRACER = t
    return t


def disable() -> Optional[Tracer]:
    """Close the active tracer (flushing to its path, if any) and restore
    the previously active one.  Returns the closed tracer."""
    global TRACER
    if not _STACK:
        return None
    t = _STACK.pop()
    t.close()
    TRACER = _STACK[-1] if _STACK else None
    return t


@contextlib.contextmanager
def tracing(path: Optional[str] = None, **meta):
    """``with tracing("run.jsonl") as trc: ...`` — enable/flush scoped."""
    t = enable(path, **meta)
    try:
        yield t
    finally:
        disable()


def load(path: str) -> List[dict]:
    """Read a JSONL trace file back into a record list."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records
