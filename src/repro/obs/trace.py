"""Structured tracer: typed event records with a zero-cost disabled path.

One :class:`Tracer` is active at a time (module global ``TRACER``); hot
paths read it ONCE per round into a local and branch on ``None`` — the
entire disabled-mode cost is that attribute read, which is why the
``sim.trace_overhead`` bench can show tracing-disabled rounds at parity
with the pre-instrumentation engine (the existing ``sim.fast_round``
gates double as the disabled-overhead regression gate: they time the
instrumented engine with the tracer off against the committed baseline).

Events are plain dicts with a ``kind`` field, buffered in memory and
flushed as JSONL (first record is a schema header, last is the
:class:`~repro.obs.metrics.Metrics` snapshot).  Paths ending in ``.gz``
are gzip-compressed transparently, on write and on :func:`load` — the
mega-1000 traces CI uploads shrink ~20x.  Two clocks coexist:

* **sim time** — event fields named ``t``/``t0``/``t_done`` carry
  simulated seconds (the engine's clock);
* **host time** — :meth:`Tracer.span` records wall-clock begin/duration
  (``t_host``/``dur_host`` seconds since tracer start) for stage timings
  (uplink encode, aggregation, kernel dispatches).

Event kinds emitted by the instrumented stack:

    ``round``      one engine sync round (t0, duration, counts, air bytes)
    ``delivery``   one :class:`repro.sim.engine.Delivery` (``to_dict``)
    ``arq``        a delivery that needed retransmissions or was lost
    ``cohort``     one contact-window delivery cohort
    ``async_run``  summary of one ``Engine.run_async`` stream
    ``fl_round``   one federated round (SpaceRunner: bytes, error, staleness)
    ``ef_revert``  loss-robust EF revert (lost sats + residual norm)
    ``ef_resync``  crash residual re-sync (crashed sats rebooted with an
                   empty EF cache — see :mod:`repro.faults`)
    ``fault``      one injected fault (sat crash, per :mod:`repro.faults`)
    ``head_failover``  a cluster-head failure mid-convergecast: salvage
                   counts + the re-elected head (``repro.sim.topology``)
    ``resume``     a crash-consistent restart from a run checkpoint
                   (:mod:`repro.checkpoint.run`)
    ``kernel``     one kernel-dispatch span (repro.kernels.ops)
    ``span``       generic host-time stage span
    ``link``       channel link-budget sample (elevation, fade, p_seg)
    ``outage``     blocked-window refresh summary per station
    ``series``     one (name, step, value) time-series sample — the
                   per-round convergence/byte curves the run ledger
                   (:mod:`repro.obs.ledger`) folds into cross-run tables
                   and the ``convgate`` CI gate compares (schema v2)
    ``phase``      per-(round, phase-path) wall-time rollup and
    ``phase_total``  the round's measured wall — the phase-attribution
                   profiler (:mod:`repro.obs.prof`); host timing, so
                   neither is a trace-diff kind

``trace-diff`` (:mod:`repro.obs.summary`) compares the deterministic
sim-schema kinds (round/delivery/arq/cohort) and ignores host-timing
fields, so fast-vs-oracle engine traces diff clean whenever the Delivery
timelines agree — and localize the FIRST diverging record when they
don't.

Two buffering modes:

* the default buffers every record in memory until :meth:`flush` /
  :meth:`close` rewrites the whole file — what short runs and the
  overhead bench use (no I/O inside the timed region);
* ``stream_every=N`` appends to the file every N buffered records and
  drops them from memory, so week-long async mega runs trace with
  bounded memory; the header goes out first, the metrics snapshot last
  (on :meth:`close`), exactly like the buffered layout, and
  ``repro.obs watch`` tails the growing file from a separate process.
"""
from __future__ import annotations

import contextlib
import gzip
import json
import time
from typing import IO, List, Optional

from .metrics import Metrics
from .prof import PhaseAcc

# v1: header/event/metrics records.  v2 adds the ``series`` record kind
# (additive — every v1 record reads unchanged; `tests/data/
# trace_schema_v1.jsonl` pins the compatibility).
SCHEMA_VERSION = 2

# the active tracer; hot paths read this once per round via active()
TRACER: Optional["Tracer"] = None
_STACK: List["Tracer"] = []

# host-timing fields trace-diff must ignore (nondeterministic wall clock)
HOST_FIELDS = ("t_host", "dur_host")


def _open(path: str, mode: str) -> IO:
    """Open a trace path, gzip-compressed when it ends in ``.gz``."""
    if path.endswith(".gz"):
        return gzip.open(path, mode if mode.endswith("t") else mode + "t")
    return open(path, mode)


class Tracer:
    """In-memory event buffer + metrics registry with JSONL flush.

    ``path=None`` keeps everything in memory (tests, overhead benches);
    a path writes JSONL on :meth:`flush` / :meth:`close` (gzip when it
    ends in ``.gz``).  ``stream_every=N`` switches to incremental
    appends: every N records the buffer is written out and cleared, so
    memory stays bounded on long runs (``records()`` then only covers
    the not-yet-flushed tail).
    """

    __slots__ = ("events", "metrics", "prof", "path", "meta",
                 "stream_every", "_t0_host", "_closed", "_fh",
                 "_n_streamed")

    def __init__(self, path: Optional[str] = None,
                 stream_every: Optional[int] = None, **meta):
        if stream_every is not None and path is None:
            raise ValueError("stream_every needs a path to append to")
        self.events: List[dict] = []
        self.metrics = Metrics()
        # phase-attribution accumulator (repro.obs.prof); the engines
        # read it once per round alongside active().  prof_sync=True in
        # the meta additionally times a block-until-ready per kernel
        # dispatch (honest host/device split; changes timing, not
        # results — keep it out of gated benches)
        self.prof = PhaseAcc(sync_device=bool(meta.get("prof_sync")))
        self.path = path
        self.meta = meta
        self.stream_every = stream_every
        self._t0_host = time.perf_counter()
        self._closed = False
        self._fh: Optional[IO] = None
        self._n_streamed = 0

    # -- emission ----------------------------------------------------------
    def event(self, kind: str, **fields) -> None:
        """Record one typed event (fields must be JSON-serializable)."""
        fields["kind"] = kind
        self.events.append(fields)
        if self.stream_every and len(self.events) >= self.stream_every:
            self._stream_out()

    def raw(self, record: dict) -> None:
        """Record a pre-built event dict (must carry ``kind``)."""
        self.events.append(record)
        if self.stream_every and len(self.events) >= self.stream_every:
            self._stream_out()

    def series(self, name: str, step: int, value: float, **labels) -> None:
        """Record one time-series sample: ``(name, step, value)``.

        The per-round curves (``e_K``, ``bytes_up``, ``ef_resid_norm``,
        ``staleness``, …) are emitted through here; the ledger
        (:mod:`repro.obs.ledger`) groups samples by name into
        step-ordered curves for cross-run comparison and the
        convergence gate."""
        rec = {"kind": "series", "name": name, "step": int(step),
               "value": float(value)}
        if labels:
            rec.update(labels)
        self.events.append(rec)
        if self.stream_every and len(self.events) >= self.stream_every:
            self._stream_out()

    def host_now(self) -> float:
        return time.perf_counter() - self._t0_host

    @contextlib.contextmanager
    def span(self, kind: str, **fields):
        """Host-time stage span: records begin + duration on exit."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            fields["kind"] = kind
            fields["t_host"] = t0 - self._t0_host
            fields["dur_host"] = time.perf_counter() - t0
            self.raw(fields)

    # -- output ------------------------------------------------------------
    def _header(self) -> dict:
        header = {"kind": "header", "schema": SCHEMA_VERSION}
        if self.stream_every:
            header["streamed"] = True       # n_events unknown up front
        else:
            header["n_events"] = len(self.events)
        header.update(self.meta)
        return header

    def _metrics_record(self) -> Optional[dict]:
        m = self.metrics.to_dict()
        if m["counters"] or m["histograms"]:
            return {"kind": "metrics", **m}
        return None

    def records(self) -> List[dict]:
        """Header + buffered events + metrics snapshot — what
        :meth:`flush` writes, and what :mod:`repro.obs.summary` consumes
        directly in-memory.  In streaming mode this only covers the
        not-yet-flushed tail; use :func:`load` on the closed file for
        the full record stream."""
        out = [self._header()]
        out.extend(self.events)
        m = self._metrics_record()
        if m is not None:
            out.append(m)
        return out

    def _stream_out(self) -> None:
        """Append the buffered events to the file and drop them (the
        bounded-memory path; header goes out first, exactly once)."""
        if self._fh is None:
            self._fh = _open(self.path, "wt")
            self._fh.write(json.dumps(self._header(), sort_keys=True,
                                      allow_nan=False) + "\n")
        for rec in self.events:
            self._fh.write(json.dumps(rec, sort_keys=True,
                                      allow_nan=False) + "\n")
        self._n_streamed += len(self.events)
        self.events.clear()

    def flush(self) -> Optional[str]:
        """Write the JSONL file (no-op without a path); returns the path.

        Buffered mode rewrites the whole file; streaming mode appends
        whatever is pending and flushes the handle (the metrics snapshot
        is only written by :meth:`close`)."""
        if self.path is None:
            return None
        if self.stream_every:
            self._stream_out()
            self._fh.flush()
            return self.path
        with _open(self.path, "wt") as f:
            for rec in self.records():
                f.write(json.dumps(rec, sort_keys=True,
                                   allow_nan=False) + "\n")
        return self.path

    def close(self) -> Optional[str]:
        if self._closed:
            return self.path
        self._closed = True
        if self.stream_every and self.path is not None:
            self._stream_out()
            m = self._metrics_record()
            if m is not None:
                self._fh.write(json.dumps(m, sort_keys=True,
                                          allow_nan=False) + "\n")
            self._fh.close()
            self._fh = None
            return self.path
        return self.flush()


def active() -> Optional[Tracer]:
    """The active tracer, or None (read once per round, not per event)."""
    return TRACER


def enable(path: Optional[str] = None,
           stream_every: Optional[int] = None, **meta) -> Tracer:
    """Install a fresh tracer as the active one (stackable: ``disable``
    restores whatever was active before)."""
    global TRACER
    t = Tracer(path, stream_every=stream_every, **meta)
    _STACK.append(t)
    TRACER = t
    return t


def disable() -> Optional[Tracer]:
    """Close the active tracer (flushing to its path, if any) and restore
    the previously active one.  Returns the closed tracer."""
    global TRACER
    if not _STACK:
        return None
    t = _STACK.pop()
    t.close()
    TRACER = _STACK[-1] if _STACK else None
    return t


@contextlib.contextmanager
def tracing(path: Optional[str] = None,
            stream_every: Optional[int] = None, **meta):
    """``with tracing("run.jsonl") as trc: ...`` — enable/flush scoped."""
    t = enable(path, stream_every=stream_every, **meta)
    try:
        yield t
    finally:
        disable()


def load(path: str) -> List[dict]:
    """Read a JSONL trace file back into a record list (``.gz`` ok).

    Tolerates a truncated FINAL line — the signature a streaming writer
    leaves when its process is killed mid-append: the valid prefix is
    returned with a :class:`UserWarning` instead of raising
    ``JSONDecodeError``, so ``summarize`` / ``watch`` / ``ingest`` can
    still read everything the run managed to record.  A malformed line
    anywhere *before* the last one is real corruption and still raises."""
    records = []
    with _open(path, "rt") as f:
        lines = [ln for ln in (ln.strip() for ln in f) if ln]
    for i, line in enumerate(lines):
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                import warnings
                warnings.warn(
                    f"{path}: truncated final record dropped (writer "
                    f"killed mid-append?) — recovered {len(records)} "
                    f"records", stacklevel=2)
                break
            raise
    return records
