"""The paper's experimental problem (§3, eq. (2)).

Regularized logistic regression over N agents:

    f_i(x) = (1/m_i) Σ_h log(1 + exp(−b_{i,h} · a_{i,h}ᵀ x)) + ε/(2N)·‖x‖²

with ε = 50, m_i = 500, n = 100, N = 100, randomly generated data.

Also provides a Newton solver for the *global* optimum x̄ of Σ_i f_i (the
reference point of the optimality-error metric e_k = Σ_i ‖x_{i,k} − x̄‖²).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def generate(key, *, n_agents: int = 100, m: int = 500, dim: int = 100,
             label_noise: float = 0.05, feature_scale: float = 1.0):
    """Random data: features ~ N(0, scale²·I), labels from a planted model."""
    k_a, k_w, k_flip = jax.random.split(key, 3)
    a = feature_scale * jax.random.normal(k_a, (n_agents, m, dim))
    w_true = jax.random.normal(k_w, (dim,))
    logits = jnp.einsum("imd,d->im", a, w_true)
    b = jnp.sign(logits + 1e-12)
    flip = jax.random.bernoulli(k_flip, label_noise, b.shape)
    b = jnp.where(flip, -b, b)
    return {"a": a, "b": b}, w_true


def make_local_loss(eps: float = 50.0, n_agents: int = 100):
    """Returns loss(params, data_i) for one agent (data_i: a (m,d), b (m,))."""

    def loss(x, data_i):
        margins = data_i["b"] * (data_i["a"] @ x)
        return jnp.mean(jnp.log1p(jnp.exp(-margins))) + eps / (2.0 * n_agents) * jnp.sum(x * x)

    return loss


def solve_global(data, eps: float = 50.0, iters: int = 50) -> jnp.ndarray:
    """Newton's method on F(x) = Σ_i f_i(x); returns x̄.

    Σ_i f_i(x) = Σ_i mean_h ℓ(x; a, b) + (ε/2)‖x‖² — smooth + strongly
    convex, Newton converges in a handful of steps for n = 100.
    """
    a = data["a"].reshape(-1, data["a"].shape[-1])   # (N·m, d)
    b = data["b"].reshape(-1)
    n_agents, m = data["a"].shape[0], data["a"].shape[1]
    d = a.shape[-1]

    def newton_step(x, _):
        margins = b * (a @ x)
        s = jax.nn.sigmoid(-margins)            # ℓ'(t) = −σ(−t), t = b aᵀx
        # gradient of Σ_i mean_h: each agent mean over its own m ⇒ 1/m per row
        g = -(a.T @ (b * s)) / m + eps * x
        w = s * (1.0 - s) / m                    # ℓ'' weights
        H = (a.T * w) @ a + eps * jnp.eye(d)
        return x - jnp.linalg.solve(H, g), jnp.linalg.norm(g)

    x0 = jnp.zeros((d,))
    x, gnorms = jax.lax.scan(newton_step, x0, None, length=iters)
    return x
