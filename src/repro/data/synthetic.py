"""Synthetic data pipeline for the LM architectures.

Deterministic per-agent token streams (seeded by agent id + step) so the
federated simulation is reproducible and shardable.  The "task" is a learnable
synthetic language: tokens follow a random order-2 Markov chain per agent
(heterogeneous across agents — exactly the federated setting), so models can
actually reduce loss and training curves are meaningful.

For VLM/audio stubs, :func:`make_batch` also emits the precomputed
frame/patch embeddings (the modality frontend carve-out in the brief).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


def markov_tokens(key, batch: int, seq: int, vocab: int, order_states: int = 64):
    """Sample from a random sparse transition table (shared per key)."""
    k_tab, k_init, k_samp = jax.random.split(key, 3)
    v_eff = min(vocab, 4096)  # transition table over a clamped vocab
    table = jax.random.dirichlet(k_tab, jnp.ones((v_eff,)) * 0.05,
                                 shape=(order_states,))
    state0 = jax.random.randint(k_init, (batch,), 0, order_states)

    def step(state, k):
        probs = table[state]                         # (B, v_eff)
        tok = jax.random.categorical(k, jnp.log(probs + 1e-9), axis=-1)
        new_state = (state * 31 + tok) % order_states
        return new_state, tok

    keys = jax.random.split(k_samp, seq)
    _, toks = jax.lax.scan(step, state0, keys)
    return jnp.transpose(toks).astype(jnp.int32)     # (B, S)


def make_batch(cfg: ModelConfig, key, batch: int, seq: int,
               vision_frac: float = 0.25):
    """Training batch for one agent. Returns the dict `forward` expects."""
    if cfg.arch_type == "vlm":
        s_vis = int(seq * vision_frac)
        s_txt = seq - s_vis
        k1, k2 = jax.random.split(key)
        tokens = markov_tokens(k1, batch, s_txt, cfg.vocab_size)
        vis = jax.random.normal(k2, (batch, s_vis, cfg.d_model),
                                jnp.dtype(cfg.dtype)) * 0.02
        labels = jnp.concatenate(
            [jnp.full((batch, s_vis), -1, jnp.int32), tokens], axis=1)
        pos3 = _mrope_positions(batch, s_vis, s_txt)
        return {"tokens": tokens, "extra_embeds": vis, "labels": labels,
                "positions": pos3}
    if cfg.arch_type == "audio":
        tokens = markov_tokens(key, batch, seq, cfg.vocab_size)
        return {"tokens": tokens, "labels": tokens}
    tokens = markov_tokens(key, batch, seq, cfg.vocab_size)
    return {"tokens": tokens, "labels": tokens}


def _mrope_positions(batch: int, s_vis: int, s_txt: int):
    """Temporal/height/width position streams: a √s_vis×√s_vis image grid
    followed by linear text positions (Qwen2-VL convention, simplified)."""
    side = max(1, int(s_vis ** 0.5))
    idx = jnp.arange(s_vis)
    h = jnp.minimum(idx // side, side - 1)
    w = idx % side
    t_vis = jnp.zeros((s_vis,), jnp.int32)
    t_txt = side + jnp.arange(s_txt)
    pos_t = jnp.concatenate([t_vis, t_txt])
    pos_h = jnp.concatenate([h, t_txt])
    pos_w = jnp.concatenate([w, t_txt])
    pos3 = jnp.stack([pos_t, pos_h, pos_w]).astype(jnp.int32)
    return jnp.broadcast_to(pos3[:, None], (3, batch, s_vis + s_txt))


def agent_batches(cfg: ModelConfig, n_agents: int, batch_per_agent: int,
                  seq: int, round_idx: int, seed: int = 0):
    """Per-agent stacked batch pytree (leading agent axis)."""
    keys = jax.vmap(lambda i: jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), i), round_idx))(
        jnp.arange(n_agents))
    return jax.vmap(lambda k: make_batch(cfg, k, batch_per_agent, seq))(keys)
