"""Wire codecs: compressor output → exact on-wire bytes (paper §2.4).

Every δ-approximate compressor in :mod:`repro.core.compression` maps to a
codec that serializes its *float output* into the bytes a satellite would
actually transmit, and back — losslessly:

=================  ========  =====================================  =============
compressor          codec     wire format                            bits/scalar
=================  ========  =====================================  =============
UniformQuantizer    quant     b-bit level indices bit-packed into    b = ⌈log₂(L+1)⌉
                              uint32 words (b = ⌈log₂(L+1)⌉)
ScaledSign          sign      1 bit/coordinate + one f32 scale       1
TopK / RandD        sparse    k packed ⌈log₂ n⌉-bit indices +        (⌈log₂n⌉+8·itemsize)·k/n
                              k raw values
Identity            dense     raw little-endian floats               8·itemsize
=================  ========  =====================================  =============

Bit-packing runs through the Pallas kernels in
:mod:`repro.kernels.pack_bits` (interpret mode on CPU, compiled on TPU).
Round-trip guarantee: ``codec.decode(codec.encode(C(x))) == C(x)``
bit-exactly, for the matching compressor ``C`` (for ``UniformQuantizer``
this requires ``clip=True`` — an out-of-range lattice point has no index
on the wire, exactly as in :func:`repro.core.compression.quantize_encode`).

``encode`` is host-side serialization (the sparse codec's payload size
depends on the actual nonzero count); use :meth:`WireCodec.tree_nbytes`
for the analytic size under nominal sparsity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compression import (Compressor, Identity, RandD, ScaledSign,
                                TopK, UniformQuantizer, quantize_decode,
                                quantize_encode, wire_index_bits)
from ..kernels.pack_bits import logical_words, pack_bits, unpack_bits
from .message import LeafWire, WireMessage, leaf_header_nbytes


def _interpret(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() != "tpu"


def index_bits(n: int) -> int:
    """Bits needed to address a coordinate in an n-vector."""
    return max(1, math.ceil(math.log2(max(n, 2))))


class WireCodec:
    """Base codec: per-leaf encode/decode + exact byte accounting."""

    kind: str = "?"
    HEADER_EXTRA_NBYTES: int = 0

    # -- per-leaf ---------------------------------------------------------
    def encode_leaf(self, x) -> LeafWire:  # pragma: no cover - abstract
        raise NotImplementedError

    def decode_leaf(self, lw: LeafWire):   # pragma: no cover - abstract
        raise NotImplementedError

    # -- exact accounting -------------------------------------------------
    def leaf_header_nbytes(self, ndim: int) -> int:
        return leaf_header_nbytes(ndim, self.HEADER_EXTRA_NBYTES)

    def leaf_payload_nbytes(self, n: int, itemsize: int = 4) -> int:
        raise NotImplementedError

    def leaf_nbytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        return (self.leaf_header_nbytes(len(shape))
                + self.leaf_payload_nbytes(n, itemsize))

    def wire_bits_per_scalar_measured(self, n: int, itemsize: int = 4
                                      ) -> float:
        """Exact bits/scalar of an n-vector leaf, headers included."""
        return 8.0 * self.leaf_nbytes((n,), itemsize) / n

    # -- pytree -----------------------------------------------------------
    def encode(self, tree) -> WireMessage:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return WireMessage([self.encode_leaf(x) for x in leaves], treedef)

    def decode(self, msg: WireMessage):
        return jax.tree_util.tree_unflatten(
            msg.treedef, [self.decode_leaf(lw) for lw in msg.leaves])

    def tree_nbytes(self, tree) -> int:
        """Analytic on-wire size of ``encode(tree)`` under nominal
        sparsity, message header included."""
        from .message import MESSAGE_HEADER_NBYTES
        leaves = jax.tree_util.tree_leaves(tree)
        return MESSAGE_HEADER_NBYTES + sum(
            self.leaf_nbytes(x.shape, x.dtype.itemsize) for x in leaves)


@dataclasses.dataclass(frozen=True)
class QuantCodec(WireCodec):
    """b-bit packed level indices for :class:`UniformQuantizer`.

    Header extras: levels ``u32`` + vmin ``f32`` + vmax ``f32``.
    """

    levels: int = 255
    vmin: float = -1.0
    vmax: float = 1.0
    interpret: Optional[bool] = None

    kind = "quant"
    HEADER_EXTRA_NBYTES = 12

    @property
    def bits(self) -> int:
        return wire_index_bits(self.levels)

    def encode_leaf(self, x) -> LeafWire:
        idx = quantize_encode(x, self.levels, self.vmin,
                              self.vmax).astype(jnp.uint32)
        words = pack_bits(idx, self.bits, interpret=_interpret(self.interpret))
        return LeafWire(self.kind, tuple(x.shape), x.dtype, {"words": words},
                        self.leaf_header_nbytes(x.ndim),
                        self.leaf_payload_nbytes(x.size),
                        meta={"bits": self.bits})

    def decode_leaf(self, lw: LeafWire):
        n = int(np.prod(lw.shape, dtype=np.int64)) if lw.shape else 1
        idx = unpack_bits(lw.payload["words"], self.bits, n,
                          interpret=_interpret(self.interpret))
        return quantize_decode(idx, self.levels, self.vmin, self.vmax,
                               jnp.float32).astype(lw.dtype).reshape(lw.shape)

    def leaf_payload_nbytes(self, n: int, itemsize: int = 4) -> int:
        return 4 * logical_words(n, self.bits)


@dataclasses.dataclass(frozen=True)
class SignCodec(WireCodec):
    """1-bit sign packing for :class:`ScaledSign` (+ one f32 scale).

    Header extras: scale ``f32``.  Requires the binarized sign convention
    ``sign(0) := +1`` (which :class:`ScaledSign` uses), so every
    coordinate is exactly ±scale and one bit round-trips it.
    """

    interpret: Optional[bool] = None

    kind = "sign"
    HEADER_EXTRA_NBYTES = 4

    def encode_leaf(self, x) -> LeafWire:
        flat = x.reshape(-1)
        scale = jnp.max(jnp.abs(flat)).astype(jnp.float32)
        bit = (flat > 0).astype(jnp.uint32)
        words = pack_bits(bit, 1, interpret=_interpret(self.interpret))
        return LeafWire(self.kind, tuple(x.shape), x.dtype,
                        {"words": words, "scale": scale},
                        self.leaf_header_nbytes(x.ndim),
                        self.leaf_payload_nbytes(x.size),
                        meta={"bits": 1})

    def decode_leaf(self, lw: LeafWire):
        n = int(np.prod(lw.shape, dtype=np.int64)) if lw.shape else 1
        bit = unpack_bits(lw.payload["words"], 1, n,
                          interpret=_interpret(self.interpret))
        s = lw.payload["scale"]
        return jnp.where(bit == 1, s, -s).astype(lw.dtype).reshape(lw.shape)

    def leaf_payload_nbytes(self, n: int, itemsize: int = 4) -> int:
        return 4 * logical_words(n, 1)


@dataclasses.dataclass(frozen=True)
class SparseCodec(WireCodec):
    """Index+value packing for :class:`TopK` / :class:`RandD` outputs.

    Indices are bit-packed at ⌈log₂ n⌉ bits through the Pallas kernel;
    values ride raw in the leaf dtype.  ``encode`` measures the *actual*
    nonzero count (host-side), so the accounted bytes are exactly what a
    transmitter would send — ties in TopK or zero-valued kept coordinates
    in RandD shrink the payload below the nominal ``fraction·n``.

    Header extras: k ``u32``.
    """

    fraction: float = 0.1
    interpret: Optional[bool] = None

    kind = "sparse"
    HEADER_EXTRA_NBYTES = 4

    def encode_leaf(self, x) -> LeafWire:
        flat = x.reshape(-1)
        n = flat.size
        nz = np.nonzero(np.asarray(flat))[0].astype(np.uint32)
        k = int(nz.size)
        bits = index_bits(n)
        words = pack_bits(jnp.asarray(nz), bits,
                          interpret=_interpret(self.interpret))
        vals = flat[jnp.asarray(nz, jnp.int32)]
        payload_nbytes = (4 * logical_words(k, bits)
                          + k * x.dtype.itemsize)
        return LeafWire(self.kind, tuple(x.shape), x.dtype,
                        {"words": words, "values": vals},
                        self.leaf_header_nbytes(x.ndim), payload_nbytes,
                        meta={"bits": bits, "k": k})

    def decode_leaf(self, lw: LeafWire):
        n = int(np.prod(lw.shape, dtype=np.int64)) if lw.shape else 1
        k = lw.meta["k"]
        idx = unpack_bits(lw.payload["words"], lw.meta["bits"], k,
                          interpret=_interpret(self.interpret))
        out = jnp.zeros((n,), lw.dtype)
        out = out.at[idx.astype(jnp.int32)].set(lw.payload["values"])
        return out.reshape(lw.shape)

    def leaf_payload_nbytes(self, n: int, itemsize: int = 4) -> int:
        k = max(1, int(round(self.fraction * n)))
        return 4 * logical_words(k, index_bits(n)) + k * itemsize


@dataclasses.dataclass(frozen=True)
class DenseCodec(WireCodec):
    """Raw float serialization for :class:`Identity` (no compression)."""

    kind = "dense"
    HEADER_EXTRA_NBYTES = 0

    def encode_leaf(self, x) -> LeafWire:
        return LeafWire(self.kind, tuple(x.shape), x.dtype,
                        {"raw": x.reshape(-1)},
                        self.leaf_header_nbytes(x.ndim),
                        self.leaf_payload_nbytes(x.size, x.dtype.itemsize))

    def decode_leaf(self, lw: LeafWire):
        return lw.payload["raw"].reshape(lw.shape)

    def leaf_payload_nbytes(self, n: int, itemsize: int = 4) -> int:
        return n * itemsize


def codec_for(compressor: Compressor, *,
              interpret: Optional[bool] = None) -> Optional[WireCodec]:
    """The wire codec matching a compressor (None if it has no codec)."""
    if isinstance(compressor, UniformQuantizer):
        return QuantCodec(compressor.levels, compressor.vmin,
                          compressor.vmax, interpret=interpret)
    if isinstance(compressor, ScaledSign):
        return SignCodec(interpret=interpret)
    if isinstance(compressor, (TopK, RandD)):
        return SparseCodec(compressor.fraction, interpret=interpret)
    if isinstance(compressor, Identity):
        return DenseCodec()
    return None


def measure_tree_bytes(compressor: Compressor, tree, *,
                       interpret: Optional[bool] = None) -> float:
    """Measured on-wire bytes of one message: really encode ``tree``
    through the compressor's codec and count.  Falls back to the nominal
    ``wire_bits_per_scalar`` estimate for compressors without a codec."""
    codec = codec_for(compressor, interpret=interpret)
    if codec is None:
        n = sum(x.size for x in jax.tree_util.tree_leaves(tree))
        return n * compressor.wire_bits_per_scalar() / 8.0
    return float(codec.encode(tree).nbytes)
