"""Wire codec subsystem: real serialization for compressed updates.

The paper's claim (§2.4) is communication *size* reduction; this package
produces the actual bytes.  ``codec_for(compressor)`` returns a
:class:`~repro.wire.codecs.WireCodec` whose ``encode`` turns a compressor
output pytree into a :class:`~repro.wire.message.WireMessage` (packed
uint32 words + exact header/payload byte counts, via the Pallas kernels
in :mod:`repro.kernels.pack_bits`) and whose ``decode`` restores it
bit-exactly.  The constellation simulator derives all transmission times
and ``bytes_up`` accounting from ``WireMessage.nbytes``.
"""
from .codecs import (DenseCodec, QuantCodec, SignCodec, SparseCodec,
                     WireCodec, codec_for, index_bits, measure_tree_bytes)
from .message import (LEAF_HEADER_BASE_NBYTES, MESSAGE_HEADER_NBYTES,
                      SHAPE_DIM_NBYTES, LeafWire, WireMessage)

__all__ = [
    "WireCodec", "QuantCodec", "SignCodec", "SparseCodec", "DenseCodec",
    "codec_for", "measure_tree_bytes", "index_bits",
    "WireMessage", "LeafWire", "MESSAGE_HEADER_NBYTES",
    "LEAF_HEADER_BASE_NBYTES", "SHAPE_DIM_NBYTES",
]
