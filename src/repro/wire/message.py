"""On-wire message container with exact byte accounting (paper §2.4).

A :class:`WireMessage` is what a satellite actually transmits: a small
fixed-size message header, one header per pytree leaf, and the packed
payload arrays.  ``nbytes`` is the canonical on-wire size — every
transmission time and ``bytes_up`` figure in the constellation simulator
derives from it, replacing the nominal ``wire_bits_per_scalar`` estimate.

Byte-accounting convention
--------------------------
* **Message header** (:data:`MESSAGE_HEADER_NBYTES` = 8): magic ``u16``,
  version ``u8``, leaf count ``u8``, total payload length ``u32``.
* **Leaf header**: 4 bytes base (kind ``u8``, ndim ``u8``, bit width
  ``u8``, dtype code ``u8``) + 4 bytes (``u32``) per shape dim + the
  codec's extra fields (quantizer range, sparse k, sign scale …) — see
  each codec's ``HEADER_EXTRA_NBYTES``.
* **Payload**: exact packed size.  Bit-packed streams count
  ``4·b·ceil(n/32)`` bytes (word-aligned groups of 32 values, the layout
  of :mod:`repro.kernels.pack_bits`); tile padding added for kernel
  alignment is memory-layout only and never counted.

The in-memory ``payload`` arrays may be larger than ``payload_nbytes``
(Pallas tile padding); a real transmitter streams exactly the logical
words.  Decoders only ever read the logical region.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

MESSAGE_HEADER_NBYTES = 8
LEAF_HEADER_BASE_NBYTES = 4
SHAPE_DIM_NBYTES = 4


@dataclasses.dataclass
class LeafWire:
    """One encoded pytree leaf: packed payload + exact byte counts."""

    kind: str                       # codec tag: quant | sign | sparse | dense
    shape: Tuple[int, ...]          # original leaf shape
    dtype: Any                      # original leaf dtype
    payload: Dict[str, Any]         # packed arrays (may be tile-padded)
    header_nbytes: int              # exact leaf header size
    payload_nbytes: int             # exact logical payload size
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return self.header_nbytes + self.payload_nbytes


@dataclasses.dataclass
class WireMessage:
    """A fully encoded pytree: ``decode`` restores the compressor output."""

    leaves: List[LeafWire]
    treedef: Any

    @property
    def header_nbytes(self) -> int:
        return MESSAGE_HEADER_NBYTES + sum(l.header_nbytes
                                           for l in self.leaves)

    @property
    def payload_nbytes(self) -> int:
        return sum(l.payload_nbytes for l in self.leaves)

    @property
    def nbytes(self) -> int:
        """Exact on-wire size in bytes (headers + packed payloads)."""
        return MESSAGE_HEADER_NBYTES + sum(l.nbytes for l in self.leaves)


def leaf_header_nbytes(ndim: int, extra: int) -> int:
    """Exact leaf header size for a codec with ``extra`` header bytes."""
    return LEAF_HEADER_BASE_NBYTES + SHAPE_DIM_NBYTES * ndim + extra
