"""Multi-hop ISL routing over the constellation link graph.

Topology: the classic +grid — each satellite keeps ISLs to its two in-plane
ring neighbours and to the same-slot satellites in the two adjacent planes
(wrapping across the seam where the last plane meets plane 0).  This
replaces the seed's hard-coded "2 in-plane neighbours" relay set: any
satellite within ``max_hops`` of a gateway can forward its update.

Shortest-TIME paths (Dijkstra, per-hop cost = ISL latency + serialization
of the message) rather than hop counts, so heterogeneous link models stay
expressible.  ``routes_to_gateways`` is the hot call: one multi-source
Dijkstra from the round's gateway satellites, bounded by ``max_hops``.

Relay accounting (fixes the seed scheduler's bugs):
  * the seed silently capped relays at 2 (``nbrs[: n_relay]`` over a
    2-tuple) — the router reaches ``n_relay`` satellites per gateway for
    any ``n_relay``;
  * the seed charged ``isl + (i + 2) · gs_time`` per relay, double-counting
    time the ISL transfer spends overlapping the gateway's wait/uplink.
    The engine's event loop serializes messages on the GS link explicitly:
    each message transmits exactly once, starting when BOTH the link is
    free and the message has arrived over the ISL.  :func:`gateway_schedule`
    is the analytic form of that serialization (no window truncation or
    cross-gateway station contention) — the reference model the engine's
    event loop is cross-checked against in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from ..constellation.links import LinkModel
from ..constellation.orbits import Walker, isl_neighbors


@dataclasses.dataclass(frozen=True)
class Route:
    gateway: int
    time: float          # total ISL transfer time to the gateway
    hops: int
    path: Tuple[int, ...]  # sat … gateway inclusive


@dataclasses.dataclass(frozen=True)
class Router:
    walker: Walker
    link: LinkModel = LinkModel()
    cross_plane: bool = True
    _cache: dict = dataclasses.field(default_factory=dict, compare=False,
                                     repr=False)

    def neighbors(self, sat: int) -> Tuple[int, ...]:
        key = ("nbrs", sat)
        nbrs = self._cache.get(key)
        if nbrs is None:
            nbrs = isl_neighbors(self.walker, sat, cross_plane=self.cross_plane)
            self._cache[key] = nbrs
        return nbrs

    def hop_time(self, msg_bytes: float) -> float:
        return self.link.isl_time(msg_bytes)

    def shortest_path(self, src: int, dst: int, msg_bytes: float,
                      max_hops: Optional[int] = None) -> Optional[Route]:
        routes = self.routes_to_gateways([dst], msg_bytes, max_hops=max_hops)
        return routes.get(src)

    def routes_to_gateways(self, gateways: Sequence[int], msg_bytes: float,
                           max_hops: Optional[int] = None
                           ) -> Dict[int, Route]:
        """Multi-source shortest-time routes: for every reachable satellite,
        the ISL route to its nearest gateway.

        Per-hop cost is uniform under the current :class:`LinkModel`, so the
        multi-source Dijkstra degenerates to a layered BFS from the gateway
        set — O(V + E) per call, memoized per (gateway set, message size).
        Gateways themselves map to a 0-hop route; expansion stops at
        ``max_hops`` ISL hops from a gateway.
        """
        key = (tuple(sorted(gateways)), float(msg_bytes), max_hops)
        cached = self._cache.get(key)
        if cached is not None:
            return cached
        # bound the memo on long-lived engines: evict oldest route entries
        route_keys = [k for k in self._cache if k[0] != "nbrs"]
        if len(route_keys) >= 256:
            for k in route_keys[:128]:
                del self._cache[k]
        w = self.hop_time(msg_bytes)
        meta: Dict[int, Tuple[int, int, Optional[int]]] = {
            g: (g, 0, None) for g in gateways}          # gateway, hops, pred
        frontier = list(gateways)
        hops = 0
        while frontier and (max_hops is None or hops < max_hops):
            hops += 1
            nxt = []
            for sat in frontier:
                gw = meta[sat][0]
                for nb in self.neighbors(sat):
                    if nb not in meta:
                        meta[nb] = (gw, hops, sat)
                        nxt.append(nb)
            frontier = nxt
        routes = {}
        for sat, (gw, h, _) in meta.items():
            path = [sat]
            while path[-1] != gw:
                path.append(meta[path[-1]][2])
            routes[sat] = Route(gateway=gw, time=h * w, hops=h,
                                path=tuple(path))
        self._cache[key] = routes
        return routes


def gateway_schedule(window_start: float,
                     arrivals: Sequence[Tuple[int, float]],
                     gs_tx: float) -> Dict[int, float]:
    """Serialize one gateway's messages on its GS link — no double counting.

    window_start: when the GS window opens for this gateway;
    arrivals:     (sat, arrival-time-at-gateway) pairs — the gateway's own
                  update (arrival = end of its training) plus forwarded
                  updates (arrival = relay train end + ISL transfer);
    gs_tx:        uplink transmission time of one message.

    Messages transmit back-to-back in arrival order; each charged exactly
    one ``gs_tx``, starting when the link is free AND the message is there.
    Returns {sat: completion time}.  Window-end truncation is the caller's
    (engine's) job — this is the analytic in-window schedule.
    """
    msgs = sorted((a, s) for s, a in arrivals)
    done: Dict[int, float] = {}
    free = window_start
    for arrival, sat in msgs:
        start = max(free, arrival)
        free = start + gs_tx
        done[sat] = free
    return done
