"""Named simulation scenarios — register your own with :func:`register`.

A scenario bundles everything the engine needs: the Walker constellation,
the ground-station set, the link budget, per-satellite compute times, a
weather/dropout model, and (optionally) a stochastic lossy channel
(:class:`repro.channel.ChannelModel`).  Built-ins cover the paper's
default setting plus the harder regimes the realistic-space-scenario
comparison needs:

    walker-kiruna       the seed setting — 100 sats, one polar GS, uniform
                        30 s compute, clear sky (parity baseline)
    dual-station        Kiruna + Svalbard: twice the window supply
    weather-dropout     dual-station with 25 % of contact windows blocked
    hetero-compute      per-satellite compute times spread 15–60 s
                        (deterministic pattern — no RNG in scenario defs)
    mega-1000           1000 sats / 20 planes, three stations, 8 gateways
                        per round — the scale target from the ROADMAP
    mega-10000          10000 sats / 40 planes, 16 gateways per round —
                        the dense mega-constellation regime (bench-only)

  lossy-channel scenarios (``Scenario.channel``, :mod:`repro.channel`):

    lossy-uplink        walker-kiruna over a flat 10 % segment-erasure
                        channel with selective-repeat ARQ (fixed rates) —
                        the loss-robust-EF experiment setting
    rain-fade           dual-station Ka-band: healthy clear-sky margin,
                        but 40 % of windows suffer an exponential rain
                        fade that crushes rate and erasure probability
    ka-band-degraded    walker-kiruna on a marginal Ka-band budget —
                        elevation-dependent rates; low passes are lossy,
                        high passes clean
    conjunction-outage  walker-kiruna with recurring conjunction
                        blackouts masking whole contact windows
    mega-1000-lossy     mega-1000 over a flat 25 % erasure channel with
                        3 ARQ rounds — scale + loss combined, with a real
                        (~14 %) lost-delivery fraction

  fault-injection scenarios (``Scenario.faults``, :mod:`repro.faults`):

    chaos-direct        walker-kiruna with radiation-upset crashes and
                        ground-station blackouts (fault-equivalence smoke)
    chaos-plane         plane aggregation with mid-convergecast head
                        failures → timeout re-election + partial salvage
    chaos-lossy         erasures and crashes composed in one round
    mega-1000-chaos     the headline robustness regime: scale + loss +
                        crashes + station blackouts
    mega-1000-chaos-plane   the same at plane topology with head failover

Usage::

    from repro.sim import get_scenario, Engine
    eng = Engine(get_scenario("dual-station"))

    @register("my-scenario")
    def _my():                      # factory, called per get_scenario()
        return Scenario(name="my-scenario", walker=Walker(n_sats=40), ...)
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from ..channel import (ChannelModel, ConjunctionBlackout, LinkBudget,
                       RainFade, SelectiveRepeatARQ)
from ..constellation.orbits import GroundStation, Walker
from ..faults import FaultModel
from .engine import Scenario

SCENARIOS: Dict[str, Callable[[], Scenario]] = {}

KIRUNA = GroundStation(lat=67.86, lon=20.22)
SVALBARD = GroundStation(lat=78.23, lon=15.39)
INUVIK = GroundStation(lat=68.32, lon=-133.55)


def register(name: str):
    """Decorator: register a zero-arg Scenario factory under ``name``."""
    def deco(fn: Callable[[], Scenario]):
        SCENARIOS[name] = fn
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {names()}")
    return SCENARIOS[name]()


def names() -> List[str]:
    return sorted(SCENARIOS)


@register("walker-kiruna")
def _walker_kiruna() -> Scenario:
    return Scenario(name="walker-kiruna", walker=Walker(), stations=(KIRUNA,))


@register("dual-station")
def _dual_station() -> Scenario:
    return Scenario(name="dual-station", walker=Walker(),
                    stations=(KIRUNA, SVALBARD))


@register("weather-dropout")
def _weather_dropout() -> Scenario:
    return Scenario(name="weather-dropout", walker=Walker(),
                    stations=(KIRUNA, SVALBARD), dropout=0.25)


@register("hetero-compute")
def _hetero_compute() -> Scenario:
    w = Walker()
    # deterministic 15–60 s spread: radiation-tolerant flight computers of
    # five different generations, interleaved across the constellation
    compute = 15.0 + 45.0 * (np.arange(w.n_sats) % 5) / 4.0
    return Scenario(name="hetero-compute", walker=w, stations=(KIRUNA,),
                    compute_time=compute)


@register("mega-1000")
def _mega_1000() -> Scenario:
    return Scenario(name="mega-1000",
                    walker=Walker(n_sats=1000, n_planes=20),
                    stations=(KIRUNA, SVALBARD, INUVIK),
                    k_direct=8, n_relay=4, max_hops=6)


@register("mega-10000")
def _mega_10000() -> Scenario:
    # dense mega-constellation regime (Razmi et al., Matthiesen et al.):
    # 10k sats / 40 planes, three polar stations, 16 gateways per round
    return Scenario(name="mega-10000",
                    walker=Walker(n_sats=10000, n_planes=40),
                    stations=(KIRUNA, SVALBARD, INUVIK),
                    k_direct=16, n_relay=4, max_hops=6)


# ---------------------------------------------------------------------------
# lossy-channel scenarios (repro.channel) — stochastic link impairments
# layered on the contact windows.  All channel elements are deterministic
# functions of (engine seed, station, sat, window), so factories stay
# RNG-free as required.
# ---------------------------------------------------------------------------

@register("lossy-uplink")
def _lossy_uplink() -> Scenario:
    # the loss-robust-EF experiment setting (benchmarks/table_lossy_ef.py):
    # fixed LinkModel rates, flat 10 % segment erasure, selective repeat
    return Scenario(name="lossy-uplink", walker=Walker(), stations=(KIRUNA,),
                    channel=ChannelModel(
                        loss=0.10,
                        arq=SelectiveRepeatARQ(seg_bytes=1024, max_rounds=4)))


@register("rain-fade")
def _rain_fade() -> Scenario:
    # healthy clear-sky Ka-band margin; 40 % of windows carry an
    # exponential rain fade (mean 8 dB) that crushes rate and raises the
    # erasure probability for the whole pass
    return Scenario(name="rain-fade", walker=Walker(),
                    stations=(KIRUNA, SVALBARD),
                    channel=ChannelModel(
                        budget=LinkBudget(eirp_dbw=26.0),
                        rain=RainFade(p_fade=0.4, mean_db=8.0)))


@register("ka-band-degraded")
def _ka_band_degraded() -> Scenario:
    # marginal link budget: the elevation profile dominates — low passes
    # are erasure-heavy and slow, near-zenith passes clean and fast
    return Scenario(name="ka-band-degraded", walker=Walker(),
                    stations=(KIRUNA,),
                    channel=ChannelModel(budget=LinkBudget(eirp_dbw=22.0)))


@register("conjunction-outage")
def _conjunction_outage() -> Scenario:
    # recurring conjunction / maneuver keep-outs: every 3 h the station
    # drops for 25 min, masking every window rising inside the blackout
    return Scenario(name="conjunction-outage", walker=Walker(),
                    stations=(KIRUNA,),
                    channel=ChannelModel(
                        blackout=ConjunctionBlackout(period=3 * 3600.0,
                                                     duration=1500.0)))


# ---------------------------------------------------------------------------
# in-orbit aggregation scenarios (repro.sim.topology) — per-plane
# convergecast to elected cluster heads; one merged wire per plane (or per
# head pair, under gossip) crosses the GS link instead of one per sat
# ---------------------------------------------------------------------------

@register("plane-agg-walker")
def _plane_agg_walker() -> Scenario:
    # the seed geometry with per-plane aggregation: ≤ 10 head uplinks per
    # round instead of k_direct + relays, every member of a live plane
    # participating — the topology-equivalence smoke scenario
    return Scenario(name="plane-agg-walker", walker=Walker(),
                    stations=(KIRUNA,), topology="plane")


@register("plane-agg-gossip")
def _plane_agg_gossip() -> Scenario:
    # plane aggregation + paired inter-head merge: ~half the uplinks again,
    # at the cost of the inter-head ISL transfer
    return Scenario(name="plane-agg-gossip", walker=Walker(),
                    stations=(KIRUNA,), topology="gossip")


@register("plane-agg-lossy")
def _plane_agg_lossy() -> Scenario:
    # plane aggregation over a harsh erasure channel: one segment per
    # typical message and no retransmission, so ~25 % of HEAD wires are
    # destroyed — each loss reverts a whole plane's worth of updates,
    # the stress case for loss-robust EF under mid-route aggregation
    return Scenario(name="plane-agg-lossy", walker=Walker(),
                    stations=(KIRUNA,), topology="plane",
                    channel=ChannelModel(
                        loss=0.25,
                        arq=SelectiveRepeatARQ(seg_bytes=16384,
                                               max_rounds=1)))


@register("mega-1000-plane")
def _mega_1000_plane() -> Scenario:
    # the mega-1000 regime aggregated in orbit: ≤ 20 head uplinks carry
    # all 1000 updates — the bytes-to-ground headline of
    # benchmarks/table_plane_agg.py
    return Scenario(name="mega-1000-plane",
                    walker=Walker(n_sats=1000, n_planes=20),
                    stations=(KIRUNA, SVALBARD, INUVIK),
                    max_hops=6, topology="plane")


@register("mega-1000-lossy")
def _mega_1000_lossy() -> Scenario:
    # scale + loss combined: the mega-1000 regime over a flat 25 %
    # erasure channel with 3 ARQ rounds (bench_lossy_round's headline
    # scenario).  The original 10 %/4-round setting had a per-delivery
    # loss probability of ~1e-3 — the bench's lost_frac sat at exactly
    # 0.0, so the loss-revert path was never exercised at scale; at
    # 25 %/3 rounds roughly one delivery in seven is lost (asserted >0
    # in the bench) while most of the fleet still lands.
    return Scenario(name="mega-1000-lossy",
                    walker=Walker(n_sats=1000, n_planes=20),
                    stations=(KIRUNA, SVALBARD, INUVIK),
                    k_direct=8, n_relay=4, max_hops=6,
                    channel=ChannelModel(
                        loss=0.25,
                        arq=SelectiveRepeatARQ(seg_bytes=1024, max_rounds=3)))


# ---------------------------------------------------------------------------
# fault-injection scenarios (repro.faults) — node- and station-level
# failures layered on top of link impairments.  Fault draws are
# counter-based (seed, namespace, entity, time-bits), so the factories
# stay RNG-free and both engines see identical faults.
# ---------------------------------------------------------------------------

@register("chaos-direct")
def _chaos_direct() -> Scenario:
    # the seed geometry with radiation upsets + ground-station blackouts:
    # ~8 % of flights crash mid-round (losing the in-flight update AND
    # the EF residual) and Kiruna goes dark in ~15 % of half-hour slots —
    # the small fast-vs-oracle fault-equivalence scenario
    return Scenario(name="chaos-direct", walker=Walker(),
                    stations=(KIRUNA,),
                    faults=FaultModel(crash_rate=0.08,
                                      gs_outage_rate=0.15,
                                      gs_outage_duration=1800.0))


@register("chaos-plane")
def _chaos_plane() -> Scenario:
    # per-plane convergecast under head failures: ~30 % of head uplinks
    # die mid-convergecast, triggering timeout re-election and partial-
    # sum salvage; member crashes exercise the residual re-sync path
    return Scenario(name="chaos-plane", walker=Walker(),
                    stations=(KIRUNA,), topology="plane",
                    faults=FaultModel(crash_rate=0.05,
                                      head_failure_rate=0.30,
                                      failover_timeout=60.0))


@register("chaos-lossy")
def _chaos_lossy() -> Scenario:
    # erasures AND crashes in the same round: link losses revert wires
    # but keep residuals, crashes wipe both — the scenario where the two
    # EF semantics (revert vs re-sync) must compose correctly
    return Scenario(name="chaos-lossy", walker=Walker(), stations=(KIRUNA,),
                    channel=ChannelModel(
                        loss=0.10,
                        arq=SelectiveRepeatARQ(seg_bytes=1024, max_rounds=4)),
                    faults=FaultModel(crash_rate=0.08))


@register("mega-1000-chaos")
def _mega_1000_chaos() -> Scenario:
    # the headline robustness regime (benchmarks/table_fault_tolerance.py
    # and the chaos convergence gate): mega-1000 over a lossy channel with
    # per-flight radiation upsets and recurring station blackouts
    return Scenario(name="mega-1000-chaos",
                    walker=Walker(n_sats=1000, n_planes=20),
                    stations=(KIRUNA, SVALBARD, INUVIK),
                    k_direct=8, n_relay=4, max_hops=6,
                    channel=ChannelModel(
                        loss=0.10,
                        arq=SelectiveRepeatARQ(seg_bytes=1024, max_rounds=3)),
                    faults=FaultModel(crash_rate=0.05,
                                      gs_outage_rate=0.10,
                                      gs_outage_duration=1800.0))


@register("mega-1000-chaos-plane")
def _mega_1000_chaos_plane() -> Scenario:
    # the in-orbit aggregation variant: 20 planes convergecast to heads
    # while ~20 % of head uplinks fail mid-round — failover + partial-sum
    # salvage at mega-constellation scale
    return Scenario(name="mega-1000-chaos-plane",
                    walker=Walker(n_sats=1000, n_planes=20),
                    stations=(KIRUNA, SVALBARD, INUVIK),
                    max_hops=6, topology="plane",
                    faults=FaultModel(crash_rate=0.03,
                                      head_failure_rate=0.20,
                                      failover_timeout=60.0))
