"""Named simulation scenarios — register your own with :func:`register`.

A scenario bundles everything the engine needs: the Walker constellation,
the ground-station set, the link budget, per-satellite compute times, and a
weather/dropout model.  Built-ins cover the paper's default setting plus
the harder regimes the realistic-space-scenario comparison needs:

    walker-kiruna    the seed setting — 100 sats, one polar GS, uniform
                     30 s compute, clear sky (parity baseline)
    dual-station     Kiruna + Svalbard: twice the window supply
    weather-dropout  dual-station with 25 % of contact windows blocked
    hetero-compute   per-satellite compute times spread 15–60 s
                     (deterministic pattern — no RNG in scenario defs)
    mega-1000        1000 sats / 20 planes, three stations, 8 gateways
                     per round — the scale target from the ROADMAP
    mega-10000       10000 sats / 40 planes, 16 gateways per round — the
                     dense mega-constellation regime (bench-only scale)

Usage::

    from repro.sim import get_scenario, Engine
    eng = Engine(get_scenario("dual-station"))

    @register("my-scenario")
    def _my():                      # factory, called per get_scenario()
        return Scenario(name="my-scenario", walker=Walker(n_sats=40), ...)
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from ..constellation.orbits import GroundStation, Walker
from .engine import Scenario

SCENARIOS: Dict[str, Callable[[], Scenario]] = {}

KIRUNA = GroundStation(lat=67.86, lon=20.22)
SVALBARD = GroundStation(lat=78.23, lon=15.39)
INUVIK = GroundStation(lat=68.32, lon=-133.55)


def register(name: str):
    """Decorator: register a zero-arg Scenario factory under ``name``."""
    def deco(fn: Callable[[], Scenario]):
        SCENARIOS[name] = fn
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {names()}")
    return SCENARIOS[name]()


def names() -> List[str]:
    return sorted(SCENARIOS)


@register("walker-kiruna")
def _walker_kiruna() -> Scenario:
    return Scenario(name="walker-kiruna", walker=Walker(), stations=(KIRUNA,))


@register("dual-station")
def _dual_station() -> Scenario:
    return Scenario(name="dual-station", walker=Walker(),
                    stations=(KIRUNA, SVALBARD))


@register("weather-dropout")
def _weather_dropout() -> Scenario:
    return Scenario(name="weather-dropout", walker=Walker(),
                    stations=(KIRUNA, SVALBARD), dropout=0.25)


@register("hetero-compute")
def _hetero_compute() -> Scenario:
    w = Walker()
    # deterministic 15–60 s spread: radiation-tolerant flight computers of
    # five different generations, interleaved across the constellation
    compute = 15.0 + 45.0 * (np.arange(w.n_sats) % 5) / 4.0
    return Scenario(name="hetero-compute", walker=w, stations=(KIRUNA,),
                    compute_time=compute)


@register("mega-1000")
def _mega_1000() -> Scenario:
    return Scenario(name="mega-1000",
                    walker=Walker(n_sats=1000, n_planes=20),
                    stations=(KIRUNA, SVALBARD, INUVIK),
                    k_direct=8, n_relay=4, max_hops=6)


@register("mega-10000")
def _mega_10000() -> Scenario:
    # dense mega-constellation regime (Razmi et al., Matthiesen et al.):
    # 10k sats / 40 planes, three polar stations, 16 gateways per round
    return Scenario(name="mega-10000",
                    walker=Walker(n_sats=10000, n_planes=40),
                    stations=(KIRUNA, SVALBARD, INUVIK),
                    k_direct=16, n_relay=4, max_hops=6)
