"""Vectorized batch-event fast path for the discrete-event engine.

The heapq engine (:meth:`repro.sim.engine.Engine._run_round_oracle` /
:meth:`~repro.sim.engine.Engine._run_async_oracle`) pops one event at a
time, allocates a kwargs dict per event, re-runs a breadth-first ISL
search per routing decision, and re-evaluates the stochastic channel
(elevation → rate → erasure, per-round counter draws) from scratch on
every window-fit check.  None of that is algorithmically necessary:

* **batch-event core** — events are flat immutable records in a
  :class:`EventQueue` (no kwargs dict per event); whole event cohorts
  materialize from numpy arrays in one heapify, and consecutive events
  sharing a timestamp and a dispatchable kind pop as ONE batch;
* **batched routing** — each dispatch batch resolves its routes through
  the already-array-shaped contact-plan lookups
  (:meth:`~repro.sim.contacts.ContactPlan.next_windows_for`): one
  vectorized window query per ISL hop distance instead of
  ``O(candidates)`` scalar ``next_window`` calls per satellite, with the
  per-satellite BFS neighborhoods precomputed once from the +grid
  translation symmetry (:class:`_Topology`);
* **vectorized channel** — time-invariant (``budget=None``) channels
  precompute each delivery's full ARQ profile from one batched
  splitmix64 counter draw over the (round, segment) grid
  (:class:`repro.channel.arq.ArqPlan`) and replay it per transmission;
  elevation-dependent estimates memoize on their full argument tuple
  (:class:`ChannelCache`).

Equivalence is the contract, speed is the feature: for any scenario and
seed the fast path reproduces the oracle's :class:`~repro.sim.engine.
Delivery` timeline — every field, bit for bit — because every cached or
batched quantity is computed with the oracle's exact float expressions
(see the per-class notes), and event ordering replicates the oracle's
``(t, push-sequence)`` total order.  ``tests/test_fastpath_equivalence``
enforces this across sync/async × lossless/lossy/rain-fade/mega
scenarios; CI runs the mega-1000 smoke on every push.

Observability attaches at the :meth:`~repro.sim.engine.Engine.run_round`
/ :meth:`~repro.sim.engine.Engine.run_async` wrappers — NOT here — so
this path and the oracle emit ``repro.obs`` trace records through one
shared schema and ``python -m repro.obs diff`` can localize the first
diverging record between the two engines.  (One asymmetry: time-invariant
channels here replay :class:`~repro.channel.arq.ArqPlan` without calling
``ChannelModel.transmit``, so per-transmission ``link`` events only
appear on budget channels; ``link`` is excluded from the diff kinds.)
"""
from __future__ import annotations

import heapq
from collections import defaultdict
from time import perf_counter
from typing import Dict, List, Optional

import numpy as np

from ..obs.trace import active as _obs_active

# event kinds (EventQueue.kind values)
TRAIN = 0       # a satellite finished local training
ISL = 1         # an update arrived at a gateway over the ISL mesh
TX_START = 2    # wakeup: a gateway's window opened / link came free
TX_DONE = 3     # a GS uplink completed (success or channel failure)
RETRY = 4       # async: no route anywhere, try again later
_DISPATCH = (TRAIN, RETRY)    # kinds that batch-pop into one dispatch


class EventQueue:
    """Batch event queue over flat immutable records.

    Each event is one ``(t, seq, kind, a, b, c, d, f)`` record — no
    per-event kwargs dict, the allocation the oracle pays on every push.
    ``seq`` is a monotone push counter, so the heap's ``(t, seq)`` total
    order is exactly the oracle's ``(t, itertools.count())`` order and
    ties at equal timestamps resolve identically.  :meth:`push_batch`
    materializes a whole event cohort from numpy arrays in one heapify;
    :meth:`peek` lets the engine batch-pop consecutive same-timestamp
    dispatch events.  Channel outcomes (TX_DONE only) ride in a side
    table keyed by ``seq``.

    Record fields by kind:  ``a`` = sat (TRAIN/ISL/RETRY) or gateway
    (TX_START/TX_DONE); ``b`` = gateway (ISL) or sat (TX_DONE);
    ``c`` = ISL hops; ``d`` = station; ``f`` = window rise time.
    """

    __slots__ = ("_heap", "_seq", "outcomes")

    def __init__(self):
        self._heap: list = []
        self._seq = 0
        self.outcomes: Dict[int, dict] = {}        # TX_DONE channel outcome

    def __bool__(self) -> bool:
        return bool(self._heap)

    def push(self, t: float, kind: int, a: int = 0, b: int = 0, c: int = 0,
             d: int = 0, f: float = 0.0, outcome: Optional[dict] = None
             ) -> None:
        i = self._seq
        self._seq = i + 1
        if outcome is not None:
            self.outcomes[i] = outcome
        heapq.heappush(self._heap, (t, i, kind, a, b, c, d, f))

    def push_batch(self, ts: np.ndarray, kind: int, sats) -> None:
        """One event per (t, sat) pair, in index order (one heapify when
        the queue starts empty — the async round-start cohort)."""
        i0 = self._seq
        self._seq = i0 + len(ts)
        recs = [(t, i0 + j, kind, s, 0, 0, 0, 0.0)
                for j, (t, s) in enumerate(zip(ts.tolist(), sats.tolist()))]
        if self._heap:
            for r in recs:
                heapq.heappush(self._heap, r)
        else:
            self._heap = recs
            heapq.heapify(self._heap)

    def pop(self):
        return heapq.heappop(self._heap)

    def peek(self):
        return self._heap[0] if self._heap else None


class ChannelCache:
    """Per-engine memo for the stochastic channel stack.

    Every cached quantity is exactly what the oracle computes for the
    same arguments: ARQ profiles replay ``transmit()``'s float
    arithmetic (:class:`~repro.channel.arq.ArqPlan`), estimates memoize
    on the full ``(gateway, station, window, t, nbytes)`` tuple, and the
    fixed-rate estimate collapses to one float per message size (it
    never depended on geometry).  Plans are pure functions of
    (seed, station, sat, window, nbytes) — they never invalidate, and
    they're what turns the per-round lossy-channel overhead from ~6x
    into the gated ≤ 2x.
    """

    def __init__(self, engine):
        self.eng = engine
        self.channel = engine.channel
        self._plans: dict = {}
        self._est: dict = {}
        self._flat_est: dict = {}

    def _live_channel(self):
        """The engine's channel is mutable (``SpaceRunner`` installs one
        post-construction) — drop every memo when it changes identity."""
        ch = self.eng.channel
        if ch is not self.channel:
            self.channel = ch
            self._plans.clear()
            self._est.clear()
            self._flat_est.clear()
        return ch

    def estimate(self, gateway: int, win, t: float, nbytes: float,
                 gs_tx: float) -> float:
        ch = self._live_channel()
        if ch is None:
            return gs_tx
        if ch.time_invariant:
            e = self._flat_est.get(nbytes)
            if e is None:
                if len(self._flat_est) > (1 << 16):  # content-exact codecs
                    self._flat_est.clear()           # vary nbytes per round
                e = self.eng.tx_estimate(gateway, win, t, nbytes, gs_tx)
                self._flat_est[nbytes] = e
            return e
        key = (gateway, win[2], self.eng._window_id(win[0]), t, nbytes)
        e = self._est.get(key)
        if e is None:
            if len(self._est) > (1 << 16):     # bound long-lived engines
                self._est.clear()
            e = self.eng.tx_estimate(gateway, win, t, nbytes, gs_tx)
            self._est[key] = e
        return e

    def commit(self, gateway: int, sat: int, win, t: float, nbytes: float,
               gs_tx: float):
        ch = self._live_channel()
        if ch is None:
            return t + gs_tx, dict(nbytes=nbytes, nbytes_attempted=nbytes,
                                   retries=0, delivered=True)
        if ch.time_invariant:
            wid = self.eng._window_id(win[0])
            key = (win[2], sat, wid, nbytes)
            plan = self._plans.get(key)
            if plan is None:
                if len(self._plans) > (1 << 16):   # bound long-lived engines
                    self._plans.clear()
                plan = ch.arq_plan(self.eng.scenario.link, nbytes, sat=sat,
                                   seed=self.eng.seed, station=win[2],
                                   window_id=wid)
                self._plans[key] = plan
            res = plan.replay(t, win[1])
            return res.t_done, dict(nbytes=res.nbytes,
                                    nbytes_attempted=res.nbytes_attempted,
                                    retries=res.retries,
                                    delivered=res.delivered)
        # elevation-dependent budget: rate/p vary with the transmission
        # instant — not replayable, route through the oracle path
        return self.eng.tx_commit(gateway, sat, win, t, nbytes, gs_tx)


class _Topology:
    """Oracle-order BFS neighborhoods, precomputed for the whole fleet.

    The async oracle re-runs ``reachable(sat)`` (a bounded BFS over the
    +grid) on EVERY dispatch.  The +grid is translation-invariant on the
    (plane, slot) torus whenever the constellation is regular
    (``n_sats == n_planes · sats_per_plane``): the BFS from satellite 0
    yields per-hop (Δplane, Δslot) offsets that are valid — in the same
    insertion order the oracle's ``dict`` iteration produces — for every
    satellite.  One BFS therefore builds the full ``(S, C)`` candidate /
    hop arrays.  Invariance is spot-checked against the literal BFS at
    construction; ragged constellations fall back to per-satellite BFS
    (still computed once, not per dispatch).
    """

    def __init__(self, engine):
        sc = engine.scenario
        self.router = engine.router
        self.max_hops = sc.max_hops
        w = sc.walker
        n = w.n_sats
        spp = w.sats_per_plane
        regular = spp > 0 and spp * w.n_planes == n
        if regular:
            offsets = self._bfs(0)                       # [(sat, hops)]
            dp = np.array([v // spp for v, _ in offsets])
            ds = np.array([v % spp for v, _ in offsets])
            hp = np.array([h for _, h in offsets], dtype=np.int64)
            plane = np.arange(n, dtype=np.int64) // spp
            slot = np.arange(n, dtype=np.int64) % spp
            ids = (((plane[:, None] + dp[None, :]) % w.n_planes) * spp
                   + (slot[:, None] + ds[None, :]) % spp)
            # spot-check the translation symmetry before trusting it
            for probe in {n // 3, n - 1} - {0}:
                ref = self._bfs(probe)
                if (len(ref) != len(offsets)
                        or any(ids[probe, k] != v or hp[k] != h
                               for k, (v, h) in enumerate(ref))):
                    regular = False
                    break
        if regular:
            self.ids = ids
            self.hops = np.broadcast_to(hp, ids.shape)
            self.valid = None
        else:
            rows = [self._bfs(s) for s in range(n)]
            c = max(len(r) for r in rows)
            self.ids = np.zeros((n, c), dtype=np.int64)
            self.hops = np.zeros((n, c), dtype=np.int64)
            self.valid = np.zeros((n, c), dtype=bool)
            for s, row in enumerate(rows):
                for k, (v, h) in enumerate(row):
                    self.ids[s, k] = v
                    self.hops[s, k] = h
                    self.valid[s, k] = True

    def _bfs(self, sat: int):
        """The oracle's ``reachable``: (candidate, hops) in insertion
        order — hops are nondecreasing, so the oracle's est tie-break
        (prefer fewer hops) reduces to first-minimum order."""
        seen = {sat: 0}
        frontier = [sat]
        for h in range(1, self.max_hops + 1):
            nxt = []
            for u in frontier:
                for v in self.router.neighbors(u):
                    if v not in seen:
                        seen[v] = h
                        nxt.append(v)
            frontier = nxt
        return list(seen.items())


class _FastState:
    """Lazily-built per-engine fast-path caches (topology + ISL times)."""

    def __init__(self, engine):
        self.topo = _Topology(engine)
        self._isl: dict = {}
        self._link = engine.router.link
        self._max_hops = engine.scenario.max_hops

    def isl_times(self, msg_bytes: float) -> np.ndarray:
        """(max_hops+1,) per-hop-count ISL transfer times; index 0 is the
        oracle's literal 0.0 for the direct (hops == 0) case."""
        arr = self._isl.get(msg_bytes)
        if arr is None:
            arr = np.array([0.0] + [self._link.isl_time(msg_bytes, hops=h)
                                    for h in range(1, self._max_hops + 1)])
            self._isl[msg_bytes] = arr
        return arr


# ---------------------------------------------------------------------------
# synchronous mode
# ---------------------------------------------------------------------------

def run_round_fast(eng, t0: float, msg_bytes: float):
    """Fast sync round: the oracle's event protocol — same pushes in the
    same order, so the same ``(t, seq)`` pop order — over the structured
    event store, with every channel evaluation served by the
    :class:`ChannelCache`."""
    from .engine import Delivery, RoundResult

    sc = eng.scenario
    trc = _obs_active()
    prof = trc.prof if trc is not None else None
    eng.ensure(t0 + 2 * sc.lookahead)
    if prof is not None:
        prof.begin("assign")
    asg = eng.policy.assign(t0, msg_bytes, eng)
    if prof is not None:
        prof.end()
    n = sc.walker.n_sats
    scheduled = np.zeros(n, dtype=bool)
    for s in asg.gateways:
        scheduled[s] = True
    for s in asg.relays:
        scheduled[s] = True
    if not asg.gateways:
        return RoundResult(np.zeros(n, dtype=bool), sc.max_compute, [],
                           scheduled, t0)

    gs_tx = sc.link.gs_time(msg_bytes)
    if prof is not None:
        prof.begin("state_build")
    cache = eng.chan_cache          # lazily built on the first round
    if prof is not None:
        prof.end()
    ev = EventQueue()
    queues = {g: [] for g in asg.gateways}
    busy = {g: False for g in asg.gateways}
    wins = {g: asg.windows[g] for g in asg.gateways}
    station_free: Dict[int, float] = defaultdict(float)
    deliveries: List = []
    hops_of = {s: r.hops for s, r in asg.relays.items()}

    for s in asg.gateways:
        ev.push(t0 + sc.compute_of(s), TRAIN, a=s)
    for s in asg.relays:
        ev.push(t0 + sc.compute_of(s), TRAIN, a=s)

    # hot-interior accumulators [fit_n, fit_s, commit_n, commit_s]:
    # inline perf_counter reads, folded into the profiler once per round
    pacc = [0, 0.0, 0, 0.0]

    def try_tx(g, t):
        if busy[g] or not queues[g]:
            return
        _t0 = perf_counter() if prof is not None else 0.0
        win = wins[g]
        fit = False
        for _ in range(64):
            if win is None:
                break
            start = max(t, win[0], station_free[win[2]])
            if start + cache.estimate(g, win, start, msg_bytes,
                                      gs_tx) <= win[1]:
                fit = True
                break
            win = eng.usable_window(g, win[1])
        if prof is not None:
            pacc[0] += 1
            pacc[1] += perf_counter() - _t0
        if not fit:                         # undeliverable this round
            queues[g].clear()
            wins[g] = None
            return
        wins[g] = win
        if start > t:
            ev.push(start, TX_START, a=g)
            return
        _, sat = queues[g].pop(0)           # FIFO = arrival order
        busy[g] = True
        _t0 = perf_counter() if prof is not None else 0.0
        t_done, outcome = cache.commit(g, sat, win, t, msg_bytes, gs_tx)
        if prof is not None:
            pacc[2] += 1
            pacc[3] += perf_counter() - _t0
        station_free[win[2]] = t_done
        ev.push(t_done, TX_DONE, a=g, b=sat, d=win[2], f=win[0],
                outcome=outcome)

    if prof is not None:
        prof.begin("event_loop")
    while ev:
        t, i, kind, a, b, _c, d, f = ev.pop()
        if kind == TRAIN:
            if a in queues:
                queues[a].append((t, a))
                try_tx(a, t)
            else:
                r = asg.relays[a]
                ev.push(t + r.time, ISL, a=a, b=r.gateway)
        elif kind == ISL:
            queues[b].append((t, a))
            try_tx(b, t)
        elif kind == TX_START:
            try_tx(a, t)
        else:                               # TX_DONE
            deliveries.append(Delivery(
                sat=b, t_done=t, t_start=t0, gateway=a,
                station=d, hops=hops_of.get(b, 0),
                window=f, **ev.outcomes.pop(i)))
            busy[a] = False
            try_tx(a, t)
    if prof is not None:
        prof.end()
        prof.add_many(("event_loop", "window_fit"), pacc[0], pacc[1])
        prof.add_many(("event_loop", "tx_commit"), pacc[2], pacc[3])

    mask = np.zeros(n, dtype=bool)
    for dlv in deliveries:
        if dlv.delivered:
            mask[dlv.sat] = True
    duration = (max(dlv.t_done for dlv in deliveries) - t0
                if deliveries else sc.max_compute)
    return RoundResult(mask, float(duration), deliveries, scheduled, t0)


# ---------------------------------------------------------------------------
# asynchronous mode
# ---------------------------------------------------------------------------

def run_async_fast(eng, t0: float, msg_bytes: float, n_deliveries: int,
                   max_time: Optional[float] = None):
    """Fast async run: dispatch events sharing a timestamp batch-pop and
    resolve their routes through vectorized window lookups; the route
    chooser reproduces the oracle's ``choose_route`` float-for-float
    (``max(t+isl, rise) + backlog·gs_tx + gs_tx`` elementwise, first
    minimum in BFS order) while honouring intra-batch backlog mutations
    via dirty-row recomputation."""
    from .engine import Delivery

    sc = eng.scenario
    n = sc.walker.n_sats
    trc = _obs_active()
    prof = trc.prof if trc is not None else None
    gs_tx = sc.link.gs_time(msg_bytes)
    # state_build covers the lazily-built shared state (first call pays
    # the BFS topology construction) so it can't pollute the residual
    if prof is not None:
        prof.begin("state_build")
    cache = eng.chan_cache
    fast = eng._fast_state()
    topo = fast.topo
    isl_times = fast.isl_times(msg_bytes)
    if prof is not None:
        prof.end()
    horizon_cap = t0 + (max_time if max_time is not None
                        else 100.0 * sc.lookahead)
    ev = EventQueue()
    if prof is not None:
        prof.begin("round_setup")
    queues: List[list] = [[] for _ in range(n)]
    qlen = np.zeros(n, dtype=np.int64)
    busy = np.zeros(n, dtype=bool)
    wins: List = [None] * n
    mutated = np.zeros(n, dtype=bool)
    station_free: Dict[int, float] = defaultdict(float)
    train_start = np.full(n, float(t0))
    deliveries: List = []

    compute = np.broadcast_to(
        np.asarray(sc.compute_time, dtype=np.float64), (n,))
    ev.push_batch(t0 + compute, TRAIN, np.arange(n))
    if prof is not None:
        prof.end()

    def park(g, t):
        """No usable window for this gateway: re-route the backlog.
        Retries only schedule strictly before the horizon cap (mirrors
        the oracle's guard — a retry at the saturated cap would cycle
        park → retry → park at constant t forever)."""
        if t < horizon_cap:
            for meta in queues[g]:
                ev.push(min(t + sc.lookahead, horizon_cap), RETRY,
                        a=meta[1])
        queues[g].clear()
        qlen[g] = 0
        wins[g] = None
        mutated[g] = True

    # async fires try_tx per event (~10k per mega run): even a counter
    # increment per call shows up against the 1.05x trace-overhead gate,
    # so the fit search is deliberately NOT timed here — its cost reads
    # out as event_loop self time (the sync path, ~100x fewer calls,
    # keeps the exact per-fit timer).  Commits are one per delivery
    # attempt and stay exactly timed.
    pacc = [0, 0.0]              # commit_n, commit_s

    def try_tx(g, t):
        if busy[g] or not queues[g]:
            return
        win = wins[g]
        if win is None or win[1] <= t:
            win = eng.usable_window(g, t)
        fit = False
        for _ in range(64):
            if win is None:
                break
            start = max(t, win[0], station_free[win[2]])
            if start + cache.estimate(g, win, start, msg_bytes,
                                      gs_tx) <= win[1]:
                fit = True
                break
            win = eng.usable_window(g, win[1])
        if not fit:
            park(g, t)
            return
        wins[g] = win
        if start > t:
            ev.push(start, TX_START, a=g)
            return
        meta = queues[g].pop(0)
        qlen[g] -= 1
        busy[g] = True
        mutated[g] = True
        _t0 = perf_counter() if prof is not None else 0.0
        t_done, outcome = cache.commit(g, meta[1], win, t, msg_bytes, gs_tx)
        if prof is not None:
            pacc[0] += 1
            pacc[1] += perf_counter() - _t0
        station_free[win[2]] = t_done
        ev.push(t_done, TX_DONE, a=g, b=meta[1], c=meta[2], d=win[2],
                f=win[0], outcome=outcome)

    def dispatch_batch(sats, t):
        """Route every satellite in one same-timestamp dispatch batch."""
        if prof is not None:
            prof.begin("dispatch")
        b = len(sats)
        ids = topo.ids[sats]                       # (B, C) candidates
        hops = topo.hops[sats]                     # (B, C)
        uniq = np.unique(ids)
        # one vectorized window query per hop distance covers every
        # (candidate, arrival-time) pair the oracle would ask about
        if prof is not None:
            prof.begin("window_query")
        starts = np.empty((len(isl_times), len(uniq)))
        for h in range(len(isl_times)):
            s_h, _, _ = eng.plan.next_windows_for(
                uniq, t + isl_times[h], blocked=eng._blocked)
            starts[h] = s_h
        if prof is not None:
            prof.end()
        pos = np.searchsorted(uniq, ids)
        ws = starts[hops, pos]                     # max(t+isl, rise), (B, C)
        est0 = ws + (qlen[ids] + busy[ids]) * gs_tx + gs_tx
        if topo.valid is not None:
            est0 = np.where(topo.valid[sats], est0, np.inf)
        mutated[:] = False
        any_mut = False
        for j in range(b):
            s = int(sats[j])
            row = ids[j]
            if any_mut and mutated[row].any():
                # an earlier batch member changed a candidate's backlog —
                # recompute this row against live queue state
                est = ws[j] + (qlen[row] + busy[row]) * gs_tx + gs_tx
                if topo.valid is not None:
                    est = np.where(topo.valid[sats[j]], est, np.inf)
            else:
                est = est0[j]
            k = int(np.argmin(est))
            if not np.isfinite(est[k]):
                if t < horizon_cap:
                    ev.push(min(t + sc.lookahead, horizon_cap), RETRY, a=s)
                continue
            gw = int(row[k])
            hp = int(hops[j, k])
            if gw == s:
                queues[s].append((t, s, 0))
                qlen[s] += 1
                mutated[s] = True
                any_mut = True
                try_tx(s, t)
            else:
                ev.push(t + float(isl_times[hp]), ISL, a=s, b=gw, c=hp)
        if prof is not None:
            prof.end()

    n_ok = 0
    if prof is not None:
        prof.begin("event_loop")
    while ev and n_ok < n_deliveries:
        t, i, kind, a, b, c, d, f = ev.pop()
        if t > horizon_cap:
            break
        eng.ensure(t + 2 * sc.lookahead)
        if kind in _DISPATCH:
            batch = [a]
            while True:
                nxt = ev.peek()
                if nxt is None or nxt[0] != t or nxt[2] not in _DISPATCH:
                    break
                batch.append(ev.pop()[3])
            dispatch_batch(np.asarray(batch, dtype=np.int64), t)
        elif kind == ISL:
            queues[b].append((t, a, c))
            qlen[b] += 1
            try_tx(b, t)
        elif kind == TX_START:
            try_tx(a, t)
        else:                               # TX_DONE
            outcome = ev.outcomes.pop(i)
            deliveries.append(Delivery(
                sat=b, t_done=t, t_start=float(train_start[b]), gateway=a,
                station=d, hops=c, window=f, **outcome))
            if outcome["delivered"]:
                n_ok += 1
            busy[a] = False
            mutated[a] = True
            try_tx(a, t)
            # the satellite retrains either way (see the oracle's note)
            train_start[b] = t
            ev.push(t + sc.compute_of(b), TRAIN, a=b)
    if prof is not None:
        prof.end()
        # commits triggered inside dispatch_batch land here too — only
        # the dispatch sub-attribution coarsens
        prof.add_many(("event_loop", "tx_commit"), pacc[0], pacc[1])

    return deliveries
