"""Discrete-event constellation simulation: contact plans, multi-hop ISL
routing, and an event-queue engine with synchronous and asynchronous
(FedBuff-style) operation."""
from .contacts import ContactPlan
from .engine import (Cohort, Delivery, Engine, RoundResult, Scenario,
                     group_cohorts)
from .routing import Route, Router, gateway_schedule
from .scenarios import SCENARIOS, get_scenario, names, register

__all__ = [
    "ContactPlan", "Cohort", "Delivery", "Engine", "RoundResult", "Scenario",
    "group_cohorts", "Route", "Router", "gateway_schedule",
    "SCENARIOS", "get_scenario", "names", "register",
]
