"""Discrete-event constellation simulation: contact plans, multi-hop ISL
routing, in-orbit aggregation topologies, and an event-queue engine with
synchronous and asynchronous (FedBuff-style) operation."""
from .contacts import ContactPlan
from .engine import (Cohort, Delivery, Engine, RoundResult, Scenario,
                     group_cohorts)
from .routing import Route, Router, gateway_schedule
from .scenarios import SCENARIOS, get_scenario, names, register
from .topology import Topology, make_topology

__all__ = [
    "ContactPlan", "Cohort", "Delivery", "Engine", "RoundResult", "Scenario",
    "group_cohorts", "Route", "Router", "gateway_schedule",
    "SCENARIOS", "get_scenario", "names", "register",
    "Topology", "make_topology",
]
