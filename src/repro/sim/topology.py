"""In-orbit aggregation topologies — how updates reach a ground station.

Today's engine uplinks every scheduled update over its own sat→GS link
(possibly after a passive ISL relay hop).  The Razmi et al. line of work
(on-board FL for dense LEO constellations / satellite clusters with ISL)
aggregates *in orbit* instead: updates are partially summed along the
intra-plane ISL ring toward an elected **cluster head**, which uplinks ONE
merged wire per plane — cutting ground-station incast by the plane size.

:func:`make_topology` resolves a scenario's ``topology`` spec into one of

  * ``direct`` — the historical behavior.  The engine's existing sync /
    async paths run untouched, so ``topology="direct"`` is bit-for-bit
    identical to a scenario without the field;
  * ``plane``  — per-orbital-plane convergecast: each plane elects the
    member with the earliest usable GS window as its head, the plane ring
    splits at the head into two arcs, and partial sums flow hop-by-hop
    (each hop costs real ISL time and ``msg_bytes`` wire bytes) until the
    head holds the plane's merged wire and uplinks it through the normal
    window / station-contention / ARQ machinery;
  * ``gossip`` — ``plane`` plus an inter-plane exchange: heads are paired
    (in plane order) and the later-windowed head of each pair ships its
    merged wire over the ISL grid to the earlier-windowed one, which
    uplinks a two-plane wire — halving GS incast again.

Fast-vs-oracle equivalence extends to the new event kinds: the oracle
runs the convergecast as literal heapq events (``agg_train`` /
``agg_forward`` hop arrivals), the fast path computes the identical
arrival times with the same float fold (``max(own, upstream) + hop``
accumulated in arc order — never a closed form like ``ready + k·hop``,
which rounds differently), and both share ONE head-uplink phase
(:func:`_uplink_heads`), parametrized only by whether channel evaluations
go through the memoizing :class:`~repro.sim.fastpath.ChannelCache` (fast)
or the live channel (oracle).  ``tests/test_topology.py`` enforces
bit-identical :class:`~repro.sim.engine.Delivery` timelines across both.

Modeling notes: heads are re-elected every round from the contact plan
(a plane whose members see no usable window within the lookahead skips
the round); aggregation consumes ``(plane_size − 1)`` ISL transfers per
plane (+ the inter-head hops under gossip), accounted in
``RoundResult.bytes_isl``; plane topologies require a regular Walker
grid (``n_sats == n_planes · sats_per_plane``) and the sync engine mode
(FedBuff-style async has no plane-synchronous merge point).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """Resolved aggregation topology (see module docstring)."""
    kind: str = "direct"          # "direct" | "plane"
    gossip: bool = False          # plane only: pair heads before uplink

    @property
    def name(self) -> str:
        return "gossip" if self.gossip else self.kind


DIRECT = Topology("direct")
PLANE = Topology("plane")
GOSSIP = Topology("plane", gossip=True)

_BY_NAME = {"direct": DIRECT, "plane": PLANE, "gossip": GOSSIP}


def make_topology(spec) -> Topology:
    """Resolve ``None`` / a name / a :class:`Topology` into a Topology."""
    if spec is None:
        return DIRECT
    if isinstance(spec, Topology):
        return spec
    try:
        return _BY_NAME[spec]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown topology {spec!r}; expected one of "
            f"{sorted(_BY_NAME)} or a Topology instance") from None


def check_plane_compatible(scenario, topology: Topology) -> None:
    """Plane topologies need a regular Walker grid: head election and the
    arc split assume every plane holds exactly ``sats_per_plane``
    members."""
    if topology.kind == "direct":
        return
    w = scenario.walker
    spp = w.sats_per_plane
    if spp < 1 or spp * w.n_planes != w.n_sats:
        raise ValueError(
            f"topology '{topology.name}' needs a regular constellation "
            f"(n_sats == n_planes * sats_per_plane); got n_sats="
            f"{w.n_sats}, n_planes={w.n_planes}")


# ---------------------------------------------------------------------------
# per-round plan: election, arcs, gossip pairing — shared by both engines
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanePlan:
    """Deterministic per-round aggregation plan (pure function of the
    contact plan + t0, so fast and oracle compute the identical plan)."""
    heads: Dict[int, int]               # plane -> head sat
    arcs: Dict[int, Tuple[List[int], List[int]]]  # head -> (up, down) far→near
    uplinkers: List[int]                # heads that perform a GS uplink
    merged: Dict[int, Tuple[int, ...]]  # uplinker -> every sat its wire sums
    pairs: List[Tuple[int, int, int]]   # (primary, secondary, isl hops)
    hops_of: Dict[int, int]             # uplinker -> max ISL hops travelled


def _plane_arcs(head: int, plane: int, spp: int) -> Tuple[List[int], List[int]]:
    """Split the plane ring at the head into two convergecast arcs.

    Members at ring offset ``o = (slot − head_slot) mod spp`` with
    ``1 ≤ o ≤ spp//2`` feed the *up* arc (distance ``o``); the rest feed
    the *down* arc (distance ``spp − o``) — ties at exactly half the ring
    go up, so the split is canonical.  Each arc lists sats far→near."""
    base = plane * spp
    hs = head - base
    up = [base + (hs + o) % spp for o in range(spp // 2, 0, -1)]
    down = [base + (hs + o) % spp for o in range(spp // 2 + 1, spp)]
    return up, down


def _ring_dist(a: int, b: int, n: int) -> int:
    d = abs(a - b) % n
    return min(d, n - d)


def plan_plane_round(eng, t0: float) -> PlanePlan:
    """Elect heads and lay out the round's aggregation plan.

    Head election: per plane, the member with the earliest usable GS
    window after its training completes (``t0 + compute``), ties broken
    by lowest sat id; members whose earliest window rises past
    ``t0 + lookahead`` are ineligible (mirrors the direct scheduler's
    horizon), and a plane with no eligible member skips the round."""
    sc = eng.scenario
    w = sc.walker
    spp = w.sats_per_plane
    n = w.n_sats
    t_ready = t0 + np.broadcast_to(
        np.asarray(sc.compute_time, dtype=np.float64), (n,))
    starts, _, _ = eng.usable_windows_all(t_ready)
    elig = np.isfinite(starts) & (starts <= t0 + sc.lookahead)
    heads: Dict[int, int] = {}
    head_start: Dict[int, float] = {}
    arcs: Dict[int, Tuple[List[int], List[int]]] = {}
    for p in range(w.n_planes):
        members = np.arange(p * spp, (p + 1) * spp)
        ok = elig[members]
        if not ok.any():
            continue                       # plane dark this round
        cand_starts = np.where(ok, starts[members], np.inf)
        head = int(members[int(np.argmin(cand_starts))])  # first min = low id
        heads[p] = head
        head_start[head] = float(starts[head])
        arcs[head] = _plane_arcs(head, p, spp)
    merged: Dict[int, Tuple[int, ...]] = {}
    hops_of: Dict[int, int] = {}
    for p, h in heads.items():
        merged[h] = tuple(range(p * spp, (p + 1) * spp))
        hops_of[h] = max(spp // 2, spp - 1 - spp // 2)   # ring radius
    pairs: List[Tuple[int, int, int]] = []
    uplinkers = [heads[p] for p in sorted(heads)]
    if eng.topology.gossip and len(uplinkers) > 1:
        planes = sorted(heads)
        uplinkers = []
        for i in range(0, len(planes) - 1, 2):
            pa, pb = planes[i], planes[i + 1]
            ha, hb = heads[pa], heads[pb]
            # earlier elected window uplinks; tie → the lower plane
            if (head_start[hb], pb) < (head_start[ha], pa):
                pri, sec, pp, sp = hb, ha, pb, pa
            else:
                pri, sec, pp, sp = ha, hb, pa, pb
            hops = (_ring_dist(pp, sp, w.n_planes)
                    + _ring_dist(pri % spp, sec % spp, spp))
            pairs.append((pri, sec, hops))
            merged[pri] = merged[pri] + merged.pop(sec)
            hops_of[pri] = max(hops_of[pri], hops_of.pop(sec) + hops)
            uplinkers.append(pri)
        if len(planes) % 2:
            uplinkers.append(heads[planes[-1]])
        uplinkers.sort()
    return PlanePlan(heads=heads, arcs=arcs, uplinkers=uplinkers,
                     merged=merged, pairs=pairs, hops_of=hops_of)


def _plan_isl_transfers(plan: PlanePlan) -> int:
    """Number of msg-sized ISL transfers the plan performs: one per
    non-head member (convergecast) plus the inter-head gossip hops."""
    n = sum(len(up) + len(down) for up, down in plan.arcs.values())
    n += sum(hops for _, _, hops in plan.pairs)
    return n


# ---------------------------------------------------------------------------
# aggregation timing — oracle event machine vs. fast fold
# ---------------------------------------------------------------------------
# Both compute, for every uplinking head, the instant its merged wire is
# complete.  The float arithmetic must agree bit-for-bit: each hop is the
# fold  forward = max(own_ready, upstream_arrival); arrival = forward +
# hop_time  accumulated in arc order, and the head's readiness is a pure
# max over (own train, arc arrivals, gossip arrivals) — max is exact, so
# only the identical + accumulation matters.

def _arc_arrival_fold(chain: List[int], ready: np.ndarray, hop: float
                      ) -> float:
    """Arrival time of a convergecast arc's partial sum at the head."""
    arr = -np.inf
    for s in chain:                        # far → near
        arr = max(float(ready[s]), arr) + hop
    return arr


def agg_ready_fast(eng, plan: PlanePlan, t0: float, msg_bytes: float
                   ) -> List[Tuple[int, float]]:
    """Per-uplinker readiness times via the direct fold (fast path)."""
    sc = eng.scenario
    n = sc.walker.n_sats
    ready = t0 + np.broadcast_to(
        np.asarray(sc.compute_time, dtype=np.float64), (n,))
    hop = sc.link.isl_time(msg_bytes, hops=1)
    head_ready: Dict[int, float] = {}
    for h, (up, down) in plan.arcs.items():
        t = float(ready[h])
        for chain in (up, down):
            if chain:
                t = max(t, _arc_arrival_fold(chain, ready, hop))
        head_ready[h] = t
    for pri, sec, hops in plan.pairs:
        arr = head_ready[sec] + sc.link.isl_time(msg_bytes, hops=hops)
        head_ready[pri] = max(head_ready[pri], arr)
    return [(h, head_ready[h]) for h in plan.uplinkers]


def agg_ready_oracle(eng, plan: PlanePlan, t0: float, msg_bytes: float
                     ) -> List[Tuple[int, float]]:
    """Per-uplinker readiness times via a literal heapq event machine:
    ``agg_train`` (a member finished local training) and ``agg_forward``
    (a partial sum crossed one ISL hop).  A member forwards as soon as
    it holds both its own update and its upstream partial sum; the event
    arithmetic is the same ``max(own, upstream) + hop`` the fast fold
    uses, so the timelines agree bit-for-bit."""
    sc = eng.scenario
    hop = sc.link.isl_time(msg_bytes, hops=1)
    q: list = []
    seq = itertools.count()

    def push(t, kind, **kw):
        heapq.heappush(q, (t, next(seq), kind, kw))

    own: Dict[int, float] = {}             # sat -> train-done time
    upstream: Dict[int, float] = {}        # sat -> upstream arrival time
    downstream: Dict[int, Optional[int]] = {}
    participants: List[int] = []
    arc_arrival: Dict[int, List[float]] = {h: [] for h in plan.arcs}
    n_arcs: Dict[int, int] = {}
    head_of: Dict[int, int] = {}
    for h, (up, down) in plan.arcs.items():
        participants.append(h)
        head_of[h] = h
        n_arcs[h] = (1 if up else 0) + (1 if down else 0)
        for chain in (up, down):
            for i, s in enumerate(chain):
                participants.append(s)
                head_of[s] = h
                downstream[s] = chain[i + 1] if i + 1 < len(chain) else None
                if i == 0:
                    upstream[s] = -np.inf  # arc tip: nothing upstream
    for s in participants:
        push(t0 + sc.compute_of(s), "agg_train", sat=s)

    head_ready: Dict[int, float] = {}
    pending: Dict[int, int] = dict(n_arcs)

    def maybe_forward(s):
        if s in own and s in upstream:
            fwd = max(own[s], upstream[s])
            nxt = downstream[s]
            if nxt is None:
                push(fwd + hop, "agg_forward", sat=head_of[s], arc_tail=s)
            else:
                push(fwd + hop, "agg_forward", sat=nxt, arc_tail=None)
            del upstream[s]                # forward exactly once

    def maybe_ready(h):
        if h in own and pending[h] == 0 and h not in head_ready:
            t = own[h]
            for a in arc_arrival[h]:
                t = max(t, a)
            head_ready[h] = t

    while q:
        t, _, kind, kw = heapq.heappop(q)
        s = kw["sat"]
        if kind == "agg_train":
            own[s] = t
            if s in plan.arcs:
                maybe_ready(s)
            else:
                maybe_forward(s)
        else:                              # agg_forward
            if kw["arc_tail"] is not None or s in plan.arcs:
                # the hop landed at the head: one arc complete
                arc_arrival[s].append(t)
                pending[s] -= 1
                maybe_ready(s)
            else:
                upstream[s] = t
                maybe_forward(s)

    for pri, sec, hops in plan.pairs:
        arr = head_ready[sec] + sc.link.isl_time(msg_bytes, hops=hops)
        head_ready[pri] = max(head_ready[pri], arr)
    return [(h, head_ready[h]) for h in plan.uplinkers]


# ---------------------------------------------------------------------------
# head uplink phase — ONE implementation for both engines
# ---------------------------------------------------------------------------

def _uplink_heads(eng, ready: List[Tuple[int, float]], msg_bytes: float,
                  use_cache: bool) -> List[tuple]:
    """Uplink each head's merged wire through the standard machinery:
    64-iteration window refit, per-station serialization, and the lossy
    channel's ARQ.  ``use_cache`` routes estimates/commits through the
    engine's :class:`~repro.sim.fastpath.ChannelCache` (fast path) or the
    live channel (oracle) — the cache's acceptance contract is that both
    produce the identical floats.

    Returns ``(head, t_done, station, win_rise, outcome)`` tuples in
    completion order; heads with no fitting window this round drop out
    (no record — mirrors the direct path's undeliverable satellites)."""
    sc = eng.scenario
    gs_tx = sc.link.gs_time(msg_bytes)
    if use_cache:
        cache = eng.chan_cache
        est, commit = cache.estimate, cache.commit
    else:
        est, commit = eng.tx_estimate, eng.tx_commit
    q: list = []
    seq = itertools.count()

    def push(t, kind, **kw):
        heapq.heappush(q, (t, next(seq), kind, kw))

    station_free: Dict[int, float] = defaultdict(float)
    wins: Dict[int, object] = {}
    done: List[tuple] = []
    for h, t in ready:                     # plane order — canonical seq ties
        push(t, "head_ready", head=h)

    def try_tx(h, t):
        win = wins.get(h)
        if win is None or win[1] <= t:
            win = eng.usable_window(h, t)
        for _ in range(64):
            if win is None:
                wins[h] = None
                return                     # undeliverable this round
            start = max(t, win[0], station_free[win[2]])
            if start + est(h, win, start, msg_bytes, gs_tx) <= win[1]:
                break
            win = eng.usable_window(h, win[1])
        else:
            wins[h] = None
            return
        wins[h] = win
        if start > t:
            push(start, "tx_start", head=h)
            return
        t_done, outcome = commit(h, h, win, t, msg_bytes, gs_tx)
        station_free[win[2]] = t_done
        push(t_done, "tx_done", head=h, station=win[2], win_rise=win[0],
             outcome=outcome)

    while q:
        t, _, kind, kw = heapq.heappop(q)
        if kind == "tx_done":
            done.append((kw["head"], t, kw["station"], kw["win_rise"],
                         kw["outcome"]))
        else:                              # head_ready / tx_start
            try_tx(kw["head"], t)
    return done


# ---------------------------------------------------------------------------
# cluster-head failure + timeout-triggered failover (repro.faults)
# ---------------------------------------------------------------------------

def _apply_head_failures(eng, plan: PlanePlan, ready: List[Tuple[int, float]],
                         t0: float, msg_bytes: float):
    """Inject cluster-head failures mid-convergecast and fail over.

    ONE shared implementation consumed after either engine's aggregation
    timing (:func:`agg_ready_fast` / :func:`agg_ready_oracle`), so the
    failover timeline is bit-identical across engines by construction.
    The failure draw is keyed on ``(plane, bits(t0))`` (see
    :mod:`repro.faults.process`); a firing head fails at
    ``t_f = t0 + frac · (t_ready − t0)``.

    Salvage granularity is the convergecast *arc*: each arc's partial sum
    arrives at the head as one message, so an arc whose arrival precedes
    ``t_f`` was already absorbed by the dead head (its members' updates
    are lost with it), while an arc still in flight is held at its
    near-most member and can be re-routed.  After a ``failover_timeout``
    detection delay the surviving members re-elect a head (same criterion
    as the original election — earliest usable GS window, ties to the low
    sat id, lookahead horizon) and surviving partials forward
    ``ring-distance`` extra ISL hops to it; the new head uplinks the
    partial plane sum.  No eligible survivor → the plane skips the round.

    EF semantics: the failed head *crashed* (residual LOST — marked in
    ``crashed``); absorbed-arc members and stranded survivors are alive
    and merely lost their in-flight updates (*erasure*: residual kept,
    marked in ``aborted`` so the runner counts them attempted-but-lost).

    Returns ``(ready', extra_isl_transfers, failover_events, crashed,
    aborted)`` and updates ``plan`` (uplinkers / merged / hops_of) in
    place; with no firing draw everything passes through unchanged.
    """
    sc = eng.scenario
    fm = eng.faults
    w = sc.walker
    spp = w.sats_per_plane
    n = w.n_sats
    ready_vec = t0 + np.broadcast_to(
        np.asarray(sc.compute_time, dtype=np.float64), (n,))
    hop = sc.link.isl_time(msg_bytes, hops=1)
    crashed = np.zeros(n, dtype=bool)
    aborted = np.zeros(n, dtype=bool)
    events: List[dict] = []
    extra_transfers = 0
    out_ready: List[Tuple[int, float]] = []
    for h, t_ready in ready:
        p = h // spp
        frac = fm.head_failure(eng.seed, p, t0)
        if frac is None:
            out_ready.append((h, t_ready))
            continue
        t_f = t0 + frac * max(t_ready - t0, 0.0)
        t_detect = t_f + fm.failover_timeout
        up, down = plan.arcs[h]
        lost = [h]
        surv_arcs: List[Tuple[List[int], float]] = []
        for chain in (up, down):
            if not chain:
                continue
            arr = _arc_arrival_fold(chain, ready_vec, hop)
            if arr <= t_f:
                lost.extend(chain)         # absorbed by the dead head
            else:
                surv_arcs.append((chain, arr))
        crashed[h] = True
        aborted[lost] = True
        survivors = [s for chain, _ in surv_arcs for s in chain]
        new_head = None
        if survivors:
            best = None
            for s in sorted(survivors):
                win = eng.usable_window(s, max(float(ready_vec[s]),
                                               t_detect))
                if win is None or win[0] > t0 + sc.lookahead:
                    continue
                key = (win[0], s)
                if best is None or key < best[0]:
                    best = (key, s)
            if best is not None:
                new_head = best[1]
        if new_head is None:
            # nobody can take over inside the horizon: the plane skips
            # the round; stranded survivors keep their residuals (erasure)
            aborted[survivors] = True
            plan.uplinkers.remove(h)
            del plan.merged[h]
            plan.hops_of.pop(h, None)
            events.append(dict(plane=int(p), head=int(h), new_head=None,
                               t_fail=float(t_f), t_detect=float(t_detect),
                               n_lost=len(lost), n_salvaged=0,
                               extra_hops=0))
            continue
        t_new = t_detect
        extra = 0
        max_d = 0
        for chain, arr in surv_arcs:
            near = chain[-1]               # holds the in-flight partial
            d = _ring_dist(near - p * spp, new_head - p * spp, spp)
            t_new = max(t_new, max(arr, t_detect) + d * hop)
            extra += d
            max_d = max(max_d, d)
        extra_transfers += extra
        plan.uplinkers[plan.uplinkers.index(h)] = new_head
        plan.merged[new_head] = tuple(sorted(survivors))
        del plan.merged[h]
        plan.hops_of[new_head] = plan.hops_of.pop(h) + max_d
        events.append(dict(plane=int(p), head=int(h), new_head=int(new_head),
                           t_fail=float(t_f), t_detect=float(t_detect),
                           n_lost=len(lost), n_salvaged=len(survivors),
                           extra_hops=int(extra)))
        out_ready.append((new_head, float(t_new)))
    if not events:
        return ready, 0, None, None, None
    return out_ready, extra_transfers, events, crashed, aborted


# ---------------------------------------------------------------------------
# round driver
# ---------------------------------------------------------------------------

def run_round_plane(eng, t0: float, msg_bytes: float):
    """One synchronous plane-aggregated round (both engines; the fast /
    oracle split lives in the aggregation timing + channel evaluation,
    see module docstring)."""
    from .engine import Delivery, RoundResult

    sc = eng.scenario
    eng.ensure(t0 + 2 * sc.lookahead)
    plan = plan_plane_round(eng, t0)
    n = sc.walker.n_sats
    scheduled = np.zeros(n, dtype=bool)
    for members in plan.merged.values():
        scheduled[list(members)] = True
    bytes_isl = _plan_isl_transfers(plan) * msg_bytes
    if not plan.uplinkers:
        return RoundResult(np.zeros(n, dtype=bool), sc.max_compute, [],
                           scheduled, t0, bytes_isl=0.0, merged={},
                           heads=dict(plan.heads))
    if eng.fast:
        ready = agg_ready_fast(eng, plan, t0, msg_bytes)
    else:
        ready = agg_ready_oracle(eng, plan, t0, msg_bytes)
    failovers = crashed = aborted = None
    fm = getattr(eng, "faults", None)
    if fm is not None and fm.head_enabled:
        ready, extra_isl, failovers, crashed, aborted = \
            _apply_head_failures(eng, plan, ready, t0, msg_bytes)
        bytes_isl += extra_isl * msg_bytes
    done = _uplink_heads(eng, ready, msg_bytes, use_cache=eng.fast)
    deliveries = [
        Delivery(sat=h, t_done=td, t_start=t0, gateway=h, station=stn,
                 hops=plan.hops_of[h], window=rise, **outcome)
        for h, td, stn, rise, outcome in done]
    mask = np.zeros(n, dtype=bool)
    for d in deliveries:
        if d.delivered:
            mask[list(plan.merged[d.sat])] = True
    duration = (max(d.t_done for d in deliveries) - t0
                if deliveries else sc.max_compute)
    return RoundResult(mask, float(duration), deliveries, scheduled, t0,
                       bytes_isl=float(bytes_isl),
                       merged=dict(plan.merged), heads=dict(plan.heads),
                       crashed=crashed, aborted=aborted, failovers=failovers)
