"""Discrete-event constellation simulation engine.

A single heapq event queue drives per-satellite state machines through the
phases  train → (ISL relay) → wait-for-window → uplink.  The engine is
pure simulation substrate: it produces a timeline of :class:`Delivery`
records (which satellite's update landed at which ground station, when);
the federated-learning algebra lives in :class:`repro.core.fedlt_sat`.

Two operating modes:

  * :meth:`Engine.run_round` — synchronous: a scheduling policy picks the
    round's gateways + relays (see ``constellation.scheduler.Scheduler``),
    the engine executes the plan event-by-event (GS-link serialization,
    per-station contention, link dropout, heterogeneous compute times) and
    returns when the last scheduled update lands.
  * :meth:`Engine.run_async` — asynchronous: every satellite trains
    continuously; on finishing it routes its update to the satellite with
    the best estimated delivery (itself, or a multi-hop ISL forward) and
    immediately retrains once the update is delivered.  Feeds FedBuff-style
    buffered aggregation.

Event kinds: ``train_done``, ``isl_arrive``, ``tx_start`` (link-free /
window-open wakeup), ``tx_done``, ``retry`` (async: no window anywhere,
try again later).

``msg_bytes`` is the measured on-wire size of one update — callers with a
wire codec pass ``WireMessage.nbytes`` (see :mod:`repro.wire`), so every
transmission time and each :class:`Delivery`'s ``nbytes`` record derive
from actual encoded bytes, not nominal estimates.

All timing is host-side numpy/python — device compute stays in the
federated core.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..constellation.links import LinkModel
from ..constellation.orbits import GroundStation, Walker
from ..obs.trace import active as _obs_active
from .contacts import ContactPlan
from .routing import Router


@dataclasses.dataclass(frozen=True, eq=False)
class Scenario:
    """A complete simulation setting — constellation, stations, links,
    per-satellite compute, weather, and (optionally) a stochastic lossy
    channel (:class:`repro.channel.ChannelModel`)."""
    name: str = "walker-kiruna"
    walker: Walker = Walker()
    stations: Tuple[GroundStation, ...] = (GroundStation(),)
    link: LinkModel = LinkModel()
    compute_time: Union[float, np.ndarray] = 30.0  # scalar or (S,) seconds
    dropout: float = 0.0        # P(a contact window is weather-blocked)
    k_direct: int = 4
    n_relay: int = 2
    max_hops: int = 4
    lookahead: float = 7200.0   # scheduling horizon per round
    dt: float = 10.0            # contact-plan grid resolution
    channel: Optional[object] = None  # repro.channel.ChannelModel or None
    # how updates reach the ground (repro.sim.topology): None ≡ "direct"
    # (per-satellite uplinks, the historical behavior), "plane" (per-plane
    # convergecast to an elected cluster head), "gossip" (plane + paired
    # inter-head merge) or a Topology instance
    topology: Optional[object] = None
    # node-level fault injection (repro.faults.FaultModel): satellite
    # crash/reboot, ground-station blackouts, cluster-head failure
    faults: Optional[object] = None

    def compute_of(self, sat: int) -> float:
        if np.ndim(self.compute_time) == 0:
            return float(self.compute_time)
        return float(np.asarray(self.compute_time)[sat])

    @property
    def max_compute(self) -> float:
        return float(np.max(self.compute_time))


@dataclasses.dataclass
class Delivery:
    sat: int            # whose update landed
    t_done: float       # delivery completion time
    t_start: float      # when that satellite started training the update
    gateway: int        # satellite that performed the GS uplink
    station: int        # ground-station index
    hops: int           # ISL hops travelled
    nbytes: float = 0.0  # payload bytes usefully delivered (0 on failure)
    window: float = float("nan")  # rise time of the contact window used
    # lossy-channel accounting (== nbytes / 0 / True without a channel):
    nbytes_attempted: float = 0.0  # bytes put on the air, retx included
    retries: int = 0               # ARQ rounds beyond the first
    delivered: bool = True         # all segments landed (False: lost/truncated)

    def to_dict(self) -> dict:
        """JSON-stable serialization (the tracer's delivery record).

        Every field maps to a plain python scalar; the one NaN-able field
        (``window``, NaN on records predating the window tagging) maps to
        ``None`` so the output survives strict JSON round-trips
        (:meth:`from_dict` restores the NaN)."""
        w = self.window
        return {"sat": int(self.sat), "t_done": float(self.t_done),
                "t_start": float(self.t_start),
                "gateway": int(self.gateway), "station": int(self.station),
                "hops": int(self.hops), "nbytes": float(self.nbytes),
                "window": float(w) if w == w else None,
                "nbytes_attempted": float(self.nbytes_attempted),
                "retries": int(self.retries),
                "delivered": bool(self.delivered)}

    @classmethod
    def from_dict(cls, d: dict) -> "Delivery":
        w = d["window"]
        return cls(sat=d["sat"], t_done=d["t_done"], t_start=d["t_start"],
                   gateway=d["gateway"], station=d["station"],
                   hops=d["hops"], nbytes=d["nbytes"],
                   window=float("nan") if w is None else w,
                   nbytes_attempted=d["nbytes_attempted"],
                   retries=d["retries"], delivered=d["delivered"])


@dataclasses.dataclass
class Cohort:
    """Deliveries sharing one (station, contact window): the unit at which
    uplink compression work batches.

    Every update that crosses the same ground-station window is, at the
    receiving end, one contiguous burst — so the compress→EF→pack chain
    for a cohort's satellites runs as ONE stacked kernel dispatch
    (:mod:`repro.kernels.compress_pipeline`) instead of one chain per
    satellite.  See ``SpaceRunner(measure="cohort")``.
    """

    station: int
    window: float               # rise time of the shared contact window
    sats: List[int]             # delivery order within the window
    deliveries: List[Delivery]

    @property
    def t_first(self) -> float:
        return self.deliveries[0].t_done

    @property
    def t_last(self) -> float:
        return self.deliveries[-1].t_done


def group_cohorts(deliveries: Sequence[Delivery]) -> List["Cohort"]:
    """Group deliveries into per-(station, contact-window) cohorts, ordered
    by first delivery time.  Deliveries predating the ``window`` field
    (NaN) each form a singleton cohort."""
    groups: Dict[tuple, Cohort] = {}
    for i, d in enumerate(deliveries):
        key = (d.station, d.window) if d.window == d.window else ("?", i)
        c = groups.get(key)
        if c is None:
            groups[key] = Cohort(d.station, d.window, [d.sat], [d])
        else:
            c.sats.append(d.sat)
            c.deliveries.append(d)
    return sorted(groups.values(), key=lambda c: c.t_first)


@dataclasses.dataclass
class RoundResult:
    mask: np.ndarray            # bool (S,) — updates actually delivered
    duration: float
    deliveries: List[Delivery]
    scheduled: np.ndarray       # bool (S,) — what the policy planned
    t0: float = 0.0
    # in-orbit aggregation (repro.sim.topology) — direct rounds keep the
    # defaults, so their serialization and downstream accounting are
    # unchanged:
    bytes_isl: float = 0.0      # wire bytes spent on ISL hops this round
    # uplinking head -> every satellite its merged wire sums (None: direct)
    merged: Optional[Dict[int, Tuple[int, ...]]] = None
    heads: Optional[Dict[int, int]] = None   # plane -> elected head
    # fault injection (repro.faults) — None on fault-free rounds:
    crashed: Optional[np.ndarray] = None   # bool (S,) — sats whose memory
    #                                        (EF residual) was wiped
    aborted: Optional[np.ndarray] = None   # bool (S,) — updates destroyed
    #                                        in-orbit with no delivery record
    faults: Optional[List[dict]] = None       # `fault` event records
    failovers: Optional[List[dict]] = None    # `head_failover` event records

    def cohorts(self) -> List[Cohort]:
        """Per-(station, contact-window) delivery cohorts (see
        :class:`Cohort`)."""
        return group_cohorts(self.deliveries)

    def to_dict(self) -> dict:
        """JSON-stable serialization: masks as bool lists, deliveries via
        :meth:`Delivery.to_dict` (round-trips through :meth:`from_dict`).
        Aggregation fields only appear on plane-topology rounds, so direct
        rounds serialize exactly as they always have."""
        out = {"mask": [bool(b) for b in self.mask],
               "duration": float(self.duration),
               "deliveries": [d.to_dict() for d in self.deliveries],
               "scheduled": [bool(b) for b in self.scheduled],
               "t0": float(self.t0)}
        if self.merged is not None:
            out["bytes_isl"] = float(self.bytes_isl)
            out["merged"] = {str(h): [int(s) for s in ms]
                             for h, ms in self.merged.items()}
            out["heads"] = {str(p): int(h)
                            for p, h in (self.heads or {}).items()}
        if self.crashed is not None:
            out["crashed"] = [bool(b) for b in self.crashed]
        if self.aborted is not None:
            out["aborted"] = [bool(b) for b in self.aborted]
        if self.faults:
            out["faults"] = [dict(ev) for ev in self.faults]
        if self.failovers:
            out["failovers"] = [dict(ev) for ev in self.failovers]
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "RoundResult":
        merged = d.get("merged")
        return cls(mask=np.asarray(d["mask"], dtype=bool),
                   duration=d["duration"],
                   deliveries=[Delivery.from_dict(x)
                               for x in d["deliveries"]],
                   scheduled=np.asarray(d["scheduled"], dtype=bool),
                   t0=d["t0"],
                   bytes_isl=d.get("bytes_isl", 0.0),
                   merged=None if merged is None else {
                       int(h): tuple(ms) for h, ms in merged.items()},
                   heads=None if merged is None else {
                       int(p): int(h)
                       for p, h in d.get("heads", {}).items()},
                   crashed=(None if "crashed" not in d else
                            np.asarray(d["crashed"], dtype=bool)),
                   aborted=(None if "aborted" not in d else
                            np.asarray(d["aborted"], dtype=bool)),
                   faults=d.get("faults"),
                   failovers=d.get("failovers"))


# ---------------------------------------------------------------------------
# trace emission (repro.obs)
# ---------------------------------------------------------------------------
# Emission happens HERE, in the run_round/run_async wrappers, after the
# engine (fast batch core or heapq oracle) has produced its result: both
# paths therefore emit the identical record schema from the identical
# Delivery timeline, which is what lets `python -m repro.obs diff`
# localize the first fast-vs-oracle divergence.  The hot event loops are
# untouched — with no active tracer the only cost is one module
# attribute read per round.

def _emit_round_trace(trc, res: "RoundResult", engine: str, k: int) -> None:
    """Emit one sync round's records (kinds: delivery/arq/cohort/round)
    and bump the byte/latency metrics."""
    mtr = trc.metrics
    lat = mtr.histogram("delivery_latency", lo=0.0)
    air_c = mtr.counter("bytes_air")
    retx_c = mtr.counter("bytes_retx")
    dlv_c = mtr.counter("deliveries")
    bytes_air = 0.0
    n_lost = 0
    for d in res.deliveries:
        rec = d.to_dict()
        rec["kind"] = "delivery"
        rec["round"] = k
        trc.raw(rec)
        bytes_air += d.nbytes_attempted
        n_lost += not d.delivered
        air_c.add(d.nbytes_attempted, station=d.station)
        retx_c.add(d.nbytes_attempted - d.nbytes)
        dlv_c.add(1.0, status="ok" if d.delivered else "lost")
        lat.observe(d.t_done - d.t_start)
        if d.retries or not d.delivered:
            w = d.window
            trc.event("arq", round=k, sat=int(d.sat),
                      gateway=int(d.gateway), station=int(d.station),
                      window=float(w) if w == w else None,
                      retries=int(d.retries), delivered=bool(d.delivered),
                      nbytes_attempted=float(d.nbytes_attempted),
                      t_done=float(d.t_done))
    for c in res.cohorts():
        w = c.window
        trc.event("cohort", round=k, station=int(c.station),
                  window=float(w) if w == w else None,
                  n_sats=len(c.sats), t_first=float(c.t_first),
                  t_last=float(c.t_last),
                  nbytes=float(sum(d.nbytes for d in c.deliveries)))
    if res.deliveries:
        mtr.histogram("lost_frac", lo=0.0).observe(
            n_lost / len(res.deliveries))
    # n_delivered counts delivered *wires* (delivery records), which for
    # direct rounds equals mask.sum() — each scheduled satellite uplinks
    # at most once — and for plane rounds counts head uplinks, keeping
    # the check() count invariant engine-agnostic; the member count rides
    # on the plane extras below
    n_ok = sum(bool(d.delivered) for d in res.deliveries)
    extra = {}
    if res.merged is not None:
        extra = dict(topology="plane", bytes_isl=float(res.bytes_isl),
                     n_members_delivered=int(res.mask.sum()))
    trc.event("round", round=k, t0=float(res.t0),
              duration=float(res.duration),
              n_scheduled=int(res.scheduled.sum()),
              n_delivered=n_ok, n_lost=n_lost,
              bytes_air=bytes_air, engine=engine, **extra)
    trc.series("bytes_air", k, bytes_air)
    if res.deliveries:
        trc.series("lost_frac_air", k, n_lost / len(res.deliveries))
    if res.merged is not None:
        # plane-topology extras: the ISL/GS byte split plus one election
        # record per plane with a head — deterministic plan output, so
        # fast and oracle traces agree (head_elect is a DIFF kind)
        mtr.counter("bytes_isl").add(float(res.bytes_isl))
        trc.series("bytes_isl", k, float(res.bytes_isl))
        trc.series("bytes_gs", k, bytes_air)
        uplinker_of = {s: h for h, ms in res.merged.items() for s in ms}
        for p in sorted(res.heads or {}):
            h = res.heads[p]
            trc.event("head_elect", round=k, plane=int(p), head=int(h),
                      uplinker=int(uplinker_of.get(h, h)),
                      n_merged=len(res.merged.get(
                          uplinker_of.get(h, h), ())))
    # fault injection (repro.faults): both engines run the identical
    # shared post-filter, so these streams are DIFF kinds like delivery
    for ev in res.faults or ():
        trc.event("fault", round=k, **ev)
        mtr.counter("faults").add(1.0, what=ev.get("what", "?"))
    for ev in res.failovers or ():
        trc.event("head_failover", round=k, **ev)
        mtr.counter("faults").add(1.0, what="head_failure")


def _emit_async_trace(trc, deliveries: Sequence[Delivery], engine: str,
                      run: int, t0: float, n_requested: int,
                      fault_events: Sequence[dict] = ()) -> None:
    """Emit one async run's records: per-delivery (``round=None``,
    tagged with the run index) plus a closing ``async_run`` summary."""
    mtr = trc.metrics
    lat = mtr.histogram("delivery_latency", lo=0.0)
    air_c = mtr.counter("bytes_air")
    retx_c = mtr.counter("bytes_retx")
    dlv_c = mtr.counter("deliveries")
    bytes_air = 0.0
    n_ok = 0
    for d in deliveries:
        rec = d.to_dict()
        rec["kind"] = "delivery"
        rec["round"] = None
        rec["run"] = run
        trc.raw(rec)
        bytes_air += d.nbytes_attempted
        n_ok += bool(d.delivered)
        air_c.add(d.nbytes_attempted, station=d.station)
        retx_c.add(d.nbytes_attempted - d.nbytes)
        dlv_c.add(1.0, status="ok" if d.delivered else "lost")
        lat.observe(d.t_done - d.t_start)
        if d.retries or not d.delivered:
            w = d.window
            trc.event("arq", round=None, run=run, sat=int(d.sat),
                      gateway=int(d.gateway), station=int(d.station),
                      window=float(w) if w == w else None,
                      retries=int(d.retries), delivered=bool(d.delivered),
                      nbytes_attempted=float(d.nbytes_attempted),
                      t_done=float(d.t_done))
    for ev in fault_events:
        trc.event("fault", round=None, run=run, **ev)
        mtr.counter("faults").add(1.0, what=ev.get("what", "?"))
    t_end = max((d.t_done for d in deliveries), default=t0)
    trc.event("async_run", run=run, t0=float(t0),
              n_requested=int(n_requested), n_deliveries=len(deliveries),
              n_ok=n_ok, n_lost=len(deliveries) - n_ok,
              bytes_air=bytes_air, t_end=float(t_end), engine=engine)
    # async curves get their own names: a trace mixing sync rounds and
    # async runs would otherwise collide on the step axis
    trc.series("async_bytes_air", run, bytes_air)
    if deliveries:
        trc.series("async_lost_frac", run,
                   (len(deliveries) - n_ok) / len(deliveries))


def _check_faults_compatible(faults, topology) -> None:
    """Head-failure injection needs the plane convergecast's failover
    machinery; the gossip pair-merge has no re-election analogue yet."""
    if (faults is not None and getattr(faults, "head_enabled", False)
            and getattr(topology, "gossip", False)):
        raise ValueError(
            "head_failure_rate > 0 supports topology='direct'/'plane' "
            "only — gossip pair-merge failover is not modeled "
            f"(topology={topology.name!r})")


def _apply_sync_faults(eng: "Engine", res: RoundResult) -> RoundResult:
    """Shared satellite-crash post-filter for sync rounds (both engines).

    Runs AFTER either engine produced its (bit-identical) result, so the
    fault timeline is bit-identical by construction.  Crash draws are
    keyed on (sat, bits(t_start)) — see :mod:`repro.faults.process`.

    * direct rounds: an upset during a flight ``[t_start, t_done]``
      destroys the in-flight update — the delivery flips to lost.
    * plane rounds: an upset during a *member's* local training destroys
      its contribution before it enters the plane sum (the merged wire
      still flies, one slot lighter); uplinking heads are handled by the
      head-failover machinery in :mod:`repro.sim.topology` instead.

    Either way the crashed sat reboots with wiped memory: ``res.crashed``
    marks it for the EF residual re-sync in
    :class:`repro.core.fedlt_sat.SpaceRunner` (residual LOST — unlike an
    erasure, where the residual is kept and telescopes forward).
    """
    fm = eng.faults
    events: List[dict] = []
    crashed = (res.crashed.copy() if res.crashed is not None
               else np.zeros(len(res.mask), dtype=bool))
    mask = res.mask
    deliveries = res.deliveries
    if res.merged is None:
        if deliveries:
            sats = np.array([d.sat for d in deliveries], dtype=np.int64)
            t_s = np.array([d.t_start for d in deliveries])
            exp = np.array([d.t_done for d in deliveries]) - t_s
            hit = fm.crash_mask(eng.seed, sats, t_s, exp)
            if hit.any():
                t_crash = fm.crash_times(eng.seed, sats, t_s, exp)
                mask = mask.copy()
                deliveries = list(deliveries)
                for i, d in enumerate(deliveries):
                    if not hit[i]:
                        continue
                    crashed[d.sat] = True
                    mask[d.sat] = False
                    events.append(dict(
                        what="sat_crash", sat=int(d.sat),
                        t_crash=float(t_crash[i]),
                        t_start=float(d.t_start), station=int(d.station),
                        in_flight=bool(d.delivered)))
                    deliveries[i] = dataclasses.replace(
                        d, delivered=False, nbytes=0.0)
    else:
        uplinkers = set(res.merged.keys())
        members = sorted(
            s for ms in res.merged.values() for s in ms
            if s not in uplinkers)
        if members:
            sats = np.asarray(members, dtype=np.int64)
            t_s = np.full(len(members), res.t0)
            exp = np.array([eng.scenario.compute_of(s) for s in members])
            hit = fm.crash_mask(eng.seed, sats, t_s, exp)
            if hit.any():
                t_crash = fm.crash_times(eng.seed, sats, t_s, exp)
                mask = mask.copy()
                for i, s in enumerate(members):
                    if not hit[i]:
                        continue
                    crashed[s] = True
                    mask[s] = False
                    events.append(dict(
                        what="sat_crash", sat=int(s),
                        t_crash=float(t_crash[i]),
                        t_start=float(res.t0), station=None,
                        in_flight=True))
    if not events and res.crashed is None:
        return res
    return dataclasses.replace(
        res, mask=mask, deliveries=deliveries,
        crashed=crashed if crashed.any() else res.crashed,
        faults=(list(res.faults or ()) + events) or None)


def _apply_async_faults(eng: "Engine", records: List[Delivery]
                        ) -> Tuple[List[Delivery], List[dict]]:
    """Shared satellite-crash post-filter for async runs (both engines).

    An upset during a flight destroys the in-flight update (the record
    flips to lost); the sat reboots and keeps training.  The async path
    has no EF revert machinery, so a crash here costs exactly the update
    — the residual-wipe semantics only bind in sync mode.
    """
    fm = eng.faults
    if not records:
        return records, []
    sats = np.array([d.sat for d in records], dtype=np.int64)
    t_s = np.array([d.t_start for d in records])
    exp = np.array([d.t_done for d in records]) - t_s
    ok = np.array([d.delivered for d in records], dtype=bool)
    hit = fm.crash_mask(eng.seed, sats, t_s, exp) & ok
    if not hit.any():
        return records, []
    t_crash = fm.crash_times(eng.seed, sats, t_s, exp)
    events: List[dict] = []
    out = list(records)
    for i, d in enumerate(out):
        if not hit[i]:
            continue
        events.append(dict(what="sat_crash", sat=int(d.sat),
                           t_crash=float(t_crash[i]),
                           t_start=float(d.t_start),
                           station=int(d.station), in_flight=True))
        out[i] = dataclasses.replace(d, delivered=False, nbytes=0.0)
    return out, events


class Engine:
    """Event-queue simulator over a :class:`Scenario`.

    ``policy`` must expose ``assign(t0, msg_bytes, engine)`` returning a
    ``constellation.scheduler.Assignment``; defaults to the contact-plan
    :class:`~repro.constellation.scheduler.Scheduler` configured from the
    scenario.

    ``fast=True`` (the default) routes :meth:`run_round` /
    :meth:`run_async` through the vectorized batch-event core
    (:mod:`repro.sim.fastpath`): structured numpy event arrays with
    same-timestamp batch pops, batched route/window resolution, and a
    cached/vectorized channel stack.  ``fast=False`` keeps the original
    heapq state machine as the reference oracle; the two produce
    bit-identical :class:`Delivery` timelines on any fixed seed (the
    fast path's acceptance contract, enforced by
    ``tests/test_fastpath_equivalence``).
    """

    def __init__(self, scenario: Scenario, policy=None, seed: int = 0,
                 fast: bool = True):
        from .topology import check_plane_compatible, make_topology
        self.scenario = scenario
        self.seed = seed
        self.fast = bool(fast)
        self.topology = make_topology(scenario.topology)
        check_plane_compatible(scenario, self.topology)
        self.channel = scenario.channel   # repro.channel.ChannelModel | None
        self.faults = scenario.faults     # repro.faults.FaultModel | None
        _check_faults_compatible(self.faults, self.topology)
        self.plan = ContactPlan(scenario.walker, scenario.stations,
                                horizon=max(2 * scenario.lookahead, 7200.0),
                                dt=scenario.dt)
        self.router = Router(scenario.walker, scenario.link)
        self._chan_cache = None
        self._fast = None
        self._round_idx = 0       # trace round counter (repro.obs)
        self._async_idx = 0       # trace async-run counter
        self._blocked: Optional[list] = None
        self._refresh_blocked()
        if policy is None:
            from ..constellation.scheduler import Scheduler  # lazy: no cycle
            policy = Scheduler(walker=scenario.walker, gs=scenario.stations,
                               link=scenario.link, k_direct=scenario.k_direct,
                               n_relay=scenario.n_relay,
                               compute_time=scenario.compute_time,
                               lookahead=scenario.lookahead, dt=scenario.dt,
                               max_hops=scenario.max_hops)
        self.policy = policy

    # -- contact-plan / weather / outage plumbing --------------------------
    def _refresh_blocked(self) -> None:
        """Recompute the blocked-window mask aligned with the plan's window
        arrays: weather dropout plus channel conjunction blackouts.

        Blocked-ness is a DETERMINISTIC hash of (seed, station, sat, window
        rise time), not a fresh draw — so extending the plan horizon never
        retroactively flips the availability of a window the simulation
        already consulted.  Conjunction blackouts
        (:class:`repro.channel.outage.ConjunctionBlackout` on the
        scenario's channel) are deterministic functions of the rise time
        and layer into the same mask: a window whose rise falls inside a
        blackout is unusable.  Ground-station blackout faults
        (:class:`repro.faults.FaultModel` ``gs_outage_rate``) layer in the
        same way — a window rising inside a dark slot of its station is
        unusable, which forces re-routing through other stations /
        windows / relays identically in BOTH engines (they consume the
        same mask)."""
        blackout = getattr(self.channel, "blackout", None)
        fm = self.faults
        gs_out = fm is not None and getattr(fm, "gs_enabled", False)
        if self.scenario.dropout <= 0.0 and blackout is None and not gs_out:
            self._blocked = [None] * self.plan.n_stations
            return
        blocked = []
        n = self.scenario.walker.n_sats
        sat_ids = np.arange(n, dtype=np.uint64)[:, None]
        for g, rises in enumerate(self.plan.rises):
            finite = np.isfinite(rises)
            if self.scenario.dropout > 0.0:
                # hand-rolled splitmix64 over the window identity; kept
                # verbatim (not repro.channel.outage.counter_uniforms,
                # which chains its counters differently) so existing
                # seeds keep producing the same weather patterns
                # window identity: its rise index on the immutable time grid
                k = np.where(finite, rises / self.plan.dt, 0.0)
                k = k.astype(np.uint64)
                x = (k * np.uint64(0x9E3779B97F4A7C15)
                     ^ sat_ids * np.uint64(0xBF58476D1CE4E5B9)
                     ^ np.uint64(((g + 1) * 0x94D049BB133111EB) % 2**64)
                     ^ np.uint64((self.seed * 2654435761 + 1) % 2**64))
                # splitmix64 finalizer → uniform in [0, 1)
                x ^= x >> np.uint64(30)
                x *= np.uint64(0xBF58476D1CE4E5B9)
                x ^= x >> np.uint64(27)
                x *= np.uint64(0x94D049BB133111EB)
                x ^= x >> np.uint64(31)
                u = x.astype(np.float64) / float(2**64)
                b = u < self.scenario.dropout
            else:
                b = np.zeros(rises.shape, dtype=bool)
            if blackout is not None:
                phase = (np.where(finite, rises, 0.0)
                         - g * blackout.station_phase) % blackout.period
                b = b | (finite & (phase < blackout.duration))
            if gs_out:
                dark = fm.station_dark(self.seed, g,
                                       np.where(finite, rises, 0.0))
                b = b | (finite & dark)
            blocked.append(b)
        self._blocked = blocked
        trc = _obs_active()
        if trc is not None:
            # outage summary per station: how much of the plan's window
            # budget weather/conjunctions removed.  Re-emitted on every
            # horizon extension (the mask is recomputed), so records carry
            # the horizon to tell refreshes apart; not a DIFF kind.
            for g, b in enumerate(blocked):
                finite = np.isfinite(self.plan.rises[g])
                trc.event("outage", station=g,
                          horizon=float(self.plan.horizon),
                          n_windows=int(finite.sum()),
                          n_blocked=int((b & finite).sum()))

    def ensure(self, t_end: float) -> None:
        old = self.plan.horizon
        # fast path (the per-event call in the async loops): replicate
        # ContactPlan.ensure's early-exit here so the covered case costs
        # one compare and the profiler only times actual extensions
        if t_end <= self.plan.t_start + old:
            return
        trc = _obs_active()
        prof = trc.prof if trc is not None else None
        if prof is not None:
            prof.begin("plan_extend")
        self.plan.ensure(t_end)
        if self.plan.horizon != old:
            self._refresh_blocked()
        if prof is not None:
            prof.end()

    def install_channel(self, channel) -> None:
        """Install (or clear) a lossy channel post-construction.

        Mutating ``engine.channel`` directly is a footgun: the fast
        path's :class:`~repro.sim.fastpath.ChannelCache` may already have
        memoized ARQ plans / estimates for the previous channel, and the
        blocked-window mask may carry its conjunction blackouts.  This is
        the supported install path — it drops the memo wholesale and
        recomputes the mask.  (:class:`repro.core.fedlt_sat.SpaceRunner`
        and :class:`repro.api.Experiment` route through here.)"""
        self.channel = channel
        self._chan_cache = None           # drop memoized plans/estimates
        self._refresh_blocked()           # re-layer conjunction blackouts

    def install_faults(self, faults) -> None:
        """Install (or clear) a fault model post-construction.

        The supported mutation path, mirroring :meth:`install_channel`:
        ground-station blackout faults live in the blocked-window mask,
        so the mask must be recomputed whenever the model changes.
        (:class:`repro.core.fedlt_sat.SpaceRunner` and
        :class:`repro.api.Experiment` route through here.)"""
        _check_faults_compatible(faults, self.topology)
        self.faults = faults
        self._refresh_blocked()           # re-layer GS outage slots

    def usable_window(self, sat: int, t: float
                      ) -> Optional[Tuple[float, float, int]]:
        """Earliest non-blocked window with ``set > t`` across stations."""
        return self.plan.next_window(sat, t, blocked=self._blocked)

    def usable_windows_all(self, t: np.ndarray
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized :meth:`usable_window` over all satellites."""
        return self.plan.next_windows_all(t, blocked=self._blocked)

    # -- lossy-channel transmission ----------------------------------------
    def _window_id(self, rise: float) -> int:
        """Stable window identity for channel RNG counters: the rise index
        on the immutable contact-plan time grid."""
        return int(round(rise / self.plan.dt))

    def tx_estimate(self, gateway: int, win, t: float, nbytes: float,
                    gs_tx: float) -> float:
        """Expected GS transmission time for window-fit checks.  The fixed
        ``gs_tx`` without a channel; otherwise the channel's rate/loss-aware
        estimate at the gateway's elevation (channel-aware scheduling)."""
        if self.channel is None:
            return gs_tx
        sc = self.scenario
        return self.channel.estimate_time(
            sc.link, nbytes, walker=sc.walker,
            station_obj=sc.stations[win[2]], gateway=gateway, t=t,
            seed=self.seed, station=win[2],
            window_id=self._window_id(win[0]))

    def tx_commit(self, gateway: int, sat: int, win, t: float,
                  nbytes: float, gs_tx: float) -> Tuple[float, dict]:
        """Execute one GS uplink starting at ``t`` inside ``win``.

        Returns ``(t_done, delivery_kwargs)`` — without a channel this is
        the historical fixed-time transmission; with one it runs the
        windowed selective-repeat ARQ, whose retransmissions consume real
        window time and may truncate the delivery mid-window.
        """
        if self.channel is None:
            return t + gs_tx, dict(nbytes=nbytes, nbytes_attempted=nbytes,
                                   retries=0, delivered=True)
        sc = self.scenario
        res = self.channel.transmit(
            sc.link, nbytes, walker=sc.walker,
            station_obj=sc.stations[win[2]], gateway=gateway, sat=sat,
            t_start=t, window_end=win[1], seed=self.seed, station=win[2],
            window_id=self._window_id(win[0]))
        return res.t_done, dict(nbytes=res.nbytes,
                                nbytes_attempted=res.nbytes_attempted,
                                retries=res.retries, delivered=res.delivered)

    # -- fast-path plumbing ------------------------------------------------
    @property
    def chan_cache(self):
        """Lazily-built :class:`repro.sim.fastpath.ChannelCache`."""
        if self._chan_cache is None:
            from .fastpath import ChannelCache    # lazy: no import cycle
            self._chan_cache = ChannelCache(self)
        return self._chan_cache

    def _fast_state(self):
        """Lazily-built fast-path topology/ISL caches."""
        if self._fast is None:
            from .fastpath import _FastState      # lazy: no import cycle
            self._fast = _FastState(self)
        return self._fast

    # -- synchronous mode --------------------------------------------------
    def run_round(self, t0: float, msg_bytes: float) -> RoundResult:
        """One synchronous round (see the class docstring).  Dispatches
        on the topology first (plane rounds run the in-orbit aggregation
        driver in :mod:`repro.sim.topology`), then to the vectorized fast
        path unless ``fast=False``."""
        trc = _obs_active()
        t_wall = time.perf_counter() if trc is not None else 0.0
        if self.topology.kind != "direct":
            from .topology import run_round_plane
            res = run_round_plane(self, t0, msg_bytes)
        elif self.fast:
            from .fastpath import run_round_fast
            res = run_round_fast(self, t0, msg_bytes)
        else:
            res = self._run_round_oracle(t0, msg_bytes)
        if self.faults is not None and self.faults.crashes_enabled:
            res = _apply_sync_faults(self, res)
        k, self._round_idx = self._round_idx, self._round_idx + 1
        if trc is not None:
            engine = "fast" if self.fast else "oracle"
            trc.prof.begin("trace_emit")
            _emit_round_trace(trc, res, engine, k)
            trc.prof.end()
            trc.prof.flush(trc, engine=engine, mode="sync", round=k,
                           wall=time.perf_counter() - t_wall)
        return res

    def _run_round_oracle(self, t0: float, msg_bytes: float) -> RoundResult:
        sc = self.scenario
        trc = _obs_active()
        prof = trc.prof if trc is not None else None
        self.ensure(t0 + 2 * sc.lookahead)
        if prof is not None:
            prof.begin("assign")
        asg = self.policy.assign(t0, msg_bytes, self)
        if prof is not None:
            prof.end()
        n = sc.walker.n_sats
        scheduled = np.zeros(n, dtype=bool)
        for s in asg.gateways:
            scheduled[s] = True
        for s in asg.relays:
            scheduled[s] = True
        if not asg.gateways:
            return RoundResult(np.zeros(n, dtype=bool), sc.max_compute, [],
                               scheduled, t0)

        gs_tx = sc.link.gs_time(msg_bytes)
        q: list = []
        seq = itertools.count()

        def push(t, kind, **kw):
            heapq.heappush(q, (t, next(seq), kind, kw))

        tx_state = {g: {"queue": [], "busy": False,
                        "win": asg.windows[g]} for g in asg.gateways}
        station_free: Dict[int, float] = defaultdict(float)
        deliveries: List[Delivery] = []
        hops_of = {s: r.hops for s, r in asg.relays.items()}

        for s in asg.gateways:
            push(t0 + sc.compute_of(s), "train_done", sat=s)
        for s in asg.relays:
            push(t0 + sc.compute_of(s), "train_done", sat=s)

        def try_tx(g, t):
            st = tx_state[g]
            if st["busy"] or not st["queue"]:
                return
            if prof is not None:
                prof.begin("window_fit")
            win = st["win"]
            fit = False
            for _ in range(64):
                if win is None:
                    break
                start = max(t, win[0], station_free[win[2]])
                if start + self.tx_estimate(g, win, start, msg_bytes,
                                            gs_tx) <= win[1]:
                    fit = True
                    break
                win = self.usable_window(g, win[1])
            if prof is not None:
                prof.end()
            if not fit:                         # undeliverable this round
                st["queue"].clear()
                st["win"] = None
                return
            st["win"] = win
            if start > t:
                push(start, "tx_start", gw=g)
                return
            _, sat = st["queue"].pop(0)         # FIFO = arrival order
            st["busy"] = True
            if prof is not None:
                prof.begin("tx_commit")
            t_done, outcome = self.tx_commit(g, sat, win, t, msg_bytes,
                                             gs_tx)
            if prof is not None:
                prof.end()
            station_free[win[2]] = t_done
            push(t_done, "tx_done", gw=g, sat=sat, station=win[2],
                 win_rise=win[0], outcome=outcome)

        if prof is not None:
            prof.begin("event_loop")
        while q:
            t, _, kind, kw = heapq.heappop(q)
            if kind == "train_done":
                s = kw["sat"]
                if s in tx_state:
                    tx_state[s]["queue"].append((t, s))
                    try_tx(s, t)
                else:
                    r = asg.relays[s]
                    push(t + r.time, "isl_arrive", sat=s, gw=r.gateway)
            elif kind == "isl_arrive":
                tx_state[kw["gw"]]["queue"].append((t, kw["sat"]))
                try_tx(kw["gw"], t)
            elif kind == "tx_start":
                try_tx(kw["gw"], t)
            elif kind == "tx_done":
                g, s = kw["gw"], kw["sat"]
                deliveries.append(Delivery(
                    sat=s, t_done=t, t_start=t0, gateway=g,
                    station=kw["station"], hops=hops_of.get(s, 0),
                    window=kw["win_rise"], **kw["outcome"]))
                tx_state[g]["busy"] = False
                try_tx(g, t)
        if prof is not None:
            prof.end()

        mask = np.zeros(n, dtype=bool)
        for d in deliveries:
            if d.delivered:
                mask[d.sat] = True
        duration = (max(d.t_done for d in deliveries) - t0
                    if deliveries else sc.max_compute)
        return RoundResult(mask, float(duration), deliveries, scheduled, t0)

    # -- asynchronous mode -------------------------------------------------
    def run_async(self, t0: float, msg_bytes: float, n_deliveries: int,
                  max_time: Optional[float] = None) -> List[Delivery]:
        """Free-running constellation: each satellite trains, ships its
        update (direct or multi-hop ISL), and retrains on delivery.

        Returns delivery records in time order up to and including the
        ``n_deliveries``-th *successful* one; stops early at ``max_time``
        simulated seconds past ``t0`` (default ``100 × lookahead``) if
        windows run dry.  With a lossy channel the list also contains the
        failed attempts (``delivered=False``) interleaved at their
        completion times — without one every record is a success, so the
        result is exactly the first ``n_deliveries`` deliveries.

        Dispatches to the vectorized fast path unless ``fast=False``.
        """
        if self.topology.kind != "direct":
            raise ValueError(
                f"run_async supports topology='direct' only — plane "
                f"aggregation needs a plane-synchronous merge point, which "
                f"the free-running mode has no analogue of (topology="
                f"{self.topology.name!r})")
        trc = _obs_active()
        t_wall = time.perf_counter() if trc is not None else 0.0
        if self.fast:
            from .fastpath import run_async_fast
            out = run_async_fast(self, t0, msg_bytes, n_deliveries,
                                 max_time=max_time)
        else:
            out = self._run_async_oracle(t0, msg_bytes, n_deliveries,
                                         max_time=max_time)
        fault_events: List[dict] = []
        if self.faults is not None and self.faults.crashes_enabled:
            out, fault_events = _apply_async_faults(self, out)
        run, self._async_idx = self._async_idx, self._async_idx + 1
        if trc is not None:
            engine = "fast" if self.fast else "oracle"
            trc.prof.begin("trace_emit")
            _emit_async_trace(trc, out, engine, run, t0, n_deliveries,
                              fault_events)
            trc.prof.end()
            trc.prof.flush(trc, engine=engine, mode="async", run=run,
                           wall=time.perf_counter() - t_wall)
        return out

    def _run_async_oracle(self, t0: float, msg_bytes: float,
                          n_deliveries: int,
                          max_time: Optional[float] = None) -> List[Delivery]:
        sc = self.scenario
        n = sc.walker.n_sats
        trc = _obs_active()
        prof = trc.prof if trc is not None else None
        gs_tx = sc.link.gs_time(msg_bytes)
        horizon_cap = t0 + (max_time if max_time is not None
                            else 100.0 * sc.lookahead)
        q: list = []
        seq = itertools.count()

        def push(t, kind, **kw):
            heapq.heappush(q, (t, next(seq), kind, kw))

        if prof is not None:
            prof.begin("round_setup")
        tx_state = {s: {"queue": [], "busy": False, "win": None}
                    for s in range(n)}
        station_free: Dict[int, float] = defaultdict(float)
        train_start = {s: t0 for s in range(n)}
        deliveries: List[Delivery] = []

        for s in range(n):
            push(t0 + sc.compute_of(s), "train_done", sat=s)
        if prof is not None:
            prof.end()

        def reachable(sat):
            """(candidate, hops) within max_hops over the ISL graph."""
            seen = {sat: 0}
            frontier = [sat]
            for h in range(1, sc.max_hops + 1):
                nxt = []
                for u in frontier:
                    for v in self.router.neighbors(u):
                        if v not in seen:
                            seen[v] = h
                            nxt.append(v)
                frontier = nxt
            return seen.items()

        def choose_route(sat, t):
            """Best (gateway, isl_time, hops) by estimated delivery time."""
            best, best_est = None, np.inf
            for cand, hops in reachable(sat):
                isl_t = self.router.link.isl_time(msg_bytes, hops=hops) if hops else 0.0
                w = self.usable_window(cand, t + isl_t)
                if w is None:
                    continue
                st = tx_state[cand]
                backlog = (len(st["queue"]) + (1 if st["busy"] else 0)) * gs_tx
                est = max(t + isl_t, w[0]) + backlog + gs_tx
                if est < best_est or (est == best_est and best is not None
                                      and hops < best[2]):
                    best, best_est = (cand, isl_t, hops), est
            return best

        def park(st, t):
            """No usable window for this gateway: re-route the backlog.

            Retries only schedule strictly before the horizon cap — a
            retry AT the cap can land back here (dispatch → self-route →
            window never fits → park) and would re-push at the same
            saturated time forever instead of letting the run drain.
            """
            if t < horizon_cap:
                for _, parked, _h in st["queue"]:
                    push(min(t + sc.lookahead, horizon_cap), "retry",
                         sat=parked)
            st["queue"].clear()
            st["win"] = None

        def try_tx(g, t):
            st = tx_state[g]
            if st["busy"] or not st["queue"]:
                return
            if prof is not None:
                prof.begin("window_fit")
            win = st["win"]
            if win is None or win[1] <= t:
                win = self.usable_window(g, t)
            fit = False
            for _ in range(64):
                if win is None:
                    break
                start = max(t, win[0], station_free[win[2]])
                if start + self.tx_estimate(g, win, start, msg_bytes,
                                            gs_tx) <= win[1]:
                    fit = True
                    break
                win = self.usable_window(g, win[1])
            if prof is not None:
                prof.end()
            if not fit:
                park(st, t)
                return
            st["win"] = win
            if start > t:
                push(start, "tx_start", gw=g)
                return
            meta = st["queue"].pop(0)
            st["busy"] = True
            if prof is not None:
                prof.begin("tx_commit")
            t_done, outcome = self.tx_commit(g, meta[1], win, t, msg_bytes,
                                             gs_tx)
            if prof is not None:
                prof.end()
            station_free[win[2]] = t_done
            push(t_done, "tx_done", gw=g, sat=meta[1], hops=meta[2],
                 station=win[2], win_rise=win[0], outcome=outcome)

        def dispatch(s, t):
            if prof is not None:
                prof.begin("route")
            route = choose_route(s, t)
            if prof is not None:
                prof.end()
            if route is None:
                if t < horizon_cap:
                    push(min(t + sc.lookahead, horizon_cap), "retry", sat=s)
                return
            gw, isl_t, hops = route
            if gw == s:
                tx_state[s]["queue"].append((t, s, 0))
                try_tx(s, t)
            else:
                push(t + isl_t, "isl_arrive", sat=s, gw=gw, hops=hops)

        n_ok = 0
        if prof is not None:
            prof.begin("event_loop")
        while q and n_ok < n_deliveries:
            t, _, kind, kw = heapq.heappop(q)
            if t > horizon_cap:
                break
            self.ensure(t + 2 * sc.lookahead)
            if kind == "train_done":
                dispatch(kw["sat"], t)
            elif kind == "retry":
                dispatch(kw["sat"], t)
            elif kind == "isl_arrive":
                tx_state[kw["gw"]]["queue"].append((t, kw["sat"], kw["hops"]))
                try_tx(kw["gw"], t)
            elif kind == "tx_start":
                try_tx(kw["gw"], t)
            elif kind == "tx_done":
                g, s = kw["gw"], kw["sat"]
                deliveries.append(Delivery(
                    sat=s, t_done=t, t_start=train_start[s], gateway=g,
                    station=kw["station"], hops=kw["hops"],
                    window=kw["win_rise"], **kw["outcome"]))
                if kw["outcome"]["delivered"]:
                    n_ok += 1
                tx_state[g]["busy"] = False
                try_tx(g, t)
                # the satellite retrains either way: on success it picks up
                # the fresh global model; on a lost uplink it moves on (its
                # stale update is gone — sync mode's loss-robust EF has no
                # async analogue yet)
                train_start[s] = t
                push(t + sc.compute_of(s), "train_done", sat=s)
        if prof is not None:
            prof.end()

        # records are appended in heap-pop order, i.e. sorted by t_done;
        # the loop stops right after the n_deliveries-th success, so the
        # lossless case returns exactly n_deliveries records
        return deliveries
