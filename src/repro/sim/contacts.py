"""Precomputed contact plans: rise/set intervals for every (station, sat) pair.

The seed scheduler re-propagated a 720-step visibility grid on every
``select`` call — O(rounds · T · S).  A :class:`ContactPlan` propagates the
whole horizon ONCE (O(T · S) vectorized), extracts the rise/set intervals
with a single ``diff`` over the boolean grid, and answers "when does
satellite *s* next see a station after time *t*" with array lookups:
O(log W) scalar, or fully vectorized over all satellites at once.

Interval semantics match brute-force grid scanning: a window is
``[rise, set)`` where ``rise`` is the first grid time with the link up and
``set`` the first grid time after it with the link down (a window still open
at the end of the horizon is capped at ``horizon_end + dt``).  Windows are
stored as per-station ``(S, W_max)`` arrays padded with ``+inf`` so batch
queries are plain numpy.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from ..constellation.orbits import GroundStation, Walker, visibility_grid


class ContactPlan:
    """Contact windows for ``walker`` against every station in ``stations``.

    Attributes (per station index ``g``):
        rises[g]: (S, W) window start times, +inf-padded
        sets[g]:  (S, W) window end times (exclusive), +inf-padded
    """

    def __init__(self, walker: Walker, stations: Sequence[GroundStation],
                 horizon: float = 86400.0, dt: float = 10.0, t_start: float = 0.0):
        self.walker = walker
        self.stations = tuple(stations)
        self.dt = float(dt)
        self.t_start = float(t_start)
        self.horizon = float(horizon)
        self._build()

    # -- construction -----------------------------------------------------
    def _grid(self) -> np.ndarray:
        """The immutable time grid covering the current horizon."""
        return self.t_start + np.arange(0.0, self.horizon, self.dt)

    def _build(self) -> None:
        ts = self._grid()
        n = self.walker.n_sats
        rises, sets, last_vis = [], [], []
        for gs in self.stations:
            vis = visibility_grid(self.walker, gs, ts).view(np.int8)  # (T, S)
            padded = np.zeros((vis.shape[0] + 2, n), dtype=np.int8)
            padded[1:-1] = vis
            d = np.diff(padded, axis=0)                       # (T+1, S)
            r_t, r_s = np.where(d == 1)                       # rise at ts[r_t]
            s_t, s_s = np.where(d == -1)                      # set  at ts[s_t]
            # set index T means "still visible at horizon end" — cap there
            s_val = np.where(s_t < len(ts), ts[np.minimum(s_t, len(ts) - 1)],
                             ts[-1] + self.dt)
            rises.append(self._to_padded(r_s, ts[r_t], n))
            sets.append(self._to_padded(s_s, s_val, n))
            last_vis.append(vis[-1].astype(bool))
        self.rises = rises
        self.sets = sets
        self._last_vis = last_vis
        self._n_steps = len(ts)

    def _extend(self, old_steps: int) -> None:
        """Incrementally extend the window arrays to the (already grown)
        horizon: propagate ONLY the new ``[old_end, horizon)`` grid
        segment and merge its windows into the existing padded arrays.

        Produces bit-identical ``rises``/``sets`` to a from-scratch
        ``_build`` over the full horizon: the extension grid is a slice
        of the full ``arange`` grid, a window that was capped at the old
        horizon end either gets its true set time patched in (the link
        dropped inside the new segment) or its cap moved to the new
        horizon end, and rise/set extraction runs the same diff-over-
        boolean-grid logic seeded with the cached visibility at the old
        boundary.  This turns the amortized cost of horizon doubling
        from O(total · rebuilds) into O(total) — the difference between
        ~10 s and sub-second mega-10000 rounds.
        """
        ts = self._grid()
        new_ts = ts[old_steps:]
        if new_ts.size == 0:
            return
        n = self.walker.n_sats
        t_add = len(new_ts)
        cap = ts[-1] + self.dt
        for g, gs in enumerate(self.stations):
            vis = visibility_grid(self.walker, gs, new_ts).view(np.int8)
            padded = np.zeros((t_add + 2, n), dtype=np.int8)
            padded[0] = self._last_vis[g]     # continuity across the seam
            padded[1:-1] = vis
            d = np.diff(padded, axis=0)                       # (T_add+1, S)
            r_t, r_s = np.where(d == 1)
            s_t, s_s = np.where(d == -1)
            s_val = np.where(s_t < t_add, new_ts[np.minimum(s_t, t_add - 1)],
                             cap)
            old_r, old_s = self.rises[g], self.sets[g]
            n_old = np.count_nonzero(np.isfinite(old_r), axis=1)  # (S,)
            was_open = self._last_vis[g]
            # column layout: windows occupy a contiguous prefix per sat.
            # A sat open at the seam contributes its FIRST set event to
            # the old capped window (column n_old-1); everything else
            # appends after the old prefix.
            n_new = np.bincount(r_s, minlength=n)
            w_need = int((n_old + n_new).max(initial=0))
            w_max = max(old_r.shape[1], w_need, 1)
            rises = np.full((n, w_max), np.inf)
            sets = np.full((n, w_max), np.inf)
            rises[:, :old_r.shape[1]] = old_r
            sets[:, :old_s.shape[1]] = old_s
            # np.where scans time-major; lexsort to (sat, time) rank order
            order = np.lexsort((s_t, s_s))
            ss = s_s[order]
            rank = np.arange(len(ss)) - np.searchsorted(ss, ss)
            sets[ss, n_old[ss] + rank - was_open[ss]] = s_val[order]
            order = np.lexsort((r_t, r_s))
            rs = r_s[order]
            rank = np.arange(len(rs)) - np.searchsorted(rs, rs)
            rises[rs, n_old[rs] + rank] = new_ts[r_t[order]]
            self.rises[g] = rises
            self.sets[g] = sets
            self._last_vis[g] = (vis[-1] if t_add else self._last_vis[g]) \
                .astype(bool)
        self._n_steps = len(ts)

    @staticmethod
    def _to_padded(sats: np.ndarray, times: np.ndarray, n: int) -> np.ndarray:
        """Scatter (sat, time) pairs (time-ordered per sat — np.where scans
        time-major) into an +inf-padded (S, W_max) array."""
        w_max = max(1, int(np.bincount(sats, minlength=n).max(initial=0)))
        pad = np.full((n, w_max), np.inf)
        order = np.lexsort((times, sats))
        s_sorted, t_sorted = sats[order], times[order]
        col = np.arange(len(order)) - np.searchsorted(s_sorted, s_sorted)
        pad[s_sorted, col] = t_sorted
        return pad

    def ensure(self, t_end: float) -> None:
        """Extend the plan (amortized doubling) to cover queries up to
        ``t_end``.  Only the new time segment is propagated
        (:meth:`_extend`); existing windows are never recomputed."""
        if t_end <= self.t_start + self.horizon:
            return
        old_steps = self._n_steps
        while self.t_start + self.horizon < t_end:
            self.horizon *= 2.0
        self._extend(old_steps)

    # -- queries ----------------------------------------------------------
    @property
    def n_stations(self) -> int:
        return len(self.stations)

    def windows(self, station: int, sat: int) -> list:
        """All (rise, set) windows of one satellite at one station."""
        r, s = self.rises[station][sat], self.sets[station][sat]
        keep = np.isfinite(r)
        return list(zip(r[keep], s[keep]))

    def next_window(self, sat: int, t: float,
                    station: Optional[int] = None,
                    blocked: Optional[list] = None
                    ) -> Optional[Tuple[float, float, int]]:
        """Earliest window with ``set > t`` → (start, end, station) or None.

        ``start`` may be ≤ t if the satellite is currently in contact.
        ``blocked``: optional per-station (S, W) bool arrays — windows to
        skip (link dropout / weather), as in :meth:`next_windows_all`.
        """
        best, best_eff = None, np.inf
        gs_range = range(self.n_stations) if station is None else (station,)
        for g in gs_range:
            s = self.sets[g][sat]
            i = int(np.searchsorted(s, t, side="right"))
            while i < s.shape[0] and np.isfinite(self.rises[g][sat][i]):
                if (blocked is None or blocked[g] is None
                        or not blocked[g][sat, i]):
                    cand = (float(self.rises[g][sat][i]), float(s[i]), g)
                    eff = max(cand[0], t)         # earliest usable start
                    if eff < best_eff:
                        best, best_eff = cand, eff
                    break
                i += 1
        return best

    def next_windows_all(self, t: np.ndarray, blocked: Optional[list] = None
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized :meth:`next_window` over every satellite.

        t: scalar or (S,) per-satellite query times.
        blocked: optional per-station (S, W) bool — windows to skip
                 (link dropout / weather).
        Returns (start (S,), end (S,), station (S,)); start=+inf where no
        window exists.  start is clipped up to the query time.
        """
        return self.next_windows_for(np.arange(self.walker.n_sats), t,
                                     blocked=blocked)

    def next_windows_for(self, sats: np.ndarray, t: np.ndarray,
                         blocked: Optional[list] = None
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """:meth:`next_windows_all` restricted to a satellite subset.

        sats: (B,) satellite indices (any order, duplicates fine);
        t: scalar or (B,) per-query times.  Same elementwise arithmetic
        as the all-satellite path, so the two agree bit-for-bit on
        shared rows — the fast engine's batched route chooser relies on
        this when a dispatch batch touches only a candidate neighborhood
        instead of the whole constellation.
        """
        rows = np.asarray(sats, dtype=np.int64)
        b = rows.shape[0]
        t = np.broadcast_to(np.asarray(t, dtype=np.float64), (b,))
        best_start = np.full(b, np.inf)
        best_end = np.full(b, np.inf)
        best_g = np.full(b, -1, dtype=np.int64)
        ar = np.arange(b)
        for g in range(self.n_stations):
            ok = self.sets[g][rows] > t[:, None]
            if blocked is not None and blocked[g] is not None:
                ok &= ~blocked[g][rows]
            i = np.argmax(ok, axis=1)                 # first usable window
            valid = ok[ar, i]
            start = np.where(valid, self.rises[g][rows, i], np.inf)
            start = np.maximum(start, t)
            end = np.where(valid, self.sets[g][rows, i], np.inf)
            better = start < best_start
            best_start = np.where(better, start, best_start)
            best_end = np.where(better, end, best_end)
            best_g = np.where(better, g, best_g)
        return best_start, best_end, best_g

    def in_contact(self, sat: int, t: float) -> Optional[int]:
        """Station index the satellite can currently reach, else None."""
        w = self.next_window(sat, t)
        if w is not None and w[0] <= t < w[1]:
            return w[2]
        return None
