"""Performance benchmark harness: named benchmarks, JSON emission, CI gate.

The perf trajectory of this repo is recorded in machine-readable
``BENCH_sim.json`` / ``BENCH_kernels.json`` files at the repo root:

  * ``python -m repro.bench --emit .`` runs the registered benchmarks
    (wrapping the ``benchmarks/*.py`` entry points) and (re)writes the
    baselines — sats/sec, pack GB/s, and end-to-end round times at
    100/1000/10000-satellite scale;
  * ``python -m repro.bench --tiny --emit bench_out/`` is the CI-sized
    run (a strict subset of the full metric set);
  * ``python -m repro.bench.compare bench_out`` checks a fresh run
    against the committed baselines with a ±20% tolerance on gated
    metrics (machine-independent ratios like fused-vs-unfused speedups;
    absolute wall-clock metrics are informational only) and exits
    non-zero on regression — the CI ``perf-gate`` job.

Register your own with :func:`repro.bench.registry.register_benchmark`.
"""
from .registry import (BENCHMARKS, Benchmark, metric, register_benchmark,
                       run_benchmarks)
from .timing import time_fn

__all__ = ["BENCHMARKS", "Benchmark", "metric", "register_benchmark",
           "run_benchmarks", "time_fn"]
