"""Warmup/timing utilities shared by the registered benchmarks.

Timed regions run with the cyclic garbage collector DISABLED (after one
up-front ``gc.collect()``): dispatch-heavy benchmark bodies allocate
thousands of small host objects, and whether a GC generation threshold
happens to trip inside the timed region depends on how much heap the
*previously run* benchmarks left behind — which made gated ratios depend
on registry order and on full-vs-tiny runs.  Pinning GC out of the timed
window removes that coupling; the collector is restored (and run once)
afterwards.
"""
from __future__ import annotations

import contextlib
import gc
import time
from typing import Callable


def _block(x) -> None:
    """Wait for async jax work referenced by ``x`` (no-op for host values)."""
    try:
        import jax
        jax.block_until_ready(x)
    except (ImportError, TypeError):
        pass


@contextlib.contextmanager
def _gc_pinned():
    """Collect once, then keep the cyclic GC out of the timed region."""
    was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()
            gc.collect()


def time_fn(fn: Callable, *, reps: int = 5, warmup: int = 1) -> float:
    """Seconds per call of ``fn()``: ``warmup`` untimed calls (compile /
    cache fill), then the MINIMUM of ``reps`` timed calls — the robust
    estimator of the achievable time on a noisy shared machine — blocking
    on the returned value so async dispatch doesn't leak out of the
    clock."""
    for _ in range(warmup):
        _block(fn())
    best = float("inf")
    with _gc_pinned():
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            _block(fn())
            best = min(best, time.perf_counter() - t0)
    return best


def time_pair(fn_a: Callable, fn_b: Callable, *, reps: int = 7,
              warmup: int = 1) -> tuple:
    """Interleaved min-of-``reps`` timing of two functions.

    Alternating A/B measurements make background load spikes hit both
    paths symmetrically, which stabilizes the A/B *ratio* (the quantity
    perf gates enforce) far better than timing each phase separately.
    Returns (seconds_a, seconds_b).
    """
    for _ in range(warmup):
        _block(fn_a())
        _block(fn_b())
    best_a = best_b = float("inf")
    with _gc_pinned():
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            _block(fn_a())
            best_a = min(best_a, time.perf_counter() - t0)
            t0 = time.perf_counter()
            _block(fn_b())
            best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def gbps(nbytes: float, seconds: float) -> float:
    """Throughput in GB/s (1e9 bytes)."""
    return nbytes / max(seconds, 1e-12) / 1e9
