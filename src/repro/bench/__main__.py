"""CLI: run the benchmark registry and emit BENCH_*.json.

    python -m repro.bench --tiny --emit bench_out/     # CI-sized run
    python -m repro.bench --emit .                     # refresh baselines
    python -m repro.bench --list
    python -m repro.bench --only sim.round_pipeline
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .registry import BENCHMARKS, run_benchmarks

SCHEMA_VERSION = 1


def emit(results, directory: str, tiny: bool) -> None:
    os.makedirs(directory, exist_ok=True)
    for group, benches in results.items():
        path = os.path.join(directory, f"BENCH_{group}.json")
        with open(path, "w") as f:
            json.dump({"schema": SCHEMA_VERSION, "tiny": tiny,
                       "benchmarks": benches}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


def emit_trace(directory: str) -> str:
    """Capture a mega-1000 obs trace next to the BENCH files.

    A dedicated post-bench pass — tracing is never enabled inside the
    timed bench regions, where buffering would perturb the gated ratios.
    CI runs ``python -m repro.obs check`` on the result (bytes
    conservation + ordering) and uploads it as an artifact, so every
    perf-gate run leaves an inspectable round timeline behind.
    """
    from repro import obs
    from repro.constellation.links import message_bytes
    from repro.sim import Engine, get_scenario

    path = os.path.join(directory, "TRACE_mega-1000.jsonl")
    eng = Engine(get_scenario("mega-1000"))
    msg = message_bytes(10000, 10.0)
    with obs.tracing(path, scenario="mega-1000", source="repro.bench"):
        t = 0.0
        for _ in range(2):
            t += eng.run_round(t, msg).duration
        eng.run_async(0.0, msg, n_deliveries=50)
    print(f"wrote {path}")
    # the same trajectory on the heapq oracle: CI perfdiffs the pair so
    # every perf-gate run records WHERE the fast path spends its time
    # relative to the reference engine (phase records included)
    o_path = os.path.join(directory, "TRACE_mega-1000-oracle.jsonl")
    o_eng = Engine(get_scenario("mega-1000"), fast=False)
    with obs.tracing(o_path, scenario="mega-1000", source="repro.bench",
                     engine="oracle"):
        t = 0.0
        for _ in range(2):
            t += o_eng.run_round(t, msg).duration
        o_eng.run_async(0.0, msg, n_deliveries=50)
    print(f"wrote {o_path}")
    # fold the trace into the run ledger artifact next to the BENCH
    # files — every perf-gate run leaves a cross-run-comparable entry
    # behind, not just the raw timeline
    from repro.obs.ledger import ingest
    ledger = os.path.join(directory, "ledger.jsonl")
    entry, added = ingest(path, ledger)
    print(f"{'ingested into' if added else 'already present in'} "
          f"{ledger} as {entry['run_id']}")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.bench",
                                 description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run (strict metric subset of the full run)")
    ap.add_argument("--emit", metavar="DIR", default=None,
                    help="write BENCH_<group>.json files into DIR")
    ap.add_argument("--only", nargs="*", default=None,
                    help="run only these registered benchmarks")
    ap.add_argument("--list", action="store_true",
                    help="list registered benchmarks and exit")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the post-bench mega-1000 obs trace capture "
                         "(TRACE_mega-1000.jsonl next to the BENCH files)")
    args = ap.parse_args(argv)

    if args.list:
        for name, b in sorted(BENCHMARKS.items()):
            print(f"{name:28s} [{b.group}] {b.description}")
        return 0

    results = run_benchmarks(args.only, tiny=args.tiny)
    if args.emit:
        emit(results, args.emit, args.tiny)
        if not args.no_trace:
            emit_trace(args.emit)
    return 0


if __name__ == "__main__":
    sys.exit(main())
