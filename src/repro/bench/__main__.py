"""CLI: run the benchmark registry and emit BENCH_*.json.

    python -m repro.bench --tiny --emit bench_out/     # CI-sized run
    python -m repro.bench --emit .                     # refresh baselines
    python -m repro.bench --list
    python -m repro.bench --only sim.round_pipeline
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .registry import BENCHMARKS, run_benchmarks

SCHEMA_VERSION = 1


def emit(results, directory: str, tiny: bool) -> None:
    os.makedirs(directory, exist_ok=True)
    for group, benches in results.items():
        path = os.path.join(directory, f"BENCH_{group}.json")
        with open(path, "w") as f:
            json.dump({"schema": SCHEMA_VERSION, "tiny": tiny,
                       "benchmarks": benches}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.bench",
                                 description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run (strict metric subset of the full run)")
    ap.add_argument("--emit", metavar="DIR", default=None,
                    help="write BENCH_<group>.json files into DIR")
    ap.add_argument("--only", nargs="*", default=None,
                    help="run only these registered benchmarks")
    ap.add_argument("--list", action="store_true",
                    help="list registered benchmarks and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, b in sorted(BENCHMARKS.items()):
            print(f"{name:28s} [{b.group}] {b.description}")
        return 0

    results = run_benchmarks(args.only, tiny=args.tiny)
    if args.emit:
        emit(results, args.emit, args.tiny)
    return 0


if __name__ == "__main__":
    sys.exit(main())
