"""Perf-gate comparator: fresh ``BENCH_*.json`` vs committed baselines.

Usage (the CI ``perf-gate`` job)::

    python -m repro.bench --tiny --emit bench_out/
    python -m repro.bench.compare bench_out [--baseline .] [--tol 0.2]

For every metric present in BOTH files the comparator reports the
new/baseline ratio; metrics the baseline marks ``gate=True`` FAIL the run
when they regress beyond the tolerance (direction-aware: a
higher-is-better metric must stay ≥ baseline·(1−tol), a lower-is-better
one ≤ baseline·(1+tol)).  Ungated metrics present in only one file are
listed but never fail — a tiny CI run is a strict subset of a full
baseline.  A GATED baseline metric that the fresh run failed to produce
is itself a failure (the gate must not fail open when a benchmark breaks
or is skipped).  Improvements beyond the tolerance are flagged as
candidates for a baseline refresh (``python -m repro.bench --emit .``).

When the gate trips and BOTH directories hold a trace with the same
filename (``TRACE_*.jsonl[.gz]``), the failure report also runs
:func:`repro.obs.prof.perfdiff` over each matching pair so the log names
*which phase* moved, not just that something did.
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

GROUPS = ("sim", "kernels")


@dataclasses.dataclass
class Verdict:
    group: str
    bench: str
    metric: str
    status: str         # "ok" | "regression" | "improved" | "info" | "missing"
    new: float = float("nan")
    base: float = float("nan")

    @property
    def ratio(self) -> float:
        return self.new / self.base if self.base else float("inf")


def bench_path(directory: str, group: str) -> str:
    return os.path.join(directory, f"BENCH_{group}.json")


def load(directory: str, group: str) -> Dict[str, Dict[str, dict]]:
    """{bench: {metric: metric_dict}} from BENCH_<group>.json ({} if absent)."""
    path = bench_path(directory, group)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f).get("benchmarks", {})


def compare_group(new: Dict[str, Dict[str, dict]],
                  base: Dict[str, Dict[str, dict]], group: str,
                  tol: float) -> List[Verdict]:
    verdicts: List[Verdict] = []
    for bench in sorted(set(new) | set(base)):
        n_metrics = new.get(bench, {})
        b_metrics = base.get(bench, {})
        for m in sorted(set(n_metrics) | set(b_metrics)):
            if m not in n_metrics or m not in b_metrics:
                # a GATED baseline metric absent from the fresh run is a
                # failure, not an info row — otherwise an import breakage
                # that skips a whole benchmark silently disables the gate
                # (tiny runs are guaranteed to contain every gated metric)
                gated_base = b_metrics.get(m, {}).get("gate", False)
                verdicts.append(Verdict(group, bench, m,
                                        "regression" if gated_base
                                        else "missing"))
                continue
            nv, bv = n_metrics[m]["value"], b_metrics[m]["value"]
            v = Verdict(group, bench, m, "info", nv, bv)
            # the BASELINE's flags define the contract under test
            if b_metrics[m].get("gate"):
                hib = b_metrics[m].get("higher_is_better", True)
                if hib and nv < bv * (1.0 - tol):
                    v.status = "regression"
                elif not hib and nv > bv * (1.0 + tol):
                    v.status = "regression"
                elif (hib and nv > bv * (1.0 + tol)) or \
                        (not hib and nv < bv * (1.0 - tol)):
                    v.status = "improved"
                else:
                    v.status = "ok"
            verdicts.append(v)
    return verdicts


def compare_dirs(new_dir: str, base_dir: str,
                 tol: float = 0.2) -> Tuple[bool, List[Verdict]]:
    """Compare every BENCH_<group>.json; returns (passed, verdicts)."""
    verdicts: List[Verdict] = []
    for group in GROUPS:
        verdicts += compare_group(load(new_dir, group),
                                  load(base_dir, group), group, tol)
    passed = not any(v.status == "regression" for v in verdicts)
    return passed, verdicts


def format_report(verdicts: List[Verdict], tol: float) -> str:
    lines = [f"{'status':12s} {'benchmark':24s} {'metric':36s} "
             f"{'new':>12s} {'baseline':>12s} {'ratio':>7s}"]
    order = {"regression": 0, "improved": 1, "ok": 2, "info": 3, "missing": 4}
    for v in sorted(verdicts, key=lambda v: (order[v.status], v.bench,
                                             v.metric)):
        if v.new != v.new or v.base != v.base:       # NaN → absent value
            lines.append(f"{v.status:12s} {v.bench:24s} {v.metric:36s} "
                         f"{'—':>12s} {'—':>12s} {'—':>7s}")
        else:
            lines.append(f"{v.status:12s} {v.bench:24s} {v.metric:36s} "
                         f"{v.new:12.4f} {v.base:12.4f} {v.ratio:7.3f}")
    n_reg = sum(v.status == "regression" for v in verdicts)
    n_gate = sum(v.status in ("regression", "improved", "ok")
                 for v in verdicts)
    lines.append(f"gated: {n_gate}  regressions (>{tol:.0%}): {n_reg}")
    return "\n".join(lines)


def perfdiff_report(new_dir: str, base_dir: str, tol: float = 0.2) -> str:
    """Phase-level localization for a tripped gate: perfdiff every trace
    filename present in BOTH directories (baseline = A, fresh = B).
    Purely diagnostic — returns "" when no pair matches or the traces
    can't be read, never raises."""
    try:
        from ..obs import prof as _prof
        from ..obs.trace import load as _load
        new_traces = {os.path.basename(p) for pat in ("TRACE_*.jsonl",
                                                      "TRACE_*.jsonl.gz")
                      for p in glob.glob(os.path.join(new_dir, pat))}
        base_traces = {os.path.basename(p) for pat in ("TRACE_*.jsonl",
                                                       "TRACE_*.jsonl.gz")
                       for p in glob.glob(os.path.join(base_dir, pat))}
        out = []
        for name in sorted(new_traces & base_traces):
            d = _prof.perfdiff(_load(os.path.join(base_dir, name)),
                               _load(os.path.join(new_dir, name)), tol=tol)
            out.append(f"phase-level perfdiff for {name} "
                       f"(A=baseline, B=fresh):")
            out.append(_prof.render_perfdiff(d))
        return "\n".join(out)
    except Exception as exc:                      # pragma: no cover
        return f"(perfdiff localization unavailable: {exc})"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new_dir", help="directory with freshly emitted "
                                    "BENCH_*.json (e.g. bench_out/)")
    ap.add_argument("--baseline", default=".",
                    help="directory with committed baselines (default: .)")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="gate tolerance as a fraction (default 0.2 = ±20%%)")
    args = ap.parse_args(argv)
    passed, verdicts = compare_dirs(args.new_dir, args.baseline, args.tol)
    print(format_report(verdicts, args.tol))
    if not passed:
        print("PERF GATE FAILED — gated metric regressed beyond tolerance")
        diag = perfdiff_report(args.new_dir, args.baseline, args.tol)
        if diag:
            print(diag)
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
