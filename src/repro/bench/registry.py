"""Named benchmark registry wrapping the ``benchmarks/*.py`` entry points.

Each benchmark is a function ``fn(tiny: bool) -> {metric: metric_dict}``
registered under ``"<group>.<name>"``; groups map to emitted files
(``sim`` → ``BENCH_sim.json``, ``kernels`` → ``BENCH_kernels.json``).

Metric schema (one dict per metric, see :func:`metric`):

    {"value": float, "unit": str, "higher_is_better": bool, "gate": bool}

``gate=True`` marks metrics the CI perf gate enforces against the
committed baseline (±tolerance, see :mod:`repro.bench.compare`).  Only
machine-independent RATIOS (fused-vs-unfused speedups) gate; absolute
wall-clock and throughput numbers are recorded as the perf trajectory but
do not fail CI, since the baseline and the CI runner are different
machines.

The ``--tiny`` metric set is a strict subset of the full set (same metric
names at the shared scales), so a tiny CI run always finds its gated
metrics in a full-run baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

BENCHMARKS: Dict[str, "Benchmark"] = {}


@dataclasses.dataclass(frozen=True)
class Benchmark:
    name: str                       # "<group>.<name>"
    group: str                      # "sim" | "kernels" | custom
    fn: Callable[[bool], Dict[str, dict]]
    description: str = ""


def metric(value: float, unit: str, *, higher_is_better: bool,
           gate: bool = False) -> dict:
    """One recorded measurement (see module docstring for the schema)."""
    return {"value": float(value), "unit": unit,
            "higher_is_better": bool(higher_is_better), "gate": bool(gate)}


def register_benchmark(name: str, group: str, description: str = ""):
    """Decorator: register ``fn(tiny) -> {metric: metric_dict}``."""
    def deco(fn):
        BENCHMARKS[name] = Benchmark(name, group, fn, description)
        return fn
    return deco


def run_benchmarks(names: Optional[List[str]] = None, *, tiny: bool = False,
                   verbose: bool = True) -> Dict[str, Dict[str, Dict[str, dict]]]:
    """Run (a subset of) the registry; returns {group: {bench: metrics}}."""
    selected = sorted(BENCHMARKS) if names is None else names
    out: Dict[str, Dict[str, Dict[str, dict]]] = {}
    for name in selected:
        if name not in BENCHMARKS:
            raise KeyError(f"unknown benchmark {name!r}; "
                           f"known: {sorted(BENCHMARKS)}")
        b = BENCHMARKS[name]
        if verbose:
            print(f"# {b.name}: {b.description}")
        try:
            metrics = b.fn(tiny)
        except ImportError as e:
            # a wrapped entry point isn't importable from this cwd (the
            # repo-root ``benchmarks/`` package): skip, don't break the
            # benchmarks that can run
            print(f"  SKIPPED ({e})")
            continue
        out.setdefault(b.group, {})[b.name] = metrics
        if verbose:
            for m, d in sorted(metrics.items()):
                g = " [gate]" if d["gate"] else ""
                print(f"  {m:40s} {d['value']:12.4f} {d['unit']}{g}")
    return out


# ---------------------------------------------------------------------------
# Built-in benchmarks.  The sim benchmarks wrap benchmarks/sim_scale.py —
# importable from the repo root (namespace package); ImportError surfaces
# as a skipped benchmark rather than breaking the registry.
# ---------------------------------------------------------------------------

@register_benchmark(
    "kernels.pack_throughput", "kernels",
    "transposed bit-plane pack/unpack value-side throughput (interpret)")
def _pack_throughput(tiny: bool) -> Dict[str, dict]:
    import jax
    import jax.numpy as jnp
    from ..kernels.pack_bits import pack_bits, unpack_bits
    from .timing import gbps, time_fn
    sizes = [1 << 16] if tiny else [1 << 16, 1 << 20]
    out: Dict[str, dict] = {}
    for n in sizes:
        x = jax.random.randint(jax.random.PRNGKey(0), (n,), 0,
                               255).astype(jnp.uint32)
        words = pack_bits(x, 8, interpret=True)
        t_pack = time_fn(lambda: pack_bits(x, 8, interpret=True))
        t_unpack = time_fn(lambda: unpack_bits(words, 8, n, interpret=True))
        out[f"pack_gbps_n{n}"] = metric(gbps(4 * n, t_pack), "GB/s",
                                        higher_is_better=True)
        out[f"unpack_gbps_n{n}"] = metric(gbps(4 * n, t_unpack), "GB/s",
                                          higher_is_better=True)
    return out


@register_benchmark(
    "kernels.fused_pipeline", "kernels",
    "fused quantize→EF→pack sweep vs separate quantize_ef + pack_bits")
def _fused_pipeline(tiny: bool) -> Dict[str, dict]:
    import jax
    import jax.numpy as jnp
    from ..kernels.compress_pipeline import quant_pipeline
    from ..kernels.pack_bits import pack_bits
    from ..kernels.quantize_ef import quantize_ef
    from .timing import time_pair
    sizes = [1 << 18] if tiny else [1 << 18, 1 << 20]
    out: Dict[str, dict] = {}
    for n in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.3
        z = jnp.zeros((n,))

        def unfused():
            w, c = quantize_ef(x, z, levels=255, vmin=-1.0, vmax=1.0,
                               interpret=True)
            return pack_bits(w, 8, interpret=True), c

        def fused():
            return quant_pipeline(x, z, levels=255, vmin=-1.0, vmax=1.0,
                                  interpret=True)

        t_u, t_f = time_pair(unfused, fused, reps=9)
        out[f"unfused_ms_n{n}"] = metric(t_u * 1e3, "ms",
                                         higher_is_better=False)
        out[f"fused_ms_n{n}"] = metric(t_f * 1e3, "ms",
                                       higher_is_better=False)
        # gate only the size the tiny CI run measures; the larger size
        # rides along informationally (its ratio shows rare cache-effect
        # outliers on small hosts that would flake a ±20% gate)
        out[f"speedup_n{n}"] = metric(t_u / t_f, "x", higher_is_better=True,
                                      gate=(n == 1 << 18))
    return out


@register_benchmark(
    "sim.round_pipeline", "sim",
    "end-to-end sync round: cohort-batched fused uplink vs per-satellite "
    "quantize_ef→pack_bits dispatch chain")
def _sim_round_pipeline(tiny: bool) -> Dict[str, dict]:
    from benchmarks.sim_scale import bench_round_pipeline
    # mega-1000 runs even in the tiny CI set: its fused-vs-unfused ratio
    # is the PR's headline claim and by far the most stable gate (~3x
    # with ±10% spread; the 64-sat ratio hovers near 1.2x where dispatch
    # noise could flake a ±20% gate, so it stays informational)
    scales = [64, 1000]
    out: Dict[str, dict] = {}
    for n in scales:
        r = bench_round_pipeline(n, rounds=3)
        p = f"n{n}_"
        out[p + "round_s_unfused"] = metric(r["round_s_unfused"], "s/round",
                                            higher_is_better=False)
        out[p + "round_s_fused"] = metric(r["round_s_fused"], "s/round",
                                          higher_is_better=False)
        out[p + "speedup"] = metric(r["speedup"], "x", higher_is_better=True,
                                    gate=(n == 1000))
        out[p + "sats_per_sec"] = metric(r["sats_per_sec_fused"], "sats/s",
                                         higher_is_better=True)
    return out


@register_benchmark(
    "sim.lossy_round", "sim",
    "stochastic lossy-channel round: ARQ/erasure engine overhead vs the "
    "lossless path, plus on-device lossy uplink transport (fused "
    "quant_pipeline→erasure_mask vs the unfused three-dispatch chain)")
def _sim_lossy_round(tiny: bool) -> Dict[str, dict]:
    from benchmarks.sim_scale import bench_lossy_round
    # like sim.round_pipeline, the 1000-sat scenario runs even in the tiny
    # CI set: its fused-vs-unfused lossy-uplink ratio is the gated claim
    scales = [64, 1000]
    out: Dict[str, dict] = {}
    for n in scales:
        r = bench_lossy_round(n, rounds=3)
        p = f"n{n}_"
        out[p + "round_s_lossless"] = metric(r["round_s_lossless"],
                                             "s/round",
                                             higher_is_better=False)
        out[p + "round_s_lossy"] = metric(r["round_s_lossy"], "s/round",
                                          higher_is_better=False)
        # host-side ARQ + counter-hash cost; informational — it depends on
        # how many deliveries the trajectory happens to contain
        out[p + "channel_overhead"] = metric(r["channel_overhead"], "x",
                                             higher_is_better=False)
        out[p + "lossy_uplink_speedup"] = metric(
            r["lossy_uplink_speedup"], "x", higher_is_better=True,
            gate=(n == 1000))
        out[p + "lost_frac"] = metric(
            r["lost"] / max(r["attempted"], 1), "frac",
            higher_is_better=False)
    return out


@register_benchmark(
    "sim.fast_round", "sim",
    "vectorized batch-event core vs the heapq oracle: Delivery-timeline "
    "equivalence asserted bit-for-bit, then warm sync/async wall-clock "
    "ratios on mega-1000")
def _sim_fast_round(tiny: bool) -> Dict[str, dict]:
    from benchmarks.sim_scale import bench_fast_round
    # mega-1000 runs even in the tiny CI set: the async fast-vs-oracle
    # ratio is this PR's gated claim, and as a same-machine ratio of two
    # pure-python/numpy paths it is stable across hosts.  The sync ratio
    # hovers near 1.1x (the warm sync loop was never the bottleneck —
    # plan extension and the channel stack were), so it stays
    # informational.
    r = bench_fast_round(1000, rounds=3)
    # the raw async ratio is large but volatile (~20-30x run to run —
    # the oracle side is GC/alloc-noise heavy), so the GATED metric caps
    # it at 10x: any healthy run saturates the cap and compares 1.0
    # against the baseline, while a real regression (the batched
    # dispatcher degrading toward per-event routing) lands far below
    # 10·(1−tol) and still fails the gate.  The raw ratio rides along.
    return {
        "n1000_round_s_fast": metric(r["round_s_fast"], "s/round",
                                     higher_is_better=False),
        "n1000_round_s_oracle": metric(r["round_s_oracle"], "s/round",
                                       higher_is_better=False),
        "n1000_sync_speedup": metric(r["sync_speedup"], "x",
                                     higher_is_better=True),
        "n1000_async_speedup": metric(r["async_speedup"], "x",
                                      higher_is_better=True),
        "n1000_async_speedup_capped": metric(
            min(r["async_speedup"], 10.0), "x", higher_is_better=True,
            gate=True),
    }


@register_benchmark(
    "sim.trace_overhead", "sim",
    "repro.obs structured tracing on mega-1000 sync+async rounds: "
    "enabled-vs-disabled wall-clock ratio (<5% hard-asserted; disabled "
    "cost is covered by the sim.fast_round/engine_scale gates, whose "
    "baselines predate the instrumentation)")
def _sim_trace_overhead(tiny: bool) -> Dict[str, dict]:
    from benchmarks.sim_scale import bench_trace_overhead
    # mega-1000 runs even in the tiny CI set: the overhead ratio IS the
    # claim, and a 2-round trajectory keeps it CI-cheap.  Gate direction:
    # lower is better, baseline ~1.0x, so the ±20% gate trips well before
    # emission cost could silently creep toward the hot loops.
    r = bench_trace_overhead(1000, rounds=2)
    return {
        "n1000_s_disabled": metric(r["s_disabled"], "s",
                                   higher_is_better=False),
        "n1000_s_enabled": metric(r["s_enabled"], "s",
                                  higher_is_better=False),
        "n1000_overhead": metric(r["overhead"], "x", higher_is_better=False,
                                 gate=True),
        "n1000_events": metric(r["events"], "events",
                               higher_is_better=True),
    }


@register_benchmark(
    "sim.engine_scale", "sim",
    "discrete-event engine throughput (cold plan build + sync rounds + "
    "async deliveries) at 100/1000/10000-satellite scale")
def _sim_engine_scale(tiny: bool) -> Dict[str, dict]:
    from benchmarks.sim_scale import bench_scale
    scales = [100] if tiny else [100, 1000, 10000]
    out: Dict[str, dict] = {}
    for n in scales:
        rounds = 3
        r = bench_scale(n, rounds=rounds, async_deliveries=100)
        p = f"n{n}_"
        out[p + "sync_sats_per_sec"] = metric(
            r["sync_active"] / max(r["sync_s"], 1e-9), "sats/s",
            higher_is_better=True)
        out[p + "round_s"] = metric(r["sync_s"] / rounds, "s/round",
                                    higher_is_better=False)
        out[p + "async_deliveries_per_sec"] = metric(
            r["async_n"] / max(r["async_s"], 1e-9), "deliveries/s",
            higher_is_better=True)
    return out
