from .solvers import local_gd, local_prox_gd, sgd, adam_init, adam_update

__all__ = ["local_gd", "local_prox_gd", "sgd", "adam_init", "adam_update"]
