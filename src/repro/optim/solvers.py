"""Local solvers (raw JAX — no optax dependency).

The Fed-LT local subproblem (paper Alg. 1/2 line 10) is

    w^{ℓ+1} = w^ℓ − γ (∇f_i(w^ℓ) + (w^ℓ − v)/ρ),

i.e. gradient descent on f_i(w) + ‖w − v‖²/(2ρ).  ``local_prox_gd`` runs
N_e such epochs with ``lax.scan`` so it stays a single compact HLO loop.
``local_gd`` is the plain (FedAvg-style) variant.  Adam/SGD are provided for
the standalone (non-federated) training drivers.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..core.pytree import tree_map


def local_prox_gd(grad_fn: Callable, w0, v, data, *, n_epochs: int, gamma: float, rho: float):
    """N_e epochs of prox-anchored GD. grad_fn(w, data) -> grad pytree."""

    inv_rho = 1.0 / rho

    def step(w, _):
        g = grad_fn(w, data)
        w = tree_map(lambda wl, gl, vl: wl - gamma * (gl + inv_rho * (wl - vl)), w, g, v)
        return w, None

    w, _ = jax.lax.scan(step, w0, None, length=n_epochs)
    return w


def local_gd(grad_fn: Callable, w0, data, *, n_epochs: int, gamma: float,
             prox_center=None, prox_mu: float = 0.0):
    """Plain local GD; optional FedProx term  μ/2·‖w − prox_center‖²."""

    def step(w, _):
        g = grad_fn(w, data)
        if prox_center is not None and prox_mu > 0.0:
            w = tree_map(lambda wl, gl, cl: wl - gamma * (gl + prox_mu * (wl - cl)),
                         w, g, prox_center)
        else:
            w = tree_map(lambda wl, gl: wl - gamma * gl, w, g)
        return w, None

    w, _ = jax.lax.scan(step, w0, None, length=n_epochs)
    return w


# ---------------------------------------------------------------------------
# Optimizers for the standalone training drivers.
# ---------------------------------------------------------------------------

def sgd(params, grads, lr: float, momentum_state=None, momentum: float = 0.0):
    if momentum_state is None or momentum == 0.0:
        return tree_map(lambda p, g: p - lr * g, params, grads), momentum_state
    new_m = tree_map(lambda m, g: momentum * m + g, momentum_state, grads)
    return tree_map(lambda p, m: p - lr * m, params, new_m), new_m


class AdamState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def adam_init(params) -> AdamState:
    z = tree_map(jnp.zeros_like, params)
    return AdamState(mu=z, nu=tree_map(jnp.zeros_like, params), count=jnp.zeros((), jnp.int32))


def adam_update(params, grads, state: AdamState, *, lr: float, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0):
    count = state.count + 1
    mu = tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    c = count.astype(jnp.float32)
    mhat_scale = 1.0 / (1.0 - b1 ** c)
    vhat_scale = 1.0 / (1.0 - b2 ** c)

    def upd(p, m, v):
        step = lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p
        return p - step

    return tree_map(upd, params, mu, nu), AdamState(mu, nu, count)
