"""Config module for --arch grok-1-314b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["grok-1-314b"]
SMOKE = smoke_variant(CONFIG)
