"""Config module for --arch h2o-danube-3-4b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["h2o-danube-3-4b"]
SMOKE = smoke_variant(CONFIG)
