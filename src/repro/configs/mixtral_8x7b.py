"""Config module for --arch mixtral-8x7b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["mixtral-8x7b"]
SMOKE = smoke_variant(CONFIG)
