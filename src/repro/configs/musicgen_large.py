"""Config module for --arch musicgen-large (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["musicgen-large"]
SMOKE = smoke_variant(CONFIG)
