"""Config module for --arch qwen2-vl-7b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["qwen2-vl-7b"]
SMOKE = smoke_variant(CONFIG)
