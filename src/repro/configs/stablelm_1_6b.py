"""Config module for --arch stablelm-1.6b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["stablelm-1.6b"]
SMOKE = smoke_variant(CONFIG)
