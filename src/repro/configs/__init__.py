from .catalog import ARCHS, get, smoke_variant

__all__ = ["ARCHS", "get", "smoke_variant"]
