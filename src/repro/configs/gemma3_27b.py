"""Config module for --arch gemma3-27b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["gemma3-27b"]
SMOKE = smoke_variant(CONFIG)
