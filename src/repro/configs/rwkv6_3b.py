"""Config module for --arch rwkv6-3b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["rwkv6-3b"]
SMOKE = smoke_variant(CONFIG)
