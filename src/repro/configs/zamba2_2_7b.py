"""Config module for --arch zamba2-2.7b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["zamba2-2.7b"]
SMOKE = smoke_variant(CONFIG)
