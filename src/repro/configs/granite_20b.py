"""Config module for --arch granite-20b (see catalog.py for the citation)."""
from .catalog import ARCHS, smoke_variant

CONFIG = ARCHS["granite-20b"]
SMOKE = smoke_variant(CONFIG)
