"""Catalog of the 10 assigned architectures (+ the paper's own problem).

Every config cites its source; reduced smoke variants (2 layers, d≤512,
≤4 experts) are derived with :func:`smoke_variant`.
"""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    unit = cfg.scan_unit
    # keep the unit structure but only 1 repeat; drop tail to ≤ the unit
    n_layers = len(unit)
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        scan_unit=unit,
        scan_repeats=1,
        tail=(),
        max_seq=512,
        chunk_size=64,
        sliding_window=min(cfg.sliding_window, 128) if cfg.sliding_window else None,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        # dense dispatch in smokes: capacity dispatch drops tokens
        # batch-dependently, which breaks exact decode-vs-full checks
        moe_dispatch="dense",
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        dtype="float32",
    )
    return dataclasses.replace(cfg, **kw)


ARCHS = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# -- [audio] MusicGen-large: decoder-only over EnCodec tokens -----------------
# [arXiv:2306.05284] 48L d=2048 32H MHA d_ff=8192 vocab=2048, sinusoidal pos,
# non-gated GELU MLP.  Audio frontend (EnCodec) is a stub per the brief.
musicgen_large = _register(ModelConfig(
    name="musicgen-large", arch_type="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, pos_embed="sinusoidal", mlp_gated=False, mlp_act="gelu",
    tie_embeddings=False, dtype="bfloat16",
))

# -- [dense] Granite-20B code (GPT-BigCode arch): MQA ------------------------
# [arXiv:2405.04324] 52L d=6144 48H kv=1 d_ff=24576 vocab=49152, learned
# positions, non-gated GELU MLP.
granite_20b = _register(ModelConfig(
    name="granite-20b", arch_type="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152, pos_embed="learned", mlp_gated=False, mlp_act="gelu",
    tie_embeddings=True, dtype="bfloat16",
))

# -- [vlm] Qwen2-VL-7B: M-RoPE, dynamic resolution (vision tower stubbed) ----
# [arXiv:2409.12191] 28L d=3584 28H kv=4 d_ff=18944 vocab=152064.
qwen2_vl_7b = _register(ModelConfig(
    name="qwen2-vl-7b", arch_type="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064, pos_embed="mrope", rope_theta=1e6,
    mlp_gated=True, mlp_act="silu", tie_embeddings=False, dtype="bfloat16",
))

# -- [moe] Grok-1 314B: 8 experts top-2, attn softcap ------------------------
# [hf:xai-org/grok-1] 64L d=6144 48H kv=8 d_ff=32768 vocab=131072.
grok_1_314b = _register(ModelConfig(
    name="grok-1-314b", arch_type="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab_size=131072, n_experts=8, moe_top_k=2, moe_dispatch="capacity",
    attn_logit_softcap=30.0, mlp_gated=True, mlp_act="gelu",
    tie_embeddings=True, dtype="bfloat16",
))

# -- [moe] Mixtral-8x7B: 8 experts top-2, sliding window ---------------------
# [arXiv:2401.04088] 32L d=4096 32H kv=8 d_ff=14336 vocab=32000, SWA 4096.
mixtral_8x7b = _register(ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, n_experts=8, moe_top_k=2, moe_dispatch="capacity",
    scan_unit=("attn_local",), sliding_window=4096, subquadratic=True,
    mlp_gated=True, mlp_act="silu", tie_embeddings=False, dtype="bfloat16",
))

# -- [dense] StableLM-2 1.6B: partial rotary ---------------------------------
# [hf:stabilityai/stablelm-2-1_6b] 24L d=2048 32H MHA d_ff=5632 vocab=100352.
stablelm_1_6b = _register(ModelConfig(
    name="stablelm-1.6b", arch_type="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100352, rotary_pct=0.25,
    mlp_gated=True, mlp_act="silu", tie_embeddings=True, dtype="bfloat16",
))

# -- [dense] Gemma-3 27B: 5 local : 1 global, 128k context -------------------
# [hf:google/gemma-3-*] 62L d=5376 32H kv=16 d_ff=21504 vocab=262144,
# window 1024, qk-norm, distinct RoPE θ for local layers.
gemma3_27b = _register(ModelConfig(
    name="gemma3-27b", arch_type="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
    vocab_size=262144, head_dim=128,
    scan_unit=("attn_local",) * 5 + ("attn",), scan_repeats=10,
    tail=("attn_local", "attn_local"),
    sliding_window=1024, subquadratic=True, qk_norm=True,
    rope_theta=1e6, rope_theta_local=1e4,
    mlp_gated=True, mlp_act="gelu", tie_embeddings=True, dtype="bfloat16",
))

# -- [hybrid] Zamba2-2.7B: Mamba2 backbone + weight-shared attention ---------
# [arXiv:2411.15242] 54 blocks d=2560, d_ff=10240, ssm_state=64; the shared
# full-attention block is invoked every 6th block (9 invocations).
zamba2_2_7b = _register(ModelConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, scan_unit=("mamba2",) * 5 + ("shared_attn",),
    scan_repeats=9, ssm_state=64, ssm_head_dim=64, subquadratic=True,
    mlp_gated=True, mlp_act="silu", tie_embeddings=True, dtype="bfloat16",
))

# -- [dense] H2O-Danube-3 4B: llama+mistral mix, SWA -------------------------
# [arXiv:2401.16818] 24L d=3840 32H kv=8 d_ff=10240 vocab=32000, SWA 4096.
h2o_danube3_4b = _register(ModelConfig(
    name="h2o-danube-3-4b", arch_type="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, scan_unit=("attn_local",), sliding_window=4096,
    subquadratic=True, head_dim=120,
    mlp_gated=True, mlp_act="silu", tie_embeddings=False, dtype="bfloat16",
))

# -- [ssm] RWKV-6 "Finch" 3B: attention-free, data-dependent decay -----------
# [arXiv:2404.05892] 32L d=2560 d_ff=8960 vocab=65536.
rwkv6_3b = _register(ModelConfig(
    name="rwkv6-3b", arch_type="ssm",
    n_layers=32, d_model=2560, n_heads=1, n_kv_heads=1, head_dim=2560,
    d_ff=8960, vocab_size=65536, scan_unit=("rwkv6",), subquadratic=True,
    rwkv_head_dim=64, pos_embed="none", tie_embeddings=False, dtype="bfloat16",
))


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]
