"""Checkpointing: pytree ⇄ npz with structure + sharding metadata.

Saves any pytree (params, DeployState, optimizer state) as a single .npz
plus a JSON treedef sidecar.  Sharding metadata (PartitionSpec strings) is
recorded so a restore onto a mesh can re-place every leaf; on restore the
arrays are device_put with the stored specs when a mesh is provided.

No external deps (the environment has no orbax); formats are stable numpy.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(path: str, tree, specs=None, step: Optional[int] = None):
    """Write tree to <path>.npz (+ <path>.meta.json)."""
    names, leaves, _ = _flatten_with_names(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)

    def to_np(leaf):
        # numpy can't serialize bf16 — store as f32 (lossless), dtype recorded
        if leaf.dtype == jnp.bfloat16:
            return np.asarray(leaf.astype(jnp.float32))
        return np.asarray(leaf)

    arrays = {f"a{i}": to_np(leaf) for i, leaf in enumerate(leaves)}
    np.savez(path + ".npz", **arrays)
    meta = {"names": names, "step": step,
            "dtypes": [str(l.dtype) for l in leaves]}
    if specs is not None:
        s_names, s_leaves, _ = _flatten_with_names(
            jax.tree_util.tree_map(str, specs,
                                   is_leaf=lambda x: hasattr(x, "index")))
        meta["specs"] = dict(zip(s_names, [str(s) for s in s_leaves]))
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)


def restore(path: str, like, mesh=None, specs=None):
    """Restore into the structure of `like` (a pytree of arrays or SDS)."""
    data = np.load(path + ".npz")
    names, leaves, treedef = _flatten_with_names(like)
    restored = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = data[f"a{i}"]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {leaf.shape}")
        restored.append(jnp.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding
        tree = jax.tree_util.tree_map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
            tree, specs, is_leaf=lambda x: not isinstance(x, (dict, tuple, list)))
    return tree


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = []
    for f in os.listdir(ckpt_dir) if os.path.isdir(ckpt_dir) else []:
        if f.endswith(".meta.json"):
            with open(os.path.join(ckpt_dir, f)) as fh:
                meta = json.load(fh)
            if meta.get("step") is not None:
                steps.append(meta["step"])
    return max(steps) if steps else None
