"""Checkpointing: pytree ⇄ npz with structure + sharding metadata.

Saves any pytree (params, DeployState, optimizer state) as a single .npz
plus a JSON treedef sidecar.  Sharding metadata (PartitionSpec strings) is
recorded so a restore onto a mesh can re-place every leaf; on restore the
arrays are device_put with the stored specs when a mesh is provided.

Writes are **crash-safe**: both files go to a temp name first and land
via ``os.replace`` (atomic on POSIX), the meta sidecar carries a SHA-256
checksum of the final npz bytes, and the sidecar is written LAST — so it
acts as the commit point.  A writer killed mid-save leaves either the old
checkpoint intact or an orphaned ``*.tmp`` / checksum-mismatched pair
that :func:`verify` and :func:`latest_valid_step` reject, never a
silently corrupt "latest" checkpoint.

No external deps (the environment has no orbax); formats are stable numpy.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(path: str, tree, specs=None, step: Optional[int] = None,
         extra: Optional[dict] = None):
    """Write tree to <path>.npz (+ <path>.meta.json), atomically.

    ``extra`` is an optional JSON-safe dict merged into the meta sidecar
    (under the ``"extra"`` key) — run-state such as time cursors and byte
    accumulators rides along with the pytree (see
    :class:`repro.checkpoint.run.RunCheckpoint`).
    """
    names, leaves, _ = _flatten_with_names(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)

    def to_np(leaf):
        # numpy can't serialize bf16 — store as f32 (lossless), dtype recorded
        if leaf.dtype == jnp.bfloat16:
            return np.asarray(leaf.astype(jnp.float32))
        return np.asarray(leaf)

    arrays = {f"a{i}": to_np(leaf) for i, leaf in enumerate(leaves)}
    tmp_npz = path + ".npz.tmp"
    with open(tmp_npz, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp_npz, path + ".npz")
    meta = {"names": names, "step": step,
            "dtypes": [str(l.dtype) for l in leaves],
            "checksum": _sha256(path + ".npz")}
    if extra is not None:
        meta["extra"] = extra
    if specs is not None:
        s_names, s_leaves, _ = _flatten_with_names(
            jax.tree_util.tree_map(str, specs,
                                   is_leaf=lambda x: hasattr(x, "index")))
        meta["specs"] = dict(zip(s_names, [str(s) for s in s_leaves]))
    tmp_meta = path + ".meta.json.tmp"
    with open(tmp_meta, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp_meta, path + ".meta.json")


def load_meta(path: str) -> Optional[dict]:
    """The meta sidecar of one checkpoint, or None if absent/unparsable."""
    try:
        with open(path + ".meta.json") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def verify(path: str) -> bool:
    """True when the checkpoint at ``path`` is complete and uncorrupted:
    both files exist, the meta parses, and the npz matches its recorded
    checksum.  Pre-checksum checkpoints (no ``"checksum"`` key) pass as
    long as both files exist — they predate crash-safety, not corruption."""
    meta = load_meta(path)
    if meta is None or not os.path.exists(path + ".npz"):
        return False
    want = meta.get("checksum")
    return want is None or _sha256(path + ".npz") == want


def restore(path: str, like, mesh=None, specs=None):
    """Restore into the structure of `like` (a pytree of arrays or SDS).

    Refuses checksum-mismatched npz payloads — a crash mid-save can't
    masquerade as a valid checkpoint (use :func:`latest_valid_step` to
    fall back to the newest intact one)."""
    meta = load_meta(path)
    if meta is not None and meta.get("checksum") is not None \
            and _sha256(path + ".npz") != meta["checksum"]:
        raise ValueError(f"corrupt checkpoint (checksum mismatch): {path}")
    data = np.load(path + ".npz")
    names, leaves, treedef = _flatten_with_names(like)
    restored = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = data[f"a{i}"]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {leaf.shape}")
        restored.append(jnp.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding
        tree = jax.tree_util.tree_map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
            tree, specs, is_leaf=lambda x: not isinstance(x, (dict, tuple, list)))
    return tree


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = []
    for f in os.listdir(ckpt_dir) if os.path.isdir(ckpt_dir) else []:
        if f.endswith(".meta.json"):
            meta = load_meta(os.path.join(ckpt_dir, f)[:-len(".meta.json")])
            if meta is not None and meta.get("step") is not None:
                steps.append(meta["step"])
    return max(steps) if steps else None


def latest_valid_step(ckpt_dir: str, prefix: str = "") -> Optional[int]:
    """Newest step in ``ckpt_dir`` whose checkpoint passes :func:`verify`.

    Corrupt or half-written checkpoints (a writer killed mid-save) are
    skipped — recovery falls back to the newest intact one."""
    best = None
    for f in os.listdir(ckpt_dir) if os.path.isdir(ckpt_dir) else []:
        if not (f.startswith(prefix) and f.endswith(".meta.json")):
            continue
        base = os.path.join(ckpt_dir, f)[:-len(".meta.json")]
        meta = load_meta(base)
        if meta is None or meta.get("step") is None:
            continue
        if (best is None or meta["step"] > best) and verify(base):
            best = meta["step"]
    return best
