"""Crash-consistent run recovery: per-round checkpoints of a sync run.

One :class:`RunCheckpoint` wraps a directory of atomic per-round
checkpoints (:mod:`repro.checkpoint.store`): the algorithm state pytree
plus the run-state that makes continuation bit-identical — the round
index, the engine time cursor, the byte accumulators, and the RoundLog
prefix.  Because engine rounds are pure functions of
``(scenario, seed, t0)`` and the per-round PRNG keys derive from one
``jax.random.split(key, n_rounds)``, restoring exactly this tuple and
resuming at round ``k`` reproduces the uninterrupted run bit-for-bit
(``tests/test_faults.py`` kills a run mid-way and asserts identical
``e_K`` / ``bytes_up`` curves).

Recovery is corruption-aware: a writer killed mid-save leaves a
checkpoint that fails its checksum, and :meth:`load` silently falls back
to the newest *intact* round.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

from .store import latest_valid_step, load_meta, restore, save

_PREFIX = "round_"


class RunCheckpoint:
    """Per-round checkpoint directory for a :class:`SpaceRunner` sync run."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = str(ckpt_dir)
        self.keep_last = int(keep_last)

    def _base(self, step: int) -> str:
        return os.path.join(self.ckpt_dir, f"{_PREFIX}{step:06d}")

    def save_round(self, state, *, step: int, t: float, up_bytes: float,
                   isl_bytes: float, logs) -> None:
        """Checkpoint the state after round ``step - 1`` (resume at
        ``step``).  Older rounds beyond ``keep_last`` are pruned AFTER
        the new checkpoint has landed atomically."""
        extra = dict(k_next=int(step), t=float(t),
                     up_bytes=float(up_bytes), isl_bytes=float(isl_bytes),
                     logs=[dataclasses.asdict(lg) for lg in logs])
        save(self._base(step), state, step=step, extra=extra)
        if self.keep_last > 0:
            self._prune(step)

    def _prune(self, newest: int) -> None:
        for f in os.listdir(self.ckpt_dir):
            if not (f.startswith(_PREFIX) and f.endswith(".meta.json")):
                continue
            try:
                step = int(f[len(_PREFIX):-len(".meta.json")])
            except ValueError:
                continue
            if step <= newest - self.keep_last:
                for ext in (".meta.json", ".npz"):
                    try:
                        os.remove(os.path.join(
                            self.ckpt_dir, f"{_PREFIX}{step:06d}{ext}"))
                    except OSError:
                        pass

    def load(self, like) -> Optional[Tuple[object, dict]]:
        """Newest intact checkpoint as ``(state, run_meta)``, or None.

        ``run_meta`` holds ``k_next`` / ``t`` / ``up_bytes`` /
        ``isl_bytes`` / ``logs`` as saved by :meth:`save_round`; corrupt
        or half-written rounds are skipped via the store's checksums."""
        if not os.path.isdir(self.ckpt_dir):
            return None
        step = latest_valid_step(self.ckpt_dir, prefix=_PREFIX)
        if step is None:
            return None
        base = self._base(step)
        state = restore(base, like)
        meta = load_meta(base) or {}
        return state, meta.get("extra", {})
