"""Deterministic node-level fault injection (crashes, blackouts, failover).

See :mod:`repro.faults.process` for the fault classes and for the
crash-vs-erasure error-feedback semantics (residual lost on crash,
residual kept on link loss / straggler erasure).
"""
from .process import (  # noqa: F401
    FaultModel,
    describe_faults,
    quorum_close_time,
    time_key,
)

__all__ = ["FaultModel", "describe_faults", "quorum_close_time", "time_key"]
