"""Deterministic fault-injection processes for the constellation sim.

Node-level failures layered on top of the link-level channel (PR 4):
satellite radiation upsets, ground-station blackouts, and cluster-head
failures mid-convergecast.  All draws use the same counter-based
splitmix64 idiom as :mod:`repro.channel.outage` — a draw is a pure
function of ``(seed, namespace, identity counters)``, never of call
order or of how far the contact plan has been extended — so both sim
engines (heapq oracle and vectorized fast path) observe bit-identical
fault timelines, and extending the plan horizon never retroactively
changes a fault the run already consulted.

Fault classes
-------------

* **Satellite crash/reboot** (radiation-upset MTBF model).  Each uplink
  flight of satellite ``s`` starting at ``t_start`` with exposure
  ``T = t_done - t_start`` crashes with probability
  ``p = 1 - (1 - crash_rate) * exp(-T / crash_mtbf)`` — a flat
  per-flight term (benchmark sweeps) composed with an exposure-
  proportional MTBF term (physics).  The draw is keyed on
  ``(sat, bits(t_start))`` so it is identical in both engines and
  stable under plan extension.  The reboot completes within the round
  (MTBF >> round length); the sat rejoins with a wiped memory.

* **Ground-station blackout**.  Time is divided into slots of
  ``gs_outage_duration`` seconds; station ``g`` is dark in slot ``j``
  with probability ``gs_outage_rate``, keyed on ``(station, slot)``.
  A contact window whose rise falls in a dark slot is unusable, which
  forces the scheduler to re-route traffic through other stations,
  windows, or ISL relays — exactly like the weather/conjunction masks
  the engine already applies.

* **Cluster-head failure** (plane convergecast).  The elected head of
  plane ``p`` fails mid-aggregation with probability
  ``head_failure_rate``, keyed on ``(plane, bits(t0))``.  Arc partial
  sums already absorbed by the dead head are lost with it; arcs still
  in flight are salvaged and re-routed to a re-elected head after a
  ``failover_timeout`` detection delay (see
  :func:`repro.sim.topology` for the failover mechanics).

Crash vs. link-loss semantics for error feedback
------------------------------------------------

The two loss modes are deliberately NOT the same for EF state:

* **Erasure (link loss, straggler past deadline, head-failover
  collateral)** — the satellite is alive and still holds its EF
  residual.  Loss-robust EF reverts both the coordinator wire
  (``z_hat``) and the residual (``c_up``) to their pre-round values,
  so the lost content telescopes into the next round's correction:
  *residual kept*.

* **Crash (radiation upset, failed head's own update)** — the
  satellite reboots with wiped memory.  The coordinator wire reverts
  exactly as for an erasure (nothing arrived), but the residual is
  gone: ``c_up`` for the crashed sat is re-synced to zero
  (:func:`repro.core.error_feedback.resync_cache`): *residual lost*.
  The content of the destroyed residual is simply never recovered —
  the price of a crash that no retransmission protocol can refund.

Round deadlines and quorum
--------------------------

:func:`quorum_close_time` computes when a round closes under a
deadline-with-quorum policy: the round ends at ``t0 + deadline``
provided at least ``ceil(quorum * n_attempted)`` update-weights have
landed; otherwise it extends to the landing instant of the quorum-th
weight (or the last landing, if even that never reaches quorum).
Deliveries landing after the close are *stragglers* — treated as
erasures (residual kept), so their content folds into the next round
via EF rather than being discarded.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

from ..channel.outage import counter_uniforms

# Counter namespaces — distinct leading tags so fault draws can never
# collide with RainFade (tags 1, 2) or each other.
NS_CRASH = 101        # crash event draw        (sat, bits(t_start))
NS_CRASH_T = 102      # crash instant draw      (sat, bits(t_start))
NS_GS = 103           # station-dark draw       (station, slot)
NS_HEAD = 104         # head-failure draw       (plane, bits(t0))
NS_HEAD_T = 105       # head-failure instant    (plane, bits(t0))


def time_key(t) -> np.ndarray:
    """Bit-pattern of a float64 time as a uint64 counter.

    Times are produced identically by both engines (bit-for-bit
    equivalence contract), so their bit patterns are stable identities —
    no grid rounding, no collisions between distinct instants.
    """
    return np.asarray(t, dtype=np.float64).view(np.uint64)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Configuration of the deterministic fault processes.

    All rates default to "off" so ``FaultModel()`` is a no-op; scenarios
    opt in per fault class.  ``salt`` decorrelates the fault stream from
    the engine's channel/weather streams that share the scenario seed.
    """

    crash_rate: float = 0.0            # flat per-flight upset probability
    crash_mtbf: float = float("inf")   # mean time between upsets (s)
    gs_outage_rate: float = 0.0        # P(station dark in a given slot)
    gs_outage_duration: float = 1800.0  # dark-slot length (s)
    head_failure_rate: float = 0.0     # P(head fails) per plane-round
    failover_timeout: float = 60.0     # failure detection + re-election (s)
    salt: int = 0x5EED_FA17            # decorrelate from channel draws

    def __post_init__(self) -> None:
        if not 0.0 <= self.crash_rate < 1.0:
            raise ValueError(f"crash_rate must be in [0,1): {self.crash_rate}")
        if self.crash_mtbf <= 0.0:
            raise ValueError(f"crash_mtbf must be > 0: {self.crash_mtbf}")
        if not 0.0 <= self.gs_outage_rate < 1.0:
            raise ValueError(
                f"gs_outage_rate must be in [0,1): {self.gs_outage_rate}")
        if self.gs_outage_duration <= 0.0:
            raise ValueError("gs_outage_duration must be > 0")
        if not 0.0 <= self.head_failure_rate <= 1.0:
            raise ValueError(
                f"head_failure_rate must be in [0,1]: {self.head_failure_rate}")
        if self.failover_timeout < 0.0:
            raise ValueError("failover_timeout must be >= 0")

    # -- feature flags ------------------------------------------------
    @property
    def crashes_enabled(self) -> bool:
        return self.crash_rate > 0.0 or math.isfinite(self.crash_mtbf)

    @property
    def gs_enabled(self) -> bool:
        return self.gs_outage_rate > 0.0

    @property
    def head_enabled(self) -> bool:
        return self.head_failure_rate > 0.0

    @property
    def active(self) -> bool:
        return self.crashes_enabled or self.gs_enabled or self.head_enabled

    # -- crash process ------------------------------------------------
    def crash_prob(self, exposure) -> np.ndarray:
        """Per-flight upset probability for the given exposure time(s)."""
        exp_term = 1.0
        if math.isfinite(self.crash_mtbf):
            exp_term = np.exp(-np.maximum(np.asarray(exposure, float), 0.0)
                              / self.crash_mtbf)
        return 1.0 - (1.0 - self.crash_rate) * exp_term

    def crash_mask(self, seed: int, sats, t_starts, exposures) -> np.ndarray:
        """Bool array: did flight (sat, t_start) suffer an upset in-flight?"""
        u = counter_uniforms(seed + self.salt, NS_CRASH,
                             np.asarray(sats), time_key(t_starts))
        return u < self.crash_prob(exposures)

    def crash_times(self, seed: int, sats, t_starts, exposures) -> np.ndarray:
        """Upset instant within the flight (decorates fault events)."""
        u = counter_uniforms(seed + self.salt, NS_CRASH_T,
                             np.asarray(sats), time_key(t_starts))
        return np.asarray(t_starts, float) + u * np.asarray(exposures, float)

    # -- ground-station blackout --------------------------------------
    def station_dark(self, seed: int, station: int, times) -> np.ndarray:
        """Bool array: is ``station`` dark at each of ``times``?

        Keyed on the outage slot index, so every query inside one slot
        agrees and plan extension appends new slots without disturbing
        old ones.
        """
        t = np.asarray(times, dtype=np.float64)
        ok = np.isfinite(t)
        slot = np.floor(np.where(ok, t, 0.0)
                        / self.gs_outage_duration).astype(np.int64)
        u = counter_uniforms(seed + self.salt, NS_GS, int(station), slot)
        dark = u < self.gs_outage_rate
        return dark & ok

    # -- cluster-head failure -----------------------------------------
    def head_failure(self, seed: int, plane: int, t0: float
                     ) -> Optional[float]:
        """Fractional failure instant for (plane, round at t0), or None.

        Returns ``f in [0,1)`` — the head fails at
        ``t0 + f * (t_ready - t0)`` — when the draw fires, else None.
        """
        u = counter_uniforms(seed + self.salt, NS_HEAD,
                             int(plane), time_key(t0))
        if float(u) >= self.head_failure_rate:
            return None
        frac = counter_uniforms(seed + self.salt, NS_HEAD_T,
                                int(plane), time_key(t0))
        return float(frac)

    def describe(self) -> str:
        """Compact label for ledger meta (stable across runs)."""
        parts = []
        if self.crash_rate > 0.0:
            parts.append(f"crash{self.crash_rate:g}")
        if math.isfinite(self.crash_mtbf):
            parts.append(f"mtbf{self.crash_mtbf:g}")
        if self.gs_enabled:
            parts.append(f"gs{self.gs_outage_rate:g}"
                         f"x{self.gs_outage_duration:g}")
        if self.head_enabled:
            parts.append(f"head{self.head_failure_rate:g}")
        return "-".join(parts) if parts else "none"


def describe_faults(fm: Optional[FaultModel]) -> str:
    """Ledger-meta label for a fault model (``"none"`` when absent)."""
    return fm.describe() if fm is not None else "none"


# -- round deadlines with quorum --------------------------------------

def quorum_close_time(t0: float, deadline: float, quorum: float,
                      landed: Sequence[Tuple[float, int]],
                      n_attempted: int) -> float:
    """Close time of a round under a deadline-with-quorum policy.

    ``landed`` is a sequence of ``(t_done, weight)`` pairs for successful
    deliveries (weight = number of member updates the delivery carries —
    1 for direct uplinks, the merged-plane size for convergecast heads).
    The round closes at ``t0 + deadline`` if at least
    ``ceil(quorum * n_attempted)`` weight has landed by then; otherwise
    it extends to the landing that completes the quorum (or the last
    landing when quorum is unreachable — nothing more will ever arrive,
    so waiting longer is pointless).
    """
    t_dl = float(t0) + float(deadline)
    need = int(math.ceil(quorum * max(int(n_attempted), 0)))
    if need <= 0:
        return t_dl
    order = sorted(landed, key=lambda p: p[0])
    total = 0
    for t_done, w in order:
        if t_done > t_dl:
            break
        total += int(w)
    if total >= need:
        return t_dl
    # extend past the deadline until quorum is met (or supply runs out)
    total = 0
    for t_done, w in order:
        total += int(w)
        if total >= need:
            return max(t_dl, float(t_done))
    return max(t_dl, float(order[-1][0])) if order else t_dl
