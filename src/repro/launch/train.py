"""Production training launcher (deploy path).

Runs federated rounds of ``DeployFedLT`` for a selected architecture on
whatever devices exist (host CPUs in this container, the 16×16 / 2×16×16
TPU meshes in production — same code path the dry-run proves).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --smoke --rounds 10 --checkpoint-dir ckpts/

``--smoke`` swaps in the reduced config (CPU-runnable); without it the full
config is used and the mesh must be able to hold it (dry-run-verified).
"""
from __future__ import annotations

import argparse
import contextlib
import os
import time

import jax
import jax.numpy as jnp

from .. import obs
from ..checkpoint.store import save
from ..configs import ARCHS, smoke_variant
from ..core.deploy import DeployFedLT, emit_round_series
from ..data.synthetic import make_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--agents", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-epochs", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=0.02)
    ap.add_argument("--rho", type=float, default=10.0)
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="stream a repro.obs trace here (.jsonl / "
                         ".jsonl.gz); tail it live with "
                         "`python -m repro.obs watch PATH`")
    ap.add_argument("--ledger", default=None, metavar="PATH",
                    help="fold the finished trace into this run ledger")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_variant(cfg)
    alg = DeployFedLT(cfg=cfg, n_epochs=args.n_epochs, gamma=args.gamma,
                      rho=args.rho, compress=not args.no_compress)
    state = alg.init(jax.random.PRNGKey(0), args.agents)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state.y_hat))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M agents={args.agents}")

    step = jax.jit(lambda s, b: alg.round_step(s, b))

    trace_ctx = (obs.tracing(args.trace, stream_every=64,
                             scenario=cfg.name, algorithm="DeployFedLT",
                             mode="deploy", n_agents=args.agents)
                 if args.trace else contextlib.nullcontext())
    with trace_ctx:
        for k in range(args.rounds):
            keys = [jax.random.fold_in(jax.random.PRNGKey(11 + i), k)
                    for i in range(args.agents)]
            per = [make_batch(cfg, kk, args.batch, args.seq) for kk in keys]
            batch = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)
            t0 = time.time()
            state, metrics = step(state, batch)
            emit_round_series(k, metrics)
            print(f"round {k:5d}  loss={float(metrics['loss']):.4f}  "
                  f"({time.time()-t0:.1f}s)")
            if (args.checkpoint_dir and
                    ((k + 1) % args.checkpoint_every == 0
                     or k == args.rounds - 1)):
                path = os.path.join(args.checkpoint_dir,
                                    f"round_{k + 1:06d}")
                save(path, state.y_hat, step=k + 1)
                print(f"  checkpoint → {path}.npz")
    if args.trace and args.ledger:
        from ..obs.ledger import ingest
        entry, added = ingest(args.trace, args.ledger)
        print(f"ledger: {entry['run_id']}"
              + ("" if added else " (already present)"))


if __name__ == "__main__":
    main()
