"""Sharding rules: parameter/state/batch pytrees → PartitionSpec trees.

The same rule table serves every architecture; a dimension is only sharded
when the mesh axis size divides it (checked here, so misconfigured configs
fail loudly at spec-construction time rather than deep inside GSPMD).

Layout summary (deploy mode):
  * agent-stacked leaves get their leading agent dim sharded over the agent
    mesh axes ("pod","data" or "pod");
  * 2-D weights: input-major  (d_in, d_out)  → (fsdp, tp)
                 output-major (d_out, d_in)  → (tp, fsdp)
  * MoE expert stacks (E, d, f) → (None, fsdp, tp) / (E, f, d) → (None, tp, fsdp)
  * embeddings (V, D) → (tp, fsdp); LM head (D, V) → (fsdp, tp)
  * norms / scalars / tiny tensors → replicated
  * scan-stacked layer dims → replicated (leading axis of stacked blocks)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# weight name → (row_role, col_role); roles: f=fsdp, t=tp, r=replicated
_2D_RULES = {
    "wq": "ft", "wk": "ft", "wv": "ft", "wo": "tf",
    "up": "ft", "gate": "ft", "down": "tf",
    "router": "fr", "in_proj": "ft", "out_proj": "tf",
    "ck": "ft", "cv": "tf", "cr": "ft", "wr": "ft", "wg": "ft",
    "mix_lora_a": "fr", "mix_lora_b": "rt",
    "decay_lora_a": "fr", "decay_lora_b": "rt",
    "table": "tf", "lm_head": "ft", "pos_table": "rt",
    "conv_w": "rt",
}

_3D_MOE = {"up": "rft", "gate": "rft", "down": "rtf"}


def _axis_size(mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape[name]


def _role_axis(role: str, fsdp, tp):
    return {"f": fsdp, "t": tp, "r": None}[role]


def _maybe(axis, dim: int, mesh) -> Optional[str]:
    """Shard dim over axis only if divisible (axis may be a tuple)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        total = int(np.prod([_axis_size(mesh, a) for a in axis]))
        return axis if total and dim % total == 0 else None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def _leaf_spec(path, shape, mesh, *, fsdp, tp, n_lead: int = 0):
    """n_lead: number of leading non-weight dims (agent and/or scan stacking)
    whose specs are provided by the caller."""
    keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    keys = [k for k in keys if isinstance(k, str)]
    name = keys[-1] if keys else ""
    core = shape[n_lead:]
    nd = len(core)

    if nd <= 1:
        return (None,) * nd
    rule = None
    if nd == 2 and name in _2D_RULES:
        rule = _2D_RULES[name]
    elif nd == 3 and name in _3D_MOE:
        rule = _3D_MOE[name]
    if rule is None:
        return (None,) * nd
    out = []
    for role, dim in zip(rule, core):
        out.append(_maybe(_role_axis(role, fsdp, tp), dim, mesh))
    return tuple(out)


# ---------------------------------------------------------------------------
# Fleet axis: shard the vmapped federated agent dimension across devices.
# ---------------------------------------------------------------------------

def fleet_mesh(n_devices: Optional[int] = None, *, axis: str = "fleet"):
    """1-D mesh over local devices for sharding the agent dimension.

    Returns ``None`` on a single device — the caller keeps the unsharded
    path (``FedLT.round``); with multiple devices the returned mesh feeds
    ``FedLT.round_sharded`` / :func:`shard_fleet`.
    """
    n = len(jax.devices()) if n_devices is None else n_devices
    if n <= 1:
        return None
    return jax.make_mesh((n,), (axis,))


def fleet_specs(tree, mesh, *, axis: str = "fleet",
                n_agents: Optional[int] = None):
    """PartitionSpec tree sharding each leaf's leading (agent) dim over the
    fleet axis.

    ``n_agents`` identifies the agent axis: only leaves whose leading dim
    EQUALS it shard (pass it whenever the tree mixes agent-stacked and
    coordinator leaves — e.g. ``FedLTState``, whose ``c_down`` has no
    agent dim and must stay replicated even if its feature dim happens to
    divide the device count).  Without ``n_agents``, any leaf whose
    leading dim the axis size divides is treated as agent-stacked.
    Non-divisible leading dims and scalars stay replicated either way.
    """
    n_dev = mesh.shape[axis]

    def spec(leaf):
        if not leaf.ndim or leaf.shape[0] % n_dev:
            return P()
        if n_agents is not None and leaf.shape[0] != n_agents:
            return P()
        return P(axis)

    return jax.tree_util.tree_map(spec, tree)


def shard_fleet(tree, mesh, *, axis: str = "fleet",
                n_agents: Optional[int] = None):
    """``device_put`` agent-stacked leaves with the leading dim sharded over
    the fleet axis (single-device ``mesh=None`` passes through); see
    :func:`fleet_specs` for why ``n_agents`` should be passed for mixed
    trees like ``FedLTState``."""
    if mesh is None:
        return tree
    specs = fleet_specs(tree, mesh, axis=axis, n_agents=n_agents)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


def param_specs(params_shape, mesh, *, agent_axes: Tuple[str, ...] = (),
                stacked: Optional[bool] = None, fsdp="data", tp="model"):
    """PartitionSpec tree for a parameter pytree (shapes via eval_shape).

    stacked: leaves carry a leading agent dim (replicated when agent_axes is
    empty — e.g. a single pod-agent on the single-pod mesh).  Defaults to
    bool(agent_axes).  Scan-stacked leaves (under the "scan" top-level key)
    get one extra replicated leading dim.
    """
    if stacked is None:
        stacked = bool(agent_axes)
    agent = tuple(agent_axes) if agent_axes else None
    if agent is not None and len(agent) == 1:
        agent = agent[0]

    def spec_for(path, leaf):
        keys = [getattr(k, "key", None) for k in path]
        n_lead = 0
        lead = []
        if stacked:
            sz = leaf.shape[0]
            lead.append(None if agent is None else _maybe(agent, sz, mesh))
            n_lead += 1
        if keys and keys[0] == "scan":
            lead.append(None)
            n_lead += 1
        core = _leaf_spec(path, leaf.shape, mesh, fsdp=fsdp, tp=tp,
                          n_lead=n_lead)
        return P(*lead, *core)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def batch_specs(batch_shape, mesh, *, agent_axes: Tuple[str, ...] = (),
                stacked: Optional[bool] = None, data="data"):
    """Batch pytree: leading agent dim (if stacked) over agent axes, then the
    batch dim over the remaining data axes; everything else replicated."""
    if stacked is None:
        stacked = bool(agent_axes)
    agent = tuple(agent_axes)
    # data axes not used by the agent dim
    names = [n for n in mesh.axis_names if n in ("pod", "data")]
    rest = tuple(n for n in names if n not in agent)
    agent_spec = (agent if len(agent) > 1 else (agent[0] if agent else None))
    rest_spec = (rest if len(rest) > 1 else (rest[0] if rest else None))

    def spec_for(path, leaf):
        dims = []
        i = 0
        if stacked:
            dims.append(None if not agent
                        else _maybe(agent_spec, leaf.shape[0], mesh))
            i = 1
        if leaf.ndim > i:
            dims.append(_maybe(rest_spec, leaf.shape[i], mesh))
        dims += [None] * (leaf.ndim - len(dims))
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def cache_specs(cache_shape, mesh, *, tp="model", seq_axis="data",
                shard_batch=True):
    """KV / SSM cache specs for serving.

    KV leaves (B, S, H, D): batch over pod+data when divisible, else the
    sequence dim over "data" (long-context batch=1 case) and heads over tp.
    """
    names = [n for n in mesh.axis_names if n in ("pod", "data")]
    dp = tuple(names) if len(names) > 1 else (names[0] if names else None)

    def core_spec(name, shape):
        if len(shape) == 4 and name in ("k", "v"):     # (B, S, Hkv, D)
            b, s, h, d = shape
            if shard_batch and _maybe(dp, b, mesh):
                return (_maybe(dp, b, mesh), None, _maybe(tp, h, mesh), None)
            return (None, _maybe(seq_axis, s, mesh), _maybe(tp, h, mesh), None)
        if len(shape) == 4 and name == "state":         # (B, H, P, N)
            b = shape[0]
            if shard_batch and _maybe(dp, b, mesh):
                return (_maybe(dp, b, mesh), None, None, None)
            return (None,) * 4
        if len(shape) == 3 and name in ("k_scale", "v_scale"):  # (B, S, Hkv)
            b, s, h = shape
            if shard_batch and _maybe(dp, b, mesh):
                return (_maybe(dp, b, mesh), None, _maybe(tp, h, mesh))
            return (None, _maybe(seq_axis, s, mesh), _maybe(tp, h, mesh))
        if name in ("conv", "last_t", "last_c") and len(shape) >= 1:
            b = shape[0]
            if shard_batch and _maybe(dp, b, mesh):
                return (_maybe(dp, b, mesh),) + (None,) * (len(shape) - 1)
            return (None,) * len(shape)
        return (None,) * len(shape)

    def spec_for(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        str_keys = [k for k in keys if isinstance(k, str)]
        name = str_keys[-1] if str_keys else ""
        scan_stacked = bool(str_keys) and str_keys[0] == "scan"
        shape = leaf.shape[1:] if scan_stacked else leaf.shape
        core = core_spec(name, shape)
        return P(None, *core) if scan_stacked else P(*core)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)
