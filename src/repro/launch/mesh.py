"""Production mesh construction (defined as functions — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def agent_axes(mesh, agent_axis: str):
    """Mesh axes carrying the federated agent dimension.

    agent_axis: "pod"  → agents = pods (big archs; single-pod ⇒ 1 agent)
                "data" → agents spread over data(+pod) axes (small archs)
    """
    names = mesh.axis_names
    if agent_axis == "data":
        return tuple(n for n in names if n in ("pod", "data"))
    if agent_axis == "pod":
        return ("pod",) if "pod" in names else ()
    raise ValueError(agent_axis)


def n_agents(mesh, agent_axis: str) -> int:
    axes = agent_axes(mesh, agent_axis)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return max(n, 1)
