"""Serving steps: prefill (context → KV/SSM cache) and decode (one token).

Satellites serve the coordinator model ŷ between training rounds (e.g.
on-board inference over freshly captured imagery); these are the steps the
inference-shaped dry-runs (prefill_32k / decode_32k / long_500k) lower.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.transformer import forward, init_cache


def make_prefill_step(cfg, backend: str = "chunked"):
    def prefill_step(params, batch):
        b = jax.tree_util.tree_leaves(batch)[0].shape[0]
        s = (batch["tokens"].shape[1] if "tokens" in batch else 0)
        if batch.get("extra_embeds") is not None:
            s += batch["extra_embeds"].shape[1]
        cache = init_cache(cfg, b, s_max=s, dtype=jnp.dtype(cfg.dtype))
        out = forward(params, cfg, batch, cache=cache, backend=backend)
        # next-token logits only — serving returns the sampled continuation
        return out.logits[:, -1], out.cache

    return prefill_step


def make_decode_step(cfg, backend: str = "chunked"):
    def decode_step(params, cache, tokens):
        out = forward(params, cfg, {"tokens": tokens}, cache=cache,
                      backend=backend)
        return out.logits[:, -1], out.cache

    return decode_step
