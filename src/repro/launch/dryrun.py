import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   This is dry-run-only; tests/benches see the real single CPU device.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from typing import Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS                                  # noqa: E402
from ..core.deploy import DeployFedLT, DeployState           # noqa: E402
from ..models.transformer import init_cache, init_params     # noqa: E402
from .mesh import agent_axes, make_production_mesh, n_agents  # noqa: E402
from .serve import make_decode_step, make_prefill_step       # noqa: E402
from .sharding import batch_specs, cache_specs, param_specs  # noqa: E402

SHAPES = {
    "train_4k":    dict(seq=4096,   batch=256, kind="train"),
    "prefill_32k": dict(seq=32768,  batch=32,  kind="prefill"),
    "decode_32k":  dict(seq=32768,  batch=128, kind="decode"),
    "long_500k":   dict(seq=524288, batch=1,   kind="decode"),
}

# agent placement per arch (see DESIGN.md §3): big models = one agent per pod
AGENT_AXIS = {
    "musicgen-large": "data", "qwen2-vl-7b": "data", "stablelm-1.6b": "data",
    "zamba2-2.7b": "data", "h2o-danube-3-4b": "data", "rwkv6-3b": "data",
    "granite-20b": "pod", "mixtral-8x7b": "pod", "gemma3-27b": "pod",
    "grok-1-314b": "pod",
}

# v5e hardware constants (roofline)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in the (SPMD) HLO."""
    totals = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*(.*?)\s*(all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        op = m.group(2)
        shapes = shape_re.findall(m.group(1))
        nbytes = 0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[op] += nbytes
        counts[op] += 1
    return totals, counts


def _tokens_sds(a, b, s):
    lead = (a,) if a else ()
    return {
        "tokens": jax.ShapeDtypeStruct(lead + (b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct(lead + (b, s), jnp.int32),
    }


def input_specs(arch: str, shape: str, a: int = 0):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    cfg = ARCHS[arch]
    info = SHAPES[shape]
    s, b = info["seq"], info["batch"]
    if info["kind"] == "train":
        b_per = b // max(a, 1)
        if cfg.arch_type == "vlm":
            s_vis = s // 4
            s_txt = s - s_vis
            lead = (a,) if a else ()
            return {
                "tokens": jax.ShapeDtypeStruct(lead + (b_per, s_txt), jnp.int32),
                "extra_embeds": jax.ShapeDtypeStruct(
                    lead + (b_per, s_vis, cfg.d_model), jnp.dtype(cfg.dtype)),
                "labels": jax.ShapeDtypeStruct(lead + (b_per, s), jnp.int32),
                "positions": jax.ShapeDtypeStruct(lead + (3, b_per, s), jnp.int32),
            }
        return _tokens_sds(a, b_per, s)
    if info["kind"] == "prefill":
        if cfg.arch_type == "vlm":
            s_vis = s // 4
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - s_vis), jnp.int32),
                "extra_embeds": jax.ShapeDtypeStruct(
                    (b, s_vis, cfg.d_model), jnp.dtype(cfg.dtype)),
                "positions": jax.ShapeDtypeStruct((3, b, s), jnp.int32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # decode: one new token against an s-long cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def model_flops(cfg, shape_name: str, n_epochs: int, a: int) -> float:
    info = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = info["batch"] * info["seq"]
    if info["kind"] == "train":
        return 6.0 * n_active * tokens * n_epochs
    if info["kind"] == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * info["batch"]  # decode: one token per sequence


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return ARCHS[arch].subquadratic
    return True


def run_dryrun(arch: str, shape: str, multi_pod: bool, *, n_epochs: int = 2,
               compress: bool = True, moe_dispatch: Optional[str] = None,
               backend: str = "chunked", donate: bool = True,
               unroll: bool = False, scan_repeats_override: Optional[int] = None,
               kv_int8: bool = False, remat_group: int = 1):
    """unroll=True makes cost_analysis FLOP/byte totals exact (XLA counts
    loop bodies once) at much higher compile cost; the default scan build is
    the production artifact whose memory_analysis is the fits-check.

    scan_repeats_override=R builds a reduced-depth variant (R units + tail).
    The roofline driver compiles unrolled R=1 and R=2 and extrapolates
    linearly to the real depth — exact per-unit costs at small compile cost.
    """
    import dataclasses
    cfg = ARCHS[arch]
    cfg = dataclasses.replace(cfg, scan_unroll=unroll)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_int8=True)
    if remat_group > 1:
        cfg = dataclasses.replace(cfg, remat_group=remat_group)
    if scan_repeats_override is not None:
        n_layers = len(cfg.scan_unit) * scan_repeats_override + len(cfg.tail)
        cfg = dataclasses.replace(cfg, scan_repeats=scan_repeats_override,
                                  n_layers=n_layers)
    if moe_dispatch and cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_dispatch=moe_dispatch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    info = SHAPES[shape]
    ax = AGENT_AXIS[arch]
    if ax == "pod" and cfg.n_experts and info["kind"] == "train":
        # per-agent batch is data-sharded → keep MoE dispatch tokens sharded
        cfg = dataclasses.replace(cfg, act_batch_axis="data")
    aaxes = agent_axes(mesh, ax)
    a = n_agents(mesh, ax)

    t0 = time.time()
    with mesh:
        if info["kind"] == "train":
            alg = DeployFedLT(cfg=cfg, n_epochs=n_epochs, compress=compress,
                              backend=backend)
            state_shape = jax.eval_shape(
                lambda: alg.init(jax.random.PRNGKey(0), a))
            # agents on the data axis ⇒ per-agent weights are TP-only;
            # agents on the pod axis ⇒ weights are FSDP(data) × TP(model)
            fsdp = None if ax == "data" else "data"
            ps_agent = param_specs(state_shape.x, mesh, agent_axes=aaxes,
                                   stacked=True, fsdp=fsdp)
            ps_coord = param_specs(state_shape.y_hat, mesh, agent_axes=())
            state_specs = DeployState(
                x=ps_agent, z=ps_agent, c_up=ps_agent,
                y_hat=ps_coord, c_down=ps_coord, k=P())
            batch_sds = input_specs(arch, shape, a)
            b_specs = batch_specs(batch_sds, mesh, agent_axes=aaxes,
                                  stacked=True)
            # wire gather target: replicate the agent dim, keep weight dims
            rep_spec = jax.tree_util.tree_map(
                lambda s: P(None, *tuple(s)[1:]), ps_agent,
                is_leaf=lambda x: isinstance(x, P))

            def train_step(state, batch):
                return alg.round_step(state, batch,
                                      agent_replicate_spec=rep_spec)

            shard = lambda spec: jax.tree_util.tree_map(
                lambda sp: NamedSharding(mesh, sp), spec,
                is_leaf=lambda x: isinstance(x, P))
            fn = jax.jit(train_step,
                         in_shardings=(shard(state_specs), shard(b_specs)),
                         out_shardings=(shard(state_specs), None),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_shape,
                               jax.tree_util.tree_map(
                                   lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                                   batch_sds))
        else:
            p_shape = jax.eval_shape(
                lambda: init_params(jax.random.PRNGKey(0), cfg))
            p_spec = param_specs(p_shape, mesh, agent_axes=())
            shard = lambda spec: jax.tree_util.tree_map(
                lambda sp: NamedSharding(mesh, sp), spec,
                is_leaf=lambda x: isinstance(x, P))
            batch_sds = input_specs(arch, shape)
            if info["kind"] == "prefill":
                step = make_prefill_step(cfg, backend=backend)
                b_specs = batch_specs(batch_sds, mesh, agent_axes=())
                fn = jax.jit(step, in_shardings=(shard(p_spec), shard(b_specs)))
                lowered = fn.lower(p_shape, batch_sds)
            else:
                b = info["batch"]
                cache_shape = jax.eval_shape(
                    lambda: init_cache(cfg, b, s_max=info["seq"],
                                       dtype=jnp.dtype(cfg.dtype)))
                c_spec = cache_specs(cache_shape, mesh,
                                     shard_batch=(b > 1))
                step = make_decode_step(cfg, backend=backend)
                tok_sds = batch_sds["tokens"]
                tok_spec = batch_specs({"tokens": tok_sds}, mesh,
                                       agent_axes=())["tokens"]
                fn = jax.jit(step, in_shardings=(shard(p_spec), shard(c_spec),
                                                 shard({"t": tok_spec})["t"]),
                             donate_argnums=(1,) if donate else ())
                lowered = fn.lower(p_shape, cache_shape, tok_sds)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # ---- analyses -------------------------------------------------------
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # older jax: list of per-module dicts
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_d[f] = getattr(mem, f, None)
    hlo = compiled.as_text()
    coll, coll_n = collective_bytes(hlo)

    n_chips = 512 if multi_pod else 256
    # NOTE: the compiled artifact is the per-partition (per-chip) module —
    # cost_analysis flops/bytes, memory_analysis and the HLO collectives are
    # all PER-DEVICE quantities (verified against hand-computed shard sizes).
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))
    mf = model_flops(cfg, shape, n_epochs, a)

    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "agent_axis": ax, "n_agents": a, "n_chips": n_chips,
        "hlo_flops_per_chip": flops, "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes": coll, "collective_counts": coll_n,
        "collective_bytes_total": coll_total,
        "memory_analysis": mem_d,
        "model_flops": mf,
        "useful_flops_ratio": mf / (flops * n_chips) if flops else None,
        # roofline terms (seconds), per-chip basis
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_acc / HBM_BW,
        "t_collective": coll_total / ICI_BW,
        "lower_s": t_lower, "compile_s": t_compile,
    }
    terms = {k: result[k] for k in ("t_compute", "t_memory", "t_collective")}
    result["bottleneck"] = max(terms, key=terms.get)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--n-epochs", type=int, default=2)
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=(None, "dense", "capacity"))
    ap.add_argument("--backend", default="chunked")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--scan-repeats", type=int, default=None)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--remat-group", type=int, default=1)
    args = ap.parse_args()

    if not applicable(args.arch, args.shape):
        js = json.dumps({"arch": args.arch, "shape": args.shape,
                         "skipped": "full-attention arch at 500k ctx "
                         "(see DESIGN.md §6)"})
        print(js)
        if args.out:
            os.makedirs(os.path.dirname(args.out), exist_ok=True)
            with open(args.out, "w") as f:
                f.write(js)
        return

    res = run_dryrun(args.arch, args.shape, args.mesh == "multi",
                     n_epochs=args.n_epochs, compress=not args.no_compress,
                     moe_dispatch=args.moe_dispatch, backend=args.backend,
                     unroll=args.unroll,
                     scan_repeats_override=args.scan_repeats,
                     kv_int8=args.kv_int8, remat_group=args.remat_group)
    js = json.dumps(res, indent=2, default=str)
    print(js)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)


if __name__ == "__main__":
    main()
