"""Outage processes layered on contact windows: rain fade + conjunctions.

Two impairments that dominate LEO availability beyond plain geometry
(Razmi et al., Matthiesen et al. both center intermittent connectivity):

* :class:`RainFade` — per-(station, window) stochastic attenuation.  Each
  contact window independently suffers a fade event with probability
  ``p_fade``; the fade depth is exponential with mean ``mean_db`` (a crude
  but standard single-parameter fit of rain-attenuation exceedance
  curves).  The draw is a DETERMINISTIC counter-based hash of
  (seed, station, sat, window-rise index) — the same convention as the
  engine's weather mask — so extending the contact plan never
  retroactively changes a fade the simulation already consulted.

* :class:`ConjunctionBlackout` — deterministic recurring blackout
  intervals (collision-avoidance maneuvers, solar conjunction, station
  keep-out): every ``period`` seconds the link is down for ``duration``
  seconds, phase-shifted per station so multi-station scenarios degrade
  gracefully.  A transmission scheduled inside a blackout is simply not
  attempted; windows fully covered by a blackout are unusable.

Both processes are pure functions — no mutable state — so the engine can
query them at any (station, sat, window, t).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_MIX1 = np.uint64(0x9E3779B97F4A7C15)
_MIX2 = np.uint64(0xBF58476D1CE4E5B9)
_MIX3 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer → uniform uint64 (vectorized)."""
    x = x.astype(np.uint64)
    x ^= x >> np.uint64(30)
    x *= _MIX2
    x ^= x >> np.uint64(27)
    x *= _MIX3
    x ^= x >> np.uint64(31)
    return x


def counter_uniforms(seed: int, *counters) -> np.ndarray:
    """Deterministic U[0,1) from integer counter tuples (splitmix64 hash).

    The host-side sibling of the Pallas erasure-mask kernel's counter RNG:
    the same (seed, counters) always yields the same draw, independent of
    call order — which is what makes ARQ outcomes and fade depths
    reproducible under contact-plan extension.  Any counter may be an
    integer array; counters broadcast together and an array of draws comes
    back (one hash chain per element, vectorized).
    """
    with np.errstate(over="ignore"):
        x = np.uint64(seed % 2**64) * _MIX1
        for i, c in enumerate(counters):
            c = np.asarray(c)
            if c.dtype.kind != "u":
                c = c.astype(np.int64).astype(np.uint64)
            x = _splitmix64(x ^ (c + np.uint64(i + 1) * _MIX3))
    return x.astype(np.float64) / float(2**64)


def counter_uniform(seed: int, *counters: int) -> float:
    """Scalar convenience wrapper over :func:`counter_uniforms`."""
    return float(counter_uniforms(seed, *counters))


@dataclasses.dataclass(frozen=True)
class RainFade:
    """Per-window exponential rain attenuation on the GS link."""

    p_fade: float = 0.3          # P(a window has a fade event at all)
    mean_db: float = 6.0         # mean attenuation of a fade event

    def fade_db(self, seed: int, station: int, sat: int,
                window_id: int) -> float:
        """Attenuation (dB) applying to one whole contact window."""
        u_event = counter_uniform(seed, 1, station, sat, window_id)
        if u_event >= self.p_fade:
            return 0.0
        u_depth = counter_uniform(seed, 2, station, sat, window_id)
        # inverse-CDF exponential; clamp the tail so log(0) can't appear
        return float(-self.mean_db * np.log(max(1.0 - u_depth, 1e-12)))


@dataclasses.dataclass(frozen=True)
class ConjunctionBlackout:
    """Deterministic recurring link blackouts (maneuvers / conjunctions)."""

    period: float = 6 * 3600.0   # seconds between blackout starts
    duration: float = 900.0      # blackout length
    station_phase: float = 1800.0  # phase offset per station index

    def blacked_out(self, station: int, t: float) -> bool:
        """True when ``t`` falls inside a blackout at ``station``."""
        phase = (float(t) - station * self.station_phase) % self.period
        return phase < self.duration

    def next_clear(self, station: int, t: float) -> float:
        """Earliest time ≥ t outside a blackout at ``station``."""
        phase = (float(t) - station * self.station_phase) % self.period
        if phase >= self.duration:
            return float(t)
        return float(t) + (self.duration - phase)
