"""Elevation-dependent link budget: slant range → SNR → BER → erasure prob.

The fixed-rate :class:`repro.constellation.links.LinkModel` treats a sat↔GS
pass as a constant-capacity pipe.  Real LEO links are nothing of the sort:
free-space path loss varies ~12 dB between a 10° and a 90° pass (the slant
range shrinks from ~1900 km to ~550 km at 550 km altitude), so both the
achievable rate and the segment-erasure probability are strong functions of
elevation.  :class:`LinkBudget` models the standard chain

    slant_range(el) → FSPL → SNR = EIRP + G/T − FSPL − k − 10·log₁₀B − L
    BER  = ½·erfc(√(Eb/N0_eff))              (coherent BPSK + coding gain)
    p_seg = 1 − (1 − BER)^(8·seg_bytes)      (segment erased on any bit hit)
    rate = min(η·B·log₂(1+SNR), rate_cap)    (Shannon with efficiency η)

Everything is a pure function of elevation plus an additive ``fade_db``
term (rain / scintillation, supplied by the outage processes in
:mod:`repro.channel.outage`), so the ARQ model and the engine can query
the instantaneous link state at any point of a contact window.

The fixed-rate model stays available as the special case ``budget=None``
on :class:`repro.channel.model.ChannelModel` — transmission times then
come from ``LinkModel`` exactly, bit-for-bit reproducing the lossless
simulator's accounting.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..constellation.orbits import R_EARTH, GroundStation, Walker, elevation

BOLTZMANN_DBW = -228.6          # 10·log10(k), dBW/K/Hz
C_LIGHT = 299792458.0           # m/s


def slant_range(elevation_deg: float, altitude: float) -> float:
    """Slant range (m) to a satellite at ``altitude`` seen at ``elevation_deg``.

    Spherical-Earth geometry (law of cosines on the Earth-center triangle):
    ``d = √((R+h)² − R²·cos²el) − R·sin el``.
    """
    el = math.radians(max(float(elevation_deg), 0.0))
    r = R_EARTH + altitude
    return math.sqrt(r * r - (R_EARTH * math.cos(el)) ** 2) \
        - R_EARTH * math.sin(el)


def fspl_db(distance_m: float, freq_hz: float) -> float:
    """Free-space path loss in dB."""
    return 20.0 * math.log10(4.0 * math.pi * distance_m * freq_hz / C_LIGHT)


@dataclasses.dataclass(frozen=True)
class LinkBudget:
    """Elevation-dependent sat↔GS link budget (defaults ≈ a small-sat
    Ka-band downlink: 26 GHz, 100 MHz channel, modest EIRP).

    ``p_seg``/``rate`` are the two quantities the ARQ model consumes; both
    accept an additive ``fade_db`` impairment from the outage processes.
    """

    freq_hz: float = 26.0e9          # Ka band
    bandwidth_hz: float = 100.0e6
    eirp_dbw: float = 18.0           # satellite EIRP
    gt_dbk: float = 20.0             # ground station G/T
    misc_loss_db: float = 3.0        # pointing, atmosphere (clear sky), impl.
    coding_gain_db: float = 6.0      # FEC gain applied to Eb/N0
    spectral_efficiency: float = 0.75  # fraction of Shannon capacity achieved
    rate_cap_bps: float = 1.2e9      # modem ceiling
    altitude: float = 550e3          # for the slant-range geometry

    def snr_db(self, elevation_deg: float, fade_db: float = 0.0) -> float:
        d = slant_range(elevation_deg, self.altitude)
        return (self.eirp_dbw + self.gt_dbk - fspl_db(d, self.freq_hz)
                - BOLTZMANN_DBW - 10.0 * math.log10(self.bandwidth_hz)
                - self.misc_loss_db - fade_db)

    def ber(self, elevation_deg: float, fade_db: float = 0.0) -> float:
        """Coherent-BPSK bit error rate with coding gain folded into Eb/N0."""
        ebn0_db = self.snr_db(elevation_deg, fade_db) + self.coding_gain_db
        ebn0 = 10.0 ** (ebn0_db / 10.0)
        return 0.5 * math.erfc(math.sqrt(max(ebn0, 0.0)))

    def p_seg(self, elevation_deg: float, seg_bytes: int,
              fade_db: float = 0.0) -> float:
        """P(a ``seg_bytes``-byte segment is erased) — any uncorrected bit
        error kills the segment's CRC."""
        ber = self.ber(elevation_deg, fade_db)
        if ber <= 0.0:
            return 0.0
        # log1p form stays accurate when ber·bits is tiny
        return float(-np.expm1(8.0 * seg_bytes * np.log1p(-min(ber, 1.0))))

    def rate(self, elevation_deg: float, fade_db: float = 0.0) -> float:
        """Achievable link rate in BYTES/s at the given elevation."""
        snr = 10.0 ** (self.snr_db(elevation_deg, fade_db) / 10.0)
        bps = self.spectral_efficiency * self.bandwidth_hz * math.log2(1.0 + snr)
        return min(bps, self.rate_cap_bps) / 8.0


def sat_position(walker: Walker, sat: int, t: float) -> np.ndarray:
    """ECI position (3,) of ONE satellite at scalar time ``t``.

    Single-orbit mirror of :meth:`Walker.positions` — the channel layer
    queries one (gateway, instant) per rate/erasure evaluation, and
    propagating the whole constellation for a scalar lookup would make
    budget-channel scheduling O(n_sats) per window-fit check.
    """
    inc = math.radians(walker.inclination)
    n = 2.0 * math.pi / walker.period
    spp = walker.sats_per_plane
    plane, slot = sat // spp, sat % spp
    raan = 2.0 * math.pi * plane / walker.n_planes
    phase = (2.0 * math.pi * slot / spp
             + 2.0 * math.pi * walker.phasing * plane / walker.n_sats)
    u = phase + n * float(t)
    x_orb = walker.radius * math.cos(u)
    y_orb = walker.radius * math.sin(u)
    cos_r, sin_r = math.cos(raan), math.sin(raan)
    cos_i, sin_i = math.cos(inc), math.sin(inc)
    return np.array([x_orb * cos_r - y_orb * cos_i * sin_r,
                     x_orb * sin_r + y_orb * cos_i * cos_r,
                     y_orb * sin_i])


def elevation_at(walker: Walker, station: GroundStation, sat: int,
                 t: float) -> float:
    """Instantaneous elevation (deg) of ``sat`` above ``station`` at ``t``."""
    pos = sat_position(walker, sat, t)[None, :]        # (S=1, 3)
    el = elevation(pos, station.position(np.asarray(float(t))))
    return float(el[0])
