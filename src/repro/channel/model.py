"""ChannelModel — the stochastic link-impairment facade the engine drives.

Bundles the three impairment layers into one object a
:class:`repro.sim.engine.Scenario` can carry (``Scenario.channel``):

* **link budget** (:class:`repro.channel.budget.LinkBudget`) — elevation-
  dependent rate and segment-erasure probability.  ``budget=None`` is the
  fixed-rate special case: rates and latency come from the scenario's
  ``LinkModel`` unchanged and ``loss`` gives a flat per-segment erasure
  probability, so ``ChannelModel()`` (all defaults) reproduces the
  lossless simulator's ``Delivery`` byte/time accounting exactly;
* **outage processes** (:mod:`repro.channel.outage`) — per-window rain
  fades feed extra dB into the budget; conjunction blackouts mask whole
  windows (the engine folds them into its blocked-window arrays);
* **ARQ** (:class:`repro.channel.arq.SelectiveRepeatARQ`) — selective
  repeat whose retransmissions consume real window time and can truncate
  a delivery mid-window.

All randomness is counter-based: a draw is a pure hash of
``(engine seed, channel seed, station, sat, window id, round, segment)``
(:func:`repro.channel.outage.counter_uniform`), so outcomes never depend
on event-processing order or contact-plan extension.  The device-side
sibling is the Pallas erasure-mask kernel
(:mod:`repro.kernels.erasure_mask`), which applies the same
counter-hash → threshold decision to packed wire words in batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..constellation.links import LinkModel
from ..constellation.orbits import GroundStation, Walker
from ..obs.trace import active as _obs_active
from .arq import ArqPlan, SelectiveRepeatARQ, TxResult
from .budget import LinkBudget, elevation_at
from .outage import ConjunctionBlackout, RainFade, counter_uniforms


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """One GS-uplink impairment stack (ISLs stay ideal — the sat↔GS leg
    dominates both loss and rate in LEO federated uplinks)."""

    budget: Optional[LinkBudget] = None   # None → fixed LinkModel rates
    arq: SelectiveRepeatARQ = SelectiveRepeatARQ()
    loss: float = 0.0                     # flat p_seg when budget is None
    rain: Optional[RainFade] = None
    blackout: Optional[ConjunctionBlackout] = None
    seed: int = 0

    # -- link state --------------------------------------------------------
    def fade_db(self, seed: int, station: int, sat: int,
                window_id: int) -> float:
        if self.rain is None:
            return 0.0
        return self.rain.fade_db(seed ^ self.seed, station, sat, window_id)

    def rate(self, link: LinkModel, elevation_deg: float,
             fade_db: float = 0.0) -> float:
        """Instantaneous GS-link rate (bytes/s)."""
        if self.budget is None:
            return link.gs_rate
        return self.budget.rate(elevation_deg, fade_db)

    def p_seg(self, elevation_deg: float, fade_db: float = 0.0) -> float:
        """Per-segment erasure probability at the given link state."""
        if self.budget is None:
            return float(self.loss)
        return self.budget.p_seg(elevation_deg, self.arq.seg_bytes, fade_db)

    # -- scheduling estimate ----------------------------------------------
    def estimate_time(self, link: LinkModel, nbytes: float, *,
                      walker: Walker, station_obj: GroundStation,
                      gateway: int, t: float, seed: int, station: int,
                      window_id: int) -> float:
        """Expected air time for window-fit checks (channel-aware
        scheduling): one-round time scaled by the expected transmission
        count per segment, ``1/(1−p)``.  Exactly ``LinkModel.gs_time``
        when the channel is lossless and fixed-rate.  Geometry and fade
        belong to the *gateway* — the satellite holding the GS link."""
        fade = self.fade_db(seed, station, gateway, window_id)
        if self.budget is None:
            base = link.gs_time(nbytes)
            p = float(self.loss)
        else:
            el = elevation_at(walker, station_obj, gateway, t)
            base = link.gs_latency + nbytes / self.rate(link, el, fade)
            p = self.p_seg(el, fade)
        if p <= 0.0:
            return base
        return base / max(1.0 - min(p, 0.9), 0.1)

    @property
    def time_invariant(self) -> bool:
        """True when rate/erasure probability don't depend on the
        transmission instant — the fixed-rate (``budget=None``) stack.
        Only then is a transmission replayable from an :class:`ArqPlan`."""
        return self.budget is None

    def arq_plan(self, link: LinkModel, nbytes: float, *, sat: int,
                 seed: int, station: int, window_id: int) -> ArqPlan:
        """Precomputed replayable delivery profile (fast-engine hot path).

        Mirrors :meth:`transmit`'s fixed-rate branch argument-for-argument
        — same constant rate/p/latency, same ``gs_time`` exact-path
        condition, same counter mix — so
        ``arq_plan(...).replay(t_start, window_end)`` returns the
        identical :class:`TxResult` bit-for-bit.  Erasure counters depend
        only on (seed, station, sat, window), so one plan serves every
        retry of the same update through the same window and caches
        across benchmark repetitions.  Raises on elevation-dependent
        (``budget``) channels — those must transmit through the oracle
        path.
        """
        if not self.time_invariant:
            raise ValueError("arq_plan requires a time-invariant channel "
                             "(budget=None); elevation-dependent budgets "
                             "must use transmit()")
        mix = (seed * 0x1F3F) ^ self.seed

        def draw(rnd, segs):
            return counter_uniforms(mix, station, sat, window_id, rnd, segs)

        return self.arq.plan(
            nbytes, rate=link.gs_rate, p_seg=float(self.loss),
            latency=link.gs_latency, draw=draw,
            gs_time=None if self.loss > 0.0 else link.gs_time)

    # -- transmission ------------------------------------------------------
    def transmit(self, link: LinkModel, nbytes: float, *,
                 walker: Walker, station_obj: GroundStation, gateway: int,
                 sat: int, t_start: float, window_end: float, seed: int,
                 station: int, window_id: int) -> TxResult:
        """Run one windowed ARQ delivery with this channel's link state.

        ``gateway`` is the transmitting satellite (elevation geometry and
        rain fade); ``sat`` identifies the update on the wire (erasure
        draw counters), so two updates relayed through the same gateway
        window share the fade but draw independent erasures.
        """
        fade = self.fade_db(seed, station, gateway, window_id)
        mix = (seed * 0x1F3F) ^ self.seed

        def draw(rnd, segs):
            return counter_uniforms(mix, station, sat, window_id, rnd, segs)

        if self.budget is None:
            return self.arq.transmit(
                nbytes, t_start, window_end,
                rate=lambda t: link.gs_rate,
                p_seg=lambda t: float(self.loss),
                latency=link.gs_latency, draw=draw,
                gs_time=None if self.loss > 0.0 else link.gs_time)

        def rate_at(t: float) -> float:
            return self.budget.rate(
                elevation_at(walker, station_obj, gateway, t), fade)

        def p_at(t: float) -> float:
            return self.budget.p_seg(
                elevation_at(walker, station_obj, gateway, t),
                self.arq.seg_bytes, fade)

        res = self.arq.transmit(nbytes, t_start, window_end, rate=rate_at,
                                p_seg=p_at, latency=link.gs_latency,
                                draw=draw)
        trc = _obs_active()
        if trc is not None:
            # budget-branch only: link-budget state per transmission.  The
            # fixed-rate branch stays silent — the fast engine replays
            # those via ArqPlan without calling transmit(), and per-link
            # SNR is a constant there anyway.  "link" events are therefore
            # NOT part of obs.summary.DIFF_KINDS.
            el = elevation_at(walker, station_obj, gateway, t_start)
            trc.event("link", station=int(station), sat=int(sat),
                      gateway=int(gateway), window_id=int(window_id),
                      t_start=float(t_start),
                      elevation_deg=float(el), fade_db=float(fade),
                      rate=float(self.budget.rate(el, fade)),
                      p_seg=float(res.p_seg), retries=int(res.retries),
                      delivered=bool(res.delivered),
                      nbytes_attempted=float(res.nbytes_attempted),
                      t_done=float(res.t_done))
            if fade > 0.0:
                trc.metrics.histogram("fade_db").observe(float(fade))
            trc.metrics.histogram("link_p_seg").observe(float(res.p_seg))
        return res
