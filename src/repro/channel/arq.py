"""Selective-repeat ARQ over an erasure link, inside one contact window.

A model update of ``nbytes`` is segmented into ``seg_bytes`` CRC-protected
segments.  Each transmission round puts every not-yet-acknowledged segment
on the air (one latency charge per round — the segments stream
back-to-back), the receiver NACKs the erased ones after an ARQ round trip,
and only those are retransmitted — classic selective repeat.  All of this
consumes *real contact-window time*: a round that would run past the
window's set time is truncated mid-flight, the remaining segments never
make it, and the delivery fails (the coordinator discards an update whose
segment set is incomplete).

Timing identities the rest of the simulator relies on:

* zero loss → exactly ONE round taking ``latency + nbytes / rate`` — the
  same float expression as ``LinkModel.gs_time``, so a lossless channel
  reproduces the fixed-rate simulator's accounting bit-for-bit;
* every retransmission round adds ``rtt + latency + retx_bytes / rate``;
* ``nbytes_attempted`` counts every byte put on the air (first rounds and
  retransmissions, including bytes of a truncated round), which is what
  the energy/bandwidth ledger of a real link pays for.

Randomness is injected through a ``draw(round, segs) -> U[0,1) array``
callable (one uniform per segment index in ``segs``, vectorized) — the
:class:`repro.channel.model.ChannelModel` binds it to the deterministic
counter hash of (seed, station, sat, window), keeping outcomes
reproducible and order-independent.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class TxResult:
    """Outcome of one windowed ARQ delivery attempt."""

    t_done: float               # when the link went quiet (success or not)
    delivered: bool             # all segments acknowledged
    nbytes: float               # payload delivered (0.0 when incomplete)
    nbytes_attempted: float     # bytes put on the air, retransmissions incl.
    retries: int                # transmission rounds beyond the first
    n_segments: int
    p_seg: float                # erasure probability the attempt saw


@dataclasses.dataclass
class ArqPlan:
    """Precomputed profile of one ARQ delivery over a TIME-INVARIANT link
    (fixed rate and erasure probability, i.e. ``budget=None`` channels).

    The erasure pattern of a delivery is a pure counter-hash of
    (seed, station, sat, window) — independent of when the transmission
    starts — so everything except window truncation can be computed once
    and replayed: :meth:`replay` re-runs only the ``t``/truncation
    arithmetic of :meth:`SelectiveRepeatARQ.transmit`, in the same float
    operation order, and therefore reproduces its :class:`TxResult`
    bit-for-bit for any ``(t_start, window_end)``.  Built by
    :meth:`SelectiveRepeatARQ.plan` from ONE batched counter draw over
    the whole (round, segment) grid instead of one draw per round.
    """

    rtt: float
    latency: float
    rate: float                 # bytes/s the truncation maths sees
    nbytes: float
    n_segments: int
    p_last: float               # erasure probability every round saw
    bursts: list                # per executed round: bytes put on the air
    t_airs: list                # per executed round: air time of the burst
    attempted_before: list      # attempted-bytes ledger entering each round
    attempted_total: float
    delivered: bool             # all segments landed within max_rounds

    def replay(self, t_start: float, window_end: float) -> TxResult:
        """Replay the planned delivery inside ``[t_start, window_end)``."""
        t = float(t_start)
        for k, t_air in enumerate(self.t_airs):
            if k > 0:
                t += self.rtt                      # wait for the NACK set
            if t + t_air > window_end:
                # truncated mid-window: count the bytes that made it out
                on_air = max(0.0, (window_end - t - self.latency)) * self.rate
                attempted = (self.attempted_before[k]
                             + min(self.bursts[k], max(on_air, 0.0)))
                return TxResult(float(window_end), False, 0.0, attempted,
                                k, self.n_segments, self.p_last)
            t += t_air
        rounds = len(self.t_airs)
        if not self.delivered:
            return TxResult(t, False, 0.0, self.attempted_total, rounds - 1,
                            self.n_segments, self.p_last)
        return TxResult(t, True, float(self.nbytes), self.attempted_total,
                        rounds - 1, self.n_segments, self.p_last)


@dataclasses.dataclass(frozen=True)
class SelectiveRepeatARQ:
    """Segmentation + retransmission policy (link-agnostic)."""

    seg_bytes: int = 1024       # segment payload granularity
    max_rounds: int = 4         # transmission rounds (1 initial + retx)
    rtt: float = 0.04           # NACK round-trip between rounds (s)

    def segment_sizes(self, nbytes: float) -> list:
        """Byte size of each segment (last one may be short)."""
        n_seg = max(1, math.ceil(nbytes / self.seg_bytes))
        sizes = [float(self.seg_bytes)] * n_seg
        sizes[-1] = nbytes - self.seg_bytes * (n_seg - 1)
        return sizes

    def plan(self, nbytes: float, *, rate: float, p_seg: float,
             latency: float,
             draw: Callable[[np.ndarray, np.ndarray], np.ndarray],
             gs_time: Optional[Callable[[float], float]] = None) -> ArqPlan:
        """Precompute a replayable :class:`ArqPlan` for a time-invariant
        link (``rate``/``p_seg`` scalars, not callables).

        Runs the same round loop as :meth:`transmit` — same burst sums in
        the same order, same per-round air-time expressions, same
        surviving-segment filtering — but samples the WHOLE
        (round, segment) uniform grid in one batched ``draw`` call (the
        counter hash is elementwise, so ``u[k, segs]`` equals what
        ``transmit``'s per-round ``draw(k, segs)`` would have returned)
        and records the per-round ledger :meth:`ArqPlan.replay` needs.
        """
        sizes = self.segment_sizes(nbytes)
        n_seg = len(sizes)
        if p_seg > 0.0:
            u = draw(np.arange(self.max_rounds, dtype=np.int64)[:, None],
                     np.arange(n_seg, dtype=np.int64)[None, :])
        remaining = list(range(n_seg))
        bursts: list = []
        t_airs: list = []
        attempted_before: list = []
        attempted = 0.0
        rounds = 0
        while remaining and rounds < self.max_rounds:
            burst = sum(sizes[i] for i in remaining)
            if gs_time is not None and len(remaining) == n_seg:
                t_air = gs_time(burst)             # exact fixed-rate path
            else:
                t_air = latency + burst / rate
            bursts.append(burst)
            t_airs.append(t_air)
            attempted_before.append(attempted)
            attempted += burst
            rounds += 1
            if p_seg > 0.0:
                segs = np.asarray(remaining)
                remaining = [int(i) for i in segs[u[rounds - 1, segs] < p_seg]]
            else:
                remaining = []
        return ArqPlan(rtt=self.rtt, latency=latency, rate=rate,
                       nbytes=nbytes, n_segments=n_seg,
                       p_last=float(p_seg), bursts=bursts, t_airs=t_airs,
                       attempted_before=attempted_before,
                       attempted_total=attempted,
                       delivered=not remaining)

    def transmit(self, nbytes: float, t_start: float, window_end: float,
                 *, rate: Callable[[float], float],
                 p_seg: Callable[[float], float],
                 latency: float,
                 draw: Callable[[int, np.ndarray], np.ndarray],
                 gs_time: Optional[Callable[[float], float]] = None
                 ) -> TxResult:
        """Run the ARQ state machine inside ``[t_start, window_end)``.

        ``rate(t)`` / ``p_seg(t)`` give the instantaneous link state (the
        budget evaluates them at each round's start — elevation changes
        between retransmissions of a long pass).  ``gs_time``, when given,
        computes a full-message round time directly; it exists so the
        fixed-rate channel reuses ``LinkModel.gs_time``'s exact float
        expression for the single-round zero-loss case.
        """
        sizes = self.segment_sizes(nbytes)
        remaining = list(range(len(sizes)))
        t = float(t_start)
        attempted = 0.0
        p_last = 0.0
        rounds = 0
        while remaining and rounds < self.max_rounds:
            if rounds > 0:
                t += self.rtt                      # wait for the NACK set
            r = rate(t)
            p_last = p_seg(t)
            burst = sum(sizes[i] for i in remaining)
            if gs_time is not None and len(remaining) == len(sizes):
                t_air = gs_time(burst)             # exact fixed-rate path
            else:
                t_air = latency + burst / r
            if t + t_air > window_end:
                # truncated mid-window: count the bytes that made it out
                on_air = max(0.0, (window_end - t - latency)) * r
                attempted += min(burst, max(on_air, 0.0))
                # the link stays claimed until the window closes under it
                return TxResult(float(window_end), False, 0.0,
                                attempted, rounds, len(sizes), p_last)
            attempted += burst
            t += t_air
            rounds += 1
            if p_last > 0.0:
                segs = np.asarray(remaining)
                u = draw(rounds - 1, segs)
                remaining = [int(i) for i in segs[u < p_last]]
            else:
                remaining = []
        if remaining:
            return TxResult(t, False, 0.0, attempted, rounds - 1,
                            len(sizes), p_last)
        return TxResult(t, True, float(nbytes), attempted, rounds - 1,
                        len(sizes), p_last)
