"""Stochastic lossy-channel subsystem: link-budget loss, outages, ARQ.

The simulator's contact windows say *when* a satellite can talk; this
package says *how well*.  See :mod:`repro.channel.model` for the facade
(`Scenario.channel` / ``SpaceRunner(channel=...)``) and
:mod:`repro.kernels.erasure_mask` for the device-side batch erasure
kernel over packed wire words.
"""
from .arq import ArqPlan, SelectiveRepeatARQ, TxResult
from .budget import LinkBudget, elevation_at, fspl_db, slant_range
from .model import ChannelModel
from .outage import (ConjunctionBlackout, RainFade, counter_uniform,
                     counter_uniforms)

__all__ = [
    "ArqPlan", "ChannelModel", "LinkBudget", "SelectiveRepeatARQ", "TxResult",
    "RainFade", "ConjunctionBlackout", "counter_uniform",
    "counter_uniforms", "elevation_at", "fspl_db", "slant_range",
]
