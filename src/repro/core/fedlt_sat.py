"""Fed-LTSat (paper Algorithm 3) — the space-ified federated runner.

Algorithm 3 = Algorithm 2 (Fed-LT + compression + EF) with the active set
S_k chosen by the orbit-aware scheduler and uplinks either direct to a GS
or forwarded over multi-hop ISLs — algebraically identical updates, but
different time/bandwidth accounting, which is what Table 2 measures.

The runner is ALGORITHM-AGNOSTIC (FedLT/FedAvg/FedProx/LED/5GCS) and drives
any of them through the discrete-event engine (``repro.sim.engine``) in one
of two aggregation modes:

  * ``mode="sync"`` — one engine round per communication round: the policy
    schedules gateways + ISL relays, the engine executes the plan, and the
    coordinator aggregates when the last scheduled update lands (the seed
    semantics, now with contact-plan scheduling, dropout, and per-station
    contention).
  * ``mode="async"`` — FedBuff-style buffered asynchrony: satellites train
    and deliver continuously; every ``buffer_size`` landed updates the
    coordinator aggregates once, weighting each satellite's received wire
    by ``(1 + staleness)^(-staleness_alpha)`` where staleness counts the
    aggregations that happened while the update was in flight.  The
    weighting is applied to the coordinator's received-wire state
    (``z_hat`` for FedLT, ``m_hat`` for the baselines) — stale updates are
    shrunk toward the previously received value, exactly the
    staleness-damped server step of FedBuff, without touching the
    algorithms themselves.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..constellation.links import message_bytes
from ..faults import quorum_close_time
from ..obs.trace import active as _obs_active
from .compression import Compressor
from .error_feedback import resync_cache
from .pytree import tree_map, tree_size, tree_split_keys, tree_where_mask


@dataclasses.dataclass
class RoundLog:
    round: int
    time: float            # wall-clock seconds since start
    bytes_up: float        # cumulative uplink bytes over GS links (air
    #                        bytes: with a lossy channel this counts
    #                        retransmissions and truncated attempts too)
    n_active: int          # updates the coordinator actually received
    error: Optional[float] = None
    staleness: Optional[float] = None   # async: mean staleness this round
    n_lost: int = 0        # attempted uplinks the channel destroyed
    bytes_isl: float = 0.0  # cumulative ISL bytes (in-orbit aggregation)


@dataclasses.dataclass(frozen=True, eq=False)
class SpaceRunner:
    """Drives any federated algorithm through the constellation simulator.

    ``engine`` is a :class:`repro.sim.engine.Engine`; a bare
    :class:`~repro.constellation.scheduler.Scheduler` is also accepted and
    wrapped in an engine over its own single-station scenario.

    With a lossy channel (``channel=`` here or on the engine's scenario),
    sync rounds distinguish *attempted* from *delivered* uplinks: lost
    satellites still train and pay air time, the coordinator's received
    wire reverts, and — with ``loss_robust=True`` and an EF-caching
    algorithm — the uplink residual reverts too, so the cached content
    telescopes into the next successful transmission instead of being
    discharged into a wire that never landed
    (:func:`_revert_lost_wires`).
    """

    engine: object
    wire_bits: float = 32.0      # nominal fallback (no-codec compressors)
    mode: str = "sync"           # "sync" | "async"
    buffer_size: int = 8         # async: aggregate every M landed updates
    staleness_alpha: float = 0.5  # async: wire weight (1+s)^(-alpha)
    compressor: Optional[Compressor] = None  # → measured WireMessage bytes
    # lossy channel (repro.channel.ChannelModel): installed on the engine;
    # an engine whose Scenario already carries one needs no argument here
    channel: Optional[object] = None
    # loss-robust error feedback (sync mode): when the channel destroys an
    # uplink, the satellite's EF residual reverts instead of being
    # discharged into the lost wire — the cached content telescopes into
    # the next successful transmission instead of vanishing.  Needs an
    # algorithm with an uplink cache (``c_up``).
    loss_robust: bool = True
    # byte measurement:
    #   "probe"  — encode ONE representative message up front; every
    #              delivery is accounted at that size (seed behavior)
    #   "cohort" — account each sync round from the actually-transmitted
    #              wire state, grouped per contact-window cohort (engine
    #              Cohorts): quant codecs cost out analytically per
    #              update (their sizes are shape-static), sparse codecs
    #              encode each update so content-dependent sizes are
    #              exact — ties in TopK or zeros in RandD shrink the
    #              accounted payload below the nominal fraction·n
    measure: str = "probe"       # "probe" | "cohort" (sync mode only)
    # node-level fault injection (repro.faults.FaultModel): installed on
    # the engine; an engine whose Scenario already carries one needs no
    # argument here.  Crashed satellites lose their in-flight update AND
    # their EF residual (resync_cache) — unlike erasures, where
    # loss_robust keeps the residual telescoping forward.
    faults: Optional[object] = None
    # round deadline with quorum (sync mode): the round closes at
    # t0 + deadline provided ≥ quorum·attempted update-weights landed
    # (else it extends to the quorum-completing landing); deliveries past
    # the close are stragglers, treated as erasures so their content
    # folds into the next round via EF.  None = wait for the last
    # scheduled delivery (historical behavior).
    deadline: Optional[float] = None
    quorum: float = 0.0

    def __post_init__(self):
        if hasattr(self.engine, "select") and not hasattr(self.engine, "run_round"):
            object.__setattr__(self, "engine", self.engine._engine())
        if self.channel is not None:
            # install on the (mutable) engine so every transmission the
            # engine commits runs through the lossy-channel ARQ; the
            # engine's install path also invalidates its ChannelCache
            # memo, which may hold ARQ plans for the previous channel
            if hasattr(self.engine, "install_channel"):
                self.engine.install_channel(self.channel)
            else:                            # wrapped non-Engine stand-ins
                self.engine.channel = self.channel
                self.engine._refresh_blocked()
        if self.faults is not None:
            if hasattr(self.engine, "install_faults"):
                self.engine.install_faults(self.faults)
            else:                            # wrapped non-Engine stand-ins
                self.engine.faults = self.faults
                self.engine._refresh_blocked()
        if self.mode not in ("sync", "async"):
            raise ValueError(f"mode must be 'sync' or 'async', got {self.mode!r}")
        if self.deadline is not None:
            if self.mode != "sync":
                raise ValueError(
                    "deadline/quorum round closing is sync-only — async "
                    "FedBuff aggregation has no round boundary to close")
            if self.deadline <= 0.0:
                raise ValueError(f"deadline must be > 0: {self.deadline}")
        if not 0.0 <= self.quorum <= 1.0:
            raise ValueError(f"quorum must be in [0,1]: {self.quorum}")
        if self.measure not in ("probe", "cohort"):
            raise ValueError(
                f"measure must be 'probe' or 'cohort', got {self.measure!r}")
        if self.measure == "cohort" and self.mode == "async":
            raise ValueError(
                "measure='cohort' needs per-round RoundResults and is sync-"
                "only; async runs account deliveries at the probe size")
        topo = getattr(self.engine, "topology", None)
        if topo is not None and getattr(topo, "kind", "direct") != "direct":
            if self.mode == "async":
                raise ValueError(
                    "mode='async' needs topology='direct' — plane "
                    "aggregation has no free-running merge point")
            if self.measure == "cohort":
                raise ValueError(
                    "measure='cohort' groups per-satellite wires by "
                    "contact window; plane topologies uplink one merged "
                    "wire per head — use measure='probe'")

    # -- shared setup ------------------------------------------------------
    def _msg_bytes(self, state) -> float:
        """On-wire size of one per-agent update.

        With a ``compressor`` whose wire codec exists, one representative
        per-agent message is actually encoded (``repro.wire``) and the
        exact ``WireMessage.nbytes`` — bit-packed payload + headers —
        drives every engine transmission time and ``bytes_up`` log.  The
        nominal ``wire_bits`` estimate is only the fallback for
        compressors without a codec.
        """
        if self.compressor is not None and \
                self.compressor.wire_codec() is not None:
            from ..wire import measure_tree_bytes  # lazy: wire imports core
            # encode one representative message: a random probe with the
            # per-agent shapes, run through the compressor (zeros — e.g.
            # the init state — would make sparse codecs count an empty
            # payload)
            template = tree_map(lambda x: x[0], state.x)
            keys = tree_split_keys(jax.random.PRNGKey(0), template)
            probe = tree_map(
                lambda k_, t: jax.random.normal(k_, t.shape, t.dtype),
                keys, template)
            wire = self.compressor(jax.random.PRNGKey(1), probe)
            return measure_tree_bytes(self.compressor, wire)
        n_params = tree_size(state.x) // jax.tree_util.tree_leaves(
            state.x)[0].shape[0]
        return message_bytes(n_params, self.wire_bits)

    def run(self, alg, state, data, n_rounds: int, key,
            error_fn: Optional[Callable] = None,
            log_every: int = 10, ckpt=None, ckpt_every: int = 1,
            resume: bool = False) -> tuple:
        """Drive ``n_rounds`` rounds.  ``ckpt`` (a
        :class:`repro.checkpoint.run.RunCheckpoint`) checkpoints the run
        every ``ckpt_every`` sync rounds; ``resume=True`` restarts from
        the newest intact checkpoint and continues bit-identically to an
        uninterrupted run (sync mode only — the async delivery stream
        has no round boundary to checkpoint at)."""
        if self.mode == "async":
            if ckpt is not None or resume:
                raise ValueError("checkpoint/resume is sync-only")
            return self._run_async(alg, state, data, n_rounds, key,
                                   error_fn, log_every)
        return self._run_sync(alg, state, data, n_rounds, key,
                              error_fn, log_every, ckpt=ckpt,
                              ckpt_every=ckpt_every, resume=resume)

    def _cohort_nbytes(self, state, cohorts) -> dict:
        """Measured on-wire bytes per satellite, grouped per cohort.

        Quant codecs have shape-static sizes, so each update is costed
        analytically (``tree_nbytes`` of one satellite's slice — no
        encode needed; the transmit-side *compute* for a cohort is the
        fused kernel benchmarked in ``benchmarks/sim_scale.py`` and
        exercised by ``FedLT(fused_uplink=True)``, not re-run here).
        Sparse codecs encode each update from the actually-transmitted
        wire state so content-dependent payload sizes are exact.
        """
        from ..wire.codecs import QuantCodec  # lazy: wire imports core
        codec = self.compressor.wire_codec()
        wire_field = "z_hat" if hasattr(state, "z_hat") else "m_hat"
        tree = getattr(state, wire_field)
        template = tree_map(lambda x: x[0], tree)
        static_nb = (float(codec.tree_nbytes(template))
                     if isinstance(codec, QuantCodec) else None)
        out: dict = {}
        for cohort in cohorts:
            if static_nb is not None:
                for s in cohort.sats:
                    out[s] = static_nb
                continue
            idx = np.asarray(cohort.sats)
            sub = tree_map(lambda x: x[idx], tree)
            for i, s in enumerate(cohort.sats):
                one = tree_map(lambda x: x[i], sub)
                out[s] = float(codec.encode(one).nbytes)
        return out

    # -- synchronous rounds ------------------------------------------------
    def _run_sync(self, alg, state, data, n_rounds, key, error_fn, log_every,
                  ckpt=None, ckpt_every: int = 1, resume: bool = False):
        msg = self._msg_bytes(state)
        use_cohorts = (self.measure == "cohort" and self.compressor is not None
                       and self.compressor.wire_codec() is not None)
        channel = getattr(self.engine, "channel", None)
        wire_field = "z_hat" if hasattr(state, "z_hat") else "m_hat"
        has_cache = hasattr(state, "c_up")
        round_fn = jax.jit(alg.round)
        t, up_bytes, isl_bytes = 0.0, 0.0, 0.0
        logs: List[RoundLog] = []
        keys = jax.random.split(key, n_rounds)
        trc = _obs_active()       # read once; None ⇒ tracing fully off
        start_k = 0
        if ckpt is not None and resume:
            loaded = ckpt.load(like=state)
            if loaded is not None:
                # bit-identical continuation: per-round keys come from the
                # same split above, engine rounds are pure functions of
                # (scenario, seed, t0), and the time cursor / accumulators
                # restore exactly — so rounds ≥ start_k replay the
                # uninterrupted run's floats
                state, meta = loaded
                start_k = int(meta.get("k_next", 0))
                t = float(meta.get("t", 0.0))
                up_bytes = float(meta.get("up_bytes", 0.0))
                isl_bytes = float(meta.get("isl_bytes", 0.0))
                logs = [RoundLog(**d) for d in meta.get("logs", [])]
                if hasattr(self.engine, "_round_idx"):
                    self.engine._round_idx = start_k   # trace round labels
                if trc is not None:
                    # replay the prefix's ledger curves so a resumed
                    # trace carries the full bit-identical series
                    trc.event("resume", k_next=start_k, t=float(t),
                              bytes_up=float(up_bytes))
                    for lg in logs:
                        trc.series("bytes_up", lg.round, lg.bytes_up)
                        if lg.error is not None:
                            trc.series("e_K", lg.round, lg.error)
        for k in range(start_k, n_rounds):
            if trc is None:
                res = self.engine.run_round(t, msg)
            else:
                with trc.span("stage", name="engine.run_round", round=k):
                    res = self.engine.run_round(t, msg)
            t_round0 = t
            delivered = res.mask
            attempted = np.zeros_like(delivered)
            merged = getattr(res, "merged", None)
            if merged is not None:
                # in-orbit aggregation: one head delivery stands for every
                # member it merged — they all trained and their wires all
                # crossed ISLs, so a lost head wire loses (and, below,
                # reverts) the whole plane
                for d in res.deliveries:
                    attempted[list(merged[d.sat])] = True
            else:
                for d in res.deliveries:
                    attempted[d.sat] = True
            aborted = getattr(res, "aborted", None)
            if aborted is not None:
                # updates destroyed in-orbit with no delivery record
                # (head-failover collateral): attempted-but-lost
                attempted = attempted | aborted
            crashed = getattr(res, "crashed", None)
            duration = res.duration
            if self.deadline is not None:
                # quorum round closing: the coordinator stops waiting at
                # t_close; anything landing later is a straggler whose
                # wire (and, with loss_robust, residual) reverts below —
                # its content folds into the next round via EF
                landed = [(d.t_done,
                           len(merged[d.sat]) if merged is not None else 1)
                          for d in res.deliveries if d.delivered]
                t_close = quorum_close_time(
                    t_round0, self.deadline, self.quorum, landed,
                    int(attempted.sum()))
                late = np.zeros_like(delivered)
                for d in res.deliveries:
                    if d.delivered and d.t_done > t_close:
                        if merged is not None:
                            late[list(merged[d.sat])] = True
                        else:
                            late[d.sat] = True
                delivered = delivered & ~late
                duration = max(t_close - t_round0, 0.0)
            lost = attempted & ~delivered
            lossy = bool(lost.any())
            # with a lossy channel the satellites that transmitted-but-lost
            # still trained and paid the uplink: they participate in the
            # round, then the coordinator-side wire is reverted below
            # (the coordinator can only know what actually landed)
            active_np = attempted if lossy else delivered
            if trc is None:
                state_new, _ = round_fn(state, data, jnp.asarray(active_np),
                                        keys[k])
            else:
                with trc.span("stage", name="alg.round", round=k,
                              n_active=int(active_np.sum())):
                    state_new, _ = round_fn(state, data,
                                            jnp.asarray(active_np), keys[k])
            # what each satellite actually put on the air this round — for
            # lost satellites that is the PRE-revert wire, so cohort byte
            # accounting below must measure this state, not the final one
            tx_state = state_new
            if lossy:
                absorb = self.loss_robust and has_cache
                state_new = _revert_lost_wires(
                    state_new, state, wire_field, jnp.asarray(lost),
                    absorb=absorb)
                if trc is not None:
                    # resid_norm: ‖c_up[lost]‖ after the revert — the EF
                    # content kept telescoping instead of vanishing
                    lost_idx = np.nonzero(lost)[0]
                    norm2 = 0.0
                    if has_cache:
                        for leaf in jax.tree_util.tree_leaves(state_new.c_up):
                            arr = np.asarray(leaf[lost_idx], dtype=np.float64)
                            norm2 += float((arr * arr).sum())
                    trc.event("ef_revert", round=k, n_lost=int(lost.sum()),
                              sats=[int(s) for s in lost_idx],
                              absorb=bool(absorb),
                              resid_norm=float(np.sqrt(norm2)))
                    trc.metrics.counter("ef_reverts").add(float(lost.sum()))
                    trc.series("ef_resid_norm", k, float(np.sqrt(norm2)))
            if crashed is not None and bool(crashed.any()) and has_cache:
                # crash semantics: the rebooted sat's memory is gone, so
                # the erasure revert above (which KEEPS the residual) is
                # overridden for crashed rows — c_up re-syncs to zero
                state_new = state_new._replace(
                    c_up=resync_cache(state_new.c_up, crashed))
                if trc is not None:
                    trc.event("ef_resync", round=k,
                              n_crashed=int(crashed.sum()),
                              sats=[int(s) for s in np.nonzero(crashed)[0]])
                    trc.metrics.counter("ef_resyncs").add(
                        float(crashed.sum()))
            state = state_new
            t += duration
            # bytes_up = what actually crossed the GS links this round —
            # air bytes, i.e. retransmissions and truncated attempts count
            if use_cohorts:
                per_sat = self._cohort_nbytes(tx_state, res.cohorts())
                if channel is not None:
                    up_bytes += sum(
                        per_sat[d.sat] * (d.nbytes_attempted / msg)
                        for d in res.deliveries)
                else:
                    up_bytes += sum(per_sat.values())
            else:
                up_bytes += sum(d.nbytes_attempted for d in res.deliveries)
            isl_bytes += float(getattr(res, "bytes_isl", 0.0))
            err = (float(error_fn(state))
                   if error_fn is not None and (k % log_every == 0
                                                or k == n_rounds - 1) else None)
            logs.append(RoundLog(k, t, up_bytes, int(delivered.sum()), err,
                                 n_lost=int(lost.sum()),
                                 bytes_isl=isl_bytes))
            if trc is not None:
                # downlink ledger: the coordinator rebroadcasts the model
                # to every satellite it scheduled (not modeled by the
                # engine's uplink timeline, so accounted here)
                down = trc.metrics.counter("bytes_down")
                down.add(msg * float(res.scheduled.sum()))
                plane_kw = ({} if merged is None
                            else dict(bytes_isl=float(isl_bytes)))
                trc.event("fl_round", round=k, t0=float(t_round0),
                          t=float(t), bytes_up=float(up_bytes),
                          n_active=int(delivered.sum()),
                          n_lost=int(lost.sum()),
                          error=err if err == err else None,
                          mode="sync", **plane_kw)
                # first-class convergence/byte curves for the run ledger
                trc.series("bytes_up", k, up_bytes)
                trc.series("bytes_down", k, down.total)
                if merged is not None:
                    trc.series("bytes_isl_cum", k, isl_bytes)
                n_att = int(attempted.sum())
                trc.series("lost_frac", k,
                           float(lost.sum()) / n_att if n_att else 0.0)
                # quorum/fault observability: who made it into this
                # round's aggregate, and what fraction of the attempted
                # cohort that is (1.0 on a healthy deadline-less round)
                n_surv = int(delivered.sum())
                trc.series("survivors", k, float(n_surv))
                trc.series("quorum_frac", k,
                           n_surv / n_att if n_att else 1.0)
                if err is not None and err == err:
                    trc.series("e_K", k, err)
            if ckpt is not None and ((k + 1) % ckpt_every == 0
                                     or k == n_rounds - 1):
                ckpt.save_round(state, step=k + 1, t=t, up_bytes=up_bytes,
                                isl_bytes=isl_bytes, logs=logs)
        return state, logs

    # -- buffered-async (FedBuff-style) -------------------------------------
    def _run_async(self, alg, state, data, n_rounds, key, error_fn, log_every):
        msg = self._msg_bytes(state)
        round_fn = jax.jit(alg.round)
        n_agents = jax.tree_util.tree_leaves(state.x)[0].shape[0]
        wire_field = "z_hat" if hasattr(state, "z_hat") else "m_hat"

        trc = _obs_active()       # read once; None ⇒ tracing fully off
        if trc is None:
            records = self.engine.run_async(
                0.0, msg, n_deliveries=n_rounds * self.buffer_size)
        else:
            with trc.span("stage", name="engine.run_async",
                          n_deliveries=n_rounds * self.buffer_size):
                records = self.engine.run_async(
                    0.0, msg, n_deliveries=n_rounds * self.buffer_size)
        # only landed updates feed the aggregator; with a lossy channel the
        # record list also holds failed attempts, whose air bytes still
        # count toward the uplink ledger below
        deliveries = [d for d in records if d.delivered]
        rec_ptr = 0
        agg_times: List[float] = []
        logs: List[RoundLog] = []
        up_bytes = 0.0
        keys = jax.random.split(key, n_rounds)
        for k in range(n_rounds):
            chunk = deliveries[k * self.buffer_size:(k + 1) * self.buffer_size]
            if not chunk:
                break           # windows ran dry before n_rounds aggregations
            active_np = np.zeros(n_agents, dtype=bool)
            stale = np.zeros(n_agents, dtype=np.float64)
            for d in chunk:
                active_np[d.sat] = True
                stale[d.sat] = len(agg_times) - bisect.bisect_right(
                    agg_times, d.t_start)
            weights = np.where(active_np,
                               (1.0 + stale) ** (-self.staleness_alpha), 1.0)
            if trc is None:
                new_state, _ = round_fn(state, data, jnp.asarray(active_np),
                                        keys[k])
            else:
                with trc.span("stage", name="alg.round", round=k,
                              n_active=int(active_np.sum())):
                    new_state, _ = round_fn(state, data,
                                            jnp.asarray(active_np), keys[k])
            state = _damp_wires(new_state, state, wire_field,
                                jnp.asarray(weights))
            t0_agg = chunk[0].t_start
            t = chunk[-1].t_done
            agg_times.append(t)
            n_lost_win = 0
            while rec_ptr < len(records) and records[rec_ptr].t_done <= t:
                up_bytes += records[rec_ptr].nbytes_attempted
                n_lost_win += not records[rec_ptr].delivered
                rec_ptr += 1
            err = (float(error_fn(state))
                   if error_fn is not None and (k % log_every == 0
                                                or k == n_rounds - 1) else None)
            mean_stale = float(stale[active_np].mean())
            logs.append(RoundLog(k, t, up_bytes, int(active_np.sum()), err,
                                 staleness=mean_stale))
            if trc is not None:
                hist = trc.metrics.histogram("staleness", lo=0.0)
                for d in chunk:
                    hist.observe(float(stale[d.sat]))
                down = trc.metrics.counter("bytes_down")
                down.add(msg * float(active_np.sum()))
                trc.event("fl_round", round=k, t0=float(t0_agg),
                          t=float(t), bytes_up=float(up_bytes),
                          n_active=int(active_np.sum()),
                          n_lost=n_lost_win, staleness=mean_stale,
                          error=err if err == err else None,
                          mode="async")
                # first-class convergence/byte curves for the run ledger
                trc.series("bytes_up", k, up_bytes)
                trc.series("bytes_down", k, down.total)
                trc.series("staleness", k, mean_stale)
                n_win = len(chunk) + n_lost_win
                trc.series("lost_frac", k,
                           n_lost_win / n_win if n_win else 0.0)
                if err is not None and err == err:
                    trc.series("e_K", k, err)
        return state, logs


def _revert_lost_wires(new_state, old_state, field: str, lost,
                       *, absorb: bool):
    """Coordinator-side fix-up for channel-destroyed uplinks.

    The round ran with the lost satellites active (they trained and
    transmitted), but the coordinator never received their wire: its
    received-wire slot (``z_hat``/``m_hat``) reverts to the previous
    value.

    With ``absorb=True`` (loss-robust EF) the satellite's uplink residual
    also reverts: ``c_up ← c_up_old``.  The EF cache update
    ``c ← (msg + c_old) − wire`` discharges the cached residual into the
    wire — legitimate only if the wire *lands*.  Reverting on loss keeps
    the residual (plus the quantization error it was carrying) in the
    cache, so the lost round's content telescopes into the agent's next
    successful transmission exactly as if the round had never been
    scheduled; per-agent, EF runs over the subsequence of successful
    uplinks, which is what the paper's telescoping argument (§2.2) needs.
    Without the revert (``absorb=False`` — naive lossy EF) the cache
    wrongly believes the wire was delivered and the residual vanishes
    from the bookkeeping.
    """
    wire_new = getattr(new_state, field)
    wire_old = getattr(old_state, field)
    out = new_state._replace(
        **{field: tree_where_mask(lost, wire_old, wire_new)})
    if absorb:
        out = out._replace(c_up=tree_where_mask(lost, old_state.c_up,
                                                new_state.c_up))
    return out


def _damp_wires(new_state, old_state, field: str, weights):
    """Staleness-weighted server step: blend the coordinator's received
    wires between this round's value and the previous one, per agent.
    Agents whose wire did not change this round are unaffected (blend is a
    no-op when new == old)."""
    new_wire = getattr(new_state, field)
    old_wire = getattr(old_state, field)

    def blend(nw, ow):
        w = weights.reshape((-1,) + (1,) * (nw.ndim - 1))
        return w * nw + (1.0 - w) * ow

    return new_state._replace(**{field: tree_map(blend, new_wire, old_wire)})
