"""Fed-LTSat (paper Algorithm 3) — the space-ified federated runner.

Algorithm 3 = Algorithm 2 (Fed-LT + compression + EF) with

  * the active set S_k chosen by the orbit-aware scheduler (line 6): the
    satellites whose GS windows minimize the round completion time, plus
    in-plane neighbours relayed through ISLs;
  * uplink transmissions either direct to the GS or forwarded through a
    neighbouring satellite (line 15) — algebraically identical updates, but
    different time/bandwidth accounting, which is what Table 2 measures.

The runner is ALGORITHM-AGNOSTIC (works for FedAvg/FedProx/LED/5GCS too) —
the paper space-ifies all baselines the same way for Table 2.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..constellation.links import message_bytes
from ..constellation.scheduler import Scheduler
from .pytree import tree_size


@dataclasses.dataclass
class RoundLog:
    round: int
    time: float            # wall-clock seconds since start
    bytes_up: float        # cumulative uplink bytes over GS links
    n_active: int
    error: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class SpaceRunner:
    """Drives any federated algorithm through the constellation simulator."""

    scheduler: Scheduler
    wire_bits: float = 32.0      # per-scalar uplink size (compressor-dependent)

    def run(self, alg, state, data, n_rounds: int, key,
            error_fn: Optional[Callable] = None,
            log_every: int = 10) -> tuple:
        n_params = tree_size(state.x) // jax.tree_util.tree_leaves(
            state.x)[0].shape[0]
        msg = message_bytes(n_params, self.wire_bits)
        round_fn = jax.jit(alg.round)

        t, up_bytes = 0.0, 0.0
        logs: List[RoundLog] = []
        keys = jax.random.split(key, n_rounds)
        for k in range(n_rounds):
            active_np, duration = self.scheduler.select(t, msg)
            active = jnp.asarray(active_np)
            state, _ = round_fn(state, data, active, keys[k])
            t += duration
            up_bytes += float(active_np.sum()) * msg
            if error_fn is not None and (k % log_every == 0 or k == n_rounds - 1):
                logs.append(RoundLog(k, t, up_bytes, int(active_np.sum()),
                                     float(error_fn(state))))
            else:
                logs.append(RoundLog(k, t, up_bytes, int(active_np.sum())))
        return state, logs
