"""δ-approximate compressors (paper §2.4, Definitions 1–3).

A compressor is a callable ``C(key, tree) -> tree`` mapping a pytree to a
pytree of the same structure/shapes.  ``key`` is a PRNG key consumed only by
stochastic compressors (rand-d); deterministic ones ignore it.

Implemented:
  * :class:`UniformQuantizer` — paper Definition 2 (component-wise uniform
    quantization with L levels over [V_min, V_max]).
  * :class:`RandD` — paper Definition 3 (keep exactly d coordinates chosen
    uniformly at random, zero the rest).
  * :class:`TopK` — keep the k largest-magnitude coordinates (classic
    δ-approximate contraction with δ = k/n).
  * :class:`ScaledSign` — ‖x‖₁/n · sign(x) (Karimireddy et al., 2019).
  * :class:`Identity` — no compression (δ = 1).

For the deploy path (real wire-bytes savings across the slow inter-pod
link), :func:`quantize_encode` / :func:`quantize_decode` provide the integer
on-wire codec matching :class:`UniformQuantizer`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .pytree import tree_map, tree_split_keys


class Compressor:
    """Base class; subclasses implement :meth:`compress_leaf`."""

    #: True if the compressor consumes PRNG randomness.
    stochastic: bool = False

    def compress_leaf(self, key, x):  # pragma: no cover - abstract
        raise NotImplementedError

    def __call__(self, key, tree):
        if self.stochastic:
            keys = tree_split_keys(key, tree)
            return tree_map(lambda k, x: self.compress_leaf(k, x), keys, tree)
        return tree_map(lambda x: self.compress_leaf(None, x), tree)

    def wire_bits_per_scalar(self) -> float:
        """Nominal on-wire cost (bits per tensor element) of this compressor.

        Used by the constellation link model to convert messages to
        transmission times.
        """
        return 32.0


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    def compress_leaf(self, key, x):
        return x

    def wire_bits_per_scalar(self) -> float:
        return 32.0


@dataclasses.dataclass(frozen=True)
class UniformQuantizer(Compressor):
    """Paper Definition 2.

    q(x) = Δ · floor((x − V_min)/Δ + 0.5) + V_min,  Δ = (V_max − V_min)/L.

    ``clip`` optionally clamps inputs into [V_min, V_max] first; the paper's
    definition does not clip (values far outside the range quantize onto the
    extrapolated lattice), so ``clip`` defaults to False for faithfulness.
    """

    levels: int = 1000
    vmin: float = -10.0
    vmax: float = 10.0
    clip: bool = False

    def compress_leaf(self, key, x):
        delta = (self.vmax - self.vmin) / self.levels
        xx = jnp.clip(x, self.vmin, self.vmax) if self.clip else x
        q = delta * jnp.floor((xx - self.vmin) / delta + 0.5) + self.vmin
        return q.astype(x.dtype)

    def wire_bits_per_scalar(self) -> float:
        # level indices need ceil(log2(L+1)) bits (+ negligible scale scalars)
        return float(max(1, int(jnp.ceil(jnp.log2(self.levels + 1)))))


@dataclasses.dataclass(frozen=True)
class RandD(Compressor):
    """Paper Definition 3: keep exactly d coordinates, uniformly at random.

    ``fraction`` gives d = round(fraction · n) per leaf (the paper uses
    d = 0.8n and d = 0.2n).
    """

    fraction: float = 0.5
    stochastic: bool = True

    def compress_leaf(self, key, x):
        n = x.size
        d = max(1, int(round(self.fraction * n)))
        # exactly-d mask: rank i.i.d. uniforms, keep the d smallest.
        u = jax.random.uniform(key, (n,))
        # threshold = d-th smallest value
        kth = jnp.sort(u)[d - 1]
        mask = (u <= kth).reshape(x.shape)
        return jnp.where(mask, x, 0).astype(x.dtype)

    def wire_bits_per_scalar(self) -> float:
        # values (32b) + indices (~32b) for the kept fraction
        return 64.0 * self.fraction


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Keep the k = round(fraction·n) largest-|x| coordinates per leaf."""

    fraction: float = 0.1

    def compress_leaf(self, key, x):
        n = x.size
        k = max(1, int(round(self.fraction * n)))
        flat = x.reshape(-1)
        mag = jnp.abs(flat)
        kth = jnp.sort(mag)[n - k]
        mask = mag >= kth
        return jnp.where(mask.reshape(x.shape), x, 0).astype(x.dtype)

    def wire_bits_per_scalar(self) -> float:
        return 64.0 * self.fraction


@dataclasses.dataclass(frozen=True)
class ScaledSign(Compressor):
    """C(x) = (‖x‖₁/n)·sign(x) — 1 bit/coordinate + one scale."""

    def compress_leaf(self, key, x):
        scale = jnp.mean(jnp.abs(x))
        return (scale * jnp.sign(x)).astype(x.dtype)

    def wire_bits_per_scalar(self) -> float:
        return 1.0


# ---------------------------------------------------------------------------
# On-wire integer codec for the deploy path.
# ---------------------------------------------------------------------------

def _int_dtype(levels: int):
    if levels <= 255:
        return jnp.uint8
    if levels <= 65535:
        return jnp.uint16
    return jnp.uint32


def quantize_encode(x, levels: int, vmin: float, vmax: float):
    """Encode to integer level indices (the bytes that cross the slow link).

    Returns the integer tensor; decode with :func:`quantize_decode`. Matches
    :class:`UniformQuantizer` with clip=True (on-wire encodings must clamp:
    an index outside [0, L] is not representable).
    """
    delta = (vmax - vmin) / levels
    idx = jnp.floor((jnp.clip(x, vmin, vmax) - vmin) / delta + 0.5)
    return jnp.clip(idx, 0, levels).astype(_int_dtype(levels))


def quantize_decode(idx, levels: int, vmin: float, vmax: float, dtype=jnp.float32):
    delta = (vmax - vmin) / levels
    return (idx.astype(dtype) * delta + vmin).astype(dtype)


def make_compressor(name: str, **kw) -> Compressor:
    table = {
        "identity": Identity,
        "quant": UniformQuantizer,
        "rand_d": RandD,
        "top_k": TopK,
        "sign": ScaledSign,
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}; options: {sorted(table)}")
    return table[name](**kw)
