"""δ-approximate compressors (paper §2.4, Definitions 1–3).

A compressor is a callable ``C(key, tree) -> tree`` mapping a pytree to a
pytree of the same structure/shapes.  ``key`` is a PRNG key consumed only by
stochastic compressors (rand-d); deterministic ones ignore it.

Implemented:
  * :class:`UniformQuantizer` — paper Definition 2 (component-wise uniform
    quantization with L levels over [V_min, V_max]).
  * :class:`RandD` — paper Definition 3 (keep exactly d coordinates chosen
    uniformly at random, zero the rest).
  * :class:`TopK` — keep the k largest-magnitude coordinates (classic
    δ-approximate contraction with δ = k/n).
  * :class:`ScaledSign` — ‖x‖₁/n · sign(x) (Karimireddy et al., 2019).
  * :class:`Identity` — no compression (δ = 1).

For the deploy path (real wire-bytes savings across the slow inter-pod
link), :func:`quantize_encode` / :func:`quantize_decode` provide the integer
on-wire codec matching :class:`UniformQuantizer`.

Exact on-wire serialization (bit-packed words + headers, paper §2.4) lives
in :mod:`repro.wire`: ``compressor.wire_codec()`` returns the matching
codec, and ``wire_bits_per_scalar`` remains the *nominal* payload estimate
the codecs are measured against (see ``benchmarks/wire_bench.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .pytree import tree_map, tree_split_keys


def wire_index_bits(levels: int) -> int:
    """Bit width of a uniform-quantizer level index: ceil(log2(L+1)).

    Single source of truth for the levels→bits mapping shared by
    :meth:`UniformQuantizer.wire_bits_per_scalar`, the wire codec's
    packing width, and the deploy-path gather width.
    """
    return max(1, math.ceil(math.log2(levels + 1)))


class Compressor:
    """Base class; subclasses implement :meth:`compress_leaf`."""

    #: True if the compressor consumes PRNG randomness.
    stochastic: bool = False

    def compress_leaf(self, key, x):  # pragma: no cover - abstract
        raise NotImplementedError

    def __call__(self, key, tree):
        if self.stochastic:
            keys = tree_split_keys(key, tree)
            return tree_map(lambda k, x: self.compress_leaf(k, x), keys, tree)
        return tree_map(lambda x: self.compress_leaf(None, x), tree)

    def wire_bits_per_scalar(self) -> float:
        """Nominal on-wire cost (bits per tensor element) of this compressor.

        Payload-only estimate (no headers); the exact measured size comes
        from :meth:`wire_codec` — see :mod:`repro.wire`.
        """
        return 32.0

    def wire_codec(self, interpret: Optional[bool] = None):
        """Exact on-wire codec for this compressor (None if it has no
        real serialization — then only the nominal estimate exists).

        The codec's round-trip is bit-exact w.r.t. the compressor's float
        output, with one caveat: a ``UniformQuantizer(clip=False)`` can
        emit lattice points outside [vmin, vmax] that have no on-wire
        index — the codec clamps them (byte accounting is still exact);
        use ``clip=True`` wherever lossless decode matters.
        """
        from ..wire.codecs import codec_for  # lazy: wire imports this module
        return codec_for(self, interpret=interpret)

    def wire_header_nbytes(self, ndim: int = 1) -> int:
        """Exact per-leaf header overhead of this compressor's codec."""
        codec = self.wire_codec()
        return 0 if codec is None else codec.leaf_header_nbytes(ndim)


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    def compress_leaf(self, key, x):
        return x

    def wire_bits_per_scalar(self) -> float:
        return 32.0


@dataclasses.dataclass(frozen=True)
class UniformQuantizer(Compressor):
    """Paper Definition 2.

    q(x) = Δ · floor((x − V_min)/Δ + 0.5) + V_min,  Δ = (V_max − V_min)/L.

    ``clip`` optionally clamps inputs into [V_min, V_max] first; the paper's
    definition does not clip (values far outside the range quantize onto the
    extrapolated lattice), so ``clip`` defaults to False for faithfulness.
    """

    levels: int = 1000
    vmin: float = -10.0
    vmax: float = 10.0
    clip: bool = False

    def compress_leaf(self, key, x):
        delta = (self.vmax - self.vmin) / self.levels
        xx = jnp.clip(x, self.vmin, self.vmax) if self.clip else x
        q = delta * jnp.floor((xx - self.vmin) / delta + 0.5) + self.vmin
        return q.astype(x.dtype)

    def wire_bits_per_scalar(self) -> float:
        # static int arithmetic stays host-side (math, not jnp — no
        # tracer/device round-trip)
        return float(wire_index_bits(self.levels))


@dataclasses.dataclass(frozen=True)
class RandD(Compressor):
    """Paper Definition 3: keep exactly d coordinates, uniformly at random.

    ``fraction`` gives d = round(fraction · n) per leaf (the paper uses
    d = 0.8n and d = 0.2n).
    """

    fraction: float = 0.5
    stochastic: bool = True

    def compress_leaf(self, key, x):
        n = x.size
        d = max(1, int(round(self.fraction * n)))
        # exactly-d mask: rank i.i.d. uniforms, keep the d smallest.
        u = jax.random.uniform(key, (n,))
        # threshold = d-th smallest value; top_k of the negation is
        # O(n log d) — the full sort only ever fed this one statistic
        kth = -jax.lax.top_k(-u, d)[0][d - 1]
        mask = (u <= kth).reshape(x.shape)
        return jnp.where(mask, x, 0).astype(x.dtype)

    def wire_bits_per_scalar(self) -> float:
        # values (32b) + indices (~32b) for the kept fraction
        return 64.0 * self.fraction


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Keep the k = round(fraction·n) largest-|x| coordinates per leaf."""

    fraction: float = 0.1

    def compress_leaf(self, key, x):
        n = x.size
        k = max(1, int(round(self.fraction * n)))
        flat = x.reshape(-1)
        mag = jnp.abs(flat)
        # threshold = k-th largest |x|: top_k selection, not a full sort
        kth = jax.lax.top_k(mag, k)[0][k - 1]
        mask = mag >= kth
        return jnp.where(mask.reshape(x.shape), x, 0).astype(x.dtype)

    def wire_bits_per_scalar(self) -> float:
        return 64.0 * self.fraction


@dataclasses.dataclass(frozen=True)
class ScaledSign(Compressor):
    """C(x) = (‖x‖₁/n)·sign(x) — 1 bit/coordinate + one scale.

    Uses the binarized signSGD convention ``sign(0) := +1`` so every
    output coordinate is exactly ±scale and the 1-bit wire codec
    (:class:`repro.wire.SignCodec`) round-trips it losslessly.  The
    contraction bound is unchanged: ‖C(x)−x‖² = ‖x‖² − (‖x‖₁)²/n ≤ ‖x‖²
    holds for either convention since zero coordinates contribute 0 to
    x·sign(x).
    """

    def compress_leaf(self, key, x):
        scale = jnp.mean(jnp.abs(x))
        return (scale * jnp.where(x >= 0, 1.0, -1.0)).astype(x.dtype)

    def wire_bits_per_scalar(self) -> float:
        return 1.0


# ---------------------------------------------------------------------------
# On-wire integer codec for the deploy path.
# ---------------------------------------------------------------------------

def _int_dtype(levels: int):
    if levels <= 255:
        return jnp.uint8
    if levels <= 65535:
        return jnp.uint16
    return jnp.uint32


def quantize_encode(x, levels: int, vmin: float, vmax: float):
    """Encode to integer level indices (the bytes that cross the slow link).

    Returns the integer tensor; decode with :func:`quantize_decode`. Matches
    :class:`UniformQuantizer` with clip=True (on-wire encodings must clamp:
    an index outside [0, L] is not representable).
    """
    delta = (vmax - vmin) / levels
    idx = jnp.floor((jnp.clip(x, vmin, vmax) - vmin) / delta + 0.5)
    return jnp.clip(idx, 0, levels).astype(_int_dtype(levels))


def quantize_decode(idx, levels: int, vmin: float, vmax: float, dtype=jnp.float32):
    delta = (vmax - vmin) / levels
    return (idx.astype(dtype) * delta + vmin).astype(dtype)


def make_compressor(name: str, **kw) -> Compressor:
    """Build a compressor by name; every returned compressor carries a
    wire codec (``.wire_codec()``) with exact byte accounting and these
    header overheads (round-trip is bit-exact except for ``quant`` with
    ``clip=False``, whose out-of-range lattice points the wire clamps):

    ============  =======  ==============================================
    name          codec    exact per-leaf header (4 + 4·ndim base bytes +)
    ============  =======  ==============================================
    identity      dense    +0
    quant         quant    +12  (levels u32, vmin f32, vmax f32)
    sign          sign     +4   (scale f32)
    top_k/rand_d  sparse   +4   (k u32)
    ============  =======  ==============================================

    plus an 8-byte per-message header; query exact numbers with
    ``make_compressor(name).wire_header_nbytes(ndim)`` — the simulator's
    byte accounting uses these, not the nominal ``wire_bits_per_scalar``.
    """
    table = {
        "identity": Identity,
        "quant": UniformQuantizer,
        "rand_d": RandD,
        "top_k": TopK,
        "sign": ScaledSign,
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}; options: {sorted(table)}")
    return table[name](**kw)
