"""The paper's contribution: Fed-LT + compression + error feedback (+ the
space-ified Fed-LTSat), as composable JAX modules."""
from .baselines import LED, FedAvg, FedProx, FiveGCS
from .compression import (Identity, RandD, ScaledSign, TopK,
                          UniformQuantizer, make_compressor,
                          quantize_decode, quantize_encode)
from .deploy import DeployFedLT, DeployState
from .error_feedback import EFChannel, GroupedEFChannel
from .fedlt import FedLT, FedLTState, optimality_error
from .fedlt_sat import RoundLog, SpaceRunner

__all__ = [
    "FedLT", "FedLTState", "optimality_error", "EFChannel",
    "GroupedEFChannel",
    "UniformQuantizer", "RandD", "TopK", "ScaledSign", "Identity",
    "make_compressor", "quantize_encode", "quantize_decode",
    "FedAvg", "FedProx", "LED", "FiveGCS",
    "SpaceRunner", "RoundLog", "DeployFedLT", "DeployState",
]
