"""State-of-the-art baselines the paper compares against (Table 2).

All four are "space-ified" exactly as the paper describes: the active set
S_k comes from the orbit scheduler (or Bernoulli sampling), and both links
are wrapped in the *algorithm-agnostic* EF channel of Fig. 3 — which is the
point the paper makes: the EF scheme plugs into any federated method.

  * FedAvg   (McMahan et al., 2017)  — local SGD/GD + model averaging
  * FedProx  (Li et al., 2020b)      — FedAvg + proximal term μ
  * LED      (Alghunaim, 2024)       — local exact-diffusion, star-adapted
  * 5GCS     (Grudzień et al., 2023) — prox-point local training with
                                       client sampling + control variates

Shared state layout (leading agent axis N where noted):
    x      (N, …)  last local model per agent (used by the e_k metric)
    m_hat  (N, …)  coordinator's last-received uplink wire per agent
    c_up   (N, …)  per-agent uplink EF cache
    c_down (…)     coordinator downlink EF cache
    extra  (algorithm-specific: ψ_prev for LED, h for 5GCS)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .error_feedback import EFChannel
from .pytree import (tree_map, tree_mean_axis0, tree_where_mask,
                     tree_zeros_like)
from ..optim.solvers import local_gd


class FedState(NamedTuple):
    x: object
    m_hat: object
    c_up: object
    c_down: object
    extra: object
    k: jnp.ndarray


def _replicate(x0, n):
    return tree_map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), x0)


@dataclasses.dataclass(frozen=True)
class _Base:
    loss: Callable
    n_epochs: int = 10
    gamma: float = 0.1
    uplink: EFChannel = EFChannel()
    downlink: EFChannel = EFChannel()

    def _ef_uplink(self, key, msgs, caches, active, m_hat_old):
        """Vmapped uplink EF over agents; inactive agents keep caches/wires."""
        n = active.shape[0]
        keys = jax.random.split(key, n)
        wire, c_new = jax.vmap(lambda kk, m, c: self.uplink.send(kk, m, c))(
            keys, msgs, caches)
        c_up = tree_where_mask(active, c_new, caches)
        m_hat = tree_where_mask(active, wire, m_hat_old)
        return m_hat, c_up

    def run(self, state, data, n_rounds: int, key, participation: float = 1.0):
        n_agents = jax.tree_util.tree_leaves(state.x)[0].shape[0]

        def body(st, kk):
            k_act, k_round = jax.random.split(kk)
            if participation < 1.0:
                active = jax.random.bernoulli(k_act, participation, (n_agents,))
                active = active.at[0].set(True)
            else:
                active = jnp.ones((n_agents,), bool)
            st, info = self.round(st, data, active, k_round)
            return st, info

        keys = jax.random.split(key, n_rounds)
        return jax.lax.scan(body, state, keys)


@dataclasses.dataclass(frozen=True)
class FedAvg(_Base):
    """Uplink message = local model; coordinator averages received models."""

    prox_mu: float = 0.0  # >0 → FedProx

    def init(self, x0, n_agents: int) -> FedState:
        xN = _replicate(x0, n_agents)
        return FedState(x=xN, m_hat=xN, c_up=tree_zeros_like(xN),
                        c_down=tree_zeros_like(x0), extra=(),
                        k=jnp.zeros((), jnp.int32))

    def round(self, state: FedState, data, active, key) -> Tuple[FedState, dict]:
        k_down, k_up = jax.random.split(key)
        # coordinator: average last-received models, downlink with EF
        y = tree_mean_axis0(state.m_hat)
        y_wire, c_down = self.downlink.send(k_down, y, state.c_down)

        grad_fn = jax.grad(self.loss)

        def local(x_i, data_i):
            start = y_wire
            if self.prox_mu > 0.0:
                return local_gd(grad_fn, start, data_i, n_epochs=self.n_epochs,
                                gamma=self.gamma, prox_center=y_wire,
                                prox_mu=self.prox_mu)
            return local_gd(grad_fn, start, data_i, n_epochs=self.n_epochs,
                            gamma=self.gamma)

        x_new = jax.vmap(local)(state.x, data)
        x = tree_where_mask(active, x_new, state.x)
        m_hat, c_up = self._ef_uplink(k_up, x, state.c_up, active, state.m_hat)
        return FedState(x, m_hat, c_up, c_down, (), state.k + 1), {}


def FedProx(loss, *, n_epochs=10, gamma=0.1, prox_mu=0.1,
            uplink=EFChannel(), downlink=EFChannel()) -> FedAvg:
    return FedAvg(loss=loss, n_epochs=n_epochs, gamma=gamma, prox_mu=prox_mu,
                  uplink=uplink, downlink=downlink)


@dataclasses.dataclass(frozen=True)
class LED(_Base):
    """Local Exact-Diffusion (Alghunaim, 2024), star-topology adaptation.

    Exact diffusion in adapt–correct–combine form, with the star graph
    realized as lazy full averaging  W̄ = (I + 11ᵀ/N)/2  (the coordinator
    broadcasts the mean, each agent mixes it with its own φ_i):

        ψ_i⁺ = LocalGD(x_i, N_e, γ)                    (adapt, local steps)
        φ_i⁺ = ψ_i⁺ + x_i − ψ_i                        (correction)
        x_i⁺ = (φ_i⁺ + mean_j φ_j⁺)/2                  (combine)

    Initialization ψ_i⁰ = x_i⁰ carries the implicit dual; exactness holds at
    full participation (verified in tests).  Uplink message = φ_i.
    """

    def init(self, x0, n_agents: int) -> FedState:
        xN = _replicate(x0, n_agents)
        return FedState(x=xN, m_hat=xN, c_up=tree_zeros_like(xN),
                        c_down=tree_zeros_like(x0), extra=xN,  # ψ_prev
                        k=jnp.zeros((), jnp.int32))

    def round(self, state: FedState, data, active, key) -> Tuple[FedState, dict]:
        k_down, k_up = jax.random.split(key)
        grad_fn = jax.grad(self.loss)
        psi_prev = state.extra

        # adapt + correct (active agents)
        def local(x_i, psi_prev_i, data_i):
            psi = local_gd(grad_fn, x_i, data_i, n_epochs=self.n_epochs,
                           gamma=self.gamma)
            phi = tree_map(lambda p, xl, pp: p + xl - pp, psi, x_i, psi_prev_i)
            return psi, phi

        psi_new, phi = jax.vmap(local)(state.x, psi_prev, data)
        psi = tree_where_mask(active, psi_new, psi_prev)

        # uplink φ_i, coordinator aggregates THIS round's wires, downlink
        m_hat, c_up = self._ef_uplink(k_up, phi, state.c_up, active, state.m_hat)
        y = tree_mean_axis0(m_hat)
        y_wire, c_down = self.downlink.send(k_down, y, state.c_down)

        # combine (lazy star mixing) — active agents only
        x_new = tree_map(lambda ph, yb: 0.5 * (ph + yb[None]), phi, y_wire)
        x = tree_where_mask(active, x_new, state.x)
        return FedState(x, m_hat, c_up, c_down, psi, state.k + 1), {}


@dataclasses.dataclass(frozen=True)
class FiveGCS(_Base):
    """5GCS (Grudzień, Malinovsky, Richtárik 2023), simplified.

    Sampled clients approximately solve the prox subproblem
        w_i ≈ argmin_w f_i(w) + ‖w − (y + γ_p·h_i)‖²/(2·γ_p)
    with N_e local GD steps; control variates h_i ← h_i + (y − w_i)/γ_p;
    server moves toward the average of the received prox points.
    """

    gamma_p: float = 1.0     # prox radius γ_p
    server_lr: float = 1.0   # η: y ← y + η·mean_active(ŵ_i − y)

    def init(self, x0, n_agents: int) -> FedState:
        xN = _replicate(x0, n_agents)
        return FedState(x=xN, m_hat=xN, c_up=tree_zeros_like(xN),
                        c_down=tree_zeros_like(x0),
                        extra=(tree_zeros_like(xN),),  # h_i
                        k=jnp.zeros((), jnp.int32))

    def round(self, state: FedState, data, active, key) -> Tuple[FedState, dict]:
        k_down, k_up = jax.random.split(key)
        y = tree_mean_axis0(state.m_hat)
        y_wire, c_down = self.downlink.send(k_down, y, state.c_down)

        grad_fn = jax.grad(self.loss)
        (h,) = state.extra
        inv_gp = 1.0 / self.gamma_p

        def local(h_i, data_i):
            center = tree_map(lambda yb, hh: yb + self.gamma_p * hh, y_wire, h_i)

            def prox_grad(w, d):
                g = grad_fn(w, d)
                return tree_map(lambda gl, wl, cl: gl + inv_gp * (wl - cl),
                                g, w, center)

            return local_gd(prox_grad, y_wire, data_i, n_epochs=self.n_epochs,
                            gamma=self.gamma)

        w_new = jax.vmap(local)(h, data)
        x = tree_where_mask(active, w_new, state.x)

        # Σ h_i-conserving control-variate update: the anchor is the mean of
        # the prox points over the active set (piggy-backed on the downlink
        # in a physical deployment): h_i ← h_i + (w̄_S − w_i)/γ_p for i∈S.
        n_act = jnp.maximum(jnp.sum(active), 1)

        def masked_mean(leaf):
            m = active.reshape((-1,) + (1,) * (leaf.ndim - 1))
            return jnp.sum(jnp.where(m, leaf, 0), axis=0) / n_act

        w_bar = tree_map(masked_mean, w_new)
        h_new = tree_map(lambda hh, wb, wl: hh + inv_gp * (wb[None] - wl),
                         h, w_bar, w_new)
        h = tree_where_mask(active, h_new, h)

        # server target: y + η·(mean of received w − y); transmitted as the
        # uplink message so the coordinator can aggregate wires directly.
        msg = tree_map(lambda yb, wl: yb + self.server_lr * (wl - yb), y_wire, w_new)
        m_hat, c_up = self._ef_uplink(k_up, msg, state.c_up, active, state.m_hat)
        return FedState(x, m_hat, c_up, c_down, (h,), state.k + 1), {}
