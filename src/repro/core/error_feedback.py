"""Algorithm-agnostic error feedback (paper Fig. 3).

The paper's byproduct contribution: a *channel* that can wrap the uplink or
downlink of ANY federated algorithm.  Every transmission through the channel
adds the locally cached compression error to the message, compresses, caches
the new error, and puts the compressed message on the wire:

    wire      = C(msg + cache)
    new_cache = msg + cache − wire

With a δ-approximate compressor the cache stays bounded, and the telescoping
sum of wires equals the sum of messages minus the final cache — i.e. all
information is ultimately transmitted (paper §2.2).

:class:`EFChannel` carries no state itself; the cache pytree is threaded
explicitly so the channel composes with jit/vmap/scan.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .compression import (Compressor, Identity, UniformQuantizer,
                          quantize_decode, wire_index_bits)
from .pytree import tree_add, tree_map, tree_sub, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class EFChannel:
    """One direction of communication (uplink or downlink) with EF.

    ``enabled=False`` degrades to plain compression (Algorithm 1) while
    keeping the same state signature, so Algorithms 1 and 2 are the same
    code path with a flag — exactly the paper's ablation in Table 1.
    """

    compressor: Compressor = Identity()
    enabled: bool = True

    def init_cache(self, msg_like):
        return tree_zeros_like(msg_like)

    def send(self, key, msg, cache) -> Tuple[object, object]:
        """Returns (wire, new_cache)."""
        if not self.enabled:
            wire = self.compressor(key, msg)
            return wire, cache
        corrected = tree_add(msg, cache)
        wire = self.compressor(key, corrected)
        new_cache = tree_sub(corrected, wire)
        return wire, new_cache

    # -- fused pipeline fast path ------------------------------------------
    def fusable(self) -> bool:
        """True when :meth:`send_fused` can replace :meth:`send`: EF on and
        a clip=True uniform quantizer (the elementwise codec the fused
        Pallas sweep implements; clip=False lattice points outside
        [vmin, vmax] have no on-wire index)."""
        return (self.enabled and isinstance(self.compressor, UniformQuantizer)
                and self.compressor.clip)

    def send_fused(self, msg, cache) -> Tuple[object, object]:
        """One fused compress→EF→pack sweep over the WHOLE (possibly
        agent-stacked) tree — a single kernel dispatch per leaf instead of
        a per-satellite add → compress → subtract chain.

        Semantically identical to :meth:`send` for a fusable channel (the
        quantizer is deterministic, so no key): the wire floats are the
        decode of the exact packed words a transmitter would put on the
        link, and the new cache is the same telescoping residual.
        """
        from ..kernels import ops  # lazy: kernels import core.compression
        C = self.compressor
        bits = wire_index_bits(C.levels)

        def leaf(m, c):
            words, newc = ops.quant_pipeline(m, c, levels=C.levels,
                                             vmin=C.vmin, vmax=C.vmax)
            idx = ops.unpack_bits(words, bits, m.size)
            wire = quantize_decode(idx, C.levels, C.vmin, C.vmax,
                                   jnp.float32).astype(m.dtype
                                                       ).reshape(m.shape)
            return wire, newc

        leaves_m, treedef = jax.tree_util.tree_flatten(msg)
        leaves_c = treedef.flatten_up_to(cache)
        pairs = [leaf(m, c) for m, c in zip(leaves_m, leaves_c)]
        return (treedef.unflatten([w for w, _ in pairs]),
                treedef.unflatten([nc for _, nc in pairs]))


def resync_cache(cache, crashed):
    """Re-sync EF residuals of crashed satellites to zero.

    A radiation-upset crash (``repro.faults``) wipes the satellite's
    memory — unlike a link erasure, where the sat is alive and
    :func:`repro.core.fedlt_sat._revert_lost_wires` keeps the residual so
    the lost content telescopes forward, a crashed sat reboots with an
    EMPTY cache: the residual's content is simply gone.  ``crashed`` is a
    ``(N,)`` bool mask over the agent-stacked cache's leading axis;
    non-crashed rows pass through untouched.
    """
    m = jnp.asarray(crashed)

    def leaf(c):
        mask = m.reshape((-1,) + (1,) * (c.ndim - 1))
        return jnp.where(mask, jnp.zeros_like(c), c)

    return tree_map(leaf, cache)


@dataclasses.dataclass(frozen=True)
class GroupedEFChannel:
    """Error feedback with residuals held at aggregation *heads* instead
    of at the leaves.

    Under an in-orbit aggregation topology (``repro.sim.topology``) the
    members of an orbital plane merge their raw updates at an elected
    cluster head, and only the head's merged wire crosses the
    ground-station bottleneck.  That opens a second EF placement: keep
    ONE residual per *group* (plane) at the head, applied to the merged
    sum right before the uplink —

        group_msg_g = Σ_{i ∈ g} msg_i
        wire_g      = C(group_msg_g + cache_g)
        cache_g'    = group_msg_g + cache_g − wire_g

    versus the leaf placement (:class:`EFChannel` vmapped over members)
    where each member compresses before the ISL hop.  Head placement
    compresses once per group, so the compressor sees the already-
    averaged-scale merged signal; leaf placement keeps residual memory
    with the member even as head election migrates.

    Group membership is a ``(N,)`` int array of group ids (``-1`` =
    inactive this round, contributes nothing); the cache carries a
    leading group axis of static size ``n_groups``, so membership can
    change every round (head re-election, orbital drift) while the
    per-group residual stays put.  The same telescoping identity holds
    per group: the sum of landed wires plus the final cache equals the
    sum of everything the group's members ever offered.

    Loss robustness mirrors :meth:`revert`'s leaf analogue in
    ``repro.core.fedlt_sat._revert_lost_wires``: a destroyed head uplink
    puts the discharged content back (``cache_g += wire_g``), so the
    whole plane's round telescopes into the head's next successful
    transmission instead of vanishing.
    """

    compressor: Compressor = Identity()
    enabled: bool = True

    def init_cache(self, msg_like, n_groups: int):
        """Zero residuals: one slot per group, member shapes minus the
        leading agent axis (``msg_like`` is agent-stacked)."""
        return tree_map(
            lambda x: jnp.zeros((n_groups,) + x.shape[1:], x.dtype),
            msg_like)

    def group_sum(self, msgs, groups, n_groups: int):
        """Merge agent-stacked messages into per-group sums.

        ``groups`` entries of ``-1`` are masked out (their rows add
        zero); everything else scatters into its group's slot."""
        g = jnp.asarray(groups, jnp.int32)
        safe = jnp.where(g < 0, 0, g)
        live = (g >= 0)

        def leaf(x):
            mask = live.reshape((-1,) + (1,) * (x.ndim - 1))
            return jax.ops.segment_sum(
                jnp.where(mask, x, 0).astype(x.dtype), safe,
                num_segments=n_groups)

        return tree_map(leaf, msgs)

    def send(self, key, msgs, cache, groups, n_groups: int):
        """Merge → correct → compress at the heads.

        Returns ``(wire, new_cache)`` with a leading group axis on both;
        groups with no live member this round still discharge their
        cached residual (the head speaks for content banked in earlier
        rounds), matching the telescoping accounting."""
        gsum = self.group_sum(msgs, groups, n_groups)
        if not self.enabled:
            return self.compressor(key, gsum), cache
        corrected = tree_add(gsum, cache)
        wire = self.compressor(key, corrected)
        return wire, tree_sub(corrected, wire)

    def revert(self, new_cache, wire, lost):
        """Loss-robust revert for destroyed head uplinks.

        ``lost`` is a ``(n_groups,)`` bool mask.  For a lost group the
        wire never landed, so the discharged content goes back into the
        residual: ``cache + wire == corrected`` restores exactly the
        pre-compression state the next send re-offers."""
        m = jnp.asarray(lost)

        def leaf(c, w):
            mask = m.reshape((-1,) + (1,) * (c.ndim - 1))
            return jnp.where(mask, c + w, c)

        return tree_map(leaf, new_cache, wire)
