"""Algorithm-agnostic error feedback (paper Fig. 3).

The paper's byproduct contribution: a *channel* that can wrap the uplink or
downlink of ANY federated algorithm.  Every transmission through the channel
adds the locally cached compression error to the message, compresses, caches
the new error, and puts the compressed message on the wire:

    wire      = C(msg + cache)
    new_cache = msg + cache − wire

With a δ-approximate compressor the cache stays bounded, and the telescoping
sum of wires equals the sum of messages minus the final cache — i.e. all
information is ultimately transmitted (paper §2.2).

:class:`EFChannel` carries no state itself; the cache pytree is threaded
explicitly so the channel composes with jit/vmap/scan.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .compression import (Compressor, Identity, UniformQuantizer,
                          quantize_decode, wire_index_bits)
from .pytree import tree_add, tree_sub, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class EFChannel:
    """One direction of communication (uplink or downlink) with EF.

    ``enabled=False`` degrades to plain compression (Algorithm 1) while
    keeping the same state signature, so Algorithms 1 and 2 are the same
    code path with a flag — exactly the paper's ablation in Table 1.
    """

    compressor: Compressor = Identity()
    enabled: bool = True

    def init_cache(self, msg_like):
        return tree_zeros_like(msg_like)

    def send(self, key, msg, cache) -> Tuple[object, object]:
        """Returns (wire, new_cache)."""
        if not self.enabled:
            wire = self.compressor(key, msg)
            return wire, cache
        corrected = tree_add(msg, cache)
        wire = self.compressor(key, corrected)
        new_cache = tree_sub(corrected, wire)
        return wire, new_cache

    # -- fused pipeline fast path ------------------------------------------
    def fusable(self) -> bool:
        """True when :meth:`send_fused` can replace :meth:`send`: EF on and
        a clip=True uniform quantizer (the elementwise codec the fused
        Pallas sweep implements; clip=False lattice points outside
        [vmin, vmax] have no on-wire index)."""
        return (self.enabled and isinstance(self.compressor, UniformQuantizer)
                and self.compressor.clip)

    def send_fused(self, msg, cache) -> Tuple[object, object]:
        """One fused compress→EF→pack sweep over the WHOLE (possibly
        agent-stacked) tree — a single kernel dispatch per leaf instead of
        a per-satellite add → compress → subtract chain.

        Semantically identical to :meth:`send` for a fusable channel (the
        quantizer is deterministic, so no key): the wire floats are the
        decode of the exact packed words a transmitter would put on the
        link, and the new cache is the same telescoping residual.
        """
        from ..kernels import ops  # lazy: kernels import core.compression
        C = self.compressor
        bits = wire_index_bits(C.levels)

        def leaf(m, c):
            words, newc = ops.quant_pipeline(m, c, levels=C.levels,
                                             vmin=C.vmin, vmax=C.vmax)
            idx = ops.unpack_bits(words, bits, m.size)
            wire = quantize_decode(idx, C.levels, C.vmin, C.vmax,
                                   jnp.float32).astype(m.dtype
                                                       ).reshape(m.shape)
            return wire, newc

        leaves_m, treedef = jax.tree_util.tree_flatten(msg)
        leaves_c = treedef.flatten_up_to(cache)
        pairs = [leaf(m, c) for m, c in zip(leaves_m, leaves_c)]
        return (treedef.unflatten([w for w, _ in pairs]),
                treedef.unflatten([nc for _, nc in pairs]))
