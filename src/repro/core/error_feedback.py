"""Algorithm-agnostic error feedback (paper Fig. 3).

The paper's byproduct contribution: a *channel* that can wrap the uplink or
downlink of ANY federated algorithm.  Every transmission through the channel
adds the locally cached compression error to the message, compresses, caches
the new error, and puts the compressed message on the wire:

    wire      = C(msg + cache)
    new_cache = msg + cache − wire

With a δ-approximate compressor the cache stays bounded, and the telescoping
sum of wires equals the sum of messages minus the final cache — i.e. all
information is ultimately transmitted (paper §2.2).

:class:`EFChannel` carries no state itself; the cache pytree is threaded
explicitly so the channel composes with jit/vmap/scan.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from .compression import Compressor, Identity
from .pytree import tree_add, tree_sub, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class EFChannel:
    """One direction of communication (uplink or downlink) with EF.

    ``enabled=False`` degrades to plain compression (Algorithm 1) while
    keeping the same state signature, so Algorithms 1 and 2 are the same
    code path with a flag — exactly the paper's ablation in Table 1.
    """

    compressor: Compressor = Identity()
    enabled: bool = True

    def init_cache(self, msg_like):
        return tree_zeros_like(msg_like)

    def send(self, key, msg, cache) -> Tuple[object, object]:
        """Returns (wire, new_cache)."""
        if not self.enabled:
            wire = self.compressor(key, msg)
            return wire, cache
        corrected = tree_add(msg, cache)
        wire = self.compressor(key, corrected)
        new_cache = tree_sub(corrected, wire)
        return wire, new_cache
