"""Pytree arithmetic helpers used throughout the federated core.

All federated state (models x_i, auxiliaries z_i, EF caches c_i, the
coordinator aggregate y) are arbitrary pytrees of jnp arrays; in simulate
mode per-agent quantities carry an extra leading agent axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Tree = object  # any pytree of jnp arrays


def tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def tree_add(a, b):
    return tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return tree_map(jnp.subtract, a, b)


def tree_scale(s, a):
    return tree_map(lambda x: s * x, a)


def tree_axpy(s, a, b):
    """s * a + b."""
    return tree_map(lambda x, y: s * x + y, a, b)


def tree_zeros_like(a):
    return tree_map(jnp.zeros_like, a)


def tree_vdot(a, b):
    leaves = jax.tree_util.tree_leaves(tree_map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(leaves)


def tree_sq_norm(a):
    return tree_vdot(a, a)


def tree_norm(a):
    return jnp.sqrt(tree_sq_norm(a))


def tree_mean_axis0(a):
    """Mean over the leading (agent) axis of every leaf."""
    return tree_map(lambda x: jnp.mean(x, axis=0), a)


def tree_sum_axis0(a):
    return tree_map(lambda x: jnp.sum(x, axis=0), a)


def tree_where_mask(mask, a, b):
    """Select per-agent: leaves of a/b have leading agent axis; mask (N,)."""

    def sel(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)

    return tree_map(sel, a, b)


def tree_broadcast_agents(a, n_agents):
    """Tile a coordinator tree to a per-agent stacked tree."""
    return tree_map(lambda x: jnp.broadcast_to(x[None], (n_agents,) + x.shape), a)


def tree_size(a):
    return sum(x.size for x in jax.tree_util.tree_leaves(a))


def tree_bytes(a):
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(a))


def tree_cast(a, dtype):
    return tree_map(lambda x: x.astype(dtype), a)


def tree_split_keys(key, tree):
    """One PRNG key per leaf, returned as a matching pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))
