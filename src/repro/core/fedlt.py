"""Fed-LT with bi-directional compression and error feedback.

Simulate mode: Algorithms 1 and 2 of the paper, with all N agents vmapped
over a leading agent axis.  Algorithm 1 (compression, no EF) and Algorithm 2
(compression + EF) are the same code path — pass ``EFChannel(C, enabled=False)``
for Algorithm 1, exactly mirroring the paper's Table-1 ablation.

State layout (leaves carry a leading agent axis N where noted):

    x      (N, …)  per-agent models x_i
    z      (N, …)  per-agent auxiliaries z_i
    c_up   (N, …)  per-agent uplink EF caches c_i
    z_hat  (N, …)  coordinator's last-received uplink wire per agent
                   (what the paper calls z_{i,k−1} for inactive agents —
                   the coordinator can only know what was transmitted)
    c_down (…)     coordinator downlink EF cache c
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .error_feedback import EFChannel
from .pytree import (tree_map, tree_mean_axis0, tree_where_mask,
                     tree_zeros_like)
from ..optim.solvers import local_prox_gd


class FedLTState(NamedTuple):
    x: object
    z: object
    c_up: object
    z_hat: object
    c_down: object
    k: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class FedLT:
    """Algorithm 2 (paper). loss(params, agent_data) -> scalar.

    ``n_epochs`` = N_e, ``gamma`` = local step γ, ``rho`` = ρ.
    """

    loss: Callable
    n_epochs: int = 10
    gamma: float = 0.1
    rho: float = 1.0
    uplink: EFChannel = EFChannel()
    downlink: EFChannel = EFChannel()
    # one fused compress→EF→pack kernel sweep over the whole agent-stacked
    # uplink instead of a vmapped per-satellite add→compress→subtract chain
    # (requires uplink.fusable(): clip=True uniform quantizer with EF on;
    # silently falls back to the vmap path otherwise)
    fused_uplink: bool = False

    # -- setup ------------------------------------------------------------
    def init(self, x0, n_agents: int) -> FedLTState:
        """x0: single-model pytree (no agent axis); replicated to all agents."""
        xN = tree_map(lambda a: jnp.broadcast_to(a[None], (n_agents,) + a.shape).copy(), x0)
        return FedLTState(
            x=xN,
            z=xN,
            c_up=tree_zeros_like(xN),
            z_hat=xN,
            c_down=tree_zeros_like(x0),
            k=jnp.zeros((), jnp.int32),
        )

    # -- one communication round ------------------------------------------
    def round(self, state: FedLTState, data, active, key) -> Tuple[FedLTState, dict]:
        """One iteration of the outer loop.

        data:   pytree with leading agent axis N on every leaf.
        active: bool (N,) — the set S_{k+1} (from Bernoulli sampling or the
                orbit scheduler).
        """
        k_down, k_up = jax.random.split(key)

        # ---- coordinator: aggregate + downlink EF (paper lines 3-5) ----
        y_mean = tree_mean_axis0(state.z_hat)
        y_wire, c_down_new = self.downlink.send(k_down, y_mean, state.c_down)

        # ---- agents: local training (paper lines 8-14), vmapped ----
        grad_fn = jax.grad(self.loss)

        def agent_update(x_i, z_i, data_i):
            v_i = tree_map(lambda y, z: 2.0 * y - z, y_wire, z_i)
            w = local_prox_gd(grad_fn, x_i, v_i, data_i,
                              n_epochs=self.n_epochs, gamma=self.gamma, rho=self.rho)
            z_new = tree_map(lambda z, xn, y: z + 2.0 * (xn - y), z_i, w, y_wire)
            return w, z_new

        x_new, z_new = jax.vmap(agent_update)(state.x, state.z, data)

        # partial participation: inactive agents keep x, z (paper line 18)
        x_next = tree_where_mask(active, x_new, state.x)
        z_next = tree_where_mask(active, z_new, state.z)

        # ---- uplink EF + transmit (paper lines 15-16), per agent ----
        n_agents = active.shape[0]
        if self.fused_uplink and self.uplink.fusable():
            # one kernel dispatch per leaf over the full agent stack
            wire, c_up_new = self.uplink.send_fused(z_next, state.c_up)
        else:
            up_keys = jax.random.split(k_up, n_agents)
            wire, c_up_new = jax.vmap(
                lambda kk, m, c: self.uplink.send(kk, m, c))(
                    up_keys, z_next, state.c_up)
        c_up_next = tree_where_mask(active, c_up_new, state.c_up)
        z_hat_next = tree_where_mask(active, wire, state.z_hat)

        new_state = FedLTState(x=x_next, z=z_next, c_up=c_up_next,
                               z_hat=z_hat_next, c_down=c_down_new,
                               k=state.k + 1)
        info = {"n_active": jnp.sum(active)}
        return new_state, info

    # -- fleet-sharded round (mega-constellation scaling) ------------------
    def round_sharded(self, mesh, n_agents: int) -> Callable:
        """Build a round function whose vmapped agent axis is sharded over
        ``mesh``'s first axis (the "fleet" axis) with ``shard_map``.

        Each device trains its shard of the fleet locally; the only
        cross-device traffic is the coordinator aggregate (one ``psum`` of
        the per-shard z_hat sums) and the replicated downlink — exactly
        the communication pattern of the real system, where ground
        stations exchange aggregated models, not per-satellite state.
        Same signature and semantics as :meth:`round` (up to float
        summation order in the aggregate).  ``n_agents`` must divide by
        the fleet axis size; use
        :func:`repro.launch.sharding.fleet_mesh` which returns ``None``
        on a single device (fall back to :meth:`round` then).
        """
        from jax.experimental.shard_map import shard_map

        fleet = mesh.axis_names[0]
        n_dev = mesh.shape[fleet]
        if n_agents % n_dev:
            raise ValueError(
                f"n_agents={n_agents} not divisible by fleet axis {n_dev}")
        grad_fn = jax.grad(self.loss)

        def body(x, z, c_up, z_hat, c_down, k, data, active, k_down,
                 up_keys):
            # coordinator aggregate: local shard sum + one psum
            y_local = tree_map(lambda s: jnp.sum(s, axis=0), z_hat)
            y_mean = tree_map(lambda s: jax.lax.psum(s, fleet) / n_agents,
                              y_local)
            y_wire, c_down_new = self.downlink.send(k_down, y_mean, c_down)

            def agent_update(x_i, z_i, data_i):
                v_i = tree_map(lambda y, zz: 2.0 * y - zz, y_wire, z_i)
                w = local_prox_gd(grad_fn, x_i, v_i, data_i,
                                  n_epochs=self.n_epochs, gamma=self.gamma,
                                  rho=self.rho)
                z_new = tree_map(lambda zz, xn, y: zz + 2.0 * (xn - y),
                                 z_i, w, y_wire)
                return w, z_new

            x_new, z_new = jax.vmap(agent_update)(x, z, data)
            x_next = tree_where_mask(active, x_new, x)
            z_next = tree_where_mask(active, z_new, z)
            if self.fused_uplink and self.uplink.fusable():
                wire, c_up_new = self.uplink.send_fused(z_next, c_up)
            else:
                wire, c_up_new = jax.vmap(
                    lambda kk, m, c: self.uplink.send(kk, m, c))(
                        up_keys, z_next, c_up)
            c_up_next = tree_where_mask(active, c_up_new, c_up)
            z_hat_next = tree_where_mask(active, wire, z_hat)
            n_active = jax.lax.psum(jnp.sum(active), fleet)
            return (x_next, z_next, c_up_next, z_hat_next, c_down_new,
                    k + 1, n_active)

        Pf, Pr = P(fleet), P()
        sharded = shard_map(
            body, mesh,
            in_specs=(Pf, Pf, Pf, Pf, Pr, Pr, Pf, Pf, Pr, Pf),
            out_specs=(Pf, Pf, Pf, Pf, Pr, Pr, Pr),
            check_rep=False)

        def round_fn(state: FedLTState, data, active, key):
            k_down, k_up = jax.random.split(key)
            up_keys = jax.random.split(k_up, n_agents)
            out = sharded(state.x, state.z, state.c_up, state.z_hat,
                          state.c_down, state.k, data, active, k_down,
                          up_keys)
            return FedLTState(*out[:6]), {"n_active": out[6]}

        return round_fn

    def run(self, state: FedLTState, data, n_rounds: int, key,
            participation: float = 1.0, mesh=None):
        """Convenience driver: Bernoulli(p) participation, jitted scan.

        ``mesh``: optional fleet mesh (see :meth:`round_sharded`) — the
        vmapped agent dimension shards across its devices; ``None`` runs
        the single-device path unchanged.
        """
        n_agents = jax.tree_util.tree_leaves(state.x)[0].shape[0]
        round_impl = (self.round if mesh is None
                      else self.round_sharded(mesh, n_agents))

        def body(st, kk):
            k_act, k_round = jax.random.split(kk)
            active = jax.random.bernoulli(k_act, participation, (n_agents,))
            # guarantee at least one active agent (paper assumes p_i > 0)
            active = active.at[0].set(True) if participation < 1.0 else jnp.ones(
                (n_agents,), bool)
            st, info = round_impl(st, data, active, k_round)
            return st, info

        keys = jax.random.split(key, n_rounds)
        return jax.lax.scan(body, state, keys)


def optimality_error(x_agents, x_star):
    """Paper §3 metric: e_k = Σ_i ‖x_{i,k} − x̄‖²."""
    diffs = tree_map(lambda xa, xs: xa - xs[None], x_agents,
                     x_star)
    return sum(jnp.sum(d * d) for d in jax.tree_util.tree_leaves(diffs))
