"""Fed-LT with bi-directional compression and error feedback.

Simulate mode: Algorithms 1 and 2 of the paper, with all N agents vmapped
over a leading agent axis.  Algorithm 1 (compression, no EF) and Algorithm 2
(compression + EF) are the same code path — pass ``EFChannel(C, enabled=False)``
for Algorithm 1, exactly mirroring the paper's Table-1 ablation.

State layout (leaves carry a leading agent axis N where noted):

    x      (N, …)  per-agent models x_i
    z      (N, …)  per-agent auxiliaries z_i
    c_up   (N, …)  per-agent uplink EF caches c_i
    z_hat  (N, …)  coordinator's last-received uplink wire per agent
                   (what the paper calls z_{i,k−1} for inactive agents —
                   the coordinator can only know what was transmitted)
    c_down (…)     coordinator downlink EF cache c
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .error_feedback import EFChannel
from .pytree import (tree_add, tree_map, tree_mean_axis0, tree_scale, tree_sub,
                     tree_where_mask, tree_zeros_like)
from ..optim.solvers import local_prox_gd


class FedLTState(NamedTuple):
    x: object
    z: object
    c_up: object
    z_hat: object
    c_down: object
    k: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class FedLT:
    """Algorithm 2 (paper). loss(params, agent_data) -> scalar.

    ``n_epochs`` = N_e, ``gamma`` = local step γ, ``rho`` = ρ.
    """

    loss: Callable
    n_epochs: int = 10
    gamma: float = 0.1
    rho: float = 1.0
    uplink: EFChannel = EFChannel()
    downlink: EFChannel = EFChannel()

    # -- setup ------------------------------------------------------------
    def init(self, x0, n_agents: int) -> FedLTState:
        """x0: single-model pytree (no agent axis); replicated to all agents."""
        xN = tree_map(lambda a: jnp.broadcast_to(a[None], (n_agents,) + a.shape).copy(), x0)
        return FedLTState(
            x=xN,
            z=xN,
            c_up=tree_zeros_like(xN),
            z_hat=xN,
            c_down=tree_zeros_like(x0),
            k=jnp.zeros((), jnp.int32),
        )

    # -- one communication round ------------------------------------------
    def round(self, state: FedLTState, data, active, key) -> Tuple[FedLTState, dict]:
        """One iteration of the outer loop.

        data:   pytree with leading agent axis N on every leaf.
        active: bool (N,) — the set S_{k+1} (from Bernoulli sampling or the
                orbit scheduler).
        """
        k_down, k_up = jax.random.split(key)

        # ---- coordinator: aggregate + downlink EF (paper lines 3-5) ----
        y_mean = tree_mean_axis0(state.z_hat)
        y_wire, c_down_new = self.downlink.send(k_down, y_mean, state.c_down)

        # ---- agents: local training (paper lines 8-14), vmapped ----
        grad_fn = jax.grad(self.loss)

        def agent_update(x_i, z_i, data_i):
            v_i = tree_map(lambda y, z: 2.0 * y - z, y_wire, z_i)
            w = local_prox_gd(grad_fn, x_i, v_i, data_i,
                              n_epochs=self.n_epochs, gamma=self.gamma, rho=self.rho)
            z_new = tree_map(lambda z, xn, y: z + 2.0 * (xn - y), z_i, w, y_wire)
            return w, z_new

        x_new, z_new = jax.vmap(agent_update)(state.x, state.z, data)

        # partial participation: inactive agents keep x, z (paper line 18)
        x_next = tree_where_mask(active, x_new, state.x)
        z_next = tree_where_mask(active, z_new, state.z)

        # ---- uplink EF + transmit (paper lines 15-16), per agent ----
        n_agents = active.shape[0]
        up_keys = jax.random.split(k_up, n_agents)
        wire, c_up_new = jax.vmap(lambda kk, m, c: self.uplink.send(kk, m, c))(
            up_keys, z_next, state.c_up)
        c_up_next = tree_where_mask(active, c_up_new, state.c_up)
        z_hat_next = tree_where_mask(active, wire, state.z_hat)

        new_state = FedLTState(x=x_next, z=z_next, c_up=c_up_next,
                               z_hat=z_hat_next, c_down=c_down_new,
                               k=state.k + 1)
        info = {"n_active": jnp.sum(active)}
        return new_state, info

    def run(self, state: FedLTState, data, n_rounds: int, key,
            participation: float = 1.0):
        """Convenience driver: Bernoulli(p) participation, jitted scan."""
        n_agents = jax.tree_util.tree_leaves(state.x)[0].shape[0]

        def body(st, kk):
            k_act, k_round = jax.random.split(kk)
            active = jax.random.bernoulli(k_act, participation, (n_agents,))
            # guarantee at least one active agent (paper assumes p_i > 0)
            active = active.at[0].set(True) if participation < 1.0 else jnp.ones(
                (n_agents,), bool)
            st, info = self.round(st, data, active, k_round)
            return st, info

        keys = jax.random.split(key, n_rounds)
        return jax.lax.scan(body, state, keys)


def optimality_error(x_agents, x_star):
    """Paper §3 metric: e_k = Σ_i ‖x_{i,k} − x̄‖²."""
    diffs = tree_map(lambda xa, xs: xa - xs[None], x_agents,
                     x_star)
    return sum(jnp.sum(d * d) for d in jax.tree_util.tree_leaves(diffs))
