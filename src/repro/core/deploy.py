"""Deploy mode: one federated round as a single mesh-sharded ``train_step``.

This is the production path the multi-pod dry-run lowers.  Mapping (see
DESIGN.md §3): agents are mesh slices (pods for the big archs, data-axis
slices for the small ones); every per-agent state leaf carries a leading
agent dim A; local training is ``vmap``-ed over it.  The paper's Algorithm 2
runs inside the step:

  1. v = 2·ŷ − z;  N_e prox-gradient epochs on the LM loss   (local training)
  2. z ← z + 2(x − ŷ)
  3. uplink: wire = Q(z + c_up) as *integer* level indices — the cross-agent
     all-gather moves int8/int16, which is the actual wire saving of the
     paper's compression, visible in the dry-run HLO     (uplink EF);
     with ``pack_wire=True`` the indices are further bit-packed into
     b-bit uint32 wire words (``repro.wire`` layout, Pallas kernels in
     ``repro.kernels.pack_bits``) so the gather moves the exact on-wire
     payload
  4. ȳ = mean_A decode(wire);  y = c_down + ȳ
  5. ŷ = decode(Q(y));  c_down = y − ŷ                      (downlink EF)

Partial participation is a host-side decision (the orbit scheduler picks
which satellites run a round); within the lowered step all present agents
participate — exactly how a real constellation executes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.compression import (quantize_decode, quantize_encode,
                                wire_index_bits)
from ..core.pytree import tree_map
from ..kernels.compress_pipeline import quant_pipeline
from ..kernels.pack_bits import _TILE_VALS, pack_bits, unpack_bits
from ..models.transformer import init_params, lm_loss


def emit_round_series(step: int, metrics: dict) -> None:
    """Fold one ``round_step`` metrics dict into the active trace as
    per-round series samples (no-op when tracing is off).

    Host-side by design: the lowered step stays pure, and the float()
    materialization of the loss only happens when a tracer is installed
    — callers that already print the loss pay nothing extra.
    """
    from ..obs.trace import active as _obs_active
    trc = _obs_active()
    if trc is None:
        return
    trc.series("loss", step, float(metrics["loss"]))
    nb = metrics.get("wire_nbytes_per_agent")
    if nb is not None:
        trc.series("wire_nbytes_per_agent", step, float(nb))
    qf = metrics.get("quorum_frac")
    if qf is not None:
        trc.series("quorum_frac", step, float(qf))


class DeployState(NamedTuple):
    x: object        # (A, …) per-agent models
    z: object        # (A, …) auxiliaries
    c_up: object     # (A, …) uplink EF caches
    y_hat: object    # (…)    last broadcast ŷ (replicated coordinator output)
    c_down: object   # (…)    downlink EF cache
    k: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DeployFedLT:
    """Fed-LT round on the mesh. cfg: ModelConfig; quantization is the
    paper's uniform quantizer with static [vmin, vmax] (wire = level ints)."""

    cfg: object
    n_epochs: int = 2
    gamma: float = 0.02
    rho: float = 10.0
    # wire format: uint8 level indices over a range that must cover the z
    # dynamics (out-of-range values clip, and the EF cache then grows until
    # they re-enter range — pick the range generously, EF absorbs coarse Δ)
    levels: int = 255          # → uint8 wire
    vmin: float = -1.0
    vmax: float = 1.0
    compress: bool = True
    # pack the uplink ints into b-bit uint32 wire words (repro.wire layout,
    # Pallas kernels) before the cross-agent gather — the collective then
    # moves b = ceil(log2(levels+1)) bits/scalar instead of the container
    # dtype's 8/16.  Leaves smaller than one kernel tile (32768 values)
    # gather as plain ints: there the tile padding would exceed the
    # packing saving.
    pack_wire: bool = False
    # run quantize + EF + pack as ONE fused Pallas sweep per tile-sized
    # leaf (repro.kernels.compress_pipeline) instead of the separate
    # quantize_encode → subtract → pack_bits dispatches: the intermediate
    # integer tensor never round-trips through HBM.  Packed words are
    # bit-identical either way; only the dispatch count changes.
    fuse_pipeline: bool = True
    backend: str = "chunked"

    @property
    def wire_word_bits(self) -> int:
        return wire_index_bits(self.levels)

    # -- state ------------------------------------------------------------
    def init(self, key, n_agents: int) -> DeployState:
        p0 = init_params(key, self.cfg)
        stack = lambda t: tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_agents,) + a.shape).copy(), t)
        zeros = lambda t: tree_map(jnp.zeros_like, t)
        xa = stack(p0)
        return DeployState(x=xa, z=xa, c_up=zeros(xa), y_hat=p0,
                           c_down=zeros(p0), k=jnp.zeros((), jnp.int32))

    # -- one round ----------------------------------------------------------
    def round_step(self, state: DeployState, batch,
                   agent_replicate_spec=None, survivors=None):
        """batch: pytree with leading agent dim A on every leaf.

        ``survivors`` (optional ``(A,)`` bool) is the quorum mask the
        host-side round-deadline scheduler hands down (``repro.faults``):
        a round closed at its deadline aggregates only the agents whose
        uplinks landed in time.  Excluded agents still train locally,
        but their wire is dropped from the coordinator mean and their
        uplink EF cache *reverts* to the full corrected message — the
        erasure semantics of ``fedlt_sat._revert_lost_wires``, so the
        straggler's content telescopes into its next landed round
        instead of vanishing.  ``None`` keeps the all-participate
        behavior (and the lowered HLO) unchanged."""
        cfg = self.cfg
        inv_rho = 1.0 / self.rho
        surv = None if survivors is None else jnp.asarray(survivors)

        def _mask(x):
            return surv.reshape((-1,) + (1,) * (x.ndim - 1))

        def local_train(x_i, v_i, batch_i):
            def epoch(w, _):
                loss, g = jax.value_and_grad(
                    lambda q: lm_loss(q, cfg, batch_i, backend=self.backend))(w)
                w = tree_map(
                    lambda wl, gl, vl: (wl - self.gamma *
                                        (gl + inv_rho * (wl - vl)).astype(wl.dtype)),
                    w, g, v_i)
                return w, loss

            if getattr(self.cfg, "scan_unroll", False):
                # dry-run costing: python loop so the epoch backward is
                # unrolled too (scan transposes are loops XLA counts once)
                w, loss = x_i, jnp.zeros((), jnp.float32)
                for _ in range(self.n_epochs):
                    w, loss = epoch(w, None)
                return w, loss
            w, losses = jax.lax.scan(epoch, x_i, None, length=self.n_epochs)
            return w, losses[-1]

        # jax.named_scope: names the round's stages inside jaxprs/HLO and
        # jax.profiler traces — the device-side counterpart of the host
        # spans repro.obs records (annotations survive jit; no-ops
        # otherwise)
        with jax.named_scope("fedlt.local_train"):
            v = tree_map(lambda y, z: (2.0 * y - z).astype(z.dtype),
                         state.y_hat, state.z)
            x_new, last_loss = jax.vmap(local_train)(state.x, v, batch)
            z_new = tree_map(lambda z, xn, y: z + 2.0 * (xn - y),
                             state.z, x_new, state.y_hat)

        # ---- uplink: quantize + EF; integer tensor crosses the slow link --
        if self.compress:
            bits = self.wire_word_bits
            interp = jax.default_backend() != "tpu"

            def _fused_uplink(z, c, **kw):
                with jax.named_scope("fedlt.uplink.fused_pipeline"):
                    return quant_pipeline(z, c, **kw)

            def uplink_leaf(z, c, spec):
                """One parameter tensor through uplink EF + wire: returns
                (gathered wire floats, new EF cache).

                Tile-sized leaves with ``pack_wire`` take the FUSED
                quantize→EF→pack sweep (one Pallas dispatch, packed words
                bit-identical to the separate path); ``fuse_pipeline=False``
                keeps the separate quantize_encode → subtract → pack_bits
                dispatches.  Leaves below one kernel tile (32768 values)
                gather as plain ints either way: there the tile padding
                would exceed the packing saving.
                """
                if (self.pack_wire and self.fuse_pipeline
                        and z.size >= _TILE_VALS):
                    words, newc = _fused_uplink(
                        z, c, levels=self.levels, vmin=self.vmin,
                        vmax=self.vmax, interpret=interp)
                    if spec is not None:
                        words = jax.lax.with_sharding_constraint(words, P(None))
                    idx = unpack_bits(words, bits, z.size, interpret=interp)
                    g = quantize_decode(idx, self.levels, self.vmin,
                                        self.vmax, z.dtype).reshape(z.shape)
                    return g, newc
                m = z + c
                w = quantize_encode(m, self.levels, self.vmin, self.vmax)
                newc = m - quantize_decode(w, self.levels, self.vmin,
                                           self.vmax, m.dtype)
                if self.pack_wire and w.size >= _TILE_VALS:
                    p = pack_bits(w, bits, interpret=interp)
                    if spec is not None:
                        p = jax.lax.with_sharding_constraint(p, P(None))
                    w = unpack_bits(p, bits, w.size, interpret=interp
                                    ).astype(w.dtype).reshape(w.shape)
                elif spec is not None:
                    # replicate the agent dim of the INT tensor (int8 gather)
                    w = jax.lax.with_sharding_constraint(w, spec)
                g = quantize_decode(w, self.levels, self.vmin, self.vmax,
                                    m.dtype)
                return g, newc

            leaves_z, treedef = jax.tree_util.tree_flatten(z_new)
            leaves_c = treedef.flatten_up_to(state.c_up)
            specs = (treedef.flatten_up_to(agent_replicate_spec)
                     if agent_replicate_spec is not None
                     else [None] * len(leaves_z))
            with jax.named_scope("fedlt.uplink"):
                pairs = [uplink_leaf(z, c, s)
                         for z, c, s in zip(leaves_z, leaves_c, specs)]
            if surv is not None:
                # quorum close: drop excluded wires from the mean, revert
                # their EF cache to the full corrected message (newc + ŵ
                # == z + c — GroupedEFChannel.revert's leaf analogue)
                pairs = [(jnp.where(_mask(g), g, 0.0).astype(g.dtype),
                          jnp.where(_mask(nc), nc, z + c).astype(nc.dtype))
                         for (g, nc), z, c
                         in zip(pairs, leaves_z, leaves_c)]
            gathered = treedef.unflatten([g for g, _ in pairs])
            c_up_new = treedef.unflatten([nc for _, nc in pairs])
            with jax.named_scope("fedlt.aggregate"):
                if surv is not None:
                    n_surv = jnp.maximum(jnp.sum(surv), 1)
                    z_bar = tree_map(
                        lambda g: (jnp.sum(g, axis=0)
                                   / n_surv.astype(g.dtype)), gathered)
                else:
                    z_bar = tree_map(lambda g: jnp.mean(g, axis=0), gathered)
        else:
            c_up_new = state.c_up
            with jax.named_scope("fedlt.aggregate"):
                if surv is not None:
                    n_surv = jnp.maximum(jnp.sum(surv), 1)
                    z_bar = tree_map(
                        lambda z: (jnp.sum(jnp.where(_mask(z), z, 0.0)
                                           .astype(z.dtype), axis=0)
                                   / n_surv.astype(z.dtype)), z_new)
                else:
                    z_bar = tree_map(lambda z: jnp.mean(z, axis=0), z_new)

        # ---- coordinator aggregate + downlink EF --------------------------
        with jax.named_scope("fedlt.downlink"):
            y = tree_map(lambda c, zb: c + zb.astype(c.dtype),
                         state.c_down, z_bar)
            if self.compress:
                y_int = tree_map(
                    lambda m: quantize_encode(m, self.levels, self.vmin,
                                              self.vmax), y)
                y_hat = tree_map(
                    lambda w, m: quantize_decode(w, self.levels, self.vmin,
                                                 self.vmax, m.dtype),
                    y_int, y)
                c_down_new = tree_map(jnp.subtract, y, y_hat)
            else:
                y_hat, c_down_new = y, state.c_down

        new_state = DeployState(x=x_new, z=z_new, c_up=c_up_new, y_hat=y_hat,
                                c_down=c_down_new, k=state.k + 1)
        metrics = {"loss": jnp.mean(last_loss)}
        if surv is not None:
            n_agents = surv.shape[0]
            metrics["quorum_frac"] = (jnp.sum(surv).astype(jnp.float32)
                                      / jnp.float32(n_agents))
        if self.compress:
            # exact measured uplink size per agent under the wire codec
            # (static shapes → a compile-time constant in the metrics)
            from ..wire.codecs import QuantCodec
            codec = QuantCodec(self.levels, self.vmin, self.vmax)
            template = tree_map(lambda x: x[0], state.x)
            metrics["wire_nbytes_per_agent"] = jnp.float32(
                codec.tree_nbytes(template))
        return new_state, metrics
