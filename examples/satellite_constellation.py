"""Fed-LTSat in a simulated LEO constellation (paper Algorithm 3).

A 100-satellite Walker constellation over a polar ground station, driven
through the discrete-event engine: the contact-plan scheduler picks ~12
satellites per round (direct GS windows + multi-hop ISL-forwarded
neighbours).  Compares Fed-LTSat against space-ified FedAvg under coarse
quantization + EF in synchronous mode — Fed-LTSat on the fused
compress→EF→pack uplink (``FedLT(fused_uplink=True)``: one Pallas kernel
dispatch per leaf over the whole agent stack) with per-cohort byte
accounting (``SpaceRunner(measure="cohort")``) — then runs Fed-LTSat in
buffered-asynchronous (FedBuff-style, staleness-weighted) mode on the
dual-station scenario, and finally over the ``lossy-uplink`` channel
scenario with loss-robust error feedback.

Every run records a ``repro.obs`` trace (``constellation_<name>.jsonl``:
engine deliveries/cohorts/ARQ, federated rounds, EF reverts, metrics)
and the report below is the obs per-round renderer over the traced
``fl_round`` records — inspect any run afterwards with::

    python -m repro.obs summarize constellation_fedltsat.jsonl
    python -m repro.obs chrome constellation_fedltsat.jsonl

Run:  PYTHONPATH=src python examples/satellite_constellation.py
"""
import jax
import jax.numpy as jnp

from repro import obs
from repro.api import Experiment
from repro.core.baselines import FedAvg
from repro.core.compression import UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.data.logistic import generate, make_local_loss, solve_global


def main(rounds=120):
    n_agents, dim = 100, 100
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=200, dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)

    quant = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    up, down = EFChannel(quant), EFChannel(quant)

    def traced_run(name, exp, st, key):
        """One Experiment.run, traced to a file; prints the obs per-round
        table over the rounds that evaluated the error."""
        slug = "".join(c for c in name.split(" ")[0].lower()
                       if c.isalnum())
        path = f"constellation_{slug}.jsonl"
        res = exp.run(st, data, rounds, key,
                      error_fn=lambda s: optimality_error(s.x, x_star),
                      log_every=20, trace=path)
        evaluated = [r for r in res.records if r.get("kind") == "fl_round"
                     and r.get("error") is not None]
        print(f"\n=== {name} (trace: {path}) ===")
        print(obs.render_rounds(evaluated))
        return res.state, res.logs

    algs = {
        # fused_uplink=True: the compress→EF→pack chain runs as ONE Pallas
        # sweep over the whole agent stack (EFChannel.send_fused) instead
        # of a vmapped per-satellite chain
        "Fed-LTSat": FedLT(loss=loss, n_epochs=10, gamma=0.005, rho=20.0,
                           uplink=up, downlink=down, fused_uplink=True),
        "FedAvg(space)": FedAvg(loss=loss, n_epochs=10, gamma=0.05,
                                uplink=up, downlink=down),
    }
    for name, alg in algs.items():
        # measure="cohort": bytes_up accounted from the actually-transmitted
        # wire state, batched per contact-window cohort
        exp = Experiment.from_scenario("walker-kiruna", algorithm=alg,
                                       compressor=quant, measure="cohort",
                                       meta=dict(example=name))
        st = exp.init(jnp.zeros((dim,)), n_agents)
        traced_run(name, exp, st, jax.random.PRNGKey(2))

    # buffered-async: two ground stations, staleness-weighted aggregation
    alg = algs["Fed-LTSat"]
    name = "async (Fed-LTSat, dual-station)"
    exp = Experiment.from_scenario("dual-station", algorithm=alg,
                                   compressor=quant, mode="async",
                                   buffer_size=10, staleness_alpha=0.5,
                                   meta=dict(example=name))
    st = exp.init(jnp.zeros((dim,)), n_agents)
    traced_run(name, exp, st, jax.random.PRNGKey(3))

    # lossy uplink: 10% segment erasures with selective-repeat ARQ; lost
    # updates keep their EF residual (loss-robust EF) so their content
    # telescopes into the next successful pass
    name = "lossy (Fed-LTSat, loss-robust EF)"
    exp = Experiment.from_scenario("lossy-uplink", algorithm=alg,
                                   compressor=quant, measure="cohort",
                                   meta=dict(example=name))
    st = exp.init(jnp.zeros((dim,)), n_agents)
    traced_run(name, exp, st, jax.random.PRNGKey(4))


if __name__ == "__main__":
    main()
