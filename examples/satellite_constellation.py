"""Fed-LTSat in a simulated LEO constellation (paper Algorithm 3).

A 100-satellite Walker constellation over a polar ground station, driven
through the discrete-event engine: the contact-plan scheduler picks ~12
satellites per round (direct GS windows + multi-hop ISL-forwarded
neighbours).  Compares Fed-LTSat against space-ified FedAvg under coarse
quantization + EF in synchronous mode — Fed-LTSat on the fused
compress→EF→pack uplink (``FedLT(fused_uplink=True)``: one Pallas kernel
dispatch per leaf over the whole agent stack) with per-cohort byte
accounting (``SpaceRunner(measure="cohort")``) — then runs Fed-LTSat in
buffered-asynchronous (FedBuff-style, staleness-weighted) mode on the
dual-station scenario, and finally over the ``lossy-uplink`` channel
scenario with loss-robust error feedback.  Reports error vs wall-clock
time and uplink bytes for each.

Run:  PYTHONPATH=src python examples/satellite_constellation.py
"""
import jax
import jax.numpy as jnp

from repro.core.baselines import FedAvg
from repro.core.compression import UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.core.fedlt_sat import SpaceRunner
from repro.data.logistic import generate, make_local_loss, solve_global
from repro.sim import Engine, get_scenario


def main(rounds=120):
    n_agents, dim = 100, 100
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=200, dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)

    quant = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    up, down = EFChannel(quant), EFChannel(quant)

    def report(name, logs):
        print(f"\n=== {name} ===")
        for log in logs:
            if log.error is not None:
                extra = (f"  stale={log.staleness:.2f}"
                         if log.staleness is not None else "")
                if log.n_lost:
                    extra += f"  lost={log.n_lost}"
                print(f"  round {log.round:4d}  t={log.time/3600:6.2f}h  "
                      f"up={log.bytes_up/1e3:8.1f}kB  active={log.n_active:3d}  "
                      f"e_k={log.error:.5f}{extra}")

    algs = {
        # fused_uplink=True: the compress→EF→pack chain runs as ONE Pallas
        # sweep over the whole agent stack (EFChannel.send_fused) instead
        # of a vmapped per-satellite chain
        "Fed-LTSat": FedLT(loss=loss, n_epochs=10, gamma=0.005, rho=20.0,
                           uplink=up, downlink=down, fused_uplink=True),
        "FedAvg(space)": FedAvg(loss=loss, n_epochs=10, gamma=0.05,
                                uplink=up, downlink=down),
    }
    engine = Engine(get_scenario("walker-kiruna"))
    for name, alg in algs.items():
        st = alg.init(jnp.zeros((dim,)), n_agents)
        # measure="cohort": bytes_up accounted from the actually-transmitted
        # wire state, batched per contact-window cohort
        runner = SpaceRunner(engine, compressor=quant, measure="cohort")
        st, logs = runner.run(alg, st, data, rounds, jax.random.PRNGKey(2),
                              error_fn=lambda s: optimality_error(s.x, x_star),
                              log_every=20)
        report(name, logs)

    # buffered-async: two ground stations, staleness-weighted aggregation
    alg = algs["Fed-LTSat"]
    st = alg.init(jnp.zeros((dim,)), n_agents)
    runner = SpaceRunner(Engine(get_scenario("dual-station")),
                         compressor=quant,
                         mode="async", buffer_size=10, staleness_alpha=0.5)
    st, logs = runner.run(alg, st, data, rounds, jax.random.PRNGKey(3),
                          error_fn=lambda s: optimality_error(s.x, x_star),
                          log_every=20)
    report("Fed-LTSat (async, dual-station)", logs)

    # lossy uplink: 10% segment erasures with selective-repeat ARQ; lost
    # updates keep their EF residual (loss-robust EF) so their content
    # telescopes into the next successful pass
    st = alg.init(jnp.zeros((dim,)), n_agents)
    runner = SpaceRunner(Engine(get_scenario("lossy-uplink")),
                         compressor=quant, measure="cohort")
    st, logs = runner.run(alg, st, data, rounds, jax.random.PRNGKey(4),
                          error_fn=lambda s: optimality_error(s.x, x_star),
                          log_every=20)
    report("Fed-LTSat (lossy uplink, loss-robust EF)", logs)


if __name__ == "__main__":
    main()
