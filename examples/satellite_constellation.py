"""Fed-LTSat in a simulated LEO constellation (paper Algorithm 3).

A 100-satellite Walker constellation over a polar ground station; the
orbit-aware scheduler picks ~10 satellites per round (direct GS windows +
ISL-forwarded neighbours).  Compares Fed-LTSat against space-ified FedAvg
under coarse quantization + EF, reporting error vs wall-clock time and
uplink bytes.

Run:  PYTHONPATH=src python examples/satellite_constellation.py
"""
import jax
import jax.numpy as jnp

from repro.constellation.orbits import GroundStation, Walker
from repro.constellation.scheduler import Scheduler
from repro.core.baselines import FedAvg
from repro.core.compression import UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.core.fedlt_sat import SpaceRunner
from repro.data.logistic import generate, make_local_loss, solve_global


def main(rounds=120):
    n_agents, dim = 100, 100
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=200, dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)

    walker = Walker(n_sats=n_agents, n_planes=10)
    sched = Scheduler(walker, GroundStation(), k_direct=4, n_relay=2)
    quant = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    up, down = EFChannel(quant), EFChannel(quant)

    algs = {
        "Fed-LTSat": FedLT(loss=loss, n_epochs=10, gamma=0.005, rho=20.0,
                           uplink=up, downlink=down),
        "FedAvg(space)": FedAvg(loss=loss, n_epochs=10, gamma=0.05,
                                uplink=up, downlink=down),
    }
    for name, alg in algs.items():
        st = alg.init(jnp.zeros((dim,)), n_agents)
        runner = SpaceRunner(sched, wire_bits=quant.wire_bits_per_scalar())
        st, logs = runner.run(alg, st, data, rounds, jax.random.PRNGKey(2),
                              error_fn=lambda s: optimality_error(s.x, x_star),
                              log_every=20)
        print(f"\n=== {name} ===")
        for log in logs:
            if log.error is not None:
                print(f"  round {log.round:4d}  t={log.time/3600:6.2f}h  "
                      f"up={log.bytes_up/1e3:8.1f}kB  active={log.n_active:3d}  "
                      f"e_k={log.error:.5f}")


if __name__ == "__main__":
    main()
