"""Serving example: batched prefill + decode with the KV/SSM cache.

Serves the coordinator model over a batch of token prompts: one prefill
step builds the cache, then greedy decode streams tokens — the same
``serve_step`` path the decode-shaped dry-runs lower.  Works for any
assigned architecture's smoke variant (``--arch``), demonstrating cache
handling across attention, sliding-window, MoE, Mamba2 and RWKV6 blocks.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_variant
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.models.transformer import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_variant(ARCHS[args.arch])
    if cfg.arch_type == "vlm":
        print("note: VLM smoke serve uses text tokens only")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    prefill = jax.jit(make_prefill_step(cfg, backend="xla"))
    decode = jax.jit(make_decode_step(cfg, backend="xla"))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits, axis=-1)[:, None]
    print(f"prefill {args.batch}×{args.prompt_len} in {time.time()-t0:.2f}s")

    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.tokens - 1} steps in {dt:.2f}s "
          f"({(args.tokens - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
