"""Quickstart: communication-efficient federated learning in 60 lines.

Trains the paper's regularized logistic regression over 50 agents with
bi-directional uniform quantization + error feedback (Algorithm 2) vs the
no-EF ablation (Algorithm 1), recording each run as a ``repro.obs``
trace: per-round ``fl_round`` events plus byte counters, flushed to
``quickstart_<variant>.jsonl`` and summarized with the obs renderer
(the same table ``python -m repro.obs summarize`` prints).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import obs
from repro.constellation.links import message_bytes
from repro.core.compression import UniformQuantizer, wire_index_bits
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.data.logistic import generate, make_local_loss, solve_global


def main():
    n_agents, dim = 50, 50
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=200, dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)

    quant = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    # nominal per-agent uplink: dim values at ceil(log2(levels+1)) bits
    msg = message_bytes(dim, wire_index_bits(quant.levels))
    for ef in (False, True):
        alg = FedLT(loss=loss, n_epochs=10, gamma=0.005, rho=20.0,
                    uplink=EFChannel(quant, enabled=ef),
                    downlink=EFChannel(quant, enabled=ef))
        state = alg.init(jnp.zeros((dim,)), n_agents)
        active = jnp.ones((n_agents,), bool)
        step = jax.jit(lambda s, k: alg.round(s, data, active, k)[0])
        keys = jax.random.split(jax.random.PRNGKey(1), 400)
        name = "alg2_ef" if ef else "alg1_no_ef"
        path = f"quickstart_{name}.jsonl"
        with obs.tracing(path, example="quickstart", ef=ef) as trc:
            up = trc.metrics.counter("bytes_up")
            for k in range(400):
                state = step(state, keys[k])
                up.add(msg * n_agents)
                if k % 80 == 0 or k == 399:
                    err = float(optimality_error(state.x, x_star))
                    trc.event("fl_round", round=k, t=float(k),
                              bytes_up=up.total, n_active=n_agents,
                              error=err)
            records = trc.records()
        print(f"\n=== Algorithm {'2 (with EF)' if ef else '1 (no EF)'} "
              f"(trace: {path}) ===")
        print(obs.render_rounds(records))


if __name__ == "__main__":
    main()
