"""Quickstart: communication-efficient federated learning in 40 lines.

Trains the paper's regularized logistic regression over 50 agents with
bi-directional uniform quantization + error feedback (Algorithm 2), and
prints the optimality-error trajectory vs the no-EF ablation (Algorithm 1).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.compression import UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.data.logistic import generate, make_local_loss, solve_global


def main():
    n_agents, dim = 50, 50
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=200, dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)

    quant = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    for ef in (False, True):
        alg = FedLT(loss=loss, n_epochs=10, gamma=0.005, rho=20.0,
                    uplink=EFChannel(quant, enabled=ef),
                    downlink=EFChannel(quant, enabled=ef))
        state = alg.init(jnp.zeros((dim,)), n_agents)
        active = jnp.ones((n_agents,), bool)
        step = jax.jit(lambda s, k: alg.round(s, data, active, k)[0])
        keys = jax.random.split(jax.random.PRNGKey(1), 400)
        print(f"\n=== Algorithm {'2 (with EF)' if ef else '1 (no EF)'} ===")
        for k in range(400):
            state = step(state, keys[k])
            if k % 80 == 0 or k == 399:
                err = float(optimality_error(state.x, x_star))
                print(f"  round {k:4d}   e_k = {err:.6f}")


if __name__ == "__main__":
    main()
