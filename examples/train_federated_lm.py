"""End-to-end driver: federated training of a transformer LM (deploy path).

Each "satellite" holds its own heterogeneous token stream (per-agent Markov
language); one round = N_e local prox-epochs + quantized/EF uplink +
aggregation + quantized/EF downlink — the same ``DeployFedLT.round_step``
the multi-pod dry-run lowers, here executed for real on the host devices.

Presets:
  smoke (default)  ~6M params,  fits a CPU run in minutes
  100m             ~100M params — the "train a ~100M model" driver; same
                   code path, sized for a real (TPU) allocation.

Run:  PYTHONPATH=src python examples/train_federated_lm.py --rounds 20
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.deploy import DeployFedLT
from repro.data.synthetic import make_batch
from repro.models.config import ModelConfig

PRESETS = {
    "smoke": ModelConfig(
        name="fed-lm-smoke", arch_type="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=2048, max_seq=512,
        chunk_size=64, tie_embeddings=True, dtype="float32"),
    "100m": ModelConfig(
        name="fed-lm-100m", arch_type="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32000, max_seq=2048,
        tie_embeddings=True, dtype="bfloat16"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=sorted(PRESETS))
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4, help="per-agent batch")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-epochs", type=int, default=2)
    ap.add_argument("--no-compress", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    alg = DeployFedLT(cfg=cfg, n_epochs=args.n_epochs, gamma=0.02, rho=10.0,
                      compress=not args.no_compress, levels=1023,
                      vmin=-0.5, vmax=0.5)
    state = alg.init(jax.random.PRNGKey(0), args.agents)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state.y_hat))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"agents={args.agents}  compress={not args.no_compress}")

    step = jax.jit(lambda s, b: alg.round_step(s, b))

    def batches(round_idx):
        keys = [jax.random.fold_in(jax.random.PRNGKey(7 + i), round_idx)
                for i in range(args.agents)]
        per = [make_batch(cfg, k, args.batch, args.seq) for k in keys]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)

    for k in range(args.rounds):
        t0 = time.time()
        state, metrics = step(state, batches(k))
        loss = float(metrics["loss"])
        print(f"round {k:4d}  local-loss={loss:.4f}  ({time.time()-t0:.1f}s)")

    print("done — coordinator model ŷ is state.y_hat (servable).")


if __name__ == "__main__":
    main()
