"""Wire codec properties: lossless round-trips + exact byte accounting.

System invariants (paper §2.4 + ISSUE 2 acceptance):
  * ``decode(encode(C(x))) == C(x)`` **bit-exactly** for every compressor
    with a codec (quant needs clip=True — the wire cannot carry an
    out-of-range lattice index);
  * ``WireMessage.nbytes`` equals the documented analytic formula, and the
    payload matches the analytic bit count to within word-group padding
    (< 32·b bits) — headers are accounted separately and exactly;
  * the Pallas pack/unpack kernels round-trip any b-bit payload and agree
    with the pure-jnp oracle word-for-word;
  * the simulator's transmission times / bytes_up derive from measured
    ``WireMessage`` bytes when the compressor has a codec.

Property tests run under hypothesis when available; a deterministic
seeded sweep covers the same invariants otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (Identity, RandD, ScaledSign, TopK,
                                    UniformQuantizer)
from repro.kernels import ref
from repro.kernels.pack_bits import logical_words, pack_bits, unpack_bits
from repro.wire import (MESSAGE_HEADER_NBYTES, codec_for, index_bits,
                        measure_tree_bytes)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _rand(n, seed=0, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale


def _roundtrip_exact(C, y):
    codec = codec_for(C)
    back = codec.decode(codec.encode(y))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(y))


ALL_COMPRESSORS = [
    UniformQuantizer(levels=3, vmin=-8, vmax=8, clip=True),
    UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True),
    UniformQuantizer(levels=255, vmin=-4, vmax=4, clip=True),
    UniformQuantizer(levels=1000, vmin=-10, vmax=10, clip=True),
    ScaledSign(),
    TopK(fraction=0.1),
    TopK(fraction=0.9),
    RandD(fraction=0.5),
    Identity(),
]


# -- deterministic sweep (always runs) -------------------------------------

@pytest.mark.parametrize("C", ALL_COMPRESSORS,
                         ids=lambda c: f"{type(c).__name__}")
@pytest.mark.parametrize("n", [2, 33, 100, 5000])
def test_codec_roundtrip_bitexact(C, n):
    key = jax.random.PRNGKey(7 * n)
    y = C(key, _rand(n, seed=n))
    _roundtrip_exact(C, y)


@pytest.mark.parametrize("n", [2, 100, 4097])
@pytest.mark.parametrize("levels", [10, 255, 4000])
def test_quant_nbytes_matches_analytic(n, levels):
    C = UniformQuantizer(levels=levels, vmin=-8.0, vmax=8.0, clip=True)
    codec = codec_for(C)
    b = codec.bits
    msg = codec.encode(C(None, _rand(n, seed=levels)))
    assert msg.payload_nbytes == 4 * logical_words(n, b)
    # payload matches the analytic bit count within word-group padding
    assert 0 <= msg.payload_nbytes * 8 - n * b < 32 * b
    # headers accounted separately and exactly
    assert msg.nbytes == (MESSAGE_HEADER_NBYTES + codec.leaf_header_nbytes(1)
                          + msg.payload_nbytes)
    assert msg.nbytes == codec.tree_nbytes(jnp.zeros((n,)))
    assert C.wire_bits_per_scalar() == float(b)


@pytest.mark.parametrize("n", [2, 65, 1000])
def test_sign_nbytes_matches_analytic(n):
    C = ScaledSign()
    codec = codec_for(C)
    msg = codec.encode(C(None, _rand(n, seed=n)))
    assert msg.payload_nbytes == 4 * logical_words(n, 1)
    assert 0 <= msg.payload_nbytes * 8 - n < 32
    assert msg.nbytes == codec.tree_nbytes(jnp.zeros((n,)))


@pytest.mark.parametrize("frac", [0.25, 0.75])
def test_sparse_nbytes_counts_actual_nonzeros(frac):
    n = 200
    C = TopK(fraction=frac)
    codec = codec_for(C)
    y = C(None, _rand(n, seed=3))
    msg = codec.encode(y)
    k = int(np.count_nonzero(np.asarray(y)))
    b = index_bits(n)
    assert msg.leaves[0].meta["k"] == k
    assert msg.payload_nbytes == 4 * logical_words(k, b) + 4 * k


def test_sparse_ties_stay_lossless():
    """TopK keeps >k coordinates on magnitude ties; the codec must still
    round-trip exactly (it counts actual nonzeros, not nominal k)."""
    x = jnp.asarray([2.0, -2.0, 2.0, 2.0, 0.5, 0.1, 0.0, -0.3])
    C = TopK(fraction=0.25)          # nominal k = 2, ties give 4
    y = C(None, x)
    assert int(np.count_nonzero(np.asarray(y))) == 4
    _roundtrip_exact(C, y)


def test_roundtrip_over_pytree_shapes():
    tree = {"w": jnp.linspace(-3, 3, 7 * 11).reshape(7, 11),
            "b": jnp.linspace(-1, 1, 5)}
    C = UniformQuantizer(levels=100, vmin=-4, vmax=4, clip=True)
    y = C(None, tree)
    codec = codec_for(C)
    back = codec.decode(codec.encode(y))
    for k_ in tree:
        np.testing.assert_array_equal(np.asarray(back[k_]),
                                      np.asarray(y[k_]))
        assert back[k_].shape == tree[k_].shape


def test_measured_approaches_nominal_for_large_n():
    """Header+padding overhead vanishes: measured bits/scalar → nominal."""
    n = 200_000
    x = _rand(n, seed=0, scale=1.0)
    for C in (UniformQuantizer(levels=255, vmin=-4, vmax=4, clip=True),
              ScaledSign()):
        measured = measure_tree_bytes(C, C(None, x))
        nominal = n * C.wire_bits_per_scalar() / 8.0
        assert abs(measured / nominal - 1.0) < 1e-3


def test_header_overhead_surfaced_by_compressor():
    C = UniformQuantizer(levels=255)
    # base 4 + 4·ndim + (levels u32, vmin f32, vmax f32)
    assert C.wire_header_nbytes(ndim=2) == 4 + 8 + 12
    assert ScaledSign().wire_header_nbytes(ndim=1) == 4 + 4 + 4
    assert TopK().wire_header_nbytes(ndim=1) == 4 + 4 + 4
    assert Identity().wire_header_nbytes(ndim=3) == 4 + 12


@pytest.mark.parametrize("bits", [1, 3, 8, 13, 32])
@pytest.mark.parametrize("n", [1, 100, 32768, 40000])
def test_pack_unpack_kernel_roundtrip(bits, n):
    hi = min(bits, 30)        # randint bound fits int32
    x = jax.random.randint(jax.random.PRNGKey(bits * n), (n,), 0,
                           2**hi).astype(jnp.uint32)
    words = pack_bits(x, bits, interpret=True)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(ref.pack_bits_ref(x, bits)))
    back = unpack_bits(words, bits, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# -- hypothesis property tests (when available) ----------------------------

if HAVE_HYPOTHESIS:
    finite_arrays = st.lists(
        st.floats(min_value=-5.0, max_value=5.0, allow_nan=False, width=32),
        min_size=2, max_size=80,
    ).map(lambda xs: jnp.asarray(np.array(xs, dtype=np.float32)))

    @settings(max_examples=40, deadline=None)
    @given(x=finite_arrays, levels=st.sampled_from([3, 10, 255, 1000]))
    def test_quant_codec_roundtrip_property(x, levels):
        C = UniformQuantizer(levels=levels, vmin=-8.0, vmax=8.0, clip=True)
        _roundtrip_exact(C, C(None, x))

    @settings(max_examples=40, deadline=None)
    @given(x=finite_arrays)
    def test_sign_codec_roundtrip_property(x):
        C = ScaledSign()
        _roundtrip_exact(C, C(None, x))

    @settings(max_examples=40, deadline=None)
    @given(x=finite_arrays, frac=st.sampled_from([0.1, 0.5, 0.9]))
    def test_topk_codec_roundtrip_property(x, frac):
        C = TopK(fraction=frac)
        _roundtrip_exact(C, C(None, x))

    @settings(max_examples=40, deadline=None)
    @given(x=finite_arrays, seed=st.integers(0, 2**31 - 1))
    def test_randd_codec_roundtrip_property(x, seed):
        C = RandD(fraction=0.5)
        _roundtrip_exact(C, C(jax.random.PRNGKey(seed), x))

    @settings(max_examples=30, deadline=None)
    @given(x=finite_arrays, levels=st.sampled_from([10, 255, 4000]))
    def test_quant_nbytes_property(x, levels):
        C = UniformQuantizer(levels=levels, vmin=-8.0, vmax=8.0, clip=True)
        codec = codec_for(C)
        msg = codec.encode(C(None, x))
        assert msg.payload_nbytes == 4 * logical_words(x.size, codec.bits)
        assert msg.nbytes == codec.tree_nbytes(x)

    @settings(max_examples=25, deadline=None)
    @given(bits=st.integers(1, 32), n=st.integers(1, 5000),
           seed=st.integers(0, 2**31 - 1))
    def test_pack_unpack_property(bits, n, seed):
        hi = min(bits, 30)
        x = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                               2**hi).astype(jnp.uint32)
        words = pack_bits(x, bits, interpret=True)
        back = unpack_bits(words, bits, n, interpret=True)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# -- simulator integration -------------------------------------------------

def test_space_runner_uses_measured_bytes():
    from repro.constellation.orbits import GroundStation, Walker
    from repro.core.error_feedback import EFChannel
    from repro.core.fedlt import FedLT
    from repro.core.fedlt_sat import SpaceRunner
    from repro.data.logistic import generate, make_local_loss
    from repro.sim import Engine
    from repro.sim.engine import Scenario

    n_agents, dim = 12, 40
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=20,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    alg = FedLT(loss=loss, n_epochs=1, gamma=0.005, rho=20.0,
                uplink=EFChannel(C), downlink=EFChannel(C))
    st_ = alg.init(jnp.zeros((dim,)), n_agents)
    sc = Scenario(walker=Walker(n_sats=n_agents, n_planes=3),
                  stations=(GroundStation(),))
    runner = SpaceRunner(Engine(sc), compressor=C)
    msg = runner._msg_bytes(st_)
    # measured = exact WireMessage bytes, not the nominal estimate
    codec = codec_for(C)
    assert msg == codec.tree_nbytes(jnp.zeros((dim,)))
    assert msg != dim * C.wire_bits_per_scalar() / 8.0
    _, logs = runner.run(alg, st_, data, 2, jax.random.PRNGKey(2))
    # bytes_up accumulates per-delivery measured bytes
    assert logs[0].bytes_up == logs[0].n_active * msg
    res = runner.engine.run_round(0.0, msg)
    assert all(d.nbytes == msg for d in res.deliveries)
