"""In-orbit aggregation topologies (repro.sim.topology).

The two contracts this file enforces:

1. ``topology="direct"`` is bit-for-bit identical to a scenario without
   the field — Delivery timelines, byte accounting, AND the obs trace
   (``repro.obs.summary.diff`` clean), so existing results can't shift.
2. Plane/gossip rounds keep the fast==oracle equivalence: the vectorized
   fold and the literal heapq event machine produce identical
   RoundResults, including under a lossy channel destroying head wires.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.constellation.orbits import GroundStation, Walker
from repro.sim import Engine, Scenario, get_scenario, make_topology
from repro.sim.routing import Router
from repro.sim.topology import (DIRECT, GOSSIP, PLANE, Topology,
                                _plane_arcs, check_plane_compatible,
                                plan_plane_round)

MSG = 120e6 / 8 * 0.01  # ~150 kB — same order as the engine test suite

KIRUNA = GroundStation()


def _pair(name, rounds=5, msg=MSG):
    """Run the same scenario on the fast and oracle engines; assert
    bit-identical RoundResults; return the fast results."""
    sc = get_scenario(name)
    ef, eo = Engine(sc, fast=True), Engine(sc, fast=False)
    t_f = t_o = 0.0
    out = []
    for k in range(rounds):
        rf, ro = ef.run_round(t_f, msg), eo.run_round(t_o, msg)
        assert rf.deliveries == ro.deliveries, f"round {k} diverged"
        assert rf.duration == ro.duration
        assert (rf.mask == ro.mask).all()
        assert (rf.scheduled == ro.scheduled).all()
        assert rf.bytes_isl == ro.bytes_isl
        assert rf.merged == ro.merged and rf.heads == ro.heads
        t_f += rf.duration
        t_o += ro.duration
        out.append(rf)
    return out


# -- resolution -------------------------------------------------------------

def test_make_topology_resolution():
    assert make_topology(None) is DIRECT
    assert make_topology("direct") is DIRECT
    assert make_topology("plane") is PLANE
    assert make_topology("gossip") is GOSSIP
    assert make_topology(PLANE) is PLANE
    assert GOSSIP.name == "gossip" and PLANE.name == "plane"
    assert Topology("plane", gossip=True).name == "gossip"
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology("mesh")
    with pytest.raises(ValueError):
        make_topology(42)


def test_plane_needs_regular_walker():
    ragged = Scenario(walker=Walker(n_sats=10, n_planes=3),
                      stations=(KIRUNA,))
    check_plane_compatible(ragged, DIRECT)       # direct: anything goes
    with pytest.raises(ValueError, match="regular"):
        check_plane_compatible(ragged, PLANE)
    with pytest.raises(ValueError, match="regular"):
        Engine(dataclasses.replace(ragged, topology="plane"))


def test_plane_arcs_partition_the_ring():
    spp = 7
    for head in range(spp):
        up, down = _plane_arcs(head, plane=0, spp=spp)
        # every non-head member exactly once, head in neither arc
        assert sorted(up + down + [head]) == list(range(spp))
        # far→near: the last element of each arc is adjacent to the head
        for chain in (up, down):
            if chain:
                assert min((chain[-1] - head) % spp,
                           (head - chain[-1]) % spp) == 1


# -- direct passthrough ------------------------------------------------------

def test_direct_topology_bit_identical():
    """topology='direct' must not perturb the existing engine AT ALL:
    same deliveries, same trace records (obs diff clean)."""
    from repro import obs
    from repro.obs.summary import diff

    sc = get_scenario("walker-kiruna")
    sc_d = dataclasses.replace(sc, topology="direct")
    traces = []
    for scenario in (sc, sc_d):
        eng = Engine(scenario)
        with obs.tracing() as trc:
            t = 0.0
            for _ in range(4):
                res = eng.run_round(t, MSG)
                t += res.duration
            recs = trc.records()
        traces.append(recs)
        assert res.merged is None and res.heads is None
        assert res.bytes_isl == 0.0
    equal, report = diff(traces[0], traces[1])
    assert equal, report


# -- plane / gossip equivalence ---------------------------------------------

def test_plane_fast_matches_oracle():
    results = _pair("plane-agg-walker")
    assert any(r.deliveries for r in results)
    for r in results:
        assert r.merged is not None
        # only heads deliver; delivered mask covers whole merged groups
        for d in r.deliveries:
            assert d.sat in r.merged
            if d.delivered:
                assert r.mask[list(r.merged[d.sat])].all()


def test_gossip_fast_matches_oracle():
    results = _pair("plane-agg-gossip")
    sc = get_scenario("plane-agg-gossip")
    spp = sc.walker.sats_per_plane
    # gossip merges pairs of planes: some wire must sum 2 planes' members
    assert any(len(m) == 2 * spp
               for r in results for m in r.merged.values())


def test_lossy_plane_fast_matches_oracle():
    results = _pair("plane-agg-lossy", rounds=12)
    lost = sum(1 for r in results for d in r.deliveries if not d.delivered)
    assert lost > 0, "lossy plane scenario produced no lost head wires"


def test_small_mega_plane_fast_matches_oracle():
    sc = Scenario(name="mini-mega-plane",
                  walker=Walker(n_sats=120, n_planes=12),
                  stations=(KIRUNA,), topology="plane")
    ef, eo = Engine(sc, fast=True), Engine(sc, fast=False)
    t = 0.0
    for _ in range(3):
        rf, ro = ef.run_round(t, MSG), eo.run_round(t, MSG)
        assert rf.deliveries == ro.deliveries
        assert rf.merged == ro.merged
        t += rf.duration


# -- plan properties ---------------------------------------------------------

def test_election_deterministic_and_well_formed():
    eng = Engine(get_scenario("plane-agg-walker"))
    p1 = plan_plane_round(eng, 0.0)
    p2 = plan_plane_round(eng, 0.0)
    assert p1.heads == p2.heads and p1.merged == p2.merged
    assert p1.uplinkers == p2.uplinkers and p1.pairs == p2.pairs
    spp = eng.scenario.walker.sats_per_plane
    for plane, head in p1.heads.items():
        assert plane * spp <= head < (plane + 1) * spp
    # each uplinker's merged set is disjoint and plane-aligned
    seen = set()
    for h, members in p1.merged.items():
        assert h in members
        assert not (seen & set(members))
        seen |= set(members)
        assert len(members) % spp == 0


def test_bytes_isl_accounting():
    """Full participation: every plane elects a head, so the convergecast
    moves exactly (n_sats - n_planes) messages; gossip adds the
    inter-head hops on top."""
    sc = get_scenario("plane-agg-walker")
    w = sc.walker
    res = Engine(sc).run_round(0.0, MSG)
    if len(res.heads) == w.n_planes:        # all planes lit
        assert res.bytes_isl == (w.n_sats - w.n_planes) * MSG
    res_g = Engine(get_scenario("plane-agg-gossip")).run_round(0.0, MSG)
    assert res_g.bytes_isl > res.bytes_isl - 1e-9
    # gossip halves (±1 odd plane) the ground-station uplink count
    assert len(res_g.deliveries) <= len(res.deliveries) // 2 + 1


def test_round_result_roundtrips_with_aggregation_fields():
    from repro.sim.engine import RoundResult
    res = Engine(get_scenario("plane-agg-walker")).run_round(0.0, MSG)
    back = RoundResult.from_dict(res.to_dict())
    assert back.deliveries == res.deliveries
    assert back.merged == res.merged and back.heads == res.heads
    assert back.bytes_isl == res.bytes_isl
    # direct rounds keep emitting the seed dict shape (no agg keys)
    res_d = Engine(get_scenario("walker-kiruna")).run_round(0.0, MSG)
    d = res_d.to_dict()
    assert "merged" not in d and "bytes_isl" not in d


# -- mode guards -------------------------------------------------------------

def test_plane_mode_guards():
    from repro.core.fedlt_sat import SpaceRunner
    eng = Engine(get_scenario("plane-agg-walker"))
    with pytest.raises(ValueError, match="async"):
        SpaceRunner(eng, mode="async")
    with pytest.raises(ValueError, match="cohort"):
        SpaceRunner(eng, measure="cohort")
    with pytest.raises(ValueError, match="topology"):
        eng.run_async(0.0, MSG, n_deliveries=10)


# -- SpaceRunner integration -------------------------------------------------

def test_lossy_plane_run_loss_robust():
    """plane-agg-lossy end-to-end: head wires get destroyed, whole planes
    revert, and loss-robust EF still converges to a finite error."""
    from repro.core.compression import UniformQuantizer
    from repro.core.error_feedback import EFChannel
    from repro.core.fedlt import FedLT
    from repro.core.fedlt_sat import SpaceRunner
    from repro.data.logistic import generate, make_local_loss

    n_agents, dim = 100, 12
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=16,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    alg = FedLT(loss=loss, n_epochs=1, gamma=0.005, rho=20.0,
                uplink=EFChannel(C), downlink=EFChannel(C))
    st = alg.init(jnp.zeros((dim,)), n_agents)
    runner = SpaceRunner(Engine(get_scenario("plane-agg-lossy")),
                         compressor=C)
    st, logs = runner.run(alg, st, data, 10, jax.random.PRNGKey(2))
    assert sum(l.n_lost for l in logs) > 0, "no head wires were lost"
    assert sum(l.bytes_isl for l in logs) > 0
    assert all(np.isfinite(l.bytes_up) for l in logs)
    # lost counts are whole planes: multiples of sats_per_plane
    spp = get_scenario("plane-agg-lossy").walker.sats_per_plane
    for l in logs:
        assert l.n_lost % spp == 0


# -- router: plane-seam routes + mid-route window close ----------------------

def test_router_seam_route():
    """The +grid wraps across the seam (last plane ↔ plane 0): a same-slot
    satellite in the last plane reaches a plane-0 gateway in ONE hop, not
    n_planes-1 hops the long way round."""
    w = Walker(n_sats=12, n_planes=3)
    r = Router(w)
    routes = r.routes_to_gateways([0], MSG)
    seam_sat = (w.n_planes - 1) * w.sats_per_plane   # last plane, slot 0
    assert routes[seam_sat].hops == 1
    assert routes[seam_sat].path == (seam_sat, 0)
    # max_hops bounds expansion
    near = r.routes_to_gateways([0], MSG, max_hops=1)
    assert seam_sat in near
    assert all(rt.hops <= 1 for rt in near.values())


def test_relay_window_close_refits_identically():
    """Mid-route window close: with a message so large that uplinks
    overflow the first usable window, relayed updates must refit into
    later windows — and the fast path must do so exactly like the
    oracle (the regression class: fast picks window W, oracle picks
    W+1)."""
    sc = get_scenario("walker-kiruna")
    big = 120e6 / 8 * 2.0       # ~30 MB: gs_time comparable to a window
    ef, eo = Engine(sc, fast=True), Engine(sc, fast=False)
    t = 0.0
    relayed, refit = 0, 0
    for _ in range(4):
        rf, ro = ef.run_round(t, big), eo.run_round(t, big)
        assert rf.deliveries == ro.deliveries
        for d in rf.deliveries:
            relayed += d.hops > 0
            # landed far past its window rise ⇒ the first window couldn't
            # hold it and the engine refit into a later one
            refit += d.t_done - d.window > 3 * sc.link.gs_time(big)
        t += rf.duration
    assert relayed > 0, "no multi-hop relays exercised"
    assert refit > 0, "message size too small to force a window refit"
