"""Fleet-axis sharding: single-device fallback + multi-device equivalence.

The multi-device case forces 4 host CPU devices via XLA_FLAGS in a
subprocess (the flag must be set before jax initializes, which the main
test process has long since done) and checks the shard_map round against
the unsharded round.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.sharding import fleet_mesh, fleet_specs, shard_fleet


def test_fleet_mesh_single_device_is_none():
    if len(jax.devices()) > 1:
        pytest.skip("host has multiple devices")
    assert fleet_mesh() is None
    # shard_fleet with mesh=None is the identity
    tree = {"a": jnp.ones((4, 3))}
    out = shard_fleet(tree, None)
    assert out is tree


def test_fleet_specs_divisibility():
    mesh = jax.make_mesh((1,), ("fleet",))
    specs = fleet_specs({"a": jnp.ones((4, 3)), "s": jnp.zeros(())}, mesh)
    assert specs["a"] == jax.sharding.PartitionSpec("fleet")
    assert specs["s"] == jax.sharding.PartitionSpec()


_SUBPROCESS_BODY = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.fedlt import FedLT
    from repro.core.error_feedback import EFChannel
    from repro.core.compression import UniformQuantizer
    from repro.data.logistic import generate, make_local_loss
    from repro.launch.sharding import fleet_mesh, shard_fleet

    assert len(jax.devices()) == 4, jax.devices()
    n_agents, m, dim = 8, 20, 12
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=m,
                       dim=dim)
    loss = make_local_loss(eps=5.0, n_agents=n_agents)
    C = UniformQuantizer(levels=100, vmin=-3, vmax=3, clip=True)
    alg = FedLT(loss=loss, n_epochs=3, gamma=0.05, rho=5.0,
                uplink=EFChannel(C), downlink=EFChannel(C))
    state = alg.init(jnp.zeros((dim,)), n_agents)
    active = jnp.ones((n_agents,), bool)
    key = jax.random.PRNGKey(7)

    s1, _ = jax.jit(alg.round)(state, data, active, key)

    mesh = fleet_mesh()
    assert mesh is not None and mesh.shape["fleet"] == 4
    round_fn = alg.round_sharded(mesh, n_agents)
    s2, info = jax.jit(round_fn)(
        shard_fleet(state, mesh, n_agents=n_agents),
        shard_fleet(data, mesh, n_agents=n_agents), active, key)
    assert int(info["n_active"]) == n_agents
    for a, b in zip(jax.tree_util.tree_leaves(s1),
                    jax.tree_util.tree_leaves(s2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)

    # run() drives the sharded round through scan
    fs, logs = alg.run(shard_fleet(state, mesh, n_agents=n_agents),
                       shard_fleet(data, mesh, n_agents=n_agents),
                       3, jax.random.PRNGKey(1), mesh=mesh)
    assert int(fs.k) == 3
    print("FLEET_OK")
""")


def test_round_sharded_matches_unsharded_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_BODY],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "FLEET_OK" in proc.stdout
