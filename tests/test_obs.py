"""repro.obs: tracer semantics, serialization round-trips, fast-vs-oracle
trace-diff (zero divergence on the equivalence scenarios), invariant
checking, metrics, Chrome export, and the CLI."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.constellation.links import message_bytes
from repro.obs.summary import DIFF_KINDS, of_kind
from repro.sim import Engine, get_scenario
from repro.sim.engine import Delivery, RoundResult

MSG = message_bytes(10000, 10.0)

# the fast-vs-oracle equivalence scenarios trace-diff must clear (ISSUE 6
# acceptance): lossless baseline, station contention, and every lossy
# channel family — flat-erasure ARQ, rain fade, degraded Ka-band budget,
# conjunction blackouts
DIFF_SCENARIOS = ["walker-kiruna", "dual-station", "lossy-uplink",
                  "rain-fade", "ka-band-degraded", "conjunction-outage"]


def _trace_run(scenario: str, fast: bool, *, rounds=2, async_n=15,
               seed=3):
    eng = Engine(get_scenario(scenario), seed=seed, fast=fast)
    with obs.tracing(scenario=scenario) as trc:
        t = 0.0
        for _ in range(rounds):
            t += eng.run_round(t, MSG).duration
        if async_n:
            eng.run_async(t, MSG, async_n)
        return trc.records()


# ---------------------------------------------------------------------------
# serialization round-trips (satellite a)
# ---------------------------------------------------------------------------

def test_delivery_roundtrip_json_stable():
    d = Delivery(sat=7, t_done=120.5, t_start=90.0, gateway=7, station=1,
                 hops=2, nbytes=1000.0, window=80.0,
                 nbytes_attempted=1250.0, retries=3, delivered=True)
    back = Delivery.from_dict(json.loads(json.dumps(d.to_dict())))
    assert back == d


def test_delivery_nan_window_maps_to_none():
    d = Delivery(sat=0, t_done=1.0, t_start=0.0, gateway=0, station=0,
                 hops=0, nbytes=0.0, delivered=False)
    enc = d.to_dict()
    assert enc["window"] is None
    json.dumps(enc, allow_nan=False)       # strict-JSON safe
    back = Delivery.from_dict(enc)
    assert math.isnan(back.window)
    assert back.delivered is False


def test_round_result_roundtrip():
    eng = Engine(get_scenario("lossy-uplink"), seed=3)
    res = eng.run_round(0.0, MSG)
    assert res.deliveries, "scenario produced no deliveries"
    back = RoundResult.from_dict(json.loads(json.dumps(res.to_dict())))
    assert back.deliveries == res.deliveries
    np.testing.assert_array_equal(back.mask, res.mask)
    np.testing.assert_array_equal(back.scheduled, res.scheduled)
    assert (back.duration, back.t0) == (res.duration, res.t0)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_tracer_disabled_is_none():
    assert obs.active() is None


def test_tracer_stack_nests():
    outer = obs.enable()
    inner = obs.enable()
    assert obs.active() is inner
    obs.disable()
    assert obs.active() is outer
    obs.disable()
    assert obs.active() is None


def test_tracing_flush_load_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with obs.tracing(path, scenario="unit") as trc:
        trc.event("round", round=0, t0=0.0, duration=1.0, n_scheduled=1,
                  n_delivered=1, n_lost=0, bytes_air=10.0, engine="fast")
        trc.metrics.counter("bytes_air").add(10.0, station=0)
    records = obs.load(path)
    assert records[0]["kind"] == "header"
    assert records[0]["scenario"] == "unit"
    assert of_kind(records, "round")[0]["bytes_air"] == 10.0
    [m] = of_kind(records, "metrics")
    assert m["counters"]["bytes_air"]["total"] == 10.0


def test_span_records_host_timing():
    with obs.tracing() as trc:
        with trc.span("stage", name="work"):
            pass
        [rec] = trc.events
    assert rec["kind"] == "stage" and rec["name"] == "work"
    assert rec["dur_host"] >= 0.0 and rec["t_host"] >= 0.0


# ---------------------------------------------------------------------------
# series records, gzip traces, streaming flush (schema v2)
# ---------------------------------------------------------------------------

def test_series_records_and_extraction():
    from repro.obs.summary import extract_series
    with obs.tracing() as trc:
        trc.series("e_K", 1, 0.5)
        trc.series("e_K", 0, 1.0)          # out of order on purpose
        trc.series("bytes_up", 0, 128.0, station=0)
        records = trc.records()
    series = extract_series(records)
    assert series["e_K"] == {"steps": [0, 1], "values": [1.0, 0.5]}
    assert series["bytes_up"]["values"] == [128.0]
    # labelled fields survive on the raw record
    [b] = [r for r in records if r.get("name") == "bytes_up"]
    assert b["station"] == 0


def test_series_stays_out_of_diff_contract():
    # series curves carry error values that legitimately differ between
    # equivalent engine configurations — they must never break the
    # fast-vs-oracle diff
    assert "series" not in DIFF_KINDS
    ra = _trace_run("walker-kiruna", fast=True)
    rb = [dict(r) for r in ra]
    rb.append({"kind": "series", "name": "e_K", "step": 0, "value": 1.0})
    equal, _ = obs.diff(ra, rb)
    assert equal


def test_gzip_trace_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl.gz")
    with obs.tracing(path, scenario="unit") as trc:
        trc.event("round", round=0, t0=0.0, duration=1.0, n_scheduled=1,
                  n_delivered=1, n_lost=0, bytes_air=10.0, engine="fast")
        trc.series("e_K", 0, 2.5)
        trc.metrics.counter("bytes_air").add(10.0)
    raw = open(path, "rb").read()
    assert raw[:2] == b"\x1f\x8b", "not gzip-compressed on disk"
    records = obs.load(path)
    assert records[0]["kind"] == "header"
    assert of_kind(records, "series")[0]["value"] == 2.5
    [m] = of_kind(records, "metrics")
    assert m["counters"]["bytes_air"]["total"] == 10.0


def test_gzip_cli_subcommands(tmp_path, capsys):
    from repro.obs.__main__ import main
    pa = str(tmp_path / "a.jsonl.gz")
    eng = Engine(get_scenario("walker-kiruna"), seed=0)
    with obs.tracing(pa):
        eng.run_round(0.0, MSG)
    assert main(["summarize", pa]) == 0
    assert "round" in capsys.readouterr().out
    assert main(["check", pa]) == 0
    assert main(["diff", pa, pa]) == 0
    capsys.readouterr()


def test_streaming_flush_bounded_memory(tmp_path):
    path = str(tmp_path / "s.jsonl")
    with obs.tracing(path, stream_every=5, scenario="stream") as trc:
        for k in range(17):
            trc.event("round", round=k, t0=0.0, duration=1.0,
                      n_scheduled=0, n_delivered=0, n_lost=0,
                      bytes_air=0.0, engine="fast")
            assert len(trc.events) < 5          # buffer stays bounded
        trc.metrics.counter("bytes_air").add(1.0)
    records = obs.load(path)
    assert records[0]["kind"] == "header" and records[0]["streamed"]
    assert [r["round"] for r in of_kind(records, "round")] == list(range(17))
    # metrics snapshot semantics kept: exactly one, last, complete
    assert records[-1]["kind"] == "metrics"
    assert records[-1]["counters"]["bytes_air"]["total"] == 1.0
    assert sum(r["kind"] == "metrics" for r in records) == 1


def test_streaming_flush_gzip_and_partial_visibility(tmp_path):
    path = str(tmp_path / "s.jsonl.gz")
    with obs.tracing(path, stream_every=2) as trc:
        for k in range(4):
            trc.event("round", round=k, t0=0.0, duration=1.0,
                      n_scheduled=0, n_delivered=0, n_lost=0,
                      bytes_air=0.0, engine="fast")
        trc.flush()
    records = obs.load(path)
    assert len(of_kind(records, "round")) == 4


def test_streaming_without_path_rejected():
    with pytest.raises(ValueError):
        obs.Tracer(stream_every=10)


def test_summarize_dict_machine_readable():
    from repro.obs.summary import summarize_dict
    records = _trace_run("lossy-uplink", fast=True, rounds=2)
    s = summarize_dict(records)
    assert s["schema"] == 2
    assert s["meta"]["scenario"] == "lossy-uplink"
    assert s["round_kind"] == "round" and s["n_rounds"] == 2
    assert s["deliveries"]["n"] == len(of_kind(records, "delivery"))
    assert s["final"]["bytes_air"] == \
        sum(r["bytes_air"] for r in of_kind(records, "round"))
    assert "bytes_air" in s["series"]
    json.dumps(s, allow_nan=False)      # strict-JSON machine output


def test_cli_summarize_json(tmp_path, capsys):
    from repro.obs.__main__ import main
    pa = str(tmp_path / "a.jsonl")
    eng = Engine(get_scenario("walker-kiruna"), seed=0)
    with obs.tracing(pa):
        eng.run_round(0.0, MSG)
    assert main(["summarize", pa, "--json"]) == 0
    s = json.loads(capsys.readouterr().out)
    assert s["n_rounds"] == 1 and s["round_kind"] == "round"


# ---------------------------------------------------------------------------
# schema-v1 compatibility (committed fixture)
# ---------------------------------------------------------------------------

V1_FIXTURE = __file__.rsplit("/", 1)[0] + "/data/trace_schema_v1.jsonl"


def test_v1_fixture_still_loads_and_summarizes():
    from repro.obs.summary import summarize_dict
    records = obs.load(V1_FIXTURE)
    assert records[0]["schema"] == 1
    text = obs.summarize(records)
    assert "round" in text
    assert obs.check(records) == []
    s = summarize_dict(records)
    assert s["round_kind"] == "fl_round" and s["n_rounds"] == 2
    # v1 has no series records: the federated curves are synthesized
    # from the fl_round records so ledger/convgate read old traces too
    assert s["series"]["e_K"]["values"] == [24.25, 21.5]
    assert s["series"]["bytes_up"]["values"] == [2112.0, 4224.0]
    assert s["final"]["e_K"] == 21.5


def test_v1_fixture_diffs_against_itself():
    records = obs.load(V1_FIXTURE)
    equal, report = obs.diff(records, records)
    assert equal, report


# ---------------------------------------------------------------------------
# engine emission + fast-vs-oracle trace-diff (the tentpole contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", DIFF_SCENARIOS)
def test_trace_diff_fast_vs_oracle_zero_divergence(scenario):
    ra = _trace_run(scenario, fast=True)
    rb = _trace_run(scenario, fast=False)
    equal, report = obs.diff(ra, rb)
    assert equal, report
    # the engine tag is the one legitimate difference
    assert of_kind(ra, "round")[0]["engine"] == "fast"
    assert of_kind(rb, "round")[0]["engine"] == "oracle"
    # and both traces satisfy the invariants
    assert obs.check(ra) == []
    assert obs.check(rb) == []


def test_trace_diff_localizes_divergence():
    ra = _trace_run("walker-kiruna", fast=True)
    rb = [dict(r) for r in ra]
    victims = of_kind(rb, "delivery")
    victims[2]["t_done"] += 1.0
    equal, report = obs.diff(ra, rb)
    assert not equal
    assert "t_done" in report and "DIVERGED" in report


def test_trace_diff_detects_missing_records():
    ra = _trace_run("walker-kiruna", fast=True)
    # a truncated trace (final async_run summary missing): every zipped
    # pair still matches, so only the count comparison can catch it
    rb = [r for r in ra if r.get("kind") != "async_run"]
    equal, report = obs.diff(ra, rb)
    assert not equal and "counts differ" in report
    assert "async_run" in report


def test_check_catches_bytes_violation():
    records = [dict(r) for r in _trace_run("walker-kiruna", fast=True)]
    of_kind(records, "round")[0]["bytes_air"] += 1.0
    bad = obs.check(records)
    assert any("bytes conservation" in m for m in bad)


def test_check_catches_failed_delivery_with_payload():
    records = [{"kind": "delivery", "round": None, "sat": 0, "t_done": 1.0,
                "t_start": 0.0, "delivered": False, "nbytes": 5.0,
                "nbytes_attempted": 5.0, "retries": 0}]
    assert any("failed but carries" in m for m in obs.check(records))


def test_lossy_trace_has_arq_and_retx_metrics():
    records = _trace_run("lossy-uplink", fast=True, rounds=3)
    arq = of_kind(records, "arq")
    assert arq, "lossy-uplink produced no ARQ events"
    [m] = of_kind(records, "metrics")
    assert m["counters"]["bytes_retx"]["total"] > 0.0
    assert m["histograms"]["delivery_latency"]["count"] == \
        len(of_kind(records, "delivery"))


def test_round_indices_and_async_runs_advance():
    eng = Engine(get_scenario("walker-kiruna"), seed=0)
    with obs.tracing() as trc:
        eng.run_round(0.0, MSG)
        eng.run_round(500.0, MSG)
        eng.run_async(0.0, MSG, 5)
        eng.run_async(0.0, MSG, 5)
        records = trc.records()
    assert [r["round"] for r in of_kind(records, "round")] == [0, 1]
    assert [r["run"] for r in of_kind(records, "async_run")] == [0, 1]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_total():
    m = obs.Metrics()
    c = m.counter("bytes")
    c.add(10.0, station=0)
    c.add(5.0, station=1)
    c.add(1.0, station=0)
    assert c.total == 16.0
    d = m.to_dict()["counters"]["bytes"]
    assert d["total"] == 16.0
    assert d["cells"]["station=0"] == 11.0


def test_histogram_stats_and_bounds():
    h = obs.Metrics().histogram("lat", bounds=(1.0, 10.0))
    for v in (0.5, 2.0, 20.0):
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == 3 and d["min"] == 0.5 and d["max"] == 20.0
    assert d["counts"] == [1, 1, 1]
    assert abs(d["mean"] - 7.5) < 1e-9
    # without a lower bound nothing underflows; above-range samples are
    # surfaced as the explicit overflow count (= the last bucket)
    assert d["lo"] is None and d["underflow"] == 0
    assert d["overflow"] == 1


def test_histogram_underflow_and_overflow_explicit():
    h = obs.Metrics().histogram("stale", bounds=(1.0, 10.0), lo=0.0)
    for v in (-2.0, -1.0, 0.5, 5.0, 100.0, 200.0):
        h.observe(v)
    d = h.to_dict()
    # below-lo samples are tallied, not folded into the first bucket
    assert d["underflow"] == 2
    assert d["overflow"] == 2 and d["counts"] == [1, 1, 2]
    assert d["counts"][0] == 1          # only the in-range 0.5
    # sidecar stats still describe EVERY observation
    assert d["count"] == 6 and d["min"] == -2.0 and d["max"] == 200.0
    assert d["sum"] == -2.0 - 1.0 + 0.5 + 5.0 + 100.0 + 200.0


def test_engine_latency_histogram_has_lower_bound():
    records = _trace_run("lossy-uplink", fast=True, rounds=2)
    [m] = of_kind(records, "metrics")
    lat = m["histograms"]["delivery_latency"]
    assert lat["lo"] == 0.0 and lat["underflow"] == 0
    assert "overflow" in lat


# ---------------------------------------------------------------------------
# SpaceRunner + kernels emission
# ---------------------------------------------------------------------------

def _small_runner(channel=None, **kw):
    from repro.channel import ChannelModel, SelectiveRepeatARQ
    from repro.constellation.orbits import GroundStation, Walker
    from repro.core.compression import UniformQuantizer
    from repro.core.error_feedback import EFChannel
    from repro.core.fedlt import FedLT
    from repro.core.fedlt_sat import SpaceRunner
    from repro.data.logistic import generate, make_local_loss
    from repro.sim import Scenario
    n = 20
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n, m=40, dim=16)
    loss = make_local_loss(eps=50.0, n_agents=n)
    q = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    alg = FedLT(loss=loss, n_epochs=2, gamma=0.005, rho=20.0,
                uplink=EFChannel(q), downlink=EFChannel(q))
    sc = Scenario(name="small", walker=Walker(n_sats=n, n_planes=4),
                  stations=(GroundStation(),), k_direct=3, n_relay=2)
    if channel == "lossy":
        channel = ChannelModel(loss=0.25,
                               arq=SelectiveRepeatARQ(seg_bytes=16,
                                                      max_rounds=2))
    runner = SpaceRunner(Engine(sc), compressor=q, channel=channel, **kw)
    return runner, alg, alg.init(jnp.zeros((16,)), n), data


def test_space_runner_sync_emits_fl_rounds_and_ef_reverts():
    runner, alg, st, data = _small_runner(channel="lossy")
    with obs.tracing() as trc:
        runner.run(alg, st, data, 6, jax.random.PRNGKey(2))
        records = trc.records()
    fl = of_kind(records, "fl_round")
    assert [r["round"] for r in fl] == list(range(6))
    assert all(r["mode"] == "sync" for r in fl)
    # cumulative ledger is monotone (also a check() invariant)
    ups = [r["bytes_up"] for r in fl]
    assert ups == sorted(ups) and ups[-1] > 0
    rev = of_kind(records, "ef_revert")
    assert rev and all(r["absorb"] for r in rev)
    assert all(r["resid_norm"] >= 0.0 for r in rev)
    assert sum(r["n_lost"] for r in rev) == \
        sum(r["n_lost"] for r in fl if r["n_lost"])
    assert obs.check(records) == []
    # host spans for both stages of every round
    stages = of_kind(records, "stage")
    assert sum(s["name"] == "engine.run_round" for s in stages) == 6
    assert sum(s["name"] == "alg.round" for s in stages) == 6


def test_space_runner_async_emits_staleness():
    runner, alg, st, data = _small_runner(mode="async", buffer_size=4)
    with obs.tracing() as trc:
        runner.run(alg, st, data, 4, jax.random.PRNGKey(2))
        records = trc.records()
    fl = of_kind(records, "fl_round")
    assert fl and all(r["mode"] == "async" for r in fl)
    assert all(r["staleness"] >= 0.0 for r in fl)
    [m] = of_kind(records, "metrics")
    assert m["histograms"]["staleness"]["count"] == \
        sum(r["n_active"] for r in fl)
    assert obs.check(records) == []


def test_kernel_dispatch_events():
    from repro.kernels import ops
    x = jnp.arange(65536, dtype=jnp.uint32) % 16
    with obs.tracing() as trc:
        words = ops.pack_bits(x, 4)
        ops.unpack_bits(words, 4, x.size)
        records = trc.records()
    names = [k["name"] for k in of_kind(records, "kernel")]
    assert names == ["pack_bits", "unpack_bits"]
    [m] = of_kind(records, "metrics")
    cells = m["counters"]["kernel_dispatches"]["cells"]
    assert cells == {"name=pack_bits": 1.0, "name=unpack_bits": 1.0}


def test_kernel_untraced_path_unchanged():
    from repro.kernels import ops
    x = jnp.arange(65536, dtype=jnp.uint32) % 16
    baseline = np.asarray(ops.pack_bits(x, 4))
    with obs.tracing():
        traced = np.asarray(ops.pack_bits(x, 4))
    np.testing.assert_array_equal(baseline, traced)


def test_link_events_only_on_budget_channels():
    # rain-fade rides a LinkBudget → link events with elevation/fade;
    # lossy-uplink is flat-rate → fast path replays ArqPlans, no link kind
    budget = _trace_run("rain-fade", fast=True, async_n=0)
    flat = _trace_run("lossy-uplink", fast=True, async_n=0)
    links = of_kind(budget, "link")
    assert links
    assert all(l["elevation_deg"] > 0.0 and l["rate"] > 0.0 for l in links)
    assert of_kind(flat, "link") == []
    # link/outage kinds stay out of the diff contract
    assert "link" not in DIFF_KINDS and "outage" not in DIFF_KINDS


def test_outage_events_on_blackout_scenarios():
    records = _trace_run("conjunction-outage", fast=True, async_n=0)
    outs = of_kind(records, "outage")
    assert outs and any(o["n_blocked"] > 0 for o in outs)
    assert all(o["n_blocked"] <= o["n_windows"] for o in outs)


# ---------------------------------------------------------------------------
# chrome export + CLI
# ---------------------------------------------------------------------------

def test_chrome_trace_structure(tmp_path):
    records = _trace_run("lossy-uplink", fast=True, rounds=2)
    doc = obs.chrome_trace(records)
    ev = doc["traceEvents"]
    phases = {e["ph"] for e in ev}
    assert {"M", "X", "C"} <= phases
    slices = [e for e in ev if e["ph"] == "X" and e.get("cat") == "delivery"]
    assert len(slices) == len(of_kind(records, "delivery"))
    for e in ev:        # Perfetto needs numeric ts on every non-meta event
        if e["ph"] != "M":
            assert isinstance(e["ts"], float)
    out = str(tmp_path / "x.json")
    obs.write_chrome_trace(records, out)
    assert json.load(open(out))["traceEvents"]


def test_cli_summarize_diff_check_chrome(tmp_path, capsys):
    from repro.obs.__main__ import main
    pa, pb = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    eng = Engine(get_scenario("walker-kiruna"), seed=0)
    with obs.tracing(pa):
        eng.run_round(0.0, MSG)
    eng2 = Engine(get_scenario("walker-kiruna"), seed=0, fast=False)
    with obs.tracing(pb):
        eng2.run_round(0.0, MSG)

    assert main(["summarize", pa]) == 0
    assert "round" in capsys.readouterr().out

    assert main(["diff", pa, pb]) == 0
    assert "identical" in capsys.readouterr().out

    assert main(["check", pa, pb]) == 0
    assert main(["--check", pa]) == 0          # the CI alias
    capsys.readouterr()

    assert main(["chrome", pa, "-o", str(tmp_path / "a.json")]) == 0
    assert json.load(open(tmp_path / "a.json"))["traceEvents"]
    capsys.readouterr()

    # a diverging pair exits 1 (same scenario, shifted round start —
    # walker-kiruna is lossless, so the seed alone can't shift it)
    eng3 = Engine(get_scenario("walker-kiruna"), seed=0)
    pc = str(tmp_path / "c.jsonl")
    with obs.tracing(pc):
        eng3.run_round(60.0, MSG)
    assert main(["diff", pa, pc]) == 1
    assert "DIVERGED" in capsys.readouterr().out

    # a tampered trace fails check with exit 1
    recs = obs.load(pa)
    for r in recs:
        if r.get("kind") == "round":
            r["bytes_air"] += 1.0
    pd = str(tmp_path / "d.jsonl")
    with open(pd, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
    assert main(["check", pd]) == 1
    assert "violation" in capsys.readouterr().out
