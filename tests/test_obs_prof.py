"""repro.obs.prof: phase accumulation, rollup/attribution math, the
≥90% mega-1000 attribution gate on both engines, perfdiff localization
of a seeded slowdown, folded-stacks/chrome export, bench history with
regression-onset localization, and the zero-round summarize/watch
regressions."""
import io
import json
import math
import re

import pytest

from repro import obs
from repro.constellation.links import message_bytes
from repro.obs import prof
from repro.obs.metrics import Histogram
from repro.obs.summary import DIFF_KINDS, of_kind
from repro.sim import Engine, get_scenario

MSG = message_bytes(10000, 10.0)


def _trace_run(scenario: str, fast: bool, *, rounds=2, async_n=15,
               seed=3):
    eng = Engine(get_scenario(scenario), seed=seed, fast=fast)
    with obs.tracing(scenario=scenario) as trc:
        t = 0.0
        for _ in range(rounds):
            t += eng.run_round(t, MSG).duration
        if async_n:
            eng.run_async(t, MSG, async_n)
        return trc.records()


# ---------------------------------------------------------------------------
# Histogram.percentile (satellite 1)
# ---------------------------------------------------------------------------

def test_percentile_interpolates_and_pins_edges():
    h = Histogram(bounds=(10.0, 20.0), lo=10.0)
    h.observe(5.0)                  # underflow bucket spans [min, lo)
    h.observe(15.0)
    assert h.percentile(25) == pytest.approx(7.5)   # inside [5, 10)
    assert h.percentile(50) == pytest.approx(10.0)  # underflow upper edge
    assert h.percentile(0) == 5.0                   # p0 → min
    assert h.percentile(100) == 15.0                # p100 → max


def test_percentile_overflow_bucket_spans_to_max():
    h = Histogram(bounds=(1.0, 2.0))
    for v in (0.5, 1.5, 10.0, 30.0):
        h.observe(v)
    # overflow bucket spans (bounds[-1], max]: p100 must hit max exactly
    assert h.percentile(100) == 30.0
    p75 = h.percentile(75)
    assert 2.0 <= p75 <= 30.0
    # clamping: every percentile stays inside [min, max]
    assert h.percentile(1) >= 0.5


def test_percentile_empty_and_from_dict_roundtrip():
    h = Histogram(bounds=(1.0, 2.0))
    assert h.percentile(50) is None
    h.observe(1.5)
    h.observe(0.2)
    back = Histogram.from_dict(json.loads(json.dumps(h.to_dict())))
    for q in (0, 25, 50, 99, 100):
        assert back.percentile(q) == pytest.approx(h.percentile(q))


# ---------------------------------------------------------------------------
# PhaseAcc mechanics + emission
# ---------------------------------------------------------------------------

def test_phase_acc_nesting_add_many_and_flush():
    with obs.tracing() as trc:
        p = trc.prof
        p.begin("a")
        p.begin("b")
        p.end()
        p.end()
        p.add("k", 0.25)                      # externally-timed, top level
        p.add_many(("a", "x"), 3, 0.5)        # folded hot-path accumulator
        p.add_many(("a", "x"), 0, 0.0)        # zero-count fold is a no-op
        p.flush(trc, engine="fast", mode="sync", wall=1.0, round=0)
        records = trc.records()
    ph = {r["path"]: r for r in of_kind(records, "phase")}
    assert set(ph) == {"a", "a/b", "a/x", "k"}
    assert ph["a/x"]["count"] == 3 and ph["a/x"]["total"] == 0.5
    assert ph["a"]["count"] == 1 and ph["a"]["total"] >= ph["a/b"]["total"]
    [pt] = of_kind(records, "phase_total")
    assert pt["wall"] == 1.0 and pt["round"] == 0
    # flush resets: a second flush with no activity emits only the total
    with obs.tracing() as trc2:
        trc2.prof.flush(trc2, engine="fast", mode="sync", wall=0.0, run=1)
        assert of_kind(trc2.records(), "phase") == []


@pytest.mark.parametrize("fast", [True, False])
def test_engine_emits_phase_records(fast):
    records = _trace_run("walker-kiruna", fast=fast)
    paths = {r["path"] for r in of_kind(records, "phase")}
    assert {"assign", "event_loop"} <= paths
    assert any(p.startswith("event_loop/") for p in paths)
    totals = of_kind(records, "phase_total")
    # 2 sync rounds + 1 async run, each flushed once
    assert len(totals) == 3
    assert all(t["wall"] > 0.0 for t in totals)
    assert {t["mode"] for t in totals} == {"sync", "async"}
    engine = "fast" if fast else "oracle"
    assert all(t["engine"] == engine for t in totals)


def test_phase_kinds_stay_out_of_diff_contract():
    # host timings are nondeterministic: phase records must never break
    # the fast-vs-oracle trace diff
    for kind in prof.PHASE_KINDS:
        assert kind not in DIFF_KINDS
    equal, report = obs.diff(_trace_run("walker-kiruna", fast=True),
                             _trace_run("walker-kiruna", fast=False))
    assert equal, report


# ---------------------------------------------------------------------------
# rollup math + attribution gate
# ---------------------------------------------------------------------------

def _fake_records():
    return [
        {"kind": "phase", "engine": "fast", "mode": "sync", "round": 0,
         "path": "a", "count": 1, "total": 0.6},
        {"kind": "phase", "engine": "fast", "mode": "sync", "round": 0,
         "path": "a/b", "count": 4, "total": 0.2},
        {"kind": "phase", "engine": "fast", "mode": "sync", "round": 0,
         "path": "c", "count": 1, "total": 0.2},
        {"kind": "phase", "engine": "fast", "mode": "sync", "round": 0,
         "path": "kernel.pack", "count": 2, "total": 5.0},
        {"kind": "phase_total", "engine": "fast", "mode": "sync",
         "round": 0, "wall": 1.0},
    ]


def test_collect_self_times_and_attribution_math():
    p = prof.collect(_fake_records())
    assert p["wall"] == 1.0 and p["units"] == 1
    selfs = prof.self_times(p["phases"])
    assert selfs["a"] == pytest.approx(0.4)     # total − direct child
    assert selfs["a/b"] == pytest.approx(0.2)
    att, frac = prof.attribution(p)
    # kernel.* roots are excluded from the attributed sum
    assert att == pytest.approx(0.8) and frac == pytest.approx(0.8)
    table = prof.render_profile(p, title="unit")
    assert "(unattributed residual)" in table
    assert "attributed 80.0%" in table
    assert "20.0%" in table                     # the residual row


def test_folded_stacks_format():
    text = prof.folded(prof.collect(_fake_records()))
    lines = text.strip().split("\n")
    # every line: semicolon-joined frames, space, integer µs
    assert all(re.fullmatch(r"[^ ]+ \d+", ln) for ln in lines)
    assert "a;b 200000" in lines
    assert "(unattributed) 200000" in lines     # 1.0 wall − 0.8 attributed


@pytest.mark.parametrize("fast", [True, False])
def test_mega1000_attribution_gate(fast):
    # the tentpole acceptance gate: ≥90% of round wall attributed to
    # named phases on mega-1000, sync AND async, both engines
    records = _trace_run("mega-1000", fast=fast, rounds=2, async_n=30,
                         seed=0)
    for mode in ("sync", "async"):
        sub = [r for r in records
               if r.get("kind") in prof.PHASE_KINDS and r["mode"] == mode]
        _, frac = prof.attribution(prof.collect(sub))
        assert frac >= 0.9, (
            f"{'fast' if fast else 'oracle'} {mode}: only {frac:.1%} "
            f"of wall attributed")
    _, overall = prof.attribution(prof.collect(records))
    assert overall >= 0.9


# ---------------------------------------------------------------------------
# perfdiff: localizing a seeded slowdown (acceptance criterion)
# ---------------------------------------------------------------------------

def test_perfdiff_localizes_seeded_commit_slowdown(monkeypatch):
    import time as _time

    from repro.sim import fastpath
    clean = _trace_run("walker-kiruna", fast=True, async_n=0)
    orig = fastpath.ChannelCache.commit

    def slow_commit(self, *a, **kw):
        _time.sleep(0.0005)
        return orig(self, *a, **kw)

    monkeypatch.setattr(fastpath.ChannelCache, "commit", slow_commit)
    slowed = _trace_run("walker-kiruna", fast=True, async_n=0)
    d = prof.perfdiff(clean, slowed, tol=0.2)
    assert d["offenders"], "seeded slowdown produced no offenders"
    worst = d["offenders"][0]
    assert worst["path"].endswith("tx_commit"), (
        f"slowdown attributed to {worst['path']!r}, not tx_commit")
    assert worst["ratio"] > 1.2
    text = prof.render_perfdiff(d)
    assert "top regressed phases" in text and "tx_commit" in text


def test_perfdiff_clean_pair_reports_no_offenders():
    a = _trace_run("walker-kiruna", fast=True, async_n=0)
    d = prof.perfdiff(a, a)
    assert d["offenders"] == []
    assert "no phase regressed beyond tolerance" in prof.render_perfdiff(d)


def test_compare_gate_failure_prints_perfdiff(tmp_path, capsys):
    from repro.bench import compare
    base, new = tmp_path / "base", tmp_path / "new"
    base.mkdir(), new.mkdir()
    doc = {"schema": 1, "tiny": True, "benchmarks": {
        "fast_round": {"speedup": {"value": 10.0, "gate": True,
                                   "higher_is_better": True}}}}
    (base / "BENCH_sim.json").write_text(json.dumps(doc))
    doc["benchmarks"]["fast_round"]["speedup"]["value"] = 1.0   # regressed
    (new / "BENCH_sim.json").write_text(json.dumps(doc))
    for d in (base, new):
        eng = Engine(get_scenario("walker-kiruna"), seed=0)
        with obs.tracing(str(d / "TRACE_wk.jsonl")):
            eng.run_round(0.0, MSG)
    assert compare.main([str(new), "--baseline", str(base)]) == 1
    out = capsys.readouterr().out
    assert "PERF GATE FAILED" in out
    assert "phase-level perfdiff for TRACE_wk.jsonl" in out


# ---------------------------------------------------------------------------
# chrome export on schema-v2 traces with series + phase spans (satellite 4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fast", [True, False])
def test_chrome_trace_with_series_and_phases(fast):
    eng = Engine(get_scenario("walker-kiruna"), seed=0, fast=fast)
    with obs.tracing(scenario="chrome-unit") as trc:
        eng.run_round(0.0, MSG)
        trc.series("e_K", 0, 2.5)
        trc.series("e_K", 1, float("nan"))      # must be skipped, not kept
        records = trc.records()
    doc = obs.chrome_trace(records)
    json.dumps(doc, allow_nan=False)            # strict-JSON loadable
    ev = doc["traceEvents"]
    prof_ev = [e for e in ev if e.get("pid") == 5 and e["ph"] == "X"]
    assert {e["cat"] for e in prof_ev} == {"phase", "phase_total"}
    # one synthetic-timeline slice per emitted phase path + the unit span
    assert len(prof_ev) == len(of_kind(records, "phase")) + 1
    # children nest inside their parents on the synthetic timeline
    by_path = {e["args"].get("path"): e for e in prof_ev
               if e["cat"] == "phase"}
    for path, e in by_path.items():
        if "/" in path:
            parent = by_path[path.rsplit("/", 1)[0]]
            assert e["ts"] >= parent["ts"]
            assert e["ts"] + e["dur"] <= parent["ts"] + parent["dur"] + 1e-6
    series_ev = [e for e in ev if e.get("pid") == 6 and e["ph"] == "C"]
    assert series_ev                             # engine curves + e_K
    e_k = [e for e in series_ev if e["name"] == "e_K"]
    assert len(e_k) == 1                         # NaN sample dropped
    assert e_k[0]["args"]["value"] == 2.5
    assert all(math.isfinite(e["args"]["value"]) for e in series_ev)


# ---------------------------------------------------------------------------
# zero-round summarize/watch regressions (satellite 2)
# ---------------------------------------------------------------------------

def test_summarize_header_only_trace(tmp_path):
    # a crashed run can leave just the header line behind
    p = tmp_path / "hdr.jsonl"
    p.write_text(json.dumps({"kind": "header", "schema": 2,
                             "scenario": "crashed"}) + "\n")
    records = obs.load(str(p))
    text = obs.summarize(records)
    assert "(no rounds recorded)" in text


def test_summarize_zero_round_trace():
    with obs.tracing(scenario="empty") as trc:
        records = trc.records()
    assert "(no rounds recorded)" in obs.summarize(records)


def test_watch_zero_round_trace_says_so(tmp_path):
    from repro.obs.report import watch
    p = str(tmp_path / "empty.jsonl")
    with obs.tracing(p, scenario="empty"):
        pass                                    # header + metrics only
    out = io.StringIO()
    assert watch(p, follow=False, out=out) == 0
    assert "no rounds recorded" in out.getvalue()


# ---------------------------------------------------------------------------
# bench history + regression-onset localization
# ---------------------------------------------------------------------------

def _bench_doc(speedup: float) -> dict:
    return {"schema": 1, "tiny": True, "benchmarks": {
        "fast_round": {
            "speedup": {"value": speedup, "gate": True,
                        "higher_is_better": True},
            "round_s": {"value": 0.01, "gate": False,
                        "higher_is_better": False}}}}


def test_bench_history_ingest_idempotent_and_onset(tmp_path):
    hist = str(tmp_path / "hist.jsonl")
    shas = ["aaa111", "bbb222", "ccc333"]
    for i, speedup in enumerate([10.0, 10.5, 6.0]):   # 3rd regresses >20%
        p = tmp_path / f"BENCH_sim_{i}.json"
        p.write_text(json.dumps(_bench_doc(speedup)))
        entry, added = prof.ingest_bench(str(p), hist, sha=shas[i])
        assert added and entry["group"] == f"sim_{i}"
    # re-ingest is a no-op (content-hashed entries)
    _, added = prof.ingest_bench(str(tmp_path / "BENCH_sim_0.json"), hist,
                                 sha="zzz999")
    assert not added
    entries = prof.load_history(hist)
    assert len(entries) == 3
    # the history treats each group independently; rebuild one group's
    # trajectory to exercise onset localization
    merged = [dict(e, group="sim") for e in entries]
    text = prof.render_history(merged, tol=0.2)
    assert "REGRESSION ONSET at emission #2 (git ccc333)" in text
    assert "6 vs best 10.5" in text
    # the ungated metric never flags even though it is flat
    assert text.count("REGRESSION ONSET") == 1


def test_onset_directionality():
    assert prof._onset([10.0, 10.5, 6.0], hib=True, tol=0.2) == 2
    assert prof._onset([10.0, 9.0, 8.5], hib=True, tol=0.2) is None
    assert prof._onset([1.0, 1.1, 1.5], hib=False, tol=0.2) == 2
    assert prof._onset([], hib=True, tol=0.2) is None


def test_render_history_empty():
    assert "empty" in prof.render_history([])


# ---------------------------------------------------------------------------
# CLI: prof / perfdiff / bench-history
# ---------------------------------------------------------------------------

def test_cli_prof_perfdiff_bench_history(tmp_path, capsys):
    from repro.obs.__main__ import main
    pa = str(tmp_path / "a.jsonl")
    eng = Engine(get_scenario("walker-kiruna"), seed=0)
    with obs.tracing(pa):
        t = eng.run_round(0.0, MSG).duration
        eng.run_round(t, MSG)

    flame = str(tmp_path / "a.folded")
    table = str(tmp_path / "a.txt")
    assert main(["prof", pa, "--flame", flame, "--out", table]) == 0
    assert "attributed" in capsys.readouterr().out
    assert "(unattributed residual)" in open(table).read()
    assert re.search(r"^event_loop", open(flame).read(), re.M)

    # the attribution gate: impossible threshold must exit 1
    assert main(["prof", pa, "--min-attribution", "1.5"]) == 1
    assert "ATTRIBUTION GATE FAILED" in capsys.readouterr().out
    assert main(["prof", pa, "--min-attribution", "0.1"]) == 0
    capsys.readouterr()

    assert main(["perfdiff", pa, pa]) == 0
    assert "no phase regressed" in capsys.readouterr().out

    bench = tmp_path / "BENCH_sim.json"
    bench.write_text(json.dumps(_bench_doc(10.0)))
    hist = str(tmp_path / "hist.jsonl")
    assert main(["bench-history", str(bench), "--history", hist,
                 "--sha", "abc123"]) == 0
    out = capsys.readouterr().out
    assert "ingested" in out and "bench history: 1 emission(s)" in out
    assert main(["bench-history", "--history", hist]) == 0
    assert "1 emission(s)" in capsys.readouterr().out
