"""attention backends agree: xla (oracle) vs chunked-scan vs unrolled."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attention_chunked,
                                    attention_chunked_unrolled, attention_xla)


def _qkv(s, h=4, hkv=2, d=32, b=2, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.arange(s)
    return q, k, v, pos


@pytest.mark.parametrize("s", [64, 130, 256])
@pytest.mark.parametrize("window", [None, 48])
def test_chunked_matches_xla(s, window):
    q, k, v, pos = _qkv(s)
    ref = attention_xla(q, k, v, pos, pos, window=window)
    out = attention_chunked(q, k, v, pos, pos, window=window,
                            chunk_q=32, chunk_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s", [64, 200])
@pytest.mark.parametrize("window", [None, 64])
def test_unrolled_matches_xla(s, window):
    q, k, v, pos = _qkv(s, seed=1)
    ref = attention_xla(q, k, v, pos, pos, window=window)
    out = attention_chunked_unrolled(q, k, v, pos, pos, window=window,
                                     chunk_q=64, chunk_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_softcap_consistency():
    q, k, v, pos = _qkv(96, seed=2)
    ref = attention_xla(q, k, v, pos, pos, softcap=30.0)
    out = attention_chunked(q, k, v, pos, pos, softcap=30.0,
                            chunk_q=32, chunk_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gqa_expansion_equivalence():
    """GQA with repeated KV == MHA with explicitly tiled KV."""
    q, k, v, pos = _qkv(64, h=4, hkv=1, seed=3)
    out_gqa = attention_xla(q, k, v, pos, pos)
    k4 = jnp.repeat(k, 4, axis=2)
    v4 = jnp.repeat(v, 4, axis=2)
    out_mha = attention_xla(q, k4, v4, pos, pos)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               rtol=1e-6, atol=1e-6)
