"""Fused compress→EF→pack pipeline kernel: oracle + unfused-path equivalence.

The contract (ISSUE 3 acceptance): the fused kernel's packed words are
BIT-EXACT vs both the pure-jnp oracle and the existing separate
quantize_ef → pack_bits path, for any shape/levels; the EF cache matches
the jitted oracle bit-exactly (and the eager oracle to 1 ulp — XLA may
FMA-fuse ``idx·Δ + vmin`` differently across jit boundaries).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (quantize_decode, quantize_encode,
                                    wire_index_bits)
from repro.kernels import ref
from repro.kernels.compress_pipeline import quant_pipeline, sign_pipeline
from repro.kernels.pack_bits import logical_words, pack_bits, unpack_bits
from repro.kernels.quantize_ef import quantize_ef


@pytest.mark.parametrize("shape", [(64,), (300,), (128, 257), (3, 100, 33),
                                   (70000,)])
@pytest.mark.parametrize("levels", [255, 1000, 10])
def test_quant_pipeline_matches_oracle(shape, levels):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    msg = jax.random.normal(k1, shape) * 0.3
    cache = jax.random.normal(k2, shape) * 0.01
    w, c = quant_pipeline(msg, cache, levels=levels, vmin=-0.5, vmax=0.5,
                          interpret=True)
    w_ref, c_ref = jax.jit(lambda m, cc: ref.quant_pipeline_ref(
        m, cc, levels=levels, vmin=-0.5, vmax=0.5))(msg, cache)
    assert np.array_equal(np.asarray(w), np.asarray(w_ref))
    assert np.array_equal(np.asarray(c), np.asarray(c_ref))
    # eager oracle: FMA fusion may flip exact lattice TIES by one level
    # (rare), shifting the cache by one step Δ — everything else is ulps
    _, c_eager = ref.quant_pipeline_ref(msg, cache, levels=levels,
                                        vmin=-0.5, vmax=0.5)
    delta = 1.0 / levels
    diff = np.abs(np.asarray(c) - np.asarray(c_eager))
    assert diff.max() <= delta + 2e-7
    assert (diff > 1e-6).mean() < 0.01


@pytest.mark.parametrize("shape", [(300,), (128, 257), (70000,)])
@pytest.mark.parametrize("levels", [255, 1000])
def test_quant_pipeline_matches_separate_path(shape, levels):
    """Words bit-exact vs the historical quantize_ef → pack_bits chain."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    msg = jax.random.normal(k1, shape) * 0.3
    cache = jax.random.normal(k2, shape) * 0.01
    w, c = quant_pipeline(msg, cache, levels=levels, vmin=-0.5, vmax=0.5,
                          interpret=True)
    wire, c_sep = quantize_ef(msg, cache, levels=levels, vmin=-0.5,
                              vmax=0.5, interpret=True)
    bits = wire_index_bits(levels)
    w_sep = pack_bits(wire, bits, interpret=True)
    assert np.array_equal(np.asarray(w), np.asarray(w_sep))
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_sep), atol=2e-7)


@pytest.mark.parametrize("levels", [255, 1000])
def test_quant_pipeline_decode_roundtrip(levels):
    """unpack+decode of the fused words reproduces the quantizer output,
    and decode + new_cache telescopes back to msg + cache (EF identity)."""
    n = 40000
    msg = jax.random.normal(jax.random.PRNGKey(2), (n,)) * 0.2
    cache = jnp.full((n,), 0.003)
    w, c = quant_pipeline(msg, cache, levels=levels, vmin=-0.5, vmax=0.5,
                          interpret=True)
    bits = wire_index_bits(levels)
    assert w.size >= logical_words(n, bits)
    idx = unpack_bits(w, bits, n, interpret=True)
    decoded = quantize_decode(idx, levels, -0.5, 0.5)
    expect = quantize_decode(
        quantize_encode(msg + cache, levels, -0.5, 0.5), levels, -0.5, 0.5)
    # exact lattice ties may flip one level across jit boundaries (FMA)
    diff = np.abs(np.asarray(decoded) - np.asarray(expect))
    assert diff.max() <= 1.0 / levels + 2e-7
    assert (diff > 1e-6).mean() < 0.01
    np.testing.assert_allclose(np.asarray(decoded + c),
                               np.asarray(msg + cache), atol=1e-5)


@pytest.mark.parametrize("shape", [(512,), (3, 100, 33), (70000,)])
def test_sign_pipeline_matches_oracle(shape):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    msg = jax.random.normal(k1, shape)
    cache = jax.random.normal(k2, shape) * 0.1
    w, s, c = sign_pipeline(msg, cache, interpret=True)
    w_ref, s_ref, c_ref = jax.jit(ref.sign_pipeline_ref)(msg, cache)
    assert np.array_equal(np.asarray(w), np.asarray(w_ref))
    np.testing.assert_allclose(float(s), float(s_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), atol=2e-7)
    # the packed bits ARE the sign patterns of msg + cache
    bit = unpack_bits(w, 1, msg.size, interpret=True)
    corrected = np.asarray(msg + cache).reshape(-1)
    assert np.array_equal(np.asarray(bit) == 1, corrected >= 0)


def test_deploy_round_fused_equals_unfused():
    """DeployFedLT(pack_wire=True): fuse_pipeline on/off give the same
    round (words are bit-identical, so state diverges only by FMA ulps)."""
    from repro.core.deploy import DeployFedLT
    from repro.data.synthetic import make_batch
    from repro.models.config import ModelConfig
    # vocab·d_model = 32768 ⇒ the embedding leaf is exactly one kernel
    # tile, engaging the fused path (smaller leaves keep the int gather)
    cfg = ModelConfig(name="fuse-test", arch_type="dense", n_layers=1,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab_size=512, max_seq=64, chunk_size=32,
                      tie_embeddings=True, dtype="float32")
    batch = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[make_batch(cfg, jax.random.fold_in(jax.random.PRNGKey(5), i),
                     2, 32) for i in range(2)])
    states = {}
    for fuse in (False, True):
        alg = DeployFedLT(cfg=cfg, n_epochs=1, gamma=0.05, rho=10.0,
                          compress=True, levels=255, vmin=-1.0, vmax=1.0,
                          pack_wire=True, fuse_pipeline=fuse)
        st = alg.init(jax.random.PRNGKey(0), 2)
        step = jax.jit(lambda s, b, a=alg: a.round_step(s, b))
        for _ in range(2):
            st, _ = step(st, batch)
        states[fuse] = st
    for a, b in zip(jax.tree_util.tree_leaves(states[False]),
                    jax.tree_util.tree_leaves(states[True])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
