"""Constellation substrate: orbital mechanics sanity + scheduler behaviour."""
import numpy as np

from repro.constellation.links import LinkModel, message_bytes
from repro.constellation.orbits import (GroundStation, Walker, elevation,
                                        in_plane_neighbors, visible)
from repro.constellation.scheduler import Scheduler


def test_orbit_radius_and_period():
    w = Walker(altitude=550e3)
    # LEO at 550 km: ~95-96 min period
    assert 90 * 60 < w.period < 100 * 60
    pos = w.positions(np.array([0.0, 60.0]))
    r = np.linalg.norm(pos, axis=-1)
    np.testing.assert_allclose(r, w.radius, rtol=1e-9)


def test_visibility_windows_are_sparse_and_periodic():
    w, gs = Walker(), GroundStation()
    ts = np.arange(0, w.period * 2, 30.0)
    vis = visible(w, gs, ts)
    frac = vis.mean()
    assert 0.0 < frac < 0.25  # sparse windows — the paper's premise
    # every satellite is visible at least once over 2 orbits (polar GS,
    # sun-synchronous constellation)
    assert vis.any(axis=0).mean() > 0.5


def test_elevation_bounds():
    w, gs = Walker(), GroundStation()
    el = elevation(w.positions(np.array([0.0])), gs.position(np.array([0.0])))
    assert np.all(el <= 90.0) and np.all(el >= -90.0)


def test_in_plane_neighbors_ring():
    w = Walker(n_sats=100, n_planes=10)
    a, b = in_plane_neighbors(w, 0)
    assert a == 9 and b == 1  # ring within plane 0 (slots 0..9)
    a, b = in_plane_neighbors(w, 15)
    assert a == 14 and b == 16


def test_scheduler_selects_bounded_active_set():
    w, gs = Walker(), GroundStation()
    s = Scheduler(w, gs, k_direct=4, n_relay=2)
    mask, duration = s.select(0.0, message_bytes(10000, 10.0))
    assert mask.sum() <= 4 * 3  # direct + ≤2 relays each
    assert mask.sum() >= 1
    assert duration > 0


def test_scheduler_progresses_over_time():
    w, gs = Walker(), GroundStation()
    s = Scheduler(w, gs, k_direct=3, n_relay=1)
    masks = []
    t = 0.0
    for _ in range(4):
        m, d = s.select(t, 1e5)
        masks.append(m)
        t += d
    union = np.any(masks, axis=0)
    assert union.sum() > masks[0].sum()  # different sats get scheduled


def test_scheduler_empty_active_set_when_nothing_visible():
    """A GS that sees nothing (mask angle ≈ 90°) yields an empty round:
    no active satellites, but time still advances by the idle duration."""
    w = Walker(n_sats=20, n_planes=4)
    s = Scheduler(w, GroundStation(mask_angle=89.9), k_direct=4,
                  lookahead=3600.0)
    mask, duration = s.select(0.0, 1e5)
    assert mask.sum() == 0
    assert duration > 0


def test_link_model_monotone():
    lm = LinkModel()
    assert lm.gs_time(2e6) > lm.gs_time(1e6)
    assert lm.isl_time(1e6, hops=2) > lm.isl_time(1e6, hops=1)
    # compression reduces wire time proportionally
    assert message_bytes(1000, 8.0) == 0.25 * message_bytes(1000, 32.0)
