"""The §Perf-iteration sharding constraints must not change MoE numerics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_variant
from repro.launch.mesh import make_host_mesh
from repro.models.moe import init_moe, moe_capacity


def test_act_batch_axis_constraint_is_numerically_neutral():
    cfg = dataclasses.replace(smoke_variant(ARCHS["mixtral-8x7b"]),
                              moe_dispatch="capacity")
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model)) * 0.5

    y_plain, aux_plain = jax.jit(
        lambda p, xx: moe_capacity(p, xx, cfg))(params, x)

    cfg_wsc = dataclasses.replace(cfg, act_batch_axis="data")
    mesh = make_host_mesh(data=1, model=1)
    with mesh:
        y_wsc, aux_wsc = jax.jit(
            lambda p, xx: moe_capacity(p, xx, cfg_wsc))(params, x)

    np.testing.assert_allclose(np.asarray(y_plain), np.asarray(y_wsc),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(aux_plain), float(aux_wsc), rtol=1e-6)


def test_capacity_gradients_flow_to_router():
    """stop-gradient on the dispatch one-hot must NOT cut router training."""
    cfg = dataclasses.replace(smoke_variant(ARCHS["mixtral-8x7b"]),
                              moe_dispatch="capacity")
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.d_model)) * 0.5

    def loss(p):
        y, aux = moe_capacity(p, x, cfg)
        return jnp.sum(y * y) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.linalg.norm(g["router"])) > 0   # combine-weight path
    assert float(jnp.linalg.norm(g["up"])) > 0
    assert float(jnp.linalg.norm(g["down"])) > 0
