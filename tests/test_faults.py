"""Fault injection (repro.faults): deterministic draws, fast-vs-oracle
bit-equivalence on fault event streams, head failover, crash-vs-erasure
EF semantics, quorum deadlines, and crash-consistent run recovery.

The contracts under test (ISSUE 10):

* fault draws are counter-based — order-independent, identical across
  engines, stable under contact-plan horizon extension;
* ``Engine(fast=True)`` and ``Engine(fast=False)`` produce bit-identical
  Delivery AND fault/head_failover event streams on every chaos
  scenario (checked via obs trace-diff, not just list comparison);
* a crash wipes the EF residual (``resync_cache``), an erasure keeps it;
* a round closed by its quorum deadline aggregates only the survivors
  (survivors ⊆ attempted, quorum_frac ∈ [0, 1]);
* a run killed mid-way resumes from the newest *intact* checkpoint with
  bit-identical e_K / bytes_up curves.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.constellation.links import message_bytes
from repro.faults import (FaultModel, describe_faults, quorum_close_time,
                          time_key)
from repro.sim import Engine, get_scenario
from repro.sim.engine import RoundResult

MSG = message_bytes(10000, 10.0)
CHAOS_SYNC = ["chaos-direct", "chaos-plane", "chaos-lossy"]


# ---------------------------------------------------------------------------
# draw determinism
# ---------------------------------------------------------------------------

def test_crash_draws_order_independent():
    """Vectorized == per-element scalar == shuffled: the draw depends on
    (seed, sat, bits(t_start)) only, never on array position."""
    fm = FaultModel(crash_rate=0.3)
    rng = np.random.default_rng(0)
    sats = rng.integers(0, 1000, size=64)
    t_st = rng.uniform(0.0, 1e6, size=64)
    exp = rng.uniform(1.0, 600.0, size=64)
    vec = fm.crash_mask(7, sats, t_st, exp)
    one = np.array([bool(fm.crash_mask(7, np.array([s]), np.array([t]),
                                       np.array([e]))[0])
                    for s, t, e in zip(sats, t_st, exp)])
    np.testing.assert_array_equal(vec, one)
    perm = rng.permutation(64)
    np.testing.assert_array_equal(
        fm.crash_mask(7, sats[perm], t_st[perm], exp[perm]), vec[perm])
    # distinct seeds / salts decorrelate
    assert not np.array_equal(vec, fm.crash_mask(8, sats, t_st, exp))
    fm2 = dataclasses.replace(fm, salt=fm.salt + 1)
    assert not np.array_equal(vec, fm2.crash_mask(7, sats, t_st, exp))


def test_crash_prob_model():
    fm = FaultModel(crash_rate=0.1, crash_mtbf=1e5)
    p = fm.crash_prob(np.array([0.0, 100.0, 1e5, 1e9]))
    assert p[0] == pytest.approx(0.1)
    assert np.all(np.diff(p) > 0) and p[-1] < 1.0 + 1e-12
    assert not FaultModel().crashes_enabled
    assert not FaultModel().active
    assert FaultModel(crash_mtbf=1e6).crashes_enabled


def test_station_dark_slot_keyed():
    """All queries inside one slot agree; disjoint slots draw afresh;
    extension (appending later times) never disturbs earlier draws."""
    fm = FaultModel(gs_outage_rate=0.4, gs_outage_duration=600.0)
    t = np.arange(0.0, 60000.0, 30.0)
    dark = fm.station_dark(3, 0, t)
    slots = np.floor(t / 600.0).astype(int)
    for s in np.unique(slots):
        assert len(set(dark[slots == s].tolist())) == 1, s
    t_ext = np.arange(0.0, 120000.0, 30.0)
    np.testing.assert_array_equal(fm.station_dark(3, 0, t_ext)[:len(t)],
                                  dark)
    assert not fm.station_dark(3, 0, np.array([np.nan, np.inf])).any()
    assert 0.1 < dark.mean() < 0.8       # the rate actually bites


def test_blocked_mask_stable_under_plan_extension():
    """GS-outage blocking (engine ``_blocked``) must be a pure function
    of the window rise times — extending the contact-plan horizon
    appends new windows without re-rolling old draws."""
    sc = get_scenario("chaos-direct")
    eng = Engine(sc, seed=2)
    before = [b.copy() for b in eng._blocked]
    finites = [np.isfinite(r) for r in eng.plan.rises]
    eng.plan.ensure(3.0 * eng.plan.horizon)
    eng._refresh_blocked()
    for g, (old, fin) in enumerate(zip(before, finites)):
        # extension may back-fill former NaN padding slots with NEW
        # windows; every window that existed before must keep its draw
        w = old.shape[1]
        np.testing.assert_array_equal(eng._blocked[g][:, :w][fin],
                                      old[fin])
    # and the mask really is dark where the fault model says so
    fm, rises = sc.faults, eng.plan.rises[0]
    finite = np.isfinite(rises)
    dark = fm.station_dark(2, 0, np.where(finite, rises, 0.0)) & finite
    assert (eng._blocked[0][:rises.shape[0], :rises.shape[1]] & dark).sum() \
        == dark.sum()


def test_fault_draw_properties_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    fm = FaultModel(crash_rate=0.25, gs_outage_rate=0.3)

    @hyp.given(st.lists(st.tuples(st.integers(0, 10000),
                                  st.floats(0.0, 1e8, allow_nan=False),
                                  st.floats(0.0, 1e4, allow_nan=False)),
                        min_size=1, max_size=50),
               st.randoms())
    @hyp.settings(deadline=None, max_examples=100)
    def check(flights, rnd):
        sats = np.array([f[0] for f in flights])
        ts = np.array([f[1] for f in flights])
        ex = np.array([f[2] for f in flights])
        vec = fm.crash_mask(11, sats, ts, ex)
        idx = list(range(len(flights)))
        rnd.shuffle(idx)
        idx = np.array(idx)
        np.testing.assert_array_equal(
            fm.crash_mask(11, sats[idx], ts[idx], ex[idx]), vec[idx])
        # stability under extension: appending flights changes nothing
        ext = fm.crash_mask(11, np.concatenate([sats, sats[:1]]),
                            np.concatenate([ts, ts[:1] + 1.0]),
                            np.concatenate([ex, ex[:1]]))
        np.testing.assert_array_equal(ext[:len(flights)], vec)

    check()


def test_quorum_close_time_invariants():
    # quorum met inside the deadline → closes exactly at the deadline
    landed = [(10.0, 1), (20.0, 1), (30.0, 1), (500.0, 1)]
    assert quorum_close_time(0.0, 100.0, 0.75, landed, 4) == 100.0
    # quorum NOT met by the deadline → extends to the completing landing
    assert quorum_close_time(0.0, 15.0, 0.75, landed, 4) == 30.0
    assert quorum_close_time(0.0, 15.0, 1.0, landed, 4) == 500.0
    # quorum unreachable → the last landing (nothing more will arrive)
    assert quorum_close_time(0.0, 15.0, 1.0, landed[:2], 4) == 20.0
    # no quorum requirement → plain deadline
    assert quorum_close_time(0.0, 15.0, 0.0, [], 4) == 15.0
    assert quorum_close_time(0.0, 15.0, 0.9, [], 0) == 15.0


def test_quorum_close_time_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hyp.given(st.floats(0.0, 1e6, allow_nan=False),
               st.floats(1.0, 1e5, allow_nan=False),
               st.floats(0.0, 1.0, allow_nan=False),
               st.lists(st.tuples(st.floats(0.0, 1e6, allow_nan=False),
                                  st.integers(1, 50)), max_size=30),
               st.integers(0, 100))
    @hyp.settings(deadline=None, max_examples=200)
    def check(t0, dl, q, rel_landed, n_att):
        landed = [(t0 + dt, w) for dt, w in rel_landed]
        t_close = quorum_close_time(t0, dl, q, landed, n_att)
        # never closes before the deadline, never after the last landing
        assert t_close >= t0 + dl - 1e-9
        assert t_close <= max([t0 + dl] + [t for t, _ in landed]) + 1e-9
        # the landed weight by t_close reaches quorum whenever possible
        need = int(np.ceil(q * n_att))
        total = sum(w for _, w in landed)
        by_close = sum(w for t, w in landed if t <= t_close + 1e-9)
        if total >= need:
            assert by_close >= min(need, total)

    check()


def test_describe_labels():
    assert describe_faults(None) == "none"
    assert describe_faults(FaultModel()) == "none"
    lab = describe_faults(FaultModel(crash_rate=0.05, gs_outage_rate=0.2,
                                     head_failure_rate=0.3))
    assert lab == "crash0.05-gs0.2x1800-head0.3"
    assert time_key(1.5).dtype == np.uint64
    with pytest.raises(ValueError, match="crash_rate"):
        FaultModel(crash_rate=1.0)
    with pytest.raises(ValueError, match="gs_outage_rate"):
        FaultModel(gs_outage_rate=-0.1)


# ---------------------------------------------------------------------------
# fast vs oracle: fault streams are part of the equivalence contract
# ---------------------------------------------------------------------------

def _traced_rounds(name, fast, n=3, seed=1):
    from repro.obs import tracing
    eng = Engine(get_scenario(name), seed=seed, fast=fast)
    with tracing() as trc:
        t = 0.0
        results = []
        for _ in range(n):
            res = eng.run_round(t, MSG)
            results.append(res)
            t += res.duration
        return results, trc.records()


@pytest.mark.parametrize("name", CHAOS_SYNC)
def test_chaos_sync_bit_for_bit(name):
    from repro.obs.summary import diff
    rs_f, trace_f = _traced_rounds(name, fast=True)
    rs_o, trace_o = _traced_rounds(name, fast=False)
    for rf, ro in zip(rs_f, rs_o):
        assert rf.to_dict() == ro.to_dict(), name
    equal, report = diff(trace_f, trace_o)
    assert equal, f"{name}: {report}"
    # the scenario actually injects something
    assert any(r.get("kind") == "fault" for r in trace_f), name


def test_chaos_plane_failover_fires_and_diffs_clean():
    """Head failovers are part of the diffed stream; their event fields
    are structurally consistent with the round result."""
    rs, trace = _traced_rounds("chaos-plane", fast=True, n=4)
    evs = [r for r in trace if r.get("kind") == "head_failover"]
    assert evs, "head_failure_rate=0.3 over 10 planes × 4 rounds"
    spp = get_scenario("chaos-plane").walker.n_sats // \
        get_scenario("chaos-plane").walker.n_planes
    for ev in evs:
        assert ev["new_head"] is None or ev["new_head"] != ev["head"]
        assert 0 <= ev["n_lost"] + ev["n_salvaged"] <= spp
        assert ev["t_detect"] >= ev["t_fail"]
    for res in rs:
        if res.failovers:
            assert res.crashed is not None
            for ev in res.failovers:
                assert res.crashed[ev["head"]]       # dead head = crash
        if res.aborted is not None:
            # aborted sats never delivered anything this round
            assert not (res.aborted & res.mask).any()


@pytest.mark.parametrize("name", ["chaos-direct", "chaos-lossy"])
def test_chaos_async_bit_for_bit(name):
    d_f = Engine(get_scenario(name), seed=1).run_async(
        0.0, MSG, n_deliveries=40)
    d_o = Engine(get_scenario(name), seed=1, fast=False).run_async(
        0.0, MSG, n_deliveries=40)
    assert d_f == d_o, name
    assert any(not d.delivered for d in d_f), name


def test_round_result_fault_fields_roundtrip():
    res = Engine(get_scenario("chaos-plane"), seed=1).run_round(0.0, MSG)
    back = RoundResult.from_dict(res.to_dict())
    assert back.to_dict() == res.to_dict()
    if res.crashed is not None:
        np.testing.assert_array_equal(back.crashed, res.crashed)
    # a fault-free scenario round still roundtrips (fields absent)
    res0 = Engine(get_scenario("walker-kiruna"), seed=1).run_round(0.0, MSG)
    d0 = res0.to_dict()
    assert "crashed" not in d0 and "faults" not in d0
    assert RoundResult.from_dict(d0).crashed is None


def test_gossip_head_failure_rejected():
    sc = dataclasses.replace(get_scenario("plane-agg-gossip"),
                             faults=FaultModel(head_failure_rate=0.5))
    with pytest.raises(ValueError, match="gossip"):
        Engine(sc)
    eng = Engine(get_scenario("plane-agg-gossip"))
    with pytest.raises(ValueError, match="gossip"):
        eng.install_faults(FaultModel(head_failure_rate=0.5))
    eng.install_faults(FaultModel(crash_rate=0.1))    # crashes are fine


# ---------------------------------------------------------------------------
# crash vs erasure EF semantics; quorum aggregation in the runner
# ---------------------------------------------------------------------------

def test_resync_cache_zeroes_crashed_rows_only():
    from repro.core.error_feedback import resync_cache
    cache = {"a": jnp.arange(12.0).reshape(4, 3),
             "b": jnp.ones((4, 2, 2))}
    crashed = np.array([False, True, False, True])
    out = resync_cache(cache, crashed)
    np.testing.assert_array_equal(np.asarray(out["a"][1]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["a"][3]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["a"][0]),
                                  np.asarray(cache["a"][0]))
    np.testing.assert_array_equal(np.asarray(out["b"][2]),
                                  np.asarray(cache["b"][2]))


DIM = 12


def _problem(n_agents=100):
    from repro.core.compression import UniformQuantizer
    from repro.core.error_feedback import EFChannel
    from repro.core.fedlt import FedLT
    from repro.data.logistic import generate, make_local_loss
    q = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=16,
                       dim=DIM)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    alg = FedLT(loss=loss, n_epochs=1, gamma=0.005, rho=20.0,
                uplink=EFChannel(q), downlink=EFChannel(q))
    return data, alg, q


def test_runner_crash_resync_and_quorum_series():
    """An end-to-end chaos run: ef_resync events fire for crashes, the
    survivors/quorum_frac series obey their invariants, and ledger meta
    carries the fault label."""
    from repro.api import Experiment
    data, alg, q = _problem()
    exp = Experiment("chaos-direct", alg, compressor=q,
                     deadline=1200.0, quorum=0.5)
    assert exp.ledger_meta()["faults"] == "crash0.08-gs0.15x1800"
    assert exp.ledger_meta()["quorum"] == 0.5
    st = exp.init(jnp.zeros((DIM,)), 100)
    res = exp.run(st, data, 6, jax.random.PRNGKey(1), trace=True)
    kinds = [r.get("kind") for r in res.records]
    assert "fault" in kinds and "ef_resync" in kinds
    surv = {r["step"]: r["value"] for r in res.records
            if r.get("kind") == "series" and r.get("name") == "survivors"}
    qf = {r["step"]: r["value"] for r in res.records
          if r.get("kind") == "series" and r.get("name") == "quorum_frac"}
    att = {r["round"]: r["n_active"] + r["n_lost"] for r in res.records
           if r.get("kind") == "fl_round"}
    assert set(surv) == set(range(6)) == set(qf)
    for k in surv:
        assert 0 <= surv[k] <= att[k]                # survivors ⊆ attempted
        assert 0.0 <= qf[k] <= 1.0
    # crashes actually removed someone at least once in 6 rounds
    assert any(surv[k] < att[k] for k in surv)


def test_deadline_closes_round_and_folds_stragglers():
    """hetero-compute (15–60 s spread) under a 40 s deadline: slow sats
    become stragglers, the round's time advance is capped near the
    deadline, and (loss-robust) nothing diverges."""
    from repro.api import Experiment
    data, alg, q = _problem()

    def run(deadline, quorum):
        exp = Experiment("hetero-compute", alg, compressor=q,
                         deadline=deadline, quorum=quorum)
        st = exp.init(jnp.zeros((DIM,)), 100)
        return exp.run(st, data, 4, jax.random.PRNGKey(1)).logs

    base = run(None, 0.0)
    dead = run(40.0, 0.25)
    assert dead[-1].time < base[-1].time             # rounds close earlier
    assert sum(l.n_lost for l in dead) > sum(l.n_lost for l in base)
    assert all(np.isfinite(l.bytes_up) for l in dead)


def test_deadline_async_rejected():
    from repro.core.fedlt_sat import SpaceRunner
    with pytest.raises(ValueError, match="sync-only"):
        SpaceRunner(Engine(get_scenario("walker-kiruna")), mode="async",
                    deadline=100.0)
    with pytest.raises(ValueError, match="quorum"):
        SpaceRunner(Engine(get_scenario("walker-kiruna")), quorum=1.5)


# ---------------------------------------------------------------------------
# crash-consistent recovery
# ---------------------------------------------------------------------------

def test_checkpoint_corruption_detected(tmp_path):
    from repro.checkpoint.store import (latest_valid_step, restore, save,
                                        verify)
    tree = {"a": jnp.arange(6, dtype=jnp.float32)}
    good = str(tmp_path / "ck_000001")
    bad = str(tmp_path / "ck_000002")
    save(good, tree, step=1)
    save(bad, tree, step=2)
    assert verify(good) and verify(bad)
    with open(bad + ".npz", "r+b") as f:         # flip bytes mid-file
        f.seek(30)
        f.write(b"\xde\xad\xbe\xef")
    assert not verify(bad)
    with pytest.raises(ValueError, match="corrupt checkpoint"):
        restore(bad, tree)
    # recovery skips the corrupt step and falls back to the intact one
    assert latest_valid_step(str(tmp_path), prefix="ck_") == 1
    out = restore(good, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_missing_meta_skipped(tmp_path):
    from repro.checkpoint.store import latest_valid_step, save
    save(str(tmp_path / "r_000003"), {"a": jnp.zeros((2,))}, step=3)
    save(str(tmp_path / "r_000005"), {"a": jnp.zeros((2,))}, step=5)
    os.remove(str(tmp_path / "r_000005") + ".meta.json")
    assert latest_valid_step(str(tmp_path), prefix="r_") == 3
    (tmp_path / "r_000007.meta.json").write_text("{not json")
    assert latest_valid_step(str(tmp_path), prefix="r_") == 3


def test_kill_mid_run_resume_bit_identical(tmp_path):
    """The tentpole recovery contract: run A checkpoints every round and
    'crashes' (we corrupt its newest checkpoint, as a writer killed
    mid-save would); run B resumes and must complete with e_K /
    bytes_up / time curves bit-identical to an uninterrupted run —
    including the replayed series in its trace."""
    from repro.api import Experiment
    from repro.core.fedlt import optimality_error
    from repro.data.logistic import solve_global
    data, alg, q = _problem()
    x_star = solve_global(data, eps=50.0)
    err = lambda s: float(optimality_error(s.x, x_star))  # noqa: E731

    def exp():
        return Experiment("chaos-lossy", alg, compressor=q,
                          deadline=1200.0, quorum=0.5)

    st0 = exp().init(jnp.zeros((DIM,)), 100)
    full = exp().run(st0, data, 6, jax.random.PRNGKey(1), error_fn=err,
                     log_every=1)

    ck = str(tmp_path / "ck")
    exp().run(st0, data, 6, jax.random.PRNGKey(1), error_fn=err,
              log_every=1, checkpoint=ck)
    # kill: the newest checkpoint is torn mid-write
    from repro.checkpoint.store import latest_valid_step
    newest = latest_valid_step(ck, prefix="round_")
    assert newest == 6
    with open(os.path.join(ck, f"round_{newest:06d}.npz"), "r+b") as f:
        f.seek(20)
        f.write(b"\x00\x00\x00\x00")
    res = exp().run(st0, data, 6, jax.random.PRNGKey(1), error_fn=err,
                    log_every=1, checkpoint=ck, resume=True, trace=True)
    assert [dataclasses.asdict(l) for l in res.logs] == \
        [dataclasses.asdict(l) for l in full.logs]
    np.testing.assert_array_equal(np.asarray(res.state.x),
                                  np.asarray(full.state.x))
    np.testing.assert_array_equal(np.asarray(res.state.c_up),
                                  np.asarray(full.state.c_up))
    # the resumed trace replayed the prefix: full e_K series, resume mark
    assert any(r.get("kind") == "resume" for r in res.records)
    ek = [r for r in res.records
          if r.get("kind") == "series" and r.get("name") == "e_K"]
    assert [r["step"] for r in ek] == list(range(6))
    assert [r["value"] for r in ek] == [l.error for l in full.logs]


def test_resume_without_checkpoint_dir_rejected():
    from repro.api import Experiment
    data, alg, q = _problem()
    exp = Experiment("walker-kiruna", alg, compressor=q)
    st = exp.init(jnp.zeros((DIM,)), 100)
    with pytest.raises(ValueError, match="checkpoint"):
        exp.run(st, data, 2, jax.random.PRNGKey(1), resume=True)


# ---------------------------------------------------------------------------
# truncated-trace tolerance (obs readers survive a killed writer)
# ---------------------------------------------------------------------------

def test_trace_load_tolerates_truncated_final_line(tmp_path):
    from repro.obs.trace import load
    path = str(tmp_path / "t.jsonl")
    rnd = dict(kind="round", duration=60.0, n_scheduled=4, n_delivered=4,
               n_lost=0, bytes_air=100.0, engine="fast")
    recs = [{"kind": "header", "schema": 2, "n_events": 2},
            {"round": 0, "t0": 0.0, **rnd},
            {"round": 1, "t0": 60.0, **rnd}]
    body = "".join(json.dumps(r) + "\n" for r in recs)
    with open(path, "w") as f:
        f.write(body[:-25])                  # killed mid-append
    with pytest.warns(UserWarning, match="truncated final record"):
        out = load(path)
    assert out == recs[:2]
    # a malformed line mid-file is real corruption — still raises
    with open(path, "w") as f:
        f.write(json.dumps(recs[0]) + "\n{broken\n"
                + json.dumps(recs[1]) + "\n")
    with pytest.raises(json.JSONDecodeError):
        load(path)
    # summarize survives the truncated file end-to-end
    from repro.obs.summary import summarize
    with open(path, "w") as f:
        f.write(body[:-25])
    with pytest.warns(UserWarning):
        assert "round" in summarize(load(path))
