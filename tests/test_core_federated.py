"""Behaviour tests for the federated core: convergence, EF, baselines.

These validate the paper's central claims at reduced scale:
  * Fed-LT converges exactly without compression (Prop. 1 with δ=1).
  * Error feedback improves the asymptotic optimality error under coarse
    quantization (Table 1).
  * Coarser compression ⇒ larger asymptotic error (§3.1 remark).
  * Baselines behave as in Table 2 (FedAvg/FedProx drift floor; 5GCS exact;
    LED exact at full participation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.baselines import LED, FedAvg, FedProx, FiveGCS
from repro.core.compression import Identity, UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.data.logistic import generate, make_local_loss, solve_global

N, M, D = 30, 150, 30


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    data, _ = generate(key, n_agents=N, m=M, dim=D)
    loss = make_local_loss(eps=50.0, n_agents=N)
    xbar = solve_global(data, eps=50.0)
    return data, loss, xbar


def _run_fedlt(problem, uplink, downlink, rounds, participation=1.0,
               gamma=0.05, rho=0.5, n_epochs=10):
    data, loss, xbar = problem
    alg = FedLT(loss=loss, n_epochs=n_epochs, gamma=gamma, rho=rho,
                uplink=uplink, downlink=downlink)
    st = alg.init(jnp.zeros((D,)), N)
    st, _ = jax.jit(
        lambda s: alg.run(s, data, rounds, jax.random.PRNGKey(1),
                          participation=participation))(st)
    return float(optimality_error(st.x, xbar)), st


def test_fedlt_exact_convergence_no_compression(problem):
    err, _ = _run_fedlt(problem, EFChannel(Identity()), EFChannel(Identity()), 200)
    assert err < 1e-8


def test_fedlt_partial_participation_converges(problem):
    err, _ = _run_fedlt(problem, EFChannel(Identity()), EFChannel(Identity()),
                        400, participation=0.5)
    assert err < 1e-6


def test_fedlt_state_no_nans_under_coarse_quantization(problem):
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    err, st = _run_fedlt(problem, EFChannel(C), EFChannel(C), 100)
    for leaf in jax.tree_util.tree_leaves(st):
        assert jnp.all(jnp.isfinite(leaf))


def test_error_feedback_improves_asymptotic_error(problem):
    """Paper Table 1: Algorithm 2 (EF) beats Algorithm 1 (no EF).

    Tuned in the slow local-training regime where the closed loop low-passes
    the EF-induced dither (see EXPERIMENTS.md §Table-1 for the analysis).
    """
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    kw = dict(rounds=600, gamma=0.002, rho=10.0)
    err_noef, _ = _run_fedlt(problem, EFChannel(C, enabled=False),
                             EFChannel(C, enabled=False), **kw)
    err_ef, _ = _run_fedlt(problem, EFChannel(C, enabled=True),
                           EFChannel(C, enabled=True), **kw)
    assert err_ef < err_noef


def test_coarser_quantization_larger_error(problem):
    kw = dict(rounds=400, gamma=0.002, rho=10.0)
    C_fine = UniformQuantizer(levels=1000, vmin=-10, vmax=10, clip=True)
    C_coarse = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    err_fine, _ = _run_fedlt(problem, EFChannel(C_fine), EFChannel(C_fine), **kw)
    err_coarse, _ = _run_fedlt(problem, EFChannel(C_coarse), EFChannel(C_coarse), **kw)
    assert err_fine < err_coarse


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def _run_baseline(problem, alg, rounds, participation=1.0):
    data, loss, xbar = problem
    st = alg.init(jnp.zeros((D,)), N)
    st, _ = jax.jit(
        lambda s: alg.run(s, data, rounds, jax.random.PRNGKey(2),
                          participation=participation))(st)
    return float(optimality_error(st.x, xbar))


def test_fedavg_has_client_drift_floor(problem):
    data, loss, xbar = problem
    err = _run_baseline(problem, FedAvg(loss=loss, n_epochs=10, gamma=0.05), 300)
    assert 1e-4 < err < 10.0  # converges to a biased neighbourhood


def test_fedlt_beats_fedavg_uncompressed(problem):
    data, loss, _ = problem
    err_avg = _run_baseline(problem, FedAvg(loss=loss, n_epochs=10, gamma=0.05), 300)
    err_lt, _ = _run_fedlt(problem, EFChannel(Identity()), EFChannel(Identity()), 300)
    assert err_lt < err_avg


def test_fedprox_reduces_drift_vs_fedavg(problem):
    data, loss, _ = problem
    err_avg = _run_baseline(problem, FedAvg(loss=loss, n_epochs=10, gamma=0.05), 300)
    err_prox = _run_baseline(
        problem, FedProx(loss, n_epochs=10, gamma=0.05, prox_mu=1.0), 300)
    assert err_prox < err_avg


def test_5gcs_exact_convergence(problem):
    data, loss, _ = problem
    alg = FiveGCS(loss=loss, n_epochs=10, gamma=0.05, gamma_p=1.0)
    assert _run_baseline(problem, alg, 400) < 1e-8
    assert _run_baseline(problem, alg, 500, participation=0.5) < 1e-6


def test_led_exact_at_full_participation(problem):
    data, loss, _ = problem
    alg = LED(loss=loss, n_epochs=10, gamma=0.01)
    assert _run_baseline(problem, alg, 600) < 1e-3
