"""Fast batch-event core vs the heapq oracle: bit-for-bit equivalence.

The fast path's acceptance contract (ISSUE 5): for any scenario and
fixed seed, ``Engine(fast=True)`` must reproduce ``Engine(fast=False)``'s
``Delivery`` timeline — every field of every record, in order, bit for
bit — across sync and async modes, lossless and every lossy channel.
Equivalence is the test; speed is the feature (``bench_fast_round``).

Also covered here: the supporting layers the fast path leans on keep
their own exactness contracts — the fused visibility grid vs the
reference elevation threshold, incremental contact-plan extension vs a
from-scratch rebuild, replayable ARQ plans vs the windowed transmit
state machine, and the translation-symmetric BFS neighborhoods vs the
oracle's literal per-satellite search.
"""
import dataclasses

import numpy as np
import pytest

from repro.channel import ChannelModel, LinkBudget, SelectiveRepeatARQ
from repro.constellation.links import LinkModel, message_bytes
from repro.constellation.orbits import (GroundStation, Walker, visible,
                                        visibility_grid)
from repro.sim import ContactPlan, Engine, Scenario, get_scenario

MSG = message_bytes(10000, 10.0)

SYNC_SCENARIOS = ["walker-kiruna", "dual-station", "weather-dropout",
                  "hetero-compute", "lossy-uplink", "rain-fade",
                  "ka-band-degraded", "conjunction-outage",
                  "chaos-direct", "chaos-lossy"]
ASYNC_SCENARIOS = ["walker-kiruna", "lossy-uplink", "rain-fade",
                   "conjunction-outage", "chaos-direct", "chaos-lossy"]


# Delivery is an eq dataclass: == compares every field, including any a
# future PR adds.  Engine-produced records always carry finite windows
# (asserted in test_sim_engine), so NaN can't defeat the comparison.


@pytest.mark.parametrize("name", SYNC_SCENARIOS)
def test_sync_rounds_bit_for_bit(name):
    eng_f = Engine(get_scenario(name), seed=1, fast=True)
    eng_o = Engine(get_scenario(name), seed=1, fast=False)
    t_f = t_o = 0.0
    for r in range(3):
        rf, ro = eng_f.run_round(t_f, MSG), eng_o.run_round(t_o, MSG)
        assert rf.deliveries == ro.deliveries, (name, r)
        assert np.array_equal(rf.mask, ro.mask)
        assert np.array_equal(rf.scheduled, ro.scheduled)
        assert rf.duration == ro.duration and rf.t0 == ro.t0
        t_f += rf.duration
        t_o += ro.duration


@pytest.mark.parametrize("name", ASYNC_SCENARIOS)
def test_async_stream_bit_for_bit(name):
    d_f = Engine(get_scenario(name), seed=1).run_async(
        0.0, MSG, n_deliveries=40)
    d_o = Engine(get_scenario(name), seed=1, fast=False).run_async(
        0.0, MSG, n_deliveries=40)
    assert d_f == d_o, name


def test_mega_1000_lossy_bit_for_bit():
    """The scale + loss scenario — the CI perf-gate smoke runs this same
    check via ``benchmarks/profile_round.py --check-equivalence``."""
    eng_f = Engine(get_scenario("mega-1000-lossy"), fast=True)
    eng_o = Engine(get_scenario("mega-1000-lossy"), fast=False)
    t = 0.0
    lost = 0
    for _ in range(2):
        rf, ro = eng_f.run_round(t, MSG), eng_o.run_round(t, MSG)
        assert rf.deliveries == ro.deliveries
        assert rf.duration == ro.duration
        lost += sum(not d.delivered for d in rf.deliveries)
        t += rf.duration
    assert lost > 0, "mega-1000-lossy should actually lose deliveries"
    d_f = eng_f.run_async(0.0, MSG, n_deliveries=40)
    d_o = eng_o.run_async(0.0, MSG, n_deliveries=40)
    assert d_f == d_o


def test_nonuniform_seeds_and_message_sizes():
    """Equivalence can't depend on the lucky defaults."""
    for seed in (0, 3, 17):
        for msg in (500.0, MSG, 2.5e6):
            sc = get_scenario("lossy-uplink")
            rf = Engine(sc, seed=seed).run_round(0.0, msg)
            ro = Engine(sc, seed=seed, fast=False).run_round(0.0, msg)
            assert rf.deliveries == ro.deliveries, (seed, msg)


def test_channel_cache_tracks_installed_channel():
    """SpaceRunner installs ``engine.channel`` AFTER construction; the
    fast path's memoized plans must follow the live channel object."""
    sc = Scenario(name="small", walker=Walker(n_sats=20, n_planes=4),
                  stations=(GroundStation(),), k_direct=3, n_relay=2)
    eng = Engine(sc)
    r_clean = eng.run_round(0.0, MSG)           # caches built channel-less
    ch = ChannelModel(loss=0.4, arq=SelectiveRepeatARQ(max_rounds=2))
    eng.channel = ch                            # what SpaceRunner does
    eng._refresh_blocked()
    r_lossy = eng.run_round(0.0, MSG)
    ref = Engine(dataclasses.replace(sc, channel=ch),
                 fast=False).run_round(0.0, MSG)
    assert r_lossy.deliveries == ref.deliveries
    assert any(not d.delivered for d in r_lossy.deliveries)
    assert all(d.delivered for d in r_clean.deliveries)


# ---------------------------------------------------------------------------
# supporting layers
# ---------------------------------------------------------------------------

def test_visibility_grid_matches_reference():
    """The fused chunked grid must agree with the elevation-threshold
    reference on every built-in geometry (chunking and the monotone
    comparison rewrite are elementwise-equivalent)."""
    cfgs = [
        (Walker(), (GroundStation(), GroundStation(lat=78.23, lon=15.39)),
         30.0, 2 * Walker().period),
        (Walker(n_sats=20, n_planes=4), (GroundStation(),), 20.0, 7200.0),
        (Walker(n_sats=20, n_planes=4), (GroundStation(mask_angle=89.9),),
         10.0, 7200.0),
        (Walker(n_sats=10, n_planes=3), (GroundStation(),), 10.0, 3600.0),
        (Walker(n_sats=4, n_planes=2),
         (GroundStation(lat=68.32, lon=-133.55),), 10.0, 3600.0),
    ]
    for w, stations, dt, horizon in cfgs:
        ts = np.arange(0.0, horizon, dt)
        for gs in stations:
            np.testing.assert_array_equal(
                visibility_grid(w, gs, ts), visible(w, gs, ts),
                err_msg=f"n_sats={w.n_sats} station={gs}")
    # chunk boundaries are invisible
    w, gs = Walker(n_sats=20, n_planes=4), GroundStation()
    ts = np.arange(0.0, 7200.0, 10.0)
    np.testing.assert_array_equal(visibility_grid(w, gs, ts, chunk=7),
                                  visibility_grid(w, gs, ts, chunk=512))


def test_incremental_extension_matches_full_rebuild():
    """``ContactPlan.ensure`` extends by propagating only the new time
    segment; the merged window arrays must be bit-identical to a
    from-scratch build over the doubled horizon — including windows that
    were capped at the old horizon end and continue into the extension."""
    cfgs = [
        (Walker(), (GroundStation(), GroundStation(lat=78.23, lon=15.39)),
         30.0, 3000.0),
        (Walker(n_sats=20, n_planes=4), (GroundStation(),), 20.0, 1800.0),
        (Walker(n_sats=50, n_planes=5),
         (GroundStation(lat=68.32, lon=-133.55),), 10.0, 2500.0),
    ]
    for w, stations, dt, horizon in cfgs:
        inc = ContactPlan(w, stations, horizon=horizon, dt=dt)
        inc.ensure(3.3 * horizon)       # two doublings in one call
        inc.ensure(7.9 * horizon)       # and another on top
        full = ContactPlan(w, stations, horizon=inc.horizon, dt=dt)
        assert inc.horizon == full.horizon
        for g in range(len(stations)):
            wmin = min(inc.rises[g].shape[1], full.rises[g].shape[1])
            np.testing.assert_array_equal(inc.rises[g][:, :wmin],
                                          full.rises[g][:, :wmin])
            np.testing.assert_array_equal(inc.sets[g][:, :wmin],
                                          full.sets[g][:, :wmin])
            assert not np.isfinite(inc.rises[g][:, wmin:]).any()
            assert not np.isfinite(full.rises[g][:, wmin:]).any()


def test_arq_plan_replay_matches_transmit():
    """``ArqPlan.replay`` reproduces ``transmit``'s TxResult bit-for-bit
    for any (t_start, window_end), including mid-window truncation and
    max-rounds exhaustion."""
    link = LinkModel()
    rng = np.random.default_rng(7)
    for loss in (0.0, 0.1, 0.3, 1.0):
        for max_rounds in (1, 2, 4):
            ch = ChannelModel(loss=loss,
                              arq=SelectiveRepeatARQ(max_rounds=max_rounds))
            for _ in range(15):
                nbytes = float(rng.choice([10.0, 1024.0, 12500.0, 5e6]))
                sat = int(rng.integers(0, 100))
                win = int(rng.integers(0, 300))
                t0 = float(rng.uniform(0.0, 1e5))
                wend = t0 + float(rng.choice([0.01, 0.2, 1.0, 1e9]))
                ref = ch.transmit(link, nbytes, walker=None,
                                  station_obj=None, gateway=sat, sat=sat,
                                  t_start=t0, window_end=wend, seed=1,
                                  station=0, window_id=win)
                plan = ch.arq_plan(link, nbytes, sat=sat, seed=1,
                                   station=0, window_id=win)
                assert plan.replay(t0, wend) == ref
    with pytest.raises(ValueError, match="time-invariant"):
        ChannelModel(budget=LinkBudget()).arq_plan(
            link, 1024.0, sat=0, seed=0, station=0, window_id=0)


def test_topology_neighborhoods_match_oracle_order():
    """The translation-symmetric (S, C) candidate arrays must list the
    exact satellites, hop counts, AND insertion order of the oracle's
    per-satellite BFS — order is load-bearing (est ties resolve to the
    first minimum)."""
    for walker in (Walker(), Walker(n_sats=60, n_planes=6),
                   Walker(n_sats=10, n_planes=3),     # ragged → fallback
                   Walker(n_sats=4, n_planes=2)):     # degenerate dedup
        sc = Scenario(name="t", walker=walker, stations=(GroundStation(),),
                      max_hops=4)
        eng = Engine(sc)
        topo = eng._fast_state().topo
        for s in {0, walker.n_sats // 2, walker.n_sats - 1}:
            ref = topo._bfs(s)
            if topo.valid is None:
                row = [(int(v), int(h))
                       for v, h in zip(topo.ids[s], topo.hops[s])]
            else:
                row = [(int(v), int(h))
                       for v, h, ok in zip(topo.ids[s], topo.hops[s],
                                           topo.valid[s]) if ok]
            assert row == ref, (walker.n_sats, s)
