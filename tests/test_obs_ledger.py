"""Run ledger, cross-run report, live watch, and the convergence gate.

The PR-7 observability contract:
  * ledger ingest is idempotent and deterministic (content-hash run ids);
  * `table_lossy_ef` rows render byte-identically from ledger entries —
    no recomputation path;
  * watch tails a growing trace reader-side (partial lines wait);
  * convgate passes on the committed CONV_reference.json curves and
    demonstrably fails — exit 1, localized round + metric — when error
    feedback is silently disabled on the lossy canonical scenario;
  * hypothesis round-trips: series and ledger records survive
    JSONL-write → load → extract unchanged.
"""
import io
import json
import os

import pytest

from repro import obs
from repro.obs import ledger as ledg
from repro.obs import report as rep
from repro.obs.summary import extract_series, of_kind, summarize_dict

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REFERENCE = os.path.join(REPO_ROOT, "CONV_reference.json")


def _fl_trace(n_rounds=4, errs=(4.0, 3.0, 2.0, 1.5), meta=None):
    """A small in-memory federated trace with series curves."""
    with obs.tracing(**(meta or dict(scenario="unit", algorithm="FedLT",
                                     compressor="quant10",
                                     channel="lossless"))) as trc:
        up = 0.0
        for k in range(n_rounds):
            up += 100.0
            trc.event("fl_round", round=k, t0=60.0 * k, t=60.0 * (k + 1),
                      bytes_up=up, n_active=3, n_lost=0, error=errs[k],
                      mode="sync")
            trc.series("bytes_up", k, up)
            trc.series("e_K", k, errs[k])
        return trc.records()


# ---------------------------------------------------------------------------
# ledger
# ---------------------------------------------------------------------------

def test_entry_from_records_promotes_meta_and_series():
    entry = ledg.entry_from_records(_fl_trace(), sha="beef123")
    assert entry["kind"] == "run"
    assert entry["scenario"] == "unit" and entry["algorithm"] == "FedLT"
    assert entry["mode"] == "sync"              # from final (not in meta)
    assert entry["git_sha"] == "beef123"
    assert entry["final"]["e_K"] == 1.5
    assert entry["final"]["bytes_up"] == 400.0
    assert entry["series"]["e_K"]["values"] == [4.0, 3.0, 2.0, 1.5]
    json.dumps(entry, allow_nan=False)


def test_run_id_content_hash_deterministic():
    a = ledg.entry_from_records(_fl_trace(), sha="aaa")
    b = ledg.entry_from_records(_fl_trace(), sha="bbb")
    assert a["run_id"] == b["run_id"]           # sha is NOT hashed
    c = ledg.entry_from_records(_fl_trace(errs=(4.0, 3.0, 2.0, 1.4)),
                                sha="aaa")
    assert c["run_id"] != a["run_id"]           # content is
    d = ledg.entry_from_records(_fl_trace(), sha="aaa", scenario="other")
    assert d["run_id"] != a["run_id"]           # promoted meta is too


def test_ingest_idempotent(tmp_path):
    path = str(tmp_path / "runs" / "ledger.jsonl")
    e1, added1 = ledg.ingest(_fl_trace(), path, sha="x")
    e2, added2 = ledg.ingest(_fl_trace(), path, sha="x")
    assert added1 and not added2
    entries = ledg.load_ledger(path)
    assert len(entries) == 1 and entries[0]["run_id"] == e1["run_id"]
    assert e2["run_id"] == e1["run_id"]
    # a different run appends
    _, added3 = ledg.ingest(_fl_trace(errs=(9.0, 8.0, 7.0, 6.0)), path)
    assert added3 and len(ledg.load_ledger(path)) == 2


def test_ingest_from_trace_file_and_gz(tmp_path):
    for suffix in (".jsonl", ".jsonl.gz"):
        tp = str(tmp_path / f"t{suffix}")
        with obs.tracing(tp, scenario="unit") as trc:
            trc.event("fl_round", round=0, t0=0.0, t=1.0, bytes_up=10.0,
                      n_active=1, n_lost=0, error=2.0, mode="sync")
            trc.series("e_K", 0, 2.0)
        lp = str(tmp_path / f"led{suffix}")
        entry, added = ledg.ingest(tp, lp)
        assert added and entry["final"]["e_K"] == 2.0
        assert ledg.load_ledger(lp)[0]["run_id"] == entry["run_id"]


def test_git_sha_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_GIT_SHA", "cafe42")
    assert ledg.git_sha() == "cafe42"


def test_load_ledger_missing_file_is_empty(tmp_path):
    assert ledg.load_ledger(str(tmp_path / "nope.jsonl")) == []


# ---------------------------------------------------------------------------
# report + frontier
# ---------------------------------------------------------------------------

def _two_entries():
    e1 = ledg.entry_from_records(_fl_trace(), sha="a")
    e2 = ledg.entry_from_records(_fl_trace(errs=(6.0, 5.5, 5.2, 5.0)),
                                 sha="a", scenario="unit2")
    return e1, e2


def test_render_report_lists_all_runs():
    e1, e2 = _two_entries()
    text = rep.render_report([e1, e2])
    assert e1["run_id"] in text and e2["run_id"] in text
    assert "unit2" in text


def test_frontier_pareto_marking():
    # cheaper+worse and dearer+better are both Pareto; dominated is not
    mk = lambda b, e: {"run_id": f"r{b}", "meta": {}, "scenario": "s",  # noqa: E731
                       "algorithm": "FedLT", "final":
                           {"bytes_up": b, "e_K": e}}
    pts = rep.frontier_points([mk(100.0, 5.0), mk(200.0, 1.0),
                               mk(300.0, 2.0)])
    assert [p["pareto"] for p in pts] == [True, True, False]
    text = rep.render_frontier([mk(100.0, 5.0), mk(200.0, 1.0),
                                mk(300.0, 2.0)])
    assert text.count("* ") == 2


def test_lossy_ef_rows_render_byte_identical(tmp_path):
    """The table_lossy_ef acceptance: rows rendered from ledger entries
    are byte-identical to rows computed directly from the RoundLogs."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from benchmarks.table_lossy_ef import render_row, run as tle_run

    lp = str(tmp_path / "ledger.jsonl")
    rows = tle_run([0.0, 0.25], rounds=12, n_agents=100, dim=8, m=10,
                   verbose=False, ledger_path=lp)
    assert len(rows) == 6
    # recompute one arm directly (same seeds/config) and compare the
    # rendered row text byte-for-byte
    from repro.channel import ChannelModel, SelectiveRepeatARQ
    from repro.core.compression import UniformQuantizer
    from repro.core.error_feedback import EFChannel
    from repro.core.fedlt import FedLT, optimality_error
    from repro.core.fedlt_sat import SpaceRunner
    from repro.data.logistic import generate, make_local_loss, solve_global
    from repro.sim import Engine, get_scenario
    from benchmarks.common import TUNED
    data, _ = generate(jax.random.PRNGKey(0), n_agents=100, m=10, dim=8)
    loss = make_local_loss(eps=50.0, n_agents=100)
    x_star = solve_global(data, eps=50.0)
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    alg = FedLT(loss=loss, uplink=EFChannel(C), downlink=EFChannel(C),
                **TUNED)
    st = alg.init(jnp.zeros((8,)), 100)
    runner = SpaceRunner(
        Engine(get_scenario("walker-kiruna")), compressor=C,
        channel=ChannelModel(loss=0.25,
                             arq=SelectiveRepeatARQ(seg_bytes=4096,
                                                    max_rounds=1)),
        loss_robust=True)
    err = lambda s: float(optimality_error(s.x, x_star))  # noqa: E731
    _, logs = runner.run(alg, st, data, 12, jax.random.PRNGKey(100),
                         error_fn=err, log_every=12)
    direct = dict(loss_rate=0.25, arm="EF (loss-robust)",
                  error=logs[-1].error,
                  lost=sum(l.n_lost for l in logs),
                  received=sum(l.n_active for l in logs),
                  bytes_up=logs[-1].bytes_up)
    [ledger_row] = [r for r in rows if r["loss_rate"] == 0.25
                    and r["arm"] == "EF (loss-robust)"]
    assert render_row(ledger_row) == render_row(direct)
    assert ledger_row == direct


# ---------------------------------------------------------------------------
# watch (reader-side live tail)
# ---------------------------------------------------------------------------

def test_trace_tail_incremental_and_partial_lines(tmp_path):
    path = str(tmp_path / "live.jsonl")
    tail = rep.TraceTail(path)
    assert tail.poll() == []                    # file not there yet
    with open(path, "w") as f:
        f.write('{"kind": "header", "schema": 2}\n')
        f.write('{"kind": "fl_round", "round": 0')   # partial line
        f.flush()
        assert [r["kind"] for r in tail.poll()] == ["header"]
        assert tail.poll() == []                # partial line waits
        f.write(', "t": 1.0, "bytes_up": 1.0, "n_active": 1}\n')
        f.flush()
        [r] = tail.poll()
        assert r["round"] == 0 and r["bytes_up"] == 1.0


def test_watch_renders_rounds_and_stops_at_close(tmp_path):
    path = str(tmp_path / "w.jsonl")
    with obs.tracing(path, scenario="unit") as trc:
        for k in range(3):
            trc.event("fl_round", round=k, t0=0.0, t=60.0 * (k + 1),
                      bytes_up=100.0 * (k + 1), n_active=5, n_lost=0,
                      error=3.0 - k, mode="sync")
        trc.metrics.counter("bytes_down").add(1.0)
    out = io.StringIO()
    rc = rep.watch(path, total=3, follow=False, out=out)
    text = out.getvalue()
    assert rc == 0
    assert "watching" in text
    assert "trace closed: 3 rounds" in text
    # the table header + one row per round
    assert "error" in text and text.count("\n") >= 5


def test_watch_is_reader_side_only(tmp_path):
    """The traced process's records are untouched by a concurrent
    watcher — watch only reads."""
    path = str(tmp_path / "w.jsonl")
    with obs.tracing(path, stream_every=2, scenario="unit") as trc:
        trc.event("fl_round", round=0, t0=0.0, t=60.0, bytes_up=1.0,
                  n_active=1, n_lost=0, error=1.0, mode="sync")
        trc.flush()
        out = io.StringIO()
        rep.watch(path, follow=False, out=out)      # mid-run tail
        assert "round" in out.getvalue()
        trc.event("fl_round", round=1, t0=60.0, t=120.0, bytes_up=2.0,
                  n_active=1, n_lost=0, error=0.5, mode="sync")
    records = obs.load(path)
    assert [r["round"] for r in of_kind(records, "fl_round")] == [0, 1]


# ---------------------------------------------------------------------------
# convergence gate
# ---------------------------------------------------------------------------

def test_committed_reference_has_three_canonical_scenarios():
    ref = rep.load_reference(REFERENCE)
    assert sorted(ref["scenarios"]) == sorted(rep.CANONICAL)
    for name, sc in ref["scenarios"].items():
        assert sc["rounds"] == rep.CANONICAL[name]["rounds"]
        assert len(sc["e_K"]["steps"]) == sc["rounds"]
        assert sc["bytes_up"] > 0


@pytest.mark.parametrize("name", sorted(rep.CANONICAL))
def test_convgate_passes_on_committed_reference(name):
    records = rep.run_canonical(name)
    ref = rep.load_reference(REFERENCE)
    bad = rep.gate_records(name, records, ref)
    assert bad == [], "\n".join(bad)


def test_convgate_fails_on_ef_disabled_lossy(tmp_path, capsys):
    """The seeded-regression acceptance: EF silently disabled on the
    lossy canonical scenario must fail the gate with exit 1 and a
    message localizing the round and metric."""
    from repro.obs.__main__ import main
    records = rep.run_canonical("sync-lossy-robust-ef", ef=False)
    path = str(tmp_path / "regressed.jsonl")
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r, allow_nan=False) + "\n")
    assert main(["convgate", path, "--reference", REFERENCE]) == 1
    out = capsys.readouterr().out
    assert "CONVGATE FAIL sync-lossy-robust-ef" in out
    assert "e_K degraded at round" in out      # localized metric + round


def test_convgate_detects_missing_samples():
    ref = rep.load_reference(REFERENCE)
    records = rep.run_canonical("sync-lossless")
    truncated = [r for r in records
                 if not (r.get("kind") == "series" and r.get("name") == "e_K"
                         and r.get("step", 0) >= 20)]
    bad = rep.gate_records("sync-lossless", truncated, ref)
    assert any("missing at round" in m for m in bad)


def test_convgate_bytes_drift_caught():
    ref = rep.load_reference(REFERENCE)
    records = [dict(r) for r in rep.run_canonical("sync-lossless")]
    for r in records:
        if r.get("kind") == "series" and r.get("name") == "bytes_up":
            r["value"] *= 1.5
    bad = rep.gate_records("sync-lossless", records, ref)
    assert any("bytes_up drifted" in m for m in bad)


def test_convgate_unknown_scenario_reported():
    ref = rep.load_reference(REFERENCE)
    bad = rep.gate_records("no-such-scenario", _fl_trace(), ref)
    assert bad and "no reference curve" in bad[0]


# ---------------------------------------------------------------------------
# hypothesis round-trips (series + ledger records) — the property tests
# skip themselves when hypothesis is absent (optional dependency, same
# convention as tests/test_property_compression.py) without taking the
# rest of this module down with them
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=-1e9, max_value=1e9, allow_nan=False,
                       allow_infinity=False)
    names = st.sampled_from(["e_K", "bytes_up", "loss", "staleness",
                             "ef_resid_norm"])

    @settings(max_examples=30, deadline=None)
    @given(samples=st.lists(
        st.tuples(names, st.integers(0, 10_000), finite),
        min_size=1, max_size=40))
    def test_series_roundtrip_property(tmp_path_factory, samples):
        """series records survive write → load → extract: per name, the
        step-sorted (step, value) multiset is preserved exactly."""
        path = str(tmp_path_factory.mktemp("h") / "t.jsonl")
        with obs.tracing(path) as trc:
            for name, step, value in samples:
                trc.series(name, step, value)
        series = extract_series(obs.load(path))
        expect = {}
        for name, step, value in samples:
            expect.setdefault(name, []).append((step, value))
        assert set(series) == set(expect)
        for name, pairs in expect.items():
            got = list(zip(series[name]["steps"], series[name]["values"]))
            assert sorted(got) == sorted(pairs)
            assert series[name]["steps"] == sorted(series[name]["steps"])

    @settings(max_examples=20, deadline=None)
    @given(errs=st.lists(finite, min_size=1, max_size=12),
           scenario=st.sampled_from(["a", "b", "walker-kiruna"]))
    def test_ledger_entry_roundtrip_property(tmp_path_factory, errs,
                                             scenario):
        """ledger entries survive append → load unchanged, and the run
        id is a pure content hash (stable across write/read and sha
        changes)."""
        with obs.tracing(scenario=scenario, algorithm="FedLT") as trc:
            for k, e in enumerate(errs):
                trc.series("e_K", k, e)
                trc.series("bytes_up", k, 10.0 * (k + 1))
            records = trc.records()
        entry = ledg.entry_from_records(records, sha="s1")
        path = str(tmp_path_factory.mktemp("h") / "led.jsonl")
        ledg.append_entry(entry, path)
        [back] = ledg.load_ledger(path)
        assert back == entry
        assert ledg.run_id(back) == entry["run_id"]
        assert ledg.entry_from_records(records, sha="other")["run_id"] \
            == entry["run_id"]
else:       # pragma: no cover — hypothesis available in CI
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_series_roundtrip_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_ledger_entry_roundtrip_property():
        pass


def test_summarize_dict_and_ingest_agree():
    """satellite d: the --json summary is what ingest consumes — the
    ledger's final/series blocks equal the summary's."""
    records = _fl_trace()
    s = summarize_dict(records)
    entry = ledg.entry_from_records(records, sha="x")
    assert entry["series"] == s["series"]
    assert entry["final"] == {k: v for k, v in s["final"].items()
                              if k != "mode"}
