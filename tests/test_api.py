"""repro.api.Experiment facade: parity with hand construction, topology
selection, the channel-install ChannelCache invalidation regression, and
trace/ledger wiring."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro.api import (Experiment, describe_channel, describe_compressor)
from repro.channel import ChannelModel, SelectiveRepeatARQ
from repro.core.compression import RandD, TopK, UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT
from repro.core.fedlt_sat import SpaceRunner
from repro.data.logistic import generate, make_local_loss
from repro.sim import Engine, get_scenario

QUANT = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
DIM = 12


def _problem(n_agents=100):
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=16,
                       dim=DIM)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    alg = FedLT(loss=loss, n_epochs=1, gamma=0.005, rho=20.0,
                uplink=EFChannel(QUANT), downlink=EFChannel(QUANT))
    return data, alg


def test_facade_matches_hand_construction():
    """Experiment.run must reproduce SpaceRunner-by-hand bit-for-bit
    (the facade is delegation, not a reimplementation)."""
    data, alg = _problem()
    exp = Experiment.from_scenario("walker-kiruna", algorithm=alg,
                                   compressor=QUANT)
    st = exp.init(jnp.zeros((DIM,)), 100)
    res = exp.run(st, data, 4, jax.random.PRNGKey(1))

    runner = SpaceRunner(Engine(get_scenario("walker-kiruna"), seed=0),
                         compressor=QUANT)
    st2 = alg.init(jnp.zeros((DIM,)), 100)
    _, logs2 = runner.run(alg, st2, data, 4, jax.random.PRNGKey(1))
    assert [l.bytes_up for l in res.logs] == [l.bytes_up for l in logs2]
    assert [l.n_active for l in res.logs] == [l.n_active for l in logs2]
    assert [l.time for l in res.logs] == [l.time for l in logs2]


def test_facade_topology_selection():
    data, alg = _problem()
    exp = Experiment("walker-kiruna", alg, compressor=QUANT,
                     topology="plane")
    assert exp.topology_name == "plane"
    assert exp.engine.topology.kind == "plane"
    st = exp.init(jnp.zeros((DIM,)), 100)
    res = exp.run(st, data, 3, jax.random.PRNGKey(1))
    assert sum(l.bytes_isl for l in res.logs) > 0
    # registered plane scenario == direct scenario + topology override
    exp2 = Experiment("plane-agg-walker", alg, compressor=QUANT)
    assert exp2.topology_name == "plane"


def test_facade_engine_passthrough_and_guards():
    data, alg = _problem()
    eng = Engine(get_scenario("plane-agg-walker"))
    exp = Experiment(None, alg, engine=eng, compressor=QUANT)
    assert exp.engine is eng and exp.topology_name == "plane"
    with pytest.raises(ValueError, match="carries topology"):
        Experiment(None, alg, engine=eng, topology="direct")
    with pytest.raises(ValueError, match="scenario"):
        Experiment(None, alg)


def test_ledger_meta_labels():
    _, alg = _problem()
    assert describe_compressor(QUANT) == "quant10"
    assert describe_compressor(TopK(fraction=0.1)) == "topk0.1"
    assert describe_compressor(RandD(fraction=0.2)) == "rand0.2"
    assert describe_compressor(None) == "none"
    assert describe_channel(None) == "lossless"
    ch = ChannelModel(loss=0.3,
                      arq=SelectiveRepeatARQ(seg_bytes=4096, max_rounds=1))
    assert describe_channel(ch) == "flat-0.3"
    exp = Experiment("walker-kiruna", alg, compressor=QUANT, channel=ch,
                     meta=dict(arm="x", compressor="override"))
    m = exp.ledger_meta()
    assert m["scenario"] == "walker-kiruna"
    assert m["algorithm"] == "FedLT"
    assert m["channel"] == "flat-0.3"
    assert m["topology"] == "direct" and m["mode"] == "sync"
    assert m["arm"] == "x"
    assert m["compressor"] == "override"     # caller meta wins


def test_facade_trace_and_ledger(tmp_path):
    from repro.obs.ledger import load_ledger

    data, alg = _problem()
    lp = os.path.join(str(tmp_path), "ledger.jsonl")
    exp = Experiment("plane-agg-walker", alg, compressor=QUANT)
    st = exp.init(jnp.zeros((DIM,)), 100)
    res = exp.run(st, data, 3, jax.random.PRNGKey(1), ledger=lp)
    assert res.records is not None
    entries = load_ledger(lp)
    assert len(entries) == 1
    assert entries[0]["run_id"] == res.run_id
    assert entries[0]["topology"] == "plane"
    assert entries[0]["compressor"] == "quant10"
    # untraced run has nothing to ingest
    res2 = exp.run(exp.init(jnp.zeros((DIM,)), 100), data, 1,
                   jax.random.PRNGKey(1))
    assert res2.records is None
    with pytest.raises(ValueError, match="no trace records"):
        res2.ingest(lp)


def test_facade_defers_to_open_tracer():
    """Inside an already-open tracing() scope the facade must not try to
    nest a second tracer — events land in the caller's scope."""
    from repro import obs

    data, alg = _problem()
    exp = Experiment("walker-kiruna", alg, compressor=QUANT)
    with obs.tracing(scenario="outer") as trc:
        res = exp.run(exp.init(jnp.zeros((DIM,)), 100), data, 2,
                      jax.random.PRNGKey(1), trace=True)
        n = len(trc.records())
    assert res.records is None
    assert n > 2


def test_install_channel_invalidates_chan_cache():
    """The historical footgun: SpaceRunner(channel=...) used to mutate
    engine.channel AFTER the fast path's ChannelCache had memoized plans
    for the old channel, silently replaying lossless ARQ plans under a
    lossy channel.  install_channel must drop the memo so post-install
    rounds are bit-identical to a fresh engine built with the channel."""
    sc = get_scenario("walker-kiruna")
    msg = 120e6 / 8 * 0.01
    eng = Engine(sc)
    # memoize: run rounds WITHOUT a channel so the cache holds
    # lossless-channel estimates
    t = 0.0
    for _ in range(2):
        t += eng.run_round(t, msg).duration
    assert eng._chan_cache is not None
    ch = ChannelModel(loss=0.5,
                      arq=SelectiveRepeatARQ(seg_bytes=16384, max_rounds=1))
    eng.install_channel(ch)
    assert eng._chan_cache is None           # memo dropped
    fresh = Engine(dataclasses.replace(sc, channel=ch))
    t_a = t_b = 0.0
    lost = 0
    for _ in range(4):
        ra, rb = eng.run_round(t_a, msg), fresh.run_round(t_b, msg)
        assert ra.deliveries == rb.deliveries
        lost += sum(not d.delivered for d in ra.deliveries)
        t_a += ra.duration
        t_b += rb.duration
    assert lost > 0, "channel install had no effect on deliveries"


def test_space_runner_install_goes_through_engine(monkeypatch):
    """SpaceRunner(channel=...) must route through install_channel, not
    bare attribute mutation."""
    eng = Engine(get_scenario("walker-kiruna"))
    calls = []
    orig = Engine.install_channel
    monkeypatch.setattr(Engine, "install_channel",
                        lambda self, ch: (calls.append(ch),
                                          orig(self, ch))[1])
    ch = ChannelModel(loss=0.1,
                      arq=SelectiveRepeatARQ(seg_bytes=4096, max_rounds=1))
    SpaceRunner(eng, channel=ch)
    assert calls == [ch]
