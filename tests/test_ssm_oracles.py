"""Chunked scan implementations vs naive recurrent oracles.

The Mamba-2 SSD and RWKV-6 chunked forms must match a step-by-step
recurrence exactly (up to fp accumulation order) for any sequence length —
including lengths that don't divide the chunk size (padding path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import _ssd_chunked
from repro.models.rwkv6 import _chunked_wkv


def naive_ssd(xh, dt, a, Bm, Cm, d_skip):
    b, s, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    B = jnp.repeat(Bm, rep, axis=2)
    C = jnp.repeat(Cm, rep, axis=2)
    S = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        a_t = jnp.exp(-dt[:, t] * a)                       # (B,H)
        S = a_t[:, :, None, None] * S + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], xh[:, t], B[:, t])
        y = jnp.einsum("bhpn,bhn->bhp", S, C[:, t]) + d_skip[None, :, None] * xh[:, t]
        ys.append(y)
    return jnp.stack(ys, axis=1), S


def naive_wkv(r, k, v, logw, u):
    b, s, h, p = r.shape
    S = jnp.zeros((b, h, p, p))
    ys = []
    for t in range(s):
        kv = jnp.einsum("bhp,bhn->bhpn", k[:, t], v[:, t])
        o = jnp.einsum("bhp,bhpn->bhn", r[:, t], S + u[None, :, :, None] * kv)
        S = jnp.exp(logw[:, t])[..., None] * S + kv
        ys.append(o)
    return jnp.stack(ys, axis=1), S


@pytest.mark.parametrize("s", [16, 64, 100, 130])
def test_ssd_chunked_matches_naive(s):
    b, h, p, g, n = 2, 4, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    d_skip = jnp.ones((h,))
    y_c, S_c = _ssd_chunked(xh, dt, a, Bm, Cm, d_skip, chunk=32)
    y_n, S_n = naive_ssd(xh, dt, a, Bm, Cm, d_skip)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_n),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_carries_initial_state():
    """Prefill in two halves == one pass (state threading)."""
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    d_skip = jnp.zeros((h,))
    y_full, S_full = _ssd_chunked(xh, dt, a, Bm, Cm, d_skip, chunk=16)
    y1, S1 = _ssd_chunked(xh[:, :32], dt[:, :32], a, Bm[:, :32], Cm[:, :32],
                          d_skip, chunk=16)
    y2, S2 = _ssd_chunked(xh[:, 32:], dt[:, 32:], a, Bm[:, 32:], Cm[:, 32:],
                          d_skip, chunk=16, state0=S1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("s", [32, 64, 100])
def test_wkv_chunked_matches_naive(s):
    b, h, p = 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    r = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, p)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, p)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, p)) * 0.5 - 2.0)
    u = jnp.ones((h, p)) * 0.3
    y_c, S_c = _chunked_wkv(r, k, v, logw, u, chunk=32)
    y_n, S_n = naive_wkv(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_n),
                               rtol=5e-4, atol=5e-4)
