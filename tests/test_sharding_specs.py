"""Sharding rules: unit tests (no multi-device mesh needed — specs only).

Uses an abstract mesh over 1 device? No — PartitionSpec construction needs
real axis sizes, so we build the production mesh shape with AbstractMesh.
"""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.launch.sharding import batch_specs, cache_specs, param_specs
from repro.models.transformer import init_cache, init_params

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _shapes(cfg):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def test_embed_and_mlp_rules():
    p = _shapes(ARCHS["stablelm-1.6b"])
    specs = param_specs(p, MESH, agent_axes=())
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["scan"][0]["mlp"]["up"] == P(None, "data", "model")
    assert specs["scan"][0]["mlp"]["down"] == P(None, "model", "data")
    assert specs["final_norm"] == P(None)


def test_mqa_kv_sharding_follows_divisibility():
    """granite kv=1 (kv_dim=128): divisible by model=16 → sharded; a
    hypothetical 24-wide dim would be replicated."""
    p = _shapes(ARCHS["granite-20b"])
    specs = param_specs(p, MESH, agent_axes=())
    assert specs["scan"][0]["attn"]["wk"] == P(None, "data", "model")
    odd = {"scan": ({"attn": {"wk": jax.ShapeDtypeStruct((1, 24, 24),
                                                         jnp.float32)}},)}
    specs_odd = param_specs(odd, MESH, agent_axes=())
    assert specs_odd["scan"][0]["attn"]["wk"] == P(None, None, None)


def test_moe_expert_stack_rules():
    p = _shapes(ARCHS["mixtral-8x7b"])
    specs = param_specs(p, MESH, agent_axes=())
    assert specs["scan"][0]["moe"]["up"] == P(None, None, "data", "model")
    assert specs["scan"][0]["moe"]["down"] == P(None, None, "model", "data")


def test_agent_stacked_tp_only():
    p = _shapes(ARCHS["rwkv6-3b"])
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((16,) + s.shape, s.dtype), p)
    specs = param_specs(stacked, MESH, agent_axes=("data",), fsdp=None)
    assert specs["embed"]["table"] == P("data", "model", None)
    assert specs["scan"][0]["rwkv"]["wr"] == P("data", None, None, "model")


def test_multipod_pod_agents():
    p = _shapes(ARCHS["mixtral-8x7b"])
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((2,) + s.shape, s.dtype), p)
    specs = param_specs(stacked, MESH3, agent_axes=("pod",), stacked=True)
    assert specs["scan"][0]["moe"]["up"] == P("pod", None, None, "data", "model")


def test_batch_specs_shapes():
    batch = {"tokens": jax.ShapeDtypeStruct((16, 16, 4096), jnp.int32)}
    specs = batch_specs(batch, MESH, agent_axes=("data",), stacked=True)
    assert specs["tokens"] == P("data", None, None)
    batch2 = {"tokens": jax.ShapeDtypeStruct((32, 32768), jnp.int32)}
    specs2 = batch_specs(batch2, MESH, agent_axes=())
    assert specs2["tokens"] == P("data", None)


def test_cache_specs_long_context_seq_sharding():
    cfg = ARCHS["gemma3-27b"]
    shapes = jax.eval_shape(
        lambda: init_cache(cfg, 1, s_max=524288, dtype=jnp.bfloat16))
    specs = cache_specs(shapes, MESH, shard_batch=False)
    # global-layer KV (slot index 5 = "attn"): seq sharded over data
    kv_spec = specs["scan"][5].k
    assert kv_spec == P(None, None, "data", "model", None)


def test_cache_specs_batch_sharding():
    cfg = ARCHS["stablelm-1.6b"]
    shapes = jax.eval_shape(
        lambda: init_cache(cfg, 128, s_max=32768, dtype=jnp.bfloat16))
    specs = cache_specs(shapes, MESH, shard_batch=True)
    assert specs["scan"][0].k == P(None, "data", None, "model", None)
