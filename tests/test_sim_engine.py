"""Discrete-event engine: sync parity with the seed path, corrected relay
accounting, dropout/empty-round behaviour, async convergence, 1000-sat."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.constellation.links import LinkModel, message_bytes
from repro.constellation.orbits import GroundStation, Walker
from repro.constellation.scheduler import Scheduler, legacy_select
from repro.core.fedlt import FedLT, optimality_error
from repro.core.fedlt_sat import SpaceRunner
from repro.data.logistic import generate, make_local_loss, solve_global
from repro.sim import Engine, Scenario, gateway_schedule, get_scenario

MSG = message_bytes(10000, 10.0)


# ---------------------------------------------------------------------------
# relay accounting (regression for the seed bugs)
# ---------------------------------------------------------------------------

def test_gateway_schedule_no_double_count():
    """Each message is charged exactly one gs_tx; ISL transfer that overlaps
    the window wait adds nothing (the seed charged isl + (i+2)·gs extra)."""
    gs_tx, isl = 2.0, 0.5
    window = 100.0
    # gateway's own update ready at 30; two relays arrive at 30.5, 31.0
    done = gateway_schedule(window, [(7, 30.0), (3, 30.5), (5, 31.0)], gs_tx)
    assert done[7] == pytest.approx(window + gs_tx)           # own first
    assert done[3] == pytest.approx(window + 2 * gs_tx)       # (j+1)·gs only
    assert done[5] == pytest.approx(window + 3 * gs_tx)
    # seed formula for relay i: window + isl + (i+2)·gs — strictly larger
    assert done[3] < window + isl + 2 * gs_tx
    assert done[5] < window + isl + 3 * gs_tx


def test_gateway_schedule_waits_for_late_arrival():
    gs_tx = 2.0
    done = gateway_schedule(10.0, [(0, 5.0), (1, 50.0)], gs_tx)
    assert done[0] == pytest.approx(12.0)
    assert done[1] == pytest.approx(52.0)      # link idle until arrival


def test_n_relay_not_silently_capped_at_two():
    """The seed sliced a 2-tuple, so n_relay > 2 was impossible.  The
    multi-hop router reaches n_relay satellites per gateway."""
    w, gs = Walker(), GroundStation()
    mask2, _ = Scheduler(w, gs, k_direct=4, n_relay=2).select(0.0, MSG)
    mask4, _ = Scheduler(w, gs, k_direct=4, n_relay=4).select(0.0, MSG)
    assert mask2.sum() == 4 * 3                # gateways + 2 relays each
    assert mask4.sum() == 4 * 5                # gateways + 4 relays each
    assert mask4.sum() > 12                    # impossible in the seed


def test_engine_deliveries_match_analytic_gateway_schedule():
    """The engine's event-loop serialization IS the corrected accounting:
    on a single-gateway round (no cross-gateway contention) every delivery
    time equals the analytic :func:`gateway_schedule` prediction."""
    sc = Scenario(name="one-gw", walker=Walker(), stations=(GroundStation(),),
                  k_direct=1, n_relay=4)
    eng = Engine(sc)
    asg = eng.policy.assign(0.0, MSG, eng)
    res = eng.run_round(0.0, MSG)
    (g,) = asg.gateways
    arrivals = [(g, sc.compute_of(g))]
    arrivals += [(s, sc.compute_of(s) + r.time) for s, r in asg.relays.items()]
    window_start = asg.windows[g][0]
    expected = gateway_schedule(max(window_start, 0.0), arrivals,
                                sc.link.gs_time(MSG))
    got = {d.sat: d.t_done for d in res.deliveries}
    assert set(got) == set(expected)
    for sat, t_exp in expected.items():
        assert got[sat] == pytest.approx(t_exp), sat


def test_relays_are_multi_hop():
    sched = Scheduler(Walker(), GroundStation(), k_direct=2, n_relay=6,
                      max_hops=4)
    eng = sched._engine()
    asg = sched.assign(0.0, MSG, eng)
    hops = [r.hops for r in asg.relays.values()]
    assert max(hops) > 1                       # beyond in-plane neighbours
    assert all(1 <= h <= 4 for h in hops)


# ---------------------------------------------------------------------------
# synchronous mode: parity with the seed SpaceRunner on Walker/Kiruna
# ---------------------------------------------------------------------------

def test_sync_parity_with_seed_round_durations():
    """Engine sync mode reproduces the seed per-round loop on the default
    Walker/Kiruna scenario: identical active-set sizes and the same round
    durations up to grid/accounting slack (the corrected accounting shifts
    individual rounds by ≤ compute + dt; cumulative time must agree)."""
    w, gs, link = Walker(), GroundStation(), LinkModel()
    sched = Scheduler(w, gs, k_direct=4, n_relay=2)
    t_new = t_old = 0.0
    d_new, d_old, a_new, a_old = [], [], [], []
    for _ in range(12):
        m, d = sched.select(t_new, MSG)
        t_new += d
        d_new.append(d)
        a_new.append(int(m.sum()))
        m, d = legacy_select(w, gs, link, t_old, MSG)
        t_old += d
        d_old.append(d)
        a_old.append(int(m.sum()))
    assert a_new == a_old
    # same duration distribution up to scheduling slack (rounds may swap
    # order by one when a window straddles the compute interval)
    np.testing.assert_allclose(sorted(d_new), sorted(d_old), atol=35.0)
    assert abs(t_new - t_old) / t_old < 0.05


def test_engine_round_mask_matches_schedule_without_dropout():
    eng = Engine(get_scenario("walker-kiruna"))
    res = eng.run_round(0.0, MSG)
    np.testing.assert_array_equal(res.mask, res.scheduled)
    assert len(res.deliveries) == res.mask.sum()
    assert res.duration >= max(d.t_done for d in res.deliveries) - res.t0


# ---------------------------------------------------------------------------
# edge cases: empty rounds, dropout, heterogeneous compute, multi-station
# ---------------------------------------------------------------------------

def _blind_scenario(**kw):
    return Scenario(name="blind", walker=Walker(n_sats=20, n_planes=4),
                    stations=(GroundStation(mask_angle=89.9),),
                    lookahead=3600.0, **kw)


def test_no_visible_satellite_round_advances_time():
    eng = Engine(_blind_scenario())
    t = 0.0
    for _ in range(3):
        res = eng.run_round(t, MSG)
        assert res.mask.sum() == 0
        assert res.duration > 0
        t += res.duration
    assert t > 0


def test_async_no_windows_terminates_empty():
    eng = Engine(_blind_scenario())
    assert eng.run_async(0.0, MSG, n_deliveries=5, max_time=20000.0) == []


def test_full_dropout_delivers_nothing():
    sc = Scenario(name="storm", walker=Walker(n_sats=20, n_planes=4),
                  stations=(GroundStation(),), dropout=1.0, lookahead=3600.0)
    res = Engine(sc).run_round(0.0, MSG)
    assert res.mask.sum() == 0


def test_dropout_mask_stable_across_plan_extension():
    """Weather blocked-ness is a deterministic hash of the window identity:
    extending the plan horizon must not retroactively flip the availability
    of windows the simulation already consulted."""
    eng = Engine(get_scenario("weather-dropout"), seed=3)
    before_b = [b.copy() for b in eng._blocked]
    before_r = [r.copy() for r in eng.plan.rises]
    eng.ensure(4 * eng.plan.horizon)
    assert eng._blocked[0].shape[1] > before_b[0].shape[1]   # plan grew
    for g in range(len(before_b)):
        w = min(before_b[g].shape[1], eng._blocked[g].shape[1])
        keep = (np.isfinite(before_r[g][:, :w])
                & np.isfinite(eng.plan.rises[g][:, :w]))
        np.testing.assert_array_equal(before_r[g][:, :w][keep],
                                      eng.plan.rises[g][:, :w][keep])
        np.testing.assert_array_equal(before_b[g][:, :w][keep],
                                      eng._blocked[g][:, :w][keep])


def test_partial_dropout_still_delivers():
    res = Engine(get_scenario("weather-dropout"), seed=3).run_round(0.0, MSG)
    assert res.mask.sum() >= 1
    clear = Engine(get_scenario("dual-station")).run_round(0.0, MSG)
    assert res.duration >= 0 and clear.duration >= 0


def test_hetero_compute_and_dual_station():
    res = Engine(get_scenario("hetero-compute")).run_round(0.0, MSG)
    assert res.mask.sum() >= 1
    eng = Engine(get_scenario("dual-station"))
    stations = set()
    t = 0.0
    for _ in range(8):
        r = eng.run_round(t, MSG)
        stations |= {d.station for d in r.deliveries}
        t += r.duration
    assert stations <= {0, 1} and stations


# ---------------------------------------------------------------------------
# asynchronous mode
# ---------------------------------------------------------------------------

def test_async_deliveries_are_ordered_and_retrain():
    eng = Engine(get_scenario("walker-kiruna"))
    ds = eng.run_async(0.0, MSG, n_deliveries=120)
    assert len(ds) == 120
    ts = [d.t_done for d in ds]
    assert ts == sorted(ts)
    # at least one satellite delivered twice — trained again after delivery
    sats = [d.sat for d in ds]
    assert len(set(sats)) < len(sats)
    again = [d for d in ds if sats.count(d.sat) > 1]
    assert any(d.t_start > 0.0 for d in again)


def test_async_park_reroutes_backlog_via_isl():
    """A gateway's contact window closes mid-queue: the engine must PARK
    the remaining backlog (``park`` in ``run_async``), push retries, and
    on retry re-route the stranded updates via ISL to other gateways —
    previously untested.  Gateway 11 collects the whole 20-sat fleet in
    its first window [0, 280); the uplink takes ~96 s, so at most two
    messages drain before the window shuts, and every later window of
    sat 11 is force-blocked so the backlog CANNOT wait it out."""
    big = 1.2e9                        # ~96 s per uplink at 100 Mbit/s

    def make_engine(fast):
        sc = Scenario(name="park", walker=Walker(n_sats=20, n_planes=4),
                      stations=(GroundStation(),), lookahead=1800.0,
                      dropout=1e-12,   # forces blocked-mask arrays to exist
                      max_hops=4)
        eng = Engine(sc, fast=fast)
        rises = eng.plan.rises[0]
        eng._blocked[0][11, np.isfinite(rises[11])
                        & (rises[11] > 280.0)] = True
        return eng

    d_fast = make_engine(True).run_async(0.0, big, n_deliveries=12,
                                         max_time=3500.0)
    d_oracle = make_engine(False).run_async(0.0, big, n_deliveries=12,
                                            max_time=3500.0)
    # the park path must behave identically on the fast and oracle cores
    # (Delivery is an eq dataclass — == compares every field)
    assert d_fast == d_oracle
    # the first window drained only a fraction of the queue through gw 11
    first = [d for d in d_fast if d.window == 0.0]
    assert first and len(first) <= 2
    assert all(d.gateway == 11 for d in first)
    # nothing ever rides gateway 11 again — its later windows are blocked
    assert all(d.gateway != 11 for d in d_fast if d.window > 280.0)
    # the parked backlog (trained at t=0, stranded in gw 11's queue)
    # re-routed via ISL to a different gateway after a park→retry cycle
    rerouted = [d for d in d_fast
                if d.t_start == 0.0 and d.gateway != 11 and d.hops >= 1
                and d.t_done > 1800.0]
    assert rerouted, "no parked satellite re-routed via ISL"


def test_async_oversized_message_terminates_at_horizon_cap():
    """A message too big for ANY contact window self-routes, parks, and
    retries; once the retry chain saturates at the horizon cap, park must
    stop re-pushing retries (regression: park → retry → park cycled
    forever at constant t instead of draining the run)."""
    sc = Scenario(name="big", walker=Walker(n_sats=20, n_planes=4),
                  stations=(GroundStation(),), lookahead=1800.0)
    for fast in (True, False):
        out = Engine(sc, fast=fast).run_async(0.0, 1e12, n_deliveries=1,
                                              max_time=3600.0)
        assert out == []


def _small_problem(n_agents=20, dim=30):
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=60, dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)
    sc = Scenario(name="small", walker=Walker(n_sats=n_agents, n_planes=4),
                  stations=(GroundStation(),), k_direct=3, n_relay=2)
    return data, loss, x_star, sc


def test_async_mode_converges_on_logistic_task():
    data, loss, x_star, sc = _small_problem()
    alg = FedLT(loss=loss, n_epochs=10, gamma=0.005, rho=20.0)
    st = alg.init(jnp.zeros((30,)), 20)
    runner = SpaceRunner(Engine(sc), wire_bits=32.0, mode="async",
                         buffer_size=5, staleness_alpha=0.5)
    err = lambda s: float(optimality_error(s.x, x_star))
    e0 = err(st)
    st, logs = runner.run(alg, st, data, 40, jax.random.PRNGKey(2),
                          error_fn=err, log_every=10)
    assert logs, "async produced no aggregation rounds"
    assert logs[-1].error < 0.6 * e0
    # staleness is tracked and non-negative; buffer bound respected
    assert all(l.staleness is not None and l.staleness >= 0 for l in logs)
    assert all(l.n_active <= 5 for l in logs)
    assert all(l.time > 0 for l in logs)


def test_sync_and_async_runners_agree_on_bytes_accounting():
    data, loss, x_star, sc = _small_problem()
    alg = FedLT(loss=loss, n_epochs=5, gamma=0.005, rho=20.0)
    st = alg.init(jnp.zeros((30,)), 20)
    runner = SpaceRunner(Engine(sc), wire_bits=32.0)
    st, logs = runner.run(alg, st, data, 4, jax.random.PRNGKey(0))
    msg = message_bytes(30, 32.0)
    assert logs[-1].bytes_up == pytest.approx(
        sum(l.n_active for l in logs) * msg)


# ---------------------------------------------------------------------------
# scale
# ---------------------------------------------------------------------------

def test_engine_runs_thousand_satellite_scenario():
    eng = Engine(get_scenario("mega-1000"))
    assert eng.scenario.walker.n_sats == 1000
    res = eng.run_round(0.0, MSG)
    assert res.mask.sum() >= eng.scenario.k_direct
    ds = eng.run_async(0.0, MSG, n_deliveries=50)
    assert len(ds) == 50


# ---------------------------------------------------------------------------
# contact-window cohorts (fused-pipeline batching unit)
# ---------------------------------------------------------------------------

def test_round_cohorts_partition_deliveries():
    eng = Engine(get_scenario("mega-1000"))
    res = eng.run_round(0.0, MSG)
    cohorts = res.cohorts()
    assert cohorts, "round delivered nothing"
    # cohorts partition the deliveries, keyed by (station, window)
    flat = [d for c in cohorts for d in c.deliveries]
    assert len(flat) == len(res.deliveries)
    for c in cohorts:
        assert c.sats == [d.sat for d in c.deliveries]
        for d in c.deliveries:
            assert d.station == c.station
            assert d.window == c.window
            assert np.isfinite(d.window)
        assert c.t_first <= c.t_last
    # ordered by first delivery
    firsts = [c.t_first for c in cohorts]
    assert firsts == sorted(firsts)


def test_async_deliveries_carry_windows():
    from repro.sim import group_cohorts
    eng = Engine(Scenario(walker=Walker(n_sats=20, n_planes=4),
                          stations=(GroundStation(),)))
    ds = eng.run_async(0.0, MSG, n_deliveries=30)
    assert all(np.isfinite(d.window) for d in ds)
    cohorts = group_cohorts(ds)
    assert sum(len(c.deliveries) for c in cohorts) == len(ds)
    # a delivery must land inside (or after the rise of) its window
    assert all(d.t_done >= d.window for d in ds)


def test_space_runner_cohort_measure_matches_probe():
    """measure='cohort' serializes the actual per-round state, batched per
    contact window — for a quant codec (static sizes) bytes_up must equal
    the probe-based accounting exactly."""
    from repro.core.compression import UniformQuantizer
    from repro.core.error_feedback import EFChannel

    n_agents, dim = 12, 40
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=20,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    alg = FedLT(loss=loss, n_epochs=1, gamma=0.005, rho=20.0,
                uplink=EFChannel(C), downlink=EFChannel(C))
    st0 = alg.init(jnp.zeros((dim,)), n_agents)
    sc = Scenario(walker=Walker(n_sats=n_agents, n_planes=3),
                  stations=(GroundStation(),))
    _, logs_probe = SpaceRunner(Engine(sc), compressor=C).run(
        alg, st0, data, 3, jax.random.PRNGKey(2))
    _, logs_cohort = SpaceRunner(Engine(sc), compressor=C,
                                 measure="cohort").run(
        alg, st0, data, 3, jax.random.PRNGKey(2))
    assert [l.bytes_up for l in logs_cohort] == \
        [l.bytes_up for l in logs_probe]


def test_group_cohorts_property():
    """Hypothesis property: cohorts exactly partition the delivery list;
    cohort keys (station, window) are disjoint; cohorts are time-ordered
    by first delivery; NaN-window deliveries stay singletons."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    from repro.sim import Delivery, group_cohorts

    window_vals = st.one_of(
        st.sampled_from([0.0, 600.0, 1200.0, float("nan")]))
    delivery = st.tuples(st.integers(0, 30), st.integers(0, 2),
                         window_vals, st.floats(0.0, 1e4))

    @hyp.given(st.lists(delivery, max_size=40))
    @hyp.settings(deadline=None, max_examples=200)
    def check(raw):
        # deliveries arrive in t_done order, as the engine produces them
        raw = sorted(raw, key=lambda r: r[3])
        ds = [Delivery(sat=s, t_done=t, t_start=0.0, gateway=s, station=g,
                       hops=0, nbytes=1.0, window=w)
              for (s, g, w, t) in raw]
        cohorts = group_cohorts(ds)
        # exact partition: every delivery in exactly one cohort, order kept
        flat = [d for c in cohorts for d in c.deliveries]
        assert sorted(map(id, flat)) == sorted(map(id, ds))
        for c in cohorts:
            assert c.sats == [d.sat for d in c.deliveries]
            ts = [d.t_done for d in c.deliveries]
            assert ts == sorted(ts)
            for d in c.deliveries:
                assert d.station == c.station
                if d.window == d.window:
                    assert d.window == c.window
        # disjoint windows: no two cohorts share a (station, window) key
        keys = [(c.station, c.window) for c in cohorts
                if c.window == c.window]
        assert len(keys) == len(set(keys))
        # NaN-window deliveries each form their own singleton cohort
        n_nan = sum(1 for d in ds if d.window != d.window)
        assert sum(1 for c in cohorts
                   if c.window != c.window) == n_nan
        assert all(len(c.deliveries) == 1 for c in cohorts
                   if c.window != c.window)
        # time-ordered by first delivery
        firsts = [c.t_first for c in cohorts]
        assert firsts == sorted(firsts)

    check()


def test_space_runner_rejects_bad_measure():
    sc = Scenario(walker=Walker(n_sats=4, n_planes=2),
                  stations=(GroundStation(),))
    with pytest.raises(ValueError, match="measure"):
        SpaceRunner(Engine(sc), measure="wat")
    # cohort accounting needs per-round RoundResults — sync only
    with pytest.raises(ValueError, match="sync"):
        SpaceRunner(Engine(sc), mode="async", measure="cohort")
