"""Checkpoint store: roundtrip + mismatch detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import restore, save


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": (jnp.zeros((2,)), jnp.array(3, jnp.int32))}
    path = str(tmp_path / "ck")
    save(path, tree, step=7)
    out = restore(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck")
    save(path, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(path, {"a": jnp.zeros((3, 2))})
