"""Checkpoint store: roundtrip, mismatch detection, and mid-training
resume (coordinator model + EF residual restored bit-identically)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import latest_step, restore, save


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": (jnp.zeros((2,)), jnp.array(3, jnp.int32))}
    path = str(tmp_path / "ck")
    save(path, tree, step=7)
    out = restore(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck")
    save(path, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(path, {"a": jnp.zeros((3, 2))})


# ---------------------------------------------------------------------------
# mid-training resume (ISSUE 6 satellite): a FedLT run checkpointed after
# 3 rounds and restored — full state incl. the uplink EF residual c_up and
# the coordinator's received wire z_hat — must continue bit-identically
# with the uninterrupted run
# ---------------------------------------------------------------------------

def _fedlt_problem(n_agents=12, dim=16):
    from repro.core.compression import UniformQuantizer
    from repro.core.error_feedback import EFChannel
    from repro.core.fedlt import FedLT
    from repro.data.logistic import generate, make_local_loss
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=40,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    q = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    alg = FedLT(loss=loss, n_epochs=2, gamma=0.005, rho=20.0,
                uplink=EFChannel(q), downlink=EFChannel(q))
    return alg, data, dim, n_agents


def test_fedlt_resume_bit_identical(tmp_path):
    alg, data, dim, n_agents = _fedlt_problem()
    step = jax.jit(lambda s, k: alg.round(
        s, data, jnp.ones((n_agents,), bool), k)[0])
    keys = jax.random.split(jax.random.PRNGKey(1), 6)

    state = alg.init(jnp.zeros((dim,)), n_agents)
    for k in range(3):
        state = step(state, keys[k])
    path = str(tmp_path / "mid")
    save(path, state, step=3)
    assert latest_step(str(tmp_path)) == 3

    # uninterrupted reference: 3 more rounds on the live state
    ref = state
    for k in range(3, 6):
        ref = step(ref, keys[k])

    # resumed run: restore into a FRESH init template, then same 3 rounds
    resumed = restore(path, alg.init(jnp.zeros((dim,)), n_agents))
    # the restore itself must already be bitwise (model, aux, EF caches,
    # received wire — every field of FedLTState)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in range(3, 6):
        resumed = step(resumed, keys[k])

    for name, a, b in zip(ref._fields, ref, resumed):
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb),
                err_msg=f"field {name} diverged after resume")
