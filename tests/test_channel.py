"""Lossy-channel subsystem: link budget, outages, ARQ, engine wiring, and
the loss-robust error-feedback path through SpaceRunner.

The load-bearing regression here is loss=0 exactness: a default
``ChannelModel()`` must reproduce the lossless simulator's ``Delivery``
byte/time accounting bit-for-bit (acceptance criterion of the channel
subsystem)."""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.channel import (ChannelModel, ConjunctionBlackout, LinkBudget,
                           RainFade, SelectiveRepeatARQ, counter_uniform,
                           counter_uniforms, slant_range)  # noqa: E402
from repro.constellation.links import LinkModel, message_bytes  # noqa: E402
from repro.constellation.orbits import GroundStation, Walker  # noqa: E402
from repro.sim import Engine, Scenario, get_scenario  # noqa: E402

MSG = message_bytes(10000, 10.0)
W, GS = Walker(), GroundStation()


def _tx(ch, nbytes=MSG, t0=0.0, wend=1e9, sat=0, seed=0, win=5):
    return ch.transmit(LinkModel(), nbytes, walker=W, station_obj=GS,
                       gateway=sat, sat=sat, t_start=t0, window_end=wend,
                       seed=seed, station=0, window_id=win)


# ---------------------------------------------------------------------------
# link budget
# ---------------------------------------------------------------------------

def test_link_budget_monotone_in_elevation():
    lb = LinkBudget()
    els = [10.0, 25.0, 45.0, 70.0, 90.0]
    slants = [slant_range(e, lb.altitude) for e in els]
    snrs = [lb.snr_db(e) for e in els]
    ps = [lb.p_seg(e, 1024) for e in els]
    rates = [lb.rate(e) for e in els]
    assert slants == sorted(slants, reverse=True)
    assert snrs == sorted(snrs)
    assert ps == sorted(ps, reverse=True)
    assert rates == sorted(rates)
    assert 0.0 <= min(ps) and max(ps) <= 1.0


def test_fade_degrades_the_link():
    lb = LinkBudget()
    assert lb.snr_db(45.0, fade_db=6.0) == pytest.approx(lb.snr_db(45.0) - 6.0)
    assert lb.p_seg(45.0, 1024, fade_db=12.0) >= lb.p_seg(45.0, 1024)
    assert lb.rate(45.0, fade_db=12.0) <= lb.rate(45.0)


def test_slant_range_geometry_limits():
    # zenith pass = altitude; horizon pass = much longer
    assert slant_range(90.0, 550e3) == pytest.approx(550e3)
    assert slant_range(0.0, 550e3) > 2000e3


def test_single_sat_propagation_matches_walker():
    """elevation_at's one-orbit propagation must agree with the full
    constellation sweep (it exists so budget-channel scheduling is O(1)
    per query, not O(n_sats))."""
    from repro.channel.budget import elevation_at, sat_position
    from repro.constellation.orbits import elevation
    rng = np.random.default_rng(0)
    for _ in range(20):
        s = int(rng.integers(0, W.n_sats))
        t = float(rng.uniform(0.0, 86400.0))
        np.testing.assert_allclose(sat_position(W, s, t),
                                   W.positions(np.asarray(t))[s],
                                   rtol=1e-12)
        el_full = float(elevation(W.positions(np.asarray(t)),
                                  GS.position(np.asarray(t)))[s])
        assert elevation_at(W, GS, s, t) == pytest.approx(el_full,
                                                          abs=1e-9)


# ---------------------------------------------------------------------------
# counter RNG + outage processes
# ---------------------------------------------------------------------------

def test_counter_uniforms_deterministic_and_vectorized():
    u1 = counter_uniform(42, 1, 2, 3)
    u2 = counter_uniform(42, 1, 2, 3)
    assert u1 == u2 and 0.0 <= u1 < 1.0
    assert counter_uniform(43, 1, 2, 3) != u1
    segs = np.arange(100)
    vec = counter_uniforms(42, 7, segs)
    assert vec.shape == (100,)
    assert vec[13] == counter_uniform(42, 7, 13)
    # decent uniformity even over sequential counters
    assert 0.3 < vec.mean() < 0.7


def test_rain_fade_deterministic_and_gated_by_p_fade():
    rf = RainFade(p_fade=0.5, mean_db=6.0)
    fades = [rf.fade_db(0, 0, s, 3) for s in range(200)]
    assert fades == [rf.fade_db(0, 0, s, 3) for s in range(200)]
    n_clear = sum(f == 0.0 for f in fades)
    assert 60 < n_clear < 140          # ~ p_fade = 0.5
    assert all(f >= 0.0 for f in fades)
    assert RainFade(p_fade=0.0).fade_db(0, 0, 1, 2) == 0.0


def test_conjunction_blackout_periodic():
    bo = ConjunctionBlackout(period=100.0, duration=10.0, station_phase=0.0)
    assert bo.blacked_out(0, 5.0)
    assert not bo.blacked_out(0, 15.0)
    assert bo.blacked_out(0, 105.0)
    assert bo.next_clear(0, 5.0) == pytest.approx(10.0)
    assert bo.next_clear(0, 15.0) == pytest.approx(15.0)
    # station phase shifts the window
    bo2 = ConjunctionBlackout(period=100.0, duration=10.0,
                              station_phase=50.0)
    assert bo2.blacked_out(1, 55.0) and not bo2.blacked_out(0, 55.0)


# ---------------------------------------------------------------------------
# selective-repeat ARQ
# ---------------------------------------------------------------------------

def test_arq_lossless_time_identity():
    """loss=0 → exactly LinkModel.gs_time, same float expression."""
    r = _tx(ChannelModel(), t0=100.0)
    assert r.t_done == 100.0 + LinkModel().gs_time(MSG)
    assert r.delivered and r.retries == 0
    assert r.nbytes == MSG and r.nbytes_attempted == MSG


def test_arq_retransmissions_cost_time_and_bytes():
    ch = ChannelModel(loss=0.3, arq=SelectiveRepeatARQ(max_rounds=6))
    r = _tx(ch)
    r0 = _tx(ChannelModel())
    assert r.delivered
    assert r.retries > 0
    assert r.nbytes_attempted > MSG
    assert r.t_done > r0.t_done
    # deterministic: same counters → same outcome
    assert _tx(ch) == r
    # different window id → different erasure pattern eventually
    assert any(_tx(ch, win=w) != r for w in range(1, 12))


def test_arq_truncates_mid_window():
    ch = ChannelModel(loss=0.3)
    big = 5e6                                  # 0.42 s on the 100 Mbit link
    r = _tx(ch, nbytes=big, wend=0.2)
    assert not r.delivered
    assert r.nbytes == 0.0
    assert r.t_done == pytest.approx(0.2)      # link held to window end
    assert 0.0 < r.nbytes_attempted < big


def test_arq_gives_up_after_max_rounds():
    ch = ChannelModel(loss=1.0, arq=SelectiveRepeatARQ(max_rounds=3))
    r = _tx(ch)
    assert not r.delivered
    assert r.retries == 2                  # 3 rounds = initial + 2 retx
    assert r.nbytes_attempted == pytest.approx(3 * MSG)


def test_arq_segment_sizes_cover_message():
    arq = SelectiveRepeatARQ(seg_bytes=1024)
    sizes = arq.segment_sizes(2500.0)
    assert sum(sizes) == pytest.approx(2500.0)
    assert sizes[:2] == [1024.0, 1024.0] and sizes[2] == pytest.approx(452.0)
    assert arq.segment_sizes(10.0) == [10.0]


# ---------------------------------------------------------------------------
# engine wiring — THE loss=0 exactness regression + lossy behaviour
# ---------------------------------------------------------------------------

def test_engine_lossless_channel_reproduces_accounting_exactly():
    """Acceptance: with loss=0 the channel path reproduces today's
    Delivery byte/time accounting exactly — sync and async."""
    sc = get_scenario("walker-kiruna")
    sc0 = dataclasses.replace(sc, channel=ChannelModel())
    e_plain, e_chan = Engine(sc), Engine(sc0)
    t = 0.0
    for _ in range(3):
        r1, r2 = e_plain.run_round(t, MSG), e_chan.run_round(t, MSG)
        assert np.array_equal(r1.mask, r2.mask)
        assert r1.duration == r2.duration
        assert len(r1.deliveries) == len(r2.deliveries)
        for a, b in zip(r1.deliveries, r2.deliveries):
            assert (a.sat, a.t_done, a.nbytes, a.station, a.window,
                    a.gateway, a.hops) == \
                   (b.sat, b.t_done, b.nbytes, b.station, b.window,
                    b.gateway, b.hops)
            assert b.delivered and b.retries == 0
            assert b.nbytes_attempted == a.nbytes
        t += r1.duration
    d1 = Engine(sc).run_async(0.0, MSG, n_deliveries=40)
    d2 = Engine(sc0).run_async(0.0, MSG, n_deliveries=40)
    assert [(d.sat, d.t_done) for d in d1] == [(d.sat, d.t_done) for d in d2]


def test_engine_lossy_round_masks_only_delivered():
    sc = dataclasses.replace(
        get_scenario("walker-kiruna"),
        channel=ChannelModel(loss=0.4, arq=SelectiveRepeatARQ(max_rounds=2)))
    res = Engine(sc).run_round(0.0, MSG)
    ok = [d for d in res.deliveries if d.delivered]
    lost = [d for d in res.deliveries if not d.delivered]
    assert lost, "expected channel losses at p=0.4 / 2 rounds"
    assert res.mask.sum() == len(ok)
    for d in res.deliveries:
        assert d.nbytes_attempted >= d.nbytes
        if not d.delivered:
            assert d.nbytes == 0.0
    # scheduled-but-lost satellites are not in the mask
    assert all(not res.mask[d.sat] for d in lost)
    # deterministic rebuild
    res2 = Engine(sc).run_round(0.0, MSG)
    assert [(d.sat, d.t_done, d.delivered) for d in res.deliveries] == \
           [(d.sat, d.t_done, d.delivered) for d in res2.deliveries]


def test_engine_lossy_async_counts_only_successes():
    sc = dataclasses.replace(
        get_scenario("walker-kiruna"),
        channel=ChannelModel(loss=0.4, arq=SelectiveRepeatARQ(max_rounds=2)))
    recs = Engine(sc).run_async(0.0, MSG, n_deliveries=30)
    ok = [d for d in recs if d.delivered]
    assert len(ok) == 30
    assert len(recs) > 30              # failures interleaved in the record
    ts = [d.t_done for d in recs]
    assert ts == sorted(ts)


def test_blackout_masks_windows_and_survives_extension():
    sc = dataclasses.replace(
        get_scenario("walker-kiruna"),
        channel=ChannelModel(blackout=ConjunctionBlackout(period=3600.0,
                                                          duration=600.0)))
    eng = Engine(sc)
    blocked = eng._blocked[0]
    assert blocked is not None and blocked.any()
    before = blocked.copy()
    rises_before = eng.plan.rises[0].copy()
    eng.ensure(4 * eng.plan.horizon)
    w = min(before.shape[1], eng._blocked[0].shape[1])
    keep = (np.isfinite(rises_before[:, :w])
            & np.isfinite(eng.plan.rises[0][:, :w]))
    np.testing.assert_array_equal(before[:, :w][keep],
                                  eng._blocked[0][:, :w][keep])


@pytest.mark.parametrize("name", ["lossy-uplink", "rain-fade",
                                  "ka-band-degraded", "conjunction-outage"])
def test_channel_scenarios_run_and_deliver(name):
    eng = Engine(get_scenario(name), seed=1)
    t, ok = 0.0, 0
    for _ in range(4):
        r = eng.run_round(t, MSG)
        t += r.duration
        ok += int(r.mask.sum())
    assert ok >= 1, f"{name} delivered nothing in 4 rounds"


def test_mega_1000_lossy_registered():
    sc = get_scenario("mega-1000-lossy")
    assert sc.walker.n_sats == 1000 and sc.channel is not None


# ---------------------------------------------------------------------------
# SpaceRunner: loss-robust EF
# ---------------------------------------------------------------------------

def _problem(n_agents=20, dim=30):
    from repro.data.logistic import generate, make_local_loss, solve_global
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=60,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    return data, loss, solve_global(data, eps=50.0)


def _fedlt(loss, ef=True):
    from repro.core.compression import UniformQuantizer
    from repro.core.error_feedback import EFChannel
    from repro.core.fedlt import FedLT
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    return FedLT(loss=loss, n_epochs=3, gamma=0.005, rho=20.0,
                 uplink=EFChannel(C, enabled=ef),
                 downlink=EFChannel(C, enabled=ef)), C


def test_revert_lost_wires_semantics():
    """Lost agents: coordinator wire AND uplink cache revert; delivered
    agents keep the round's values; x/z always advance."""
    from repro.core.fedlt_sat import _revert_lost_wires

    data, loss, _ = _problem()
    alg, _C = _fedlt(loss)
    st0 = alg.init(jnp.zeros((30,)), 20)
    active = jnp.ones((20,), bool)
    st1, _ = alg.round(st0, data, active, jax.random.PRNGKey(1))
    lost = np.zeros(20, bool)
    lost[[3, 7]] = True
    fixed = _revert_lost_wires(st1, st0, "z_hat", jnp.asarray(lost),
                               absorb=True)
    for leaf_new, leaf_old, leaf_fix in zip(
            jax.tree_util.tree_leaves(st1.z_hat),
            jax.tree_util.tree_leaves(st0.z_hat),
            jax.tree_util.tree_leaves(fixed.z_hat)):
        np.testing.assert_array_equal(leaf_fix[lost], leaf_old[lost])
        np.testing.assert_array_equal(leaf_fix[~lost], leaf_new[~lost])
    for leaf_new, leaf_old, leaf_fix in zip(
            jax.tree_util.tree_leaves(st1.c_up),
            jax.tree_util.tree_leaves(st0.c_up),
            jax.tree_util.tree_leaves(fixed.c_up)):
        np.testing.assert_array_equal(leaf_fix[lost], leaf_old[lost])
        np.testing.assert_array_equal(leaf_fix[~lost], leaf_new[~lost])
    # x advances for everyone (the satellite did train)
    for leaf_new, leaf_fix in zip(jax.tree_util.tree_leaves(st1.x),
                                  jax.tree_util.tree_leaves(fixed.x)):
        np.testing.assert_array_equal(leaf_fix, leaf_new)


def test_space_runner_lossless_channel_logs_match_plain():
    from repro.core.fedlt_sat import SpaceRunner

    data, loss, _ = _problem()
    sc = Scenario(name="small", walker=Walker(n_sats=20, n_planes=4),
                  stations=(GroundStation(),), k_direct=3, n_relay=2)
    alg, C = _fedlt(loss)
    st0 = alg.init(jnp.zeros((30,)), 20)
    _, logs_plain = SpaceRunner(Engine(sc), compressor=C).run(
        alg, st0, data, 4, jax.random.PRNGKey(2))
    _, logs_chan = SpaceRunner(Engine(sc), compressor=C,
                               channel=ChannelModel()).run(
        alg, st0, data, 4, jax.random.PRNGKey(2))
    assert [(l.time, l.bytes_up, l.n_active) for l in logs_plain] == \
           [(l.time, l.bytes_up, l.n_active) for l in logs_chan]
    assert all(l.n_lost == 0 for l in logs_chan)


def test_space_runner_lossy_accounts_losses_and_air_bytes():
    from repro.core.fedlt_sat import SpaceRunner

    data, loss, _ = _problem()
    sc = Scenario(name="small", walker=Walker(n_sats=20, n_planes=4),
                  stations=(GroundStation(),), k_direct=3, n_relay=2)
    alg, C = _fedlt(loss)
    st0 = alg.init(jnp.zeros((30,)), 20)
    ch = ChannelModel(loss=0.25, arq=SelectiveRepeatARQ(seg_bytes=16,
                                                        max_rounds=2))
    _, logs = SpaceRunner(Engine(sc), compressor=C, channel=ch).run(
        alg, st0, data, 8, jax.random.PRNGKey(2))
    assert sum(l.n_lost for l in logs) > 0
    _, logs0 = SpaceRunner(Engine(sc), compressor=C,
                           channel=ChannelModel()).run(
        alg, st0, data, 8, jax.random.PRNGKey(2))
    # retransmissions make air bytes strictly exceed the lossless ledger
    assert logs[-1].bytes_up > logs0[-1].bytes_up


def test_cohort_measure_accounts_transmitted_wire_for_lost_sats():
    """Sparse-codec cohort accounting must measure the PRE-revert wire (what
    actually went on the air), not the reverted coordinator state — at
    loss=1 every attempt is lost, yet each transmitted TopK payload still
    carries k values, far above the header-only size of the all-zeros
    init wire the revert restores."""
    from repro.core.compression import TopK
    from repro.core.error_feedback import EFChannel
    from repro.core.fedlt import FedLT
    from repro.core.fedlt_sat import SpaceRunner

    data, loss, _ = _problem(n_agents=20, dim=30)
    C = TopK(fraction=0.25)
    alg = FedLT(loss=loss, n_epochs=2, gamma=0.005, rho=20.0,
                uplink=EFChannel(C), downlink=EFChannel(C))
    st0 = alg.init(jnp.zeros((30,)), 20)
    sc = Scenario(name="small", walker=Walker(n_sats=20, n_planes=4),
                  stations=(GroundStation(),), k_direct=3, n_relay=2)
    ch = ChannelModel(loss=1.0, arq=SelectiveRepeatARQ(seg_bytes=1 << 20,
                                                       max_rounds=1))
    _, logs = SpaceRunner(Engine(sc), compressor=C, measure="cohort",
                          channel=ch).run(alg, st0, data, 2,
                                          jax.random.PRNGKey(2))
    n_attempts = sum(l.n_lost + l.n_active for l in logs)
    assert n_attempts > 0 and all(l.n_active == 0 for l in logs)
    # ~8 of 30 coords kept → ≥ 8·4 payload bytes per attempt, well above
    # the ~30-byte header-only floor of an empty sparse message
    assert logs[-1].bytes_up / n_attempts > 50.0


def test_loss_robust_ef_dominates_no_ef_on_walker_kiruna():
    """Acceptance claim (test-scale): at >= 10% segment loss on
    walker-kiruna, loss-robust EF beats no-EF optimality error.  The
    benchmark (`benchmarks/table_lossy_ef.py`) runs the full sweep."""
    from repro.core.fedlt import optimality_error
    from repro.core.fedlt_sat import SpaceRunner

    n_agents, dim = 100, 40
    from repro.data.logistic import generate, make_local_loss, solve_global
    data, _ = generate(jax.random.PRNGKey(0), n_agents=n_agents, m=40,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)
    ch = ChannelModel(loss=0.15, arq=SelectiveRepeatARQ(seg_bytes=4096,
                                                        max_rounds=1))
    errs = {}
    for ef in (True, False):
        alg, C = _fedlt(loss, ef=ef)
        st = alg.init(jnp.zeros((dim,)), n_agents)
        runner = SpaceRunner(Engine(get_scenario("walker-kiruna")),
                             compressor=C, channel=ch, loss_robust=ef)
        st, logs = runner.run(alg, st, data, 200, jax.random.PRNGKey(2))
        errs[ef] = float(optimality_error(st.x, x_star))
        assert sum(l.n_lost for l in logs) > 0
    assert errs[True] < errs[False], errs
