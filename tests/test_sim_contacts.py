"""Contact plans: interval extraction vs brute force, lookups, ISL topology."""
import numpy as np
import pytest

from repro.constellation.orbits import (GroundStation, Walker,
                                        in_plane_neighbors, isl_neighbors,
                                        visible)
from repro.sim import ContactPlan


def _reconstruct(plan, station, sat, ts):
    rec = np.zeros(len(ts), dtype=bool)
    for r, e in plan.windows(station, sat):
        rec |= (ts >= r) & (ts < e)
    return rec


def test_contact_plan_matches_bruteforce_scan():
    w = Walker()
    stations = (GroundStation(), GroundStation(lat=78.23, lon=15.39))
    dt = 30.0
    horizon = 2 * w.period
    plan = ContactPlan(w, stations, horizon=horizon, dt=dt)
    ts = np.arange(0.0, horizon, dt)
    for g, gs in enumerate(stations):
        vis = visible(w, gs, ts)
        for sat in [0, 3, 17, 42, 99]:
            np.testing.assert_array_equal(
                _reconstruct(plan, g, sat, ts), vis[:, sat],
                err_msg=f"station {g} sat {sat}")


def test_next_window_matches_windows_and_horizon():
    w = Walker(n_sats=20, n_planes=4)
    plan = ContactPlan(w, (GroundStation(),), horizon=w.period, dt=20.0)
    for sat in range(0, 20, 3):
        wins = plan.windows(0, sat)
        if not wins:
            assert plan.next_window(sat, 0.0) is None
            continue
        r0, e0 = wins[0]
        got = plan.next_window(sat, 0.0)
        assert got is not None and got[0] == r0 and got[1] == e0
        # query inside the window → same window (in contact)
        mid = 0.5 * (r0 + e0)
        got = plan.next_window(sat, mid)
        assert got is not None and got[0] == r0
        assert plan.in_contact(sat, mid) == 0
        # query past the last set time → None
        assert plan.next_window(sat, wins[-1][1] + 1.0) is None or \
            plan.next_window(sat, wins[-1][1] + 1.0)[0] > wins[-1][1]


def test_ensure_extends_horizon():
    w = Walker(n_sats=20, n_planes=4)
    plan = ContactPlan(w, (GroundStation(),), horizon=1800.0, dt=20.0)
    h0 = plan.horizon
    plan.ensure(4 * h0)
    assert plan.horizon >= 4 * h0
    # windows still match brute force after the rebuild
    ts = np.arange(0.0, plan.horizon, 20.0)
    vis = visible(w, GroundStation(), ts)
    np.testing.assert_array_equal(_reconstruct(plan, 0, 5, ts), vis[:, 5])


def test_vectorized_lookup_agrees_with_scalar():
    w = Walker()
    plan = ContactPlan(w, (GroundStation(), GroundStation(lat=68.32, lon=-133.55)),
                       horizon=w.period, dt=30.0)
    for t in [0.0, 777.0, 3000.0]:
        start, end, station = plan.next_windows_all(t)
        for sat in [0, 11, 55, 99]:
            got = plan.next_window(sat, t)
            if got is None:
                assert not np.isfinite(start[sat])
            else:
                assert start[sat] == pytest.approx(max(got[0], t))
                assert end[sat] == pytest.approx(got[1])
                assert station[sat] == got[2]


def test_in_plane_wraparound_at_slot_zero():
    w = Walker(n_sats=100, n_planes=10)
    # slot 0 wraps to the last slot of the same plane
    a, b = in_plane_neighbors(w, 0)
    assert (a, b) == (9, 1)
    # last slot wraps to slot 0
    a, b = in_plane_neighbors(w, 9)
    assert (a, b) == (8, 0)
    # plane 3, slot 0
    a, b = in_plane_neighbors(w, 30)
    assert (a, b) == (39, 31)


def test_isl_neighbors_cross_plane_seam():
    w = Walker(n_sats=100, n_planes=10)
    nbrs = isl_neighbors(w, 0)          # plane 0, slot 0
    assert set(nbrs) == {9, 1, 90, 10}  # ring pair + seam plane 9 + plane 1
    nbrs = isl_neighbors(w, 95)         # plane 9, slot 5 — seam to plane 0
    assert set(nbrs) == {94, 96, 85, 5}
    # in-plane only
    assert set(isl_neighbors(w, 0, cross_plane=False)) == {9, 1}


def test_isl_neighbors_degenerate_dedup():
    w = Walker(n_sats=4, n_planes=2)    # 2 planes, 2 slots: heavy overlap
    for s in range(4):
        nbrs = isl_neighbors(w, s)
        assert s not in nbrs
        assert len(nbrs) == len(set(nbrs))
