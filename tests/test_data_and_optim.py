"""Data pipeline determinism/heterogeneity + optimizer correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_variant
from repro.data.synthetic import agent_batches, make_batch, markov_tokens
from repro.optim.solvers import adam_init, adam_update, local_prox_gd, sgd


def test_markov_tokens_deterministic_and_in_range():
    a = markov_tokens(jax.random.PRNGKey(3), 4, 64, 1000)
    b = markov_tokens(jax.random.PRNGKey(3), 4, 64, 1000)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (4, 64)
    assert int(a.min()) >= 0 and int(a.max()) < 1000


def test_agents_heterogeneous_streams():
    cfg = smoke_variant(ARCHS["stablelm-1.6b"])
    batch = agent_batches(cfg, n_agents=3, batch_per_agent=2, seq=32,
                          round_idx=0)
    toks = np.asarray(batch["tokens"])
    assert not np.array_equal(toks[0], toks[1])  # heterogeneity


def test_vlm_batch_layout():
    cfg = smoke_variant(ARCHS["qwen2-vl-7b"])
    b = make_batch(cfg, jax.random.PRNGKey(0), 2, 64)
    s_vis = b["extra_embeds"].shape[1]
    assert b["tokens"].shape[1] + s_vis == 64
    assert b["labels"].shape == (2, 64)
    assert bool((b["labels"][:, :s_vis] == -1).all())  # vision not predicted
    assert b["positions"].shape == (3, 2, 64)


def test_sgd_and_adam_descend_quadratic():
    def loss(p):
        return jnp.sum((p - 3.0) ** 2)

    p = jnp.zeros((5,))
    for _ in range(50):
        g = jax.grad(loss)(p)
        p, _ = sgd(p, g, lr=0.1)
    assert float(loss(p)) < 1e-6

    p = jnp.zeros((5,))
    st = adam_init(p)
    for _ in range(300):
        g = jax.grad(loss)(p)
        p, st = adam_update(p, g, st, lr=0.1)
    assert float(loss(p)) < 1e-4


def test_local_prox_gd_solves_anchored_problem():
    """w* of  f(w) + ‖w−v‖²/(2ρ)  for quadratic f has closed form."""
    A = jnp.diag(jnp.array([1.0, 2.0, 4.0]))
    b = jnp.array([1.0, -1.0, 0.5])
    v = jnp.array([0.3, 0.3, 0.3])
    rho = 2.0

    def grad_fn(w, _):
        return A @ w - b

    w = local_prox_gd(grad_fn, jnp.zeros(3), v, None, n_epochs=500,
                      gamma=0.2, rho=rho)
    w_star = jnp.linalg.solve(A + jnp.eye(3) / rho, b + v / rho)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_star),
                               rtol=1e-4, atol=1e-5)
