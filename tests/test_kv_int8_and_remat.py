"""Beyond-paper optimizations: int8 KV cache and two-level remat.

Correctness guards for the §Perf iterations:
  * int8 KV decode logits stay close to the bf16-cache logits;
  * remat_group>1 computes bit-comparable gradients to baseline remat.
"""
import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS, smoke_variant
from repro.data.synthetic import make_batch
from repro.models.transformer import forward, init_cache, init_params, lm_loss

B = 2


def test_int8_kv_cache_close_to_fp():
    cfg = smoke_variant(ARCHS["stablelm-1.6b"])
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = make_batch(cfg, jax.random.PRNGKey(1), B, 49)["tokens"]

    outs = {}
    for quant in (False, True):
        c = dataclasses.replace(cfg, kv_cache_int8=quant)
        cache = init_cache(c, B, s_max=64)
        pre = forward(params, c, {"tokens": toks[:, :48]}, cache=cache,
                      backend="xla")
        dec = forward(params, c, {"tokens": toks[:, 48:]}, cache=pre.cache,
                      backend="xla")
        outs[quant] = np.asarray(dec.logits[:, 0], np.float32)
    # int8 KV: logits agree to ~1e-2 relative on smoke scale
    rel = np.abs(outs[True] - outs[False]) / (np.abs(outs[False]) + 1e-3)
    assert np.median(rel) < 0.05
    corr = np.corrcoef(outs[True].ravel(), outs[False].ravel())[0, 1]
    assert corr > 0.999


def test_remat_group_same_loss_and_grads():
    base = smoke_variant(ARCHS["h2o-danube-3-4b"])
    # 4 scan repeats so grouping by 2 is non-trivial
    cfg = dataclasses.replace(base, scan_repeats=4, n_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(2), B, 64)

    grads = {}
    for g in (1, 2):
        c = dataclasses.replace(cfg, remat_group=g)
        loss, grad = jax.value_and_grad(lambda p: lm_loss(p, c, batch))(params)
        grads[g] = (float(loss), grad)
    assert abs(grads[1][0] - grads[2][0]) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(grads[1][1]),
                    jax.tree_util.tree_leaves(grads[2][1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)
