"""EFChannel coverage: telescoping property + fused-channel equivalence.

The paper's §2.2 invariant — no information is ever lost through an EF
channel — is the telescoping identity

    Σ_k wire_k + cache_K = Σ_k msg_k        (cache_0 = 0)

which must hold for EVERY compressor, over pytrees, and through the fused
kernel path (``EFChannel.send_fused``).  A hypothesis variant sweeps
random shapes/rounds when hypothesis is installed; the deterministic
sweep below always runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (Identity, ScaledSign, TopK,
                                    UniformQuantizer)
from repro.core.error_feedback import EFChannel

QUANT = UniformQuantizer(levels=50, vmin=-2.0, vmax=2.0, clip=True)


def _run_channel(ch, msgs, tree=False):
    """Thread ``msgs`` (R, n) through the channel; returns (Σ wires + final
    cache, Σ msgs) as flat numpy arrays."""
    def as_tree(x):
        return {"a": x[:7], "b": x[7:].reshape(3, -1)} if tree else x

    cache = jax.tree_util.tree_map(jnp.zeros_like, as_tree(msgs[0]))
    total = jax.tree_util.tree_map(jnp.zeros_like, as_tree(msgs[0]))
    for r in range(msgs.shape[0]):
        wire, cache = ch.send(jax.random.PRNGKey(r), as_tree(msgs[r]), cache)
        total = jax.tree_util.tree_map(jnp.add, total, wire)
    lhs = jnp.concatenate([x.reshape(-1) for x in
                           jax.tree_util.tree_leaves(
                               jax.tree_util.tree_map(jnp.add, total, cache))])
    rhs = np.asarray(msgs).sum(axis=0).reshape(-1)
    return np.asarray(lhs), rhs


@pytest.mark.parametrize("name,compressor", [
    ("quant", QUANT),
    ("topk", TopK(fraction=0.3)),
    ("sign", ScaledSign()),
    ("identity", Identity()),
])
@pytest.mark.parametrize("tree", [False, True])
@pytest.mark.parametrize("seed,rounds", [(0, 3), (1, 8), (2, 15)])
def test_ef_telescopes_to_uncompressed_sum(name, compressor, tree, seed,
                                           rounds):
    """compressed-plus-residual telescopes to the uncompressed sum."""
    msgs = jax.random.uniform(jax.random.PRNGKey(seed), (rounds, 25),
                              minval=-1.5, maxval=1.5)
    lhs, rhs = _run_channel(EFChannel(compressor), msgs, tree=tree)
    np.testing.assert_allclose(lhs, rhs, rtol=0, atol=1e-4)


def test_ef_disabled_does_not_telescope():
    """Sanity: without EF (Algorithm 1) the quantization error is LOST —
    the telescoping identity must fail for a coarse quantizer."""
    msgs = jax.random.uniform(jax.random.PRNGKey(3), (10, 25),
                              minval=-1.5, maxval=1.5)
    lhs, rhs = _run_channel(EFChannel(QUANT, enabled=False), msgs)
    assert np.abs(lhs - rhs).max() > 1e-3


def test_send_fused_matches_send():
    """The fused kernel path is the same channel: identical wires (the
    quantizer is deterministic) and identical caches, over pytrees."""
    ch = EFChannel(UniformQuantizer(levels=255, vmin=-1.0, vmax=1.0,
                                    clip=True))
    assert ch.fusable()
    key = jax.random.PRNGKey(0)
    msg = {"w": jax.random.normal(key, (8, 40)) * 0.3,
           "b": jax.random.normal(jax.random.fold_in(key, 1), (130,)) * 0.3}
    cache = ch.init_cache(msg)
    for r in range(4):
        wire_v, cache_v = ch.send(None, msg, cache)
        wire_f, cache_f = ch.send_fused(msg, cache)
        for a, b in zip(jax.tree_util.tree_leaves(wire_v),
                        jax.tree_util.tree_leaves(wire_f)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-7)
        for a, b in zip(jax.tree_util.tree_leaves(cache_v),
                        jax.tree_util.tree_leaves(cache_f)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-7)
        cache = cache_f
        msg = jax.tree_util.tree_map(
            lambda x: x * 0.9 + 0.01, msg)


def test_send_fused_telescopes():
    """Telescoping holds through the fused path too."""
    ch = EFChannel(UniformQuantizer(levels=50, vmin=-2.0, vmax=2.0,
                                    clip=True))
    msgs = jax.random.uniform(jax.random.PRNGKey(5), (8, 64),
                              minval=-1.5, maxval=1.5)
    cache = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for r in range(8):
        wire, cache = ch.send_fused(msgs[r], cache)
        total = total + wire
    np.testing.assert_allclose(np.asarray(total + cache),
                               np.asarray(msgs.sum(axis=0)),
                               rtol=0, atol=1e-4)


def test_not_fusable_cases():
    assert not EFChannel(TopK(fraction=0.5)).fusable()
    assert not EFChannel(UniformQuantizer(clip=False)).fusable()
    assert not EFChannel(QUANT, enabled=False).fusable()


# -- hypothesis sweep (optional dep, CI installs it) -----------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), rounds=st.integers(2, 10),
           n=st.integers(2, 80))
    def test_ef_telescopes_property(seed, rounds, n):
        msgs = jax.random.uniform(jax.random.PRNGKey(seed), (rounds, n),
                                  minval=-1.5, maxval=1.5)
        ch = EFChannel(UniformQuantizer(levels=8, vmin=-2, vmax=2,
                                        clip=True))
        lhs, rhs = _run_channel(ch, msgs)
        np.testing.assert_allclose(lhs, rhs, rtol=0, atol=1e-4)


# -- GroupedEFChannel: residuals at aggregation heads ----------------------

def test_grouped_ef_telescopes_per_group():
    """Per-group telescoping under churning membership AND head-wire
    loss with loss-robust revert: landed wires + final cache == every
    message each group's members ever offered."""
    from repro.core.error_feedback import GroupedEFChannel

    ch = GroupedEFChannel(QUANT)
    N, G, D = 12, 3, 7
    rng = np.random.default_rng(3)
    cache = ch.init_cache(jnp.zeros((N, D)), G)
    total_msgs = np.zeros((G, D))
    total_landed = np.zeros((G, D))
    for k in range(30):
        msgs = jnp.asarray(rng.normal(scale=0.1, size=(N, D))
                           .astype(np.float32))
        groups = jnp.asarray(rng.integers(-1, G, size=N), jnp.int32)
        wire, cache = ch.send(jax.random.PRNGKey(k), msgs, cache,
                              groups, G)
        total_msgs += np.asarray(ch.group_sum(msgs, groups, G))
        lost = jnp.asarray(rng.random(G) < 0.3)
        cache = ch.revert(cache, wire, lost)
        total_landed += np.asarray(wire) * (~np.asarray(lost))[:, None]
    np.testing.assert_allclose(total_landed + np.asarray(cache),
                               total_msgs, rtol=0, atol=1e-4)


def test_grouped_ef_matches_per_group_efchannel():
    """Grouped send == a plain EFChannel driven on the group sums: the
    head placement is EXACTLY leaf EF applied after the merge."""
    from repro.core.error_feedback import GroupedEFChannel

    ch, ef = GroupedEFChannel(QUANT), EFChannel(QUANT)
    N, G, D = 10, 4, 5
    rng = np.random.default_rng(4)
    cache_g = ch.init_cache(jnp.zeros((N, D)), G)
    cache_e = jnp.zeros((G, D))
    for k in range(8):
        msgs = jnp.asarray(rng.normal(scale=0.2, size=(N, D))
                           .astype(np.float32))
        groups = jnp.asarray(rng.integers(0, G, size=N), jnp.int32)
        kk = jax.random.PRNGKey(100 + k)
        w_g, cache_g = ch.send(kk, msgs, cache_g, groups, G)
        w_e, cache_e = ef.send(kk, ch.group_sum(msgs, groups, G), cache_e)
        assert np.array_equal(np.asarray(w_g), np.asarray(w_e))
        assert np.array_equal(np.asarray(cache_g), np.asarray(cache_e))


def test_grouped_ef_disabled_and_masking():
    from repro.core.error_feedback import GroupedEFChannel

    N, G, D = 6, 2, 3
    ch0 = GroupedEFChannel(Identity(), enabled=False)
    cache = ch0.init_cache(jnp.zeros((N, D)), G)
    msgs = jnp.arange(N * D, dtype=jnp.float32).reshape(N, D)
    groups = jnp.asarray([0, 0, 1, 1, -1, -1], jnp.int32)
    wire, cache2 = ch0.send(jax.random.PRNGKey(0), msgs, cache, groups, G)
    assert np.array_equal(np.asarray(cache), np.asarray(cache2))
    # -1 members contribute nothing; identity wire == exact group sums
    expect = np.stack([np.asarray(msgs[:2]).sum(0),
                       np.asarray(msgs[2:4]).sum(0)])
    np.testing.assert_array_equal(np.asarray(wire), expect)


def test_grouped_ef_revert_restores_corrected_state():
    """revert(new_cache, wire, lost) must restore cache + wire ==
    corrected for lost groups and leave landed groups untouched."""
    from repro.core.error_feedback import GroupedEFChannel

    ch = GroupedEFChannel(QUANT)
    N, G, D = 8, 2, 4
    rng = np.random.default_rng(5)
    msgs = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    groups = jnp.asarray(rng.integers(0, G, size=N), jnp.int32)
    cache0 = ch.init_cache(jnp.zeros((N, D)), G)
    wire, cache1 = ch.send(jax.random.PRNGKey(1), msgs, cache0, groups, G)
    corrected = np.asarray(ch.group_sum(msgs, groups, G))  # cache0 == 0
    lost = jnp.asarray([True, False])
    reverted = np.asarray(ch.revert(cache1, wire, lost))
    np.testing.assert_allclose(reverted[0], corrected[0], atol=1e-6)
    np.testing.assert_array_equal(reverted[1], np.asarray(cache1)[1])
