"""EFChannel coverage: telescoping property + fused-channel equivalence.

The paper's §2.2 invariant — no information is ever lost through an EF
channel — is the telescoping identity

    Σ_k wire_k + cache_K = Σ_k msg_k        (cache_0 = 0)

which must hold for EVERY compressor, over pytrees, and through the fused
kernel path (``EFChannel.send_fused``).  A hypothesis variant sweeps
random shapes/rounds when hypothesis is installed; the deterministic
sweep below always runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (Identity, ScaledSign, TopK,
                                    UniformQuantizer)
from repro.core.error_feedback import EFChannel

QUANT = UniformQuantizer(levels=50, vmin=-2.0, vmax=2.0, clip=True)


def _run_channel(ch, msgs, tree=False):
    """Thread ``msgs`` (R, n) through the channel; returns (Σ wires + final
    cache, Σ msgs) as flat numpy arrays."""
    def as_tree(x):
        return {"a": x[:7], "b": x[7:].reshape(3, -1)} if tree else x

    cache = jax.tree_util.tree_map(jnp.zeros_like, as_tree(msgs[0]))
    total = jax.tree_util.tree_map(jnp.zeros_like, as_tree(msgs[0]))
    for r in range(msgs.shape[0]):
        wire, cache = ch.send(jax.random.PRNGKey(r), as_tree(msgs[r]), cache)
        total = jax.tree_util.tree_map(jnp.add, total, wire)
    lhs = jnp.concatenate([x.reshape(-1) for x in
                           jax.tree_util.tree_leaves(
                               jax.tree_util.tree_map(jnp.add, total, cache))])
    rhs = np.asarray(msgs).sum(axis=0).reshape(-1)
    return np.asarray(lhs), rhs


@pytest.mark.parametrize("name,compressor", [
    ("quant", QUANT),
    ("topk", TopK(fraction=0.3)),
    ("sign", ScaledSign()),
    ("identity", Identity()),
])
@pytest.mark.parametrize("tree", [False, True])
@pytest.mark.parametrize("seed,rounds", [(0, 3), (1, 8), (2, 15)])
def test_ef_telescopes_to_uncompressed_sum(name, compressor, tree, seed,
                                           rounds):
    """compressed-plus-residual telescopes to the uncompressed sum."""
    msgs = jax.random.uniform(jax.random.PRNGKey(seed), (rounds, 25),
                              minval=-1.5, maxval=1.5)
    lhs, rhs = _run_channel(EFChannel(compressor), msgs, tree=tree)
    np.testing.assert_allclose(lhs, rhs, rtol=0, atol=1e-4)


def test_ef_disabled_does_not_telescope():
    """Sanity: without EF (Algorithm 1) the quantization error is LOST —
    the telescoping identity must fail for a coarse quantizer."""
    msgs = jax.random.uniform(jax.random.PRNGKey(3), (10, 25),
                              minval=-1.5, maxval=1.5)
    lhs, rhs = _run_channel(EFChannel(QUANT, enabled=False), msgs)
    assert np.abs(lhs - rhs).max() > 1e-3


def test_send_fused_matches_send():
    """The fused kernel path is the same channel: identical wires (the
    quantizer is deterministic) and identical caches, over pytrees."""
    ch = EFChannel(UniformQuantizer(levels=255, vmin=-1.0, vmax=1.0,
                                    clip=True))
    assert ch.fusable()
    key = jax.random.PRNGKey(0)
    msg = {"w": jax.random.normal(key, (8, 40)) * 0.3,
           "b": jax.random.normal(jax.random.fold_in(key, 1), (130,)) * 0.3}
    cache = ch.init_cache(msg)
    for r in range(4):
        wire_v, cache_v = ch.send(None, msg, cache)
        wire_f, cache_f = ch.send_fused(msg, cache)
        for a, b in zip(jax.tree_util.tree_leaves(wire_v),
                        jax.tree_util.tree_leaves(wire_f)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-7)
        for a, b in zip(jax.tree_util.tree_leaves(cache_v),
                        jax.tree_util.tree_leaves(cache_f)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-7)
        cache = cache_f
        msg = jax.tree_util.tree_map(
            lambda x: x * 0.9 + 0.01, msg)


def test_send_fused_telescopes():
    """Telescoping holds through the fused path too."""
    ch = EFChannel(UniformQuantizer(levels=50, vmin=-2.0, vmax=2.0,
                                    clip=True))
    msgs = jax.random.uniform(jax.random.PRNGKey(5), (8, 64),
                              minval=-1.5, maxval=1.5)
    cache = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for r in range(8):
        wire, cache = ch.send_fused(msgs[r], cache)
        total = total + wire
    np.testing.assert_allclose(np.asarray(total + cache),
                               np.asarray(msgs.sum(axis=0)),
                               rtol=0, atol=1e-4)


def test_not_fusable_cases():
    assert not EFChannel(TopK(fraction=0.5)).fusable()
    assert not EFChannel(UniformQuantizer(clip=False)).fusable()
    assert not EFChannel(QUANT, enabled=False).fusable()


# -- hypothesis sweep (optional dep, CI installs it) -----------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), rounds=st.integers(2, 10),
           n=st.integers(2, 80))
    def test_ef_telescopes_property(seed, rounds, n):
        msgs = jax.random.uniform(jax.random.PRNGKey(seed), (rounds, n),
                                  minval=-1.5, maxval=1.5)
        ch = EFChannel(UniformQuantizer(levels=8, vmin=-2, vmax=2,
                                        clip=True))
        lhs, rhs = _run_channel(ch, msgs)
        np.testing.assert_allclose(lhs, rhs, rtol=0, atol=1e-4)
