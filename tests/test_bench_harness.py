"""Bench harness: registry shape, JSON emission, and the perf-gate logic.

The comparator tests run on synthetic BENCH_*.json files — no timing in
tier-1.  Benchmark execution itself is covered by the CI perf-gate job
(``python -m repro.bench --tiny``).
"""
import json

import pytest

from repro.bench import BENCHMARKS, metric
from repro.bench.compare import compare_dirs, format_report
from repro.bench.__main__ import emit


def _write(directory, group, benches):
    emit({group: benches}, str(directory), tiny=False)


def test_registry_has_builtin_benchmarks():
    assert {"kernels.pack_throughput", "kernels.fused_pipeline",
            "sim.round_pipeline", "sim.engine_scale"} <= set(BENCHMARKS)
    for name, b in BENCHMARKS.items():
        assert name == b.name and name.startswith(f"{b.group}.")
        assert b.description


def test_metric_schema():
    m = metric(1.5, "x", higher_is_better=True, gate=True)
    assert m == {"value": 1.5, "unit": "x", "higher_is_better": True,
                 "gate": True}


def test_emit_writes_schema_json(tmp_path):
    _write(tmp_path, "sim", {"sim.fake": {
        "speedup": metric(2.0, "x", higher_is_better=True, gate=True)}})
    payload = json.loads((tmp_path / "BENCH_sim.json").read_text())
    assert payload["schema"] == 1
    assert payload["benchmarks"]["sim.fake"]["speedup"]["value"] == 2.0


@pytest.mark.parametrize("hib,new,expected", [
    (True, 2.0, "ok"),            # unchanged
    (True, 1.7, "ok"),            # within −20%
    (True, 1.5, "regression"),    # worse than −20%
    (True, 2.6, "improved"),      # better than +20%
    (False, 2.3, "ok"),           # lower-is-better within +20%
    (False, 2.5, "regression"),   # lower-is-better worse than +20%
    (False, 1.5, "improved"),
])
def test_gate_verdicts(tmp_path, hib, new, expected):
    base_dir, new_dir = tmp_path / "base", tmp_path / "new"
    _write(base_dir, "sim", {"sim.fake": {
        "m": metric(2.0, "x", higher_is_better=hib, gate=True)}})
    _write(new_dir, "sim", {"sim.fake": {
        "m": metric(new, "x", higher_is_better=hib, gate=True)}})
    passed, verdicts = compare_dirs(str(new_dir), str(base_dir), tol=0.2)
    (v,) = [v for v in verdicts if v.metric == "m"]
    assert v.status == expected
    assert passed == (expected != "regression")
    assert "gated" in format_report(verdicts, 0.2)


def test_ungated_metrics_never_fail(tmp_path):
    base_dir, new_dir = tmp_path / "base", tmp_path / "new"
    _write(base_dir, "kernels", {"kernels.fake": {
        "gbps": metric(10.0, "GB/s", higher_is_better=True)}})
    _write(new_dir, "kernels", {"kernels.fake": {
        "gbps": metric(1.0, "GB/s", higher_is_better=True)}})
    passed, verdicts = compare_dirs(str(new_dir), str(base_dir), tol=0.2)
    assert passed
    (v,) = [v for v in verdicts if v.metric == "gbps"]
    assert v.status == "info"


def test_tiny_subset_of_full_baseline_compares_clean(tmp_path):
    """A tiny run (subset of metrics) against a full baseline: UNGATED
    metrics only in the baseline are 'missing' informational rows — the
    CI contract (tiny runs always contain every gated metric)."""
    base_dir, new_dir = tmp_path / "base", tmp_path / "new"
    _write(base_dir, "sim", {"sim.fake": {
        "n64_speedup": metric(1.4, "x", higher_is_better=True, gate=True),
        "n10000_sats_per_sec": metric(9.0, "sats/s", higher_is_better=True),
    }})
    _write(new_dir, "sim", {"sim.fake": {
        "n64_speedup": metric(1.35, "x", higher_is_better=True, gate=True)}})
    passed, verdicts = compare_dirs(str(new_dir), str(base_dir), tol=0.2)
    assert passed
    statuses = {v.metric: v.status for v in verdicts}
    assert statuses["n64_speedup"] == "ok"
    assert statuses["n10000_sats_per_sec"] == "missing"


def test_gate_fails_closed_when_gated_metric_absent(tmp_path):
    """A GATED baseline metric the fresh run failed to produce (broken or
    skipped benchmark) must fail the gate, not report 'missing'."""
    base_dir, new_dir = tmp_path / "base", tmp_path / "new"
    _write(base_dir, "sim", {"sim.fake": {
        "speedup": metric(2.8, "x", higher_is_better=True, gate=True)}})
    _write(new_dir, "sim", {})          # benchmark skipped / crashed
    passed, verdicts = compare_dirs(str(new_dir), str(base_dir), tol=0.2)
    assert not passed
    (v,) = [v for v in verdicts if v.metric == "speedup"]
    assert v.status == "regression"
    assert "regression" in format_report(verdicts, 0.2)


def test_missing_baseline_files_pass(tmp_path):
    """No committed baselines at all (fresh repo) — gate passes vacuously."""
    new_dir = tmp_path / "new"
    _write(new_dir, "sim", {"sim.fake": {
        "m": metric(1.0, "x", higher_is_better=True, gate=True)}})
    passed, verdicts = compare_dirs(str(new_dir), str(tmp_path / "nope"),
                                    tol=0.2)
    assert passed
