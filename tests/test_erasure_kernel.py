"""Erasure-mask Pallas kernel: bit-exactness vs the ref oracle, counter-RNG
determinism, segment coherence, and statistical sanity."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ops  # noqa: E402
from repro.kernels.erasure_mask import (BLOCK_M, LANES, drop_threshold,
                                        erasure_mask)  # noqa: E402
from repro.kernels.ref import erasure_mask_ref  # noqa: E402


def _words(n, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                              2 ** 30).astype(jnp.uint32)


@pytest.mark.parametrize("n", [1, 64, 4096, BLOCK_M * LANES + 17,
                               3 * BLOCK_M * LANES])
@pytest.mark.parametrize("p", [0.0, 0.13, 0.5, 1.0])
def test_kernel_bit_exact_vs_oracle(n, p):
    w = _words(n)
    mk, kk = erasure_mask(w, p=p, seed=7, segment_words=32, interpret=True)
    mr, kr = erasure_mask_ref(w, p=p, seed=7, segment_words=32)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(kr))


@pytest.mark.parametrize("segment_words", [1, 8, 32, 100])
def test_kernel_bit_exact_across_segment_sizes(segment_words):
    w = _words(20000, seed=3)
    mk, kk = erasure_mask(w, p=0.3, seed=5, segment_words=segment_words,
                          interpret=True)
    mr, kr = erasure_mask_ref(w, p=0.3, seed=5, segment_words=segment_words)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(kr))


def test_p_zero_is_identity_and_p_one_erases_everything():
    w = _words(5000)
    m0, k0 = erasure_mask(w, p=0.0, seed=1)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(w))
    assert np.asarray(k0).all()
    m1, k1 = erasure_mask(w, p=1.0, seed=1)
    assert not np.asarray(m1).any()
    assert not np.asarray(k1).any()


def test_segment_fate_is_coherent():
    """Every word of a segment shares its segment's erasure decision."""
    w = jnp.ones(32 * 50, jnp.uint32)
    _, keep = erasure_mask(w, p=0.5, seed=2, segment_words=32)
    rows = np.asarray(keep).reshape(50, 32)
    assert all(len(set(r)) == 1 for r in rows)
    # and the decisions are not degenerate at p=0.5
    firsts = rows[:, 0]
    assert 0 < firsts.sum() < 50


def test_counter_rng_is_deterministic_and_seed_sensitive():
    w = _words(10000)
    _, k1 = erasure_mask(w, p=0.4, seed=11)
    _, k2 = erasure_mask(w, p=0.4, seed=11)
    _, k3 = erasure_mask(w, p=0.4, seed=12)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    assert not np.array_equal(np.asarray(k1), np.asarray(k3))


def test_mask_is_a_pure_function_of_flat_index():
    """Counter-based RNG: word i's fate never depends on the array length
    or tile decomposition — a longer stream's prefix matches exactly."""
    w_long = _words(3 * BLOCK_M * LANES)
    w_short = w_long[:5000]
    _, k_long = erasure_mask(w_long, p=0.25, seed=9, segment_words=16)
    _, k_short = erasure_mask(w_short, p=0.25, seed=9, segment_words=16)
    np.testing.assert_array_equal(np.asarray(k_long)[:5000],
                                  np.asarray(k_short))


def test_empirical_drop_fraction_tracks_p():
    n_seg = 20000
    w = jnp.ones(n_seg, jnp.uint32)
    for p in (0.1, 0.5, 0.9):
        _, keep = erasure_mask(w, p=p, seed=4, segment_words=1)
        frac = 1.0 - np.asarray(keep, dtype=np.float64).mean()
        assert abs(frac - p) < 0.02, (p, frac)


def test_threshold_edge_values():
    assert drop_threshold(0.0) == 0
    assert drop_threshold(1.0) == 2 ** 32 - 1
    assert drop_threshold(0.5) == 2 ** 31


def test_ops_wrapper_matches_both_paths():
    w = _words(4096)
    mk, kk = ops.erasure_mask(w, p=0.3, seed=6, use_pallas=True)
    mr, kr = ops.erasure_mask(w, p=0.3, seed=6, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(kr))


def test_shape_preserved_for_stacked_cohort_words():
    """The cohort use case: (n_sats, words_per_sat) stacks keep shape and
    segment indexing runs over the flattened stream."""
    w = _words(8 * 512).reshape(8, 512)
    masked, keep = erasure_mask(w, p=0.2, seed=8, segment_words=64)
    assert masked.shape == w.shape and keep.shape == w.shape
    mr, kr = erasure_mask_ref(w, p=0.2, seed=8, segment_words=64)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(mr))
