"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
the same pallas_call lowers to TPU with explicit BlockSpec VMEM tiling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quantize_ef import quantize_ef


@pytest.mark.parametrize("shape", [(64,), (300,), (128, 257), (3, 100, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("levels", [255, 1000])
def test_quantize_ef_matches_ref(shape, dtype, levels):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    msg = (jax.random.normal(k1, shape) * 0.2).astype(dtype)
    cache = (jax.random.normal(k2, shape) * 0.01).astype(dtype)
    w, c = quantize_ef(msg, cache, levels=levels, vmin=-0.5, vmax=0.5,
                       interpret=True)
    w_ref, c_ref = ref.quantize_ef_ref(msg, cache, levels=levels,
                                       vmin=-0.5, vmax=0.5)
    assert w.dtype == w_ref.dtype and w.shape == msg.shape
    # XLA may FMA-fuse the index computation, flipping exact lattice ties by
    # one ulp — EF's cache absorbs either side, so ties may differ by ≤1
    # level and must be rare; everything else must match exactly.
    diff = np.abs(np.asarray(w, np.int64) - np.asarray(w_ref, np.int64))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.01
    delta = 1.0 / levels
    np.testing.assert_allclose(np.asarray(c, np.float32),
                               np.asarray(c_ref, np.float32),
                               atol=delta + 2e-2)
    # EF conservation: decode(wire) + new_cache == msg + cache (in-range)
    dec = np.asarray(w, np.float32) * delta - 0.5
    lhs = dec + np.asarray(c, np.float32)
    rhs = (np.asarray(msg, np.float32) + np.asarray(cache, np.float32))
    inr = np.abs(rhs) < 0.45
    np.testing.assert_allclose(lhs[inr], rhs[inr], atol=1e-2)


def test_quantize_ef_information_conservation():
    """wire decodes + new cache == msg + old cache (exact EF identity)."""
    msg = jnp.linspace(-0.4, 0.4, 512).reshape(4, 128)
    cache = jnp.full((4, 128), 0.003)
    w, c = quantize_ef(msg, cache, levels=255, vmin=-0.5, vmax=0.5,
                       interpret=True)
    decoded = w.astype(jnp.float32) * (1.0 / 255) + (-0.5)
    np.testing.assert_allclose(np.asarray(decoded + c),
                               np.asarray(msg + cache), atol=1e-5)


@pytest.mark.parametrize("s,d", [(128, 64), (257, 64), (384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal_matches_ref(s, d, dtype):
    b, h = 2, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = (jax.random.normal(ks[0], (b, s, h, d))).astype(dtype)
    k = (jax.random.normal(ks[1], (b, s, h, d))).astype(dtype)
    v = (jax.random.normal(ks[2], (b, s, h, d))).astype(dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    b, s, h, d = 1, 320, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    b, s, h, d = 1, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, d)) * 2 for kk in ks)
    out = flash_attention(q, k, v, causal=True, softcap=30.0, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (64, 128), (256, 128)])
def test_flash_attention_block_shape_invariance(block_q, block_k):
    """Output must be independent of the BlockSpec tiling choice."""
    b, s, h, d = 1, 320, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
    out = flash_attention(q, k, v, causal=True, block_q=block_q,
                          block_k=block_k, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)
