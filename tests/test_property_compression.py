"""Property-based tests (hypothesis) for compressors and error feedback.

System invariants:
  * every compressor is a contraction of the error: ‖C(x) − x‖ ≤ ‖x‖
    (δ-approximate with δ > 0, paper Definition 1);
  * TopK satisfies the sharp bound ‖C(x) − x‖² ≤ (1 − k/n)·‖x‖²;
  * RandD keeps exactly d coordinates and zeroes the rest;
  * quantization error is ≤ Δ/2 per coordinate inside [vmin, vmax];
  * EF telescoping: Σ wires + final cache = Σ messages (no information is
    ever lost, paper §2.2);
  * EF cache stays bounded under repeated transmission of bounded messages.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.compression import (RandD, ScaledSign, TopK,  # noqa: E402
                                    UniformQuantizer, quantize_decode,
                                    quantize_encode)
from repro.core.error_feedback import EFChannel  # noqa: E402

finite_arrays = st.lists(
    st.floats(min_value=-5.0, max_value=5.0, allow_nan=False, width=32),
    min_size=2, max_size=64,
).map(lambda xs: jnp.asarray(np.array(xs, dtype=np.float32)))


@settings(max_examples=50, deadline=None)
@given(x=finite_arrays)
def test_topk_delta_approximate(x):
    frac = 0.5
    C = TopK(fraction=frac)
    err = C(None, x) - x
    k = max(1, int(round(frac * x.size)))
    bound = (1.0 - k / x.size) * jnp.sum(x * x)
    assert float(jnp.sum(err * err)) <= float(bound) + 1e-5


@settings(max_examples=50, deadline=None)
@given(x=finite_arrays, seed=st.integers(0, 2**31 - 1))
def test_randd_keeps_exactly_d(x, seed):
    frac = 0.5
    C = RandD(fraction=frac)
    y = C(jax.random.PRNGKey(seed), x)
    d = max(1, int(round(frac * x.size)))
    kept = int(jnp.sum(y != 0))
    zeros_in_x = int(jnp.sum(x == 0))
    assert kept <= d
    assert kept >= d - zeros_in_x  # only original zeros may "hide"
    # error contraction
    assert float(jnp.sum((y - x) ** 2)) <= float(jnp.sum(x * x)) + 1e-6


@settings(max_examples=50, deadline=None)
@given(x=finite_arrays)
def test_scaled_sign_contracts(x):
    C = ScaledSign()
    err = C(None, x) - x
    # ‖C(x)−x‖² = ‖x‖² − n·s² ≤ ‖x‖²
    assert float(jnp.sum(err * err)) <= float(jnp.sum(x * x)) + 1e-5


@settings(max_examples=50, deadline=None)
@given(x=finite_arrays)
def test_uniform_quantizer_halfstep_bound(x):
    L, vmin, vmax = 100, -8.0, 8.0
    C = UniformQuantizer(levels=L, vmin=vmin, vmax=vmax)
    delta = (vmax - vmin) / L
    err = jnp.abs(C(None, x) - x)
    assert float(jnp.max(err)) <= delta / 2 + 1e-5


@settings(max_examples=30, deadline=None)
@given(x=finite_arrays)
def test_wire_codec_roundtrip_matches_quantizer(x):
    """int8/int16 on-wire codec decodes to the clip=True quantizer output."""
    L, vmin, vmax = 200, -6.0, 6.0
    C = UniformQuantizer(levels=L, vmin=vmin, vmax=vmax, clip=True)
    idx = quantize_encode(x, L, vmin, vmax)
    assert idx.dtype == jnp.uint8
    dec = quantize_decode(idx, L, vmin, vmax)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(C(None, x)),
                               rtol=0, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rounds=st.integers(2, 12))
def test_ef_telescoping_sum(seed, rounds):
    """Σ wires + final cache == Σ messages — all information transmitted."""
    key = jax.random.PRNGKey(seed)
    ch = EFChannel(UniformQuantizer(levels=8, vmin=-2, vmax=2, clip=True))
    msgs = jax.random.uniform(key, (rounds, 16), minval=-1.5, maxval=1.5)
    cache = jnp.zeros((16,))
    total_wire = jnp.zeros((16,))
    for r in range(rounds):
        wire, cache = ch.send(None, msgs[r], cache)
        total_wire = total_wire + wire
    np.testing.assert_allclose(np.asarray(total_wire + cache),
                               np.asarray(jnp.sum(msgs, axis=0)),
                               rtol=0, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ef_cache_bounded(seed):
    """With a contraction compressor, the cache norm stays bounded."""
    key = jax.random.PRNGKey(seed)
    ch = EFChannel(TopK(fraction=0.25))
    msgs = jax.random.normal(key, (60, 32))
    cache = jnp.zeros((32,))
    norms = []
    for r in range(60):
        _, cache = ch.send(None, msgs[r], cache)
        norms.append(float(jnp.linalg.norm(cache)))
    # bound from EF theory: ‖c‖ ≤ √(1−δ)/(1−√(1−δ))·max‖msg‖ ; generous 4×
    max_msg = float(jnp.max(jnp.linalg.norm(msgs, axis=1)))
    delta = 0.25
    bound = np.sqrt(1 - delta) / (1 - np.sqrt(1 - delta)) * max_msg
    assert max(norms[20:]) <= 4 * bound


def test_ef_disabled_is_plain_compression():
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1)
    ch = EFChannel(C, enabled=False)
    x = jnp.linspace(-0.9, 0.9, 16)
    cache = jnp.ones((16,)) * 0.123
    wire, new_cache = ch.send(None, x, cache)
    np.testing.assert_allclose(np.asarray(wire), np.asarray(C(None, x)))
    np.testing.assert_allclose(np.asarray(new_cache), np.asarray(cache))
