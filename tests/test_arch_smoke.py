"""Per-architecture smoke tests (brief requirement f).

For each of the 10 assigned architectures, instantiate the REDUCED variant
(1 scan repeat of the same unit structure, d_model=256, ≤4 experts) and run
one forward + one train step on CPU, asserting output shapes and no NaNs.
Also exercise the serve path: prefill + one decode step, checking that
incremental decode matches the full-sequence forward (the KV-cache/SSM-state
correctness invariant).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_variant
from repro.data.synthetic import make_batch
from repro.models.transformer import forward, init_cache, init_params, lm_loss

ARCH_NAMES = sorted(ARCHS)

B, S = 2, 128


@pytest.fixture(scope="module")
def smoke_models():
    return {}


def _get(smoke_models, name):
    if name not in smoke_models:
        cfg = smoke_variant(ARCHS[name])
        params = init_params(jax.random.PRNGKey(0), cfg)
        smoke_models[name] = (cfg, params)
    return smoke_models[name]


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(smoke_models, name):
    cfg, params = _get(smoke_models, name)
    batch = make_batch(cfg, jax.random.PRNGKey(1), B, S)
    out = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert out.logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_reduces_loss_and_finite(smoke_models, name):
    cfg, params = _get(smoke_models, name)
    batch = make_batch(cfg, jax.random.PRNGKey(2), B, S)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: lm_loss(q, cfg, batch))(p)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b.astype(a.dtype), p, g)
        return p, loss

    p1, l0 = step(params)
    _, l1 = step(p1)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0)  # one step on the same batch must help


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_then_decode_matches_full_forward(smoke_models, name):
    cfg, params = _get(smoke_models, name)
    s_ctx = 48
    batch = make_batch(cfg, jax.random.PRNGKey(3), B, s_ctx + 1)
    toks = batch["tokens"]
    if cfg.arch_type == "vlm":
        pytest.skip("mixed-modality decode covered by test_vlm_decode")

    # full forward over s_ctx+1 tokens (oracle)
    full = forward(params, cfg, {"tokens": toks}, backend="xla")

    # prefill s_ctx, then decode token s_ctx
    cache = init_cache(cfg, B, s_max=s_ctx + 8)
    pre = forward(params, cfg, {"tokens": toks[:, :s_ctx]}, cache=cache,
                  backend="xla")
    dec = forward(params, cfg, {"tokens": toks[:, s_ctx:s_ctx + 1]},
                  cache=pre.cache, backend="xla")
    np.testing.assert_allclose(
        np.asarray(dec.logits[:, 0].astype(jnp.float32)),
        np.asarray(full.logits[:, s_ctx].astype(jnp.float32)),
        rtol=2e-2, atol=2e-2)


def test_vlm_decode():
    cfg, params = None, None
    cfg = smoke_variant(ARCHS["qwen2-vl-7b"])
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(4), B, 64)
    out = forward(params, cfg, batch)
    assert out.logits.shape[1] == 64
    # decode one token after the mixed prefix
    cache = init_cache(cfg, B, s_max=80)
    pre = forward(params, cfg, batch, cache=cache)
    nxt = {"tokens": batch["tokens"][:, -1:]}
    dec = forward(params, cfg, nxt, cache=pre.cache)
    assert dec.logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(dec.logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ["mixtral-8x7b", "gemma3-27b", "h2o-danube-3-4b"])
def test_sliding_window_restricts_attention(smoke_models, name):
    """Perturbing a token outside every window must not change the last
    logits of a pure-SWA model; gemma3 has global layers so is excluded."""
    if name == "gemma3-27b":
        pytest.skip("has global layers — perturbation legitimately leaks")
    cfg, params = _get(smoke_models, name)
    w = cfg.sliding_window
    s = w + 64
    toks = make_batch(cfg, jax.random.PRNGKey(5), 1, s)["tokens"]
    out1 = forward(params, cfg, {"tokens": toks}, backend="xla")
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    out2 = forward(params, cfg, {"tokens": toks2}, backend="xla")
    np.testing.assert_allclose(
        np.asarray(out1.logits[0, -1].astype(jnp.float32)),
        np.asarray(out2.logits[0, -1].astype(jnp.float32)), rtol=1e-4, atol=1e-4)
