"""Deploy-mode federated round: correctness on the host device.

Verifies the mesh-shardable ``DeployFedLT.round_step``:
  * loss decreases over rounds (local training works through the round);
  * the compressed round tracks the uncompressed round within the EF bound;
  * EF caches stay bounded;
  * with compression off and one agent, the round reduces to plain
    prox-anchored training (x == y_hat fixed point drift check).
"""
import jax
import jax.numpy as jnp

from repro.core.deploy import DeployFedLT
from repro.data.synthetic import make_batch
from repro.models.config import ModelConfig

CFG = ModelConfig(name="deploy-test", arch_type="dense", n_layers=2,
                  d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                  vocab_size=128, max_seq=128, chunk_size=32,
                  tie_embeddings=True, dtype="float32")


def _batches(n_agents, rounds_key, batch=2, seq=32):
    keys = [jax.random.fold_in(rounds_key, i) for i in range(n_agents)]
    per = [make_batch(CFG, k, batch, seq) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)


def test_round_reduces_loss():
    alg = DeployFedLT(cfg=CFG, n_epochs=2, gamma=0.05, rho=10.0,
                      compress=True, levels=1023, vmin=-0.5, vmax=0.5)
    state = alg.init(jax.random.PRNGKey(0), 2)
    step = jax.jit(lambda s, b: alg.round_step(s, b))
    batch = _batches(2, jax.random.PRNGKey(5))
    losses = []
    for k in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    for leaf in jax.tree_util.tree_leaves(state):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_compressed_tracks_uncompressed():
    batch = _batches(2, jax.random.PRNGKey(6))
    states = {}
    for compress in (False, True):
        alg = DeployFedLT(cfg=CFG, n_epochs=2, gamma=0.05, rho=10.0,
                          compress=compress, levels=65535, vmin=-2.0, vmax=2.0)
        st = alg.init(jax.random.PRNGKey(0), 2)
        step = jax.jit(lambda s, b: alg.round_step(s, b))
        for _ in range(4):
            st, _ = step(st, batch)
        states[compress] = st
    # fine quantization (65535 levels over ±2) ⇒ y_hat nearly identical
    d = jax.tree_util.tree_map(lambda a, b: jnp.max(jnp.abs(a - b)),
                               states[False].y_hat, states[True].y_hat)
    max_dev = max(float(x) for x in jax.tree_util.tree_leaves(d))
    assert max_dev < 1e-2


def test_quorum_survivor_mask():
    """``survivors=`` (the host-side quorum close, repro.faults): the
    coordinator mean covers survivors only; an excluded agent's wire is
    dropped and its uplink EF cache reverts to the full corrected
    message (erasure semantics), so nothing is silently discarded."""
    batch = _batches(2, jax.random.PRNGKey(8))
    alg = DeployFedLT(cfg=CFG, n_epochs=1, gamma=0.05, rho=10.0,
                      compress=True, levels=255, vmin=-4.0, vmax=4.0)
    state = alg.init(jax.random.PRNGKey(0), 2)
    all_in = jnp.array([True, True])
    st_all, m_all = alg.round_step(state, batch, survivors=all_in)
    st_none, _ = alg.round_step(state, batch)
    # a full quorum is exactly the unmasked round
    for a, b in zip(jax.tree_util.tree_leaves(st_all.y_hat),
                    jax.tree_util.tree_leaves(st_none.y_hat)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-6
    assert float(m_all["quorum_frac"]) == 1.0

    surv = jnp.array([True, False])
    st_q, m_q = alg.round_step(state, batch, survivors=surv)
    assert float(m_q["quorum_frac"]) == 0.5
    # excluded agent: cache reverted to z + c (content kept, not sent)
    z1 = jax.tree_util.tree_leaves(st_q.z)
    c0 = jax.tree_util.tree_leaves(state.c_up)
    c1 = jax.tree_util.tree_leaves(st_q.c_up)
    for z, c_old, c_new in zip(z1, c0, c1):
        assert float(jnp.max(jnp.abs(c_new[1] - (z[1] + c_old[1])))) < 1e-6
    # survivor keeps the normal small EF residual
    for c_new in c1:
        assert float(jnp.max(jnp.abs(c_new[0]))) < 8.0 / 255 + 1e-3
    for leaf in jax.tree_util.tree_leaves(st_q.y_hat):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_ef_caches_bounded():
    # range generously covers the z dynamics → cache stays within one step
    alg = DeployFedLT(cfg=CFG, n_epochs=1, gamma=0.05, rho=10.0,
                      compress=True, levels=255, vmin=-4.0, vmax=4.0)
    state = alg.init(jax.random.PRNGKey(0), 2)
    step = jax.jit(lambda s, b: alg.round_step(s, b))
    batch = _batches(2, jax.random.PRNGKey(7))
    for _ in range(8):
        state, _ = step(state, batch)
    delta = 8.0 / 255
    # per-coordinate uplink cache must stay within one quantization step
    # when messages are in-range (EF never accumulates unboundedly in-range)
    for leaf in jax.tree_util.tree_leaves(state.c_up):
        assert float(jnp.max(jnp.abs(leaf))) < delta + 1e-3
