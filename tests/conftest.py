"""Shared test config: CI shard markers.

Tier-1 runs as three parallel CI shards selected by pytest markers (see
.github/workflows/ci.yml).  Markers are assigned here from the test
module name so individual test files stay marker-free; any module neither
set claims falls into the "models" shard, whose CI expression is
``not kernels and not simwire`` — so the three shards always partition
the full suite and a new test file can never silently drop out of CI.
"""
from __future__ import annotations

import pytest

KERNEL_MODULES = {
    "test_kernels",
    "test_compress_pipeline",
    "test_erasure_kernel",
    "test_attention_backends",
    "test_ssm_oracles",
}
SIMWIRE_MODULES = {
    "test_sim_contacts",
    "test_sim_engine",
    "test_fastpath_equivalence",
    "test_constellation",
    "test_wire_codecs",
    "test_bench_harness",
    "test_channel",
    "test_obs",
    "test_obs_ledger",
    "test_obs_prof",
    "test_topology",
    "test_api",
    "test_faults",
}


def pytest_collection_modifyitems(items):
    for item in items:
        mod = item.module.__name__.rsplit(".", 1)[-1]
        if mod in KERNEL_MODULES:
            item.add_marker(pytest.mark.kernels)
        elif mod in SIMWIRE_MODULES:
            item.add_marker(pytest.mark.simwire)
        else:
            item.add_marker(pytest.mark.models)
