"""Integration: the multi-pod dry-run lowers + compiles end-to-end.

Runs repro.launch.dryrun in a subprocess (XLA_FLAGS device-count=512 must be
set before jax initializes — exactly what dryrun.py's first lines do) for
one fast combo per step-kind, asserting the compile succeeds and the
roofline record is well-formed.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    env.pop("XLA_FLAGS", None)  # dryrun sets its own
    r = subprocess.run([sys.executable, "-m", "repro.launch.dryrun", *args],
                       capture_output=True, text=True, timeout=timeout,
                       cwd=ROOT, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout)


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_decode_dryrun_compiles(mesh):
    res = _run(["--arch", "stablelm-1.6b", "--shape", "decode_32k",
                "--mesh", mesh])
    assert res["n_chips"] == (512 if mesh == "multi" else 256)
    assert res["hlo_flops_per_chip"] > 0
    assert res["bottleneck"] in ("t_compute", "t_memory", "t_collective")
    assert res["memory_analysis"]["argument_size_in_bytes"] > 0


def test_train_dryrun_compiles_and_reports_collectives():
    res = _run(["--arch", "stablelm-1.6b", "--shape", "train_4k",
                "--mesh", "single"])
    assert res["n_agents"] == 16          # agent-stacked over the data axis
    assert res["collective_bytes_total"] > 0
    assert res["useful_flops_ratio"] is not None


def test_long500k_skip_for_full_attention_arch():
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    r = subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", "granite-20b", "--shape", "long_500k",
                        "--mesh", "single"],
                       capture_output=True, text=True, timeout=120,
                       cwd=ROOT, env=env)
    assert r.returncode == 0
    assert "skipped" in json.loads(r.stdout)
