"""Profile one mega-constellation engine round — evidence for perf PRs.

Every simulator perf change so far started from a cProfile dump showing
where a mega round actually spends its time (PR 5's was unambiguous:
~100 % contact-plan rebuild, ~0 % event loop).  This script makes that
evidence a one-liner and a CI artifact, so the next optimization doesn't
start from guesswork:

    PYTHONPATH=src python benchmarks/profile_round.py                  \
        [--scenario mega-1000] [--rounds 3] [--seed 0]                 \
        [--out profile_round.txt] [--oracle] [--check-equivalence]

* profiles ``Engine.run_round`` over ``--rounds`` rounds (engine
  construction — the one-off cold contact-plan build — stays outside the
  profiler, matching how ``bench_scale`` accounts it);
* prints the top-25 cumulative entries and, with ``--out``, writes the
  same table plus a raw pstats dump (``<out>.pstats``) for snakeviz /
  ``pstats.Stats`` spelunking — the CI perf-gate job uploads both;
* ``--check-equivalence`` first replays the trajectory on the heapq
  oracle (``Engine(fast=False)``) and asserts the fast path's Delivery
  records match field-for-field — the fast-vs-oracle smoke CI runs on
  every push (exits non-zero on divergence).
"""
from __future__ import annotations

import argparse
import cProfile
import io
import pstats
import sys

from repro.constellation.links import message_bytes
from repro.sim import Engine, get_scenario

MSG = message_bytes(10000, 10.0)


def check_equivalence(scenario: str, rounds: int, seed: int,
                      async_deliveries: int = 100) -> None:
    """Assert fast == oracle Delivery timelines, sync and async (the
    shared ``assert_fast_oracle_equivalent`` contract — one definition
    for this CI smoke and ``sim_scale.bench_fast_round``)."""
    try:                  # package mode (-m / registry)
        from benchmarks.common import assert_fast_oracle_equivalent
    except ImportError:   # script mode: benchmarks/ itself is sys.path[0]
        from common import assert_fast_oracle_equivalent
    eng_f = Engine(get_scenario(scenario), seed=seed, fast=True)
    eng_o = Engine(get_scenario(scenario), seed=seed, fast=False)
    assert_fast_oracle_equivalent(eng_f, eng_o, MSG, rounds=rounds,
                                  async_deliveries=async_deliveries)
    print(f"equivalence OK: fast == oracle on {scenario!r} "
          f"({rounds} sync rounds + {async_deliveries} async successes, "
          f"seed {seed})")


def profile_rounds(scenario: str, rounds: int, seed: int,
                   fast: bool = True) -> pstats.Stats:
    eng = Engine(get_scenario(scenario), seed=seed, fast=fast)
    prof = cProfile.Profile()
    prof.enable()
    t = 0.0
    for _ in range(rounds):
        t += eng.run_round(t, MSG).duration
    prof.disable()
    return pstats.Stats(prof)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="mega-1000",
                    help="registered scenario name (default mega-1000)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the top-25 table to FILE and raw pstats "
                         "data to FILE.pstats")
    ap.add_argument("--oracle", action="store_true",
                    help="profile the heapq oracle instead of the fast "
                         "path (before/after comparisons)")
    ap.add_argument("--check-equivalence", action="store_true",
                    help="assert fast == oracle Delivery timelines before "
                         "profiling (CI smoke)")
    args = ap.parse_args(argv)

    if args.check_equivalence:
        check_equivalence(args.scenario, args.rounds, args.seed)

    stats = profile_rounds(args.scenario, args.rounds, args.seed,
                           fast=not args.oracle)
    buf = io.StringIO()
    stats.stream = buf
    stats.sort_stats("cumulative").print_stats(25)
    table = buf.getvalue()
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(f"# profile_round --scenario {args.scenario} "
                    f"--rounds {args.rounds} --seed {args.seed}"
                    f"{' --oracle' if args.oracle else ''}\n")
            f.write(table)
        stats.dump_stats(args.out + ".pstats")
        print(f"wrote {args.out} and {args.out}.pstats")
    return 0


if __name__ == "__main__":
    sys.exit(main())
