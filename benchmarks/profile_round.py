"""Profile mega-constellation engine rounds — evidence for perf PRs.

Every simulator perf change so far started from a profile showing where
a mega round actually spends its time (PR 5's was unambiguous: ~100 %
contact-plan rebuild, ~0 % event loop).  This script makes that evidence
a one-liner and a CI artifact, so the next optimization doesn't start
from guesswork:

    PYTHONPATH=src python benchmarks/profile_round.py                  \
        [--scenario mega-1000] [--rounds 3] [--seed 0]                 \
        [--out profile_round.txt] [--flame profile_round.folded]      \
        [--oracle] [--cprofile] [--check-equivalence]

* the DEFAULT profiler is the deterministic phase-attribution layer
  (:mod:`repro.obs.prof`): rounds run under an in-memory tracer and the
  per-phase self/total/p50/p99 table — with its explicit unattributed
  residual — is printed and (``--out``) written; ``--flame`` adds folded
  stacks for speedscope / flamegraph.pl;
* ``--cprofile`` switches to the old function-level cProfile path
  (top-25 cumulative entries + a raw ``<out>.pstats`` dump for
  snakeviz), which still answers "which *function*" when the phase
  table's "which *stage*" isn't enough;
* engine construction — the one-off cold contact-plan build — stays
  outside the profiled region, matching how ``bench_scale`` accounts it;
* ``--check-equivalence`` first replays the trajectory on the heapq
  oracle (``Engine(fast=False)``) and asserts the fast path's Delivery
  records match field-for-field — the fast-vs-oracle smoke CI runs on
  every push (exits non-zero on divergence).
"""
from __future__ import annotations

import argparse
import cProfile
import io
import pstats
import sys

from repro import obs
from repro.constellation.links import message_bytes
from repro.obs import prof as obs_prof
from repro.sim import Engine, get_scenario

MSG = message_bytes(10000, 10.0)


def check_equivalence(scenario: str, rounds: int, seed: int,
                      async_deliveries: int = 100) -> None:
    """Assert fast == oracle Delivery timelines, sync and async (the
    shared ``assert_fast_oracle_equivalent`` contract — one definition
    for this CI smoke and ``sim_scale.bench_fast_round``)."""
    try:                  # package mode (-m / registry)
        from benchmarks.common import assert_fast_oracle_equivalent
    except ImportError:   # script mode: benchmarks/ itself is sys.path[0]
        from common import assert_fast_oracle_equivalent
    eng_f = Engine(get_scenario(scenario), seed=seed, fast=True)
    eng_o = Engine(get_scenario(scenario), seed=seed, fast=False)
    assert_fast_oracle_equivalent(eng_f, eng_o, MSG, rounds=rounds,
                                  async_deliveries=async_deliveries)
    print(f"equivalence OK: fast == oracle on {scenario!r} "
          f"({rounds} sync rounds + {async_deliveries} async successes, "
          f"seed {seed})")


def _warm(eng, warmup: int) -> float:
    """Run ``warmup`` untraced rounds so one-off costs (lazy imports,
    the first contact-plan extension) stay out of the profiled region —
    the steady-state view the 0.88x fast-vs-oracle sync-gap analysis in
    ``results/prof/`` is built from."""
    t = 0.0
    for _ in range(warmup):
        t += eng.run_round(t, MSG).duration
    return t


def profile_phases(scenario: str, rounds: int, seed: int,
                   fast: bool = True, warmup: int = 0) -> dict:
    """Run ``rounds`` rounds under an in-memory tracer and return the
    collected phase profile (:func:`repro.obs.prof.collect` shape)."""
    eng = Engine(get_scenario(scenario), seed=seed, fast=fast)
    t = _warm(eng, warmup)
    trc = obs.enable()              # in-memory (path=None)
    try:
        for _ in range(rounds):
            t += eng.run_round(t, MSG).duration
        records = trc.records()
    finally:
        obs.disable()
    return obs_prof.collect(records)


def profile_rounds(scenario: str, rounds: int, seed: int,
                   fast: bool = True, warmup: int = 0) -> pstats.Stats:
    """The ``--cprofile`` path: function-level stats over the rounds."""
    eng = Engine(get_scenario(scenario), seed=seed, fast=fast)
    t = _warm(eng, warmup)
    prof = cProfile.Profile()
    prof.enable()
    for _ in range(rounds):
        t += eng.run_round(t, MSG).duration
    prof.disable()
    return pstats.Stats(prof)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="mega-1000",
                    help="registered scenario name (default mega-1000)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=0,
                    help="untraced rounds before profiling (keeps one-off "
                         "plan-build/import costs out of the table)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the profile table to FILE (with "
                         "--cprofile also raw pstats to FILE.pstats)")
    ap.add_argument("--flame", default=None, metavar="FILE",
                    help="write folded stacks (speedscope/flamegraph.pl "
                         "input); phase profiler only")
    ap.add_argument("--oracle", action="store_true",
                    help="profile the heapq oracle instead of the fast "
                         "path (before/after comparisons)")
    ap.add_argument("--cprofile", action="store_true",
                    help="function-level cProfile instead of the phase "
                         "profiler")
    ap.add_argument("--check-equivalence", action="store_true",
                    help="assert fast == oracle Delivery timelines before "
                         "profiling (CI smoke)")
    args = ap.parse_args(argv)

    if args.check_equivalence:
        check_equivalence(args.scenario, args.rounds, args.seed)

    header = (f"# profile_round --scenario {args.scenario} "
              f"--rounds {args.rounds} --warmup {args.warmup} "
              f"--seed {args.seed}"
              f"{' --oracle' if args.oracle else ''}"
              f"{' --cprofile' if args.cprofile else ''}")

    if args.cprofile:
        stats = profile_rounds(args.scenario, args.rounds, args.seed,
                               fast=not args.oracle, warmup=args.warmup)
        buf = io.StringIO()
        stats.stream = buf
        stats.sort_stats("cumulative").print_stats(25)
        table = buf.getvalue()
        print(table)
        if args.out:
            with open(args.out, "w") as f:
                f.write(header + "\n")
                f.write(table)
            stats.dump_stats(args.out + ".pstats")
            print(f"wrote {args.out} and {args.out}.pstats")
        return 0

    profile = profile_phases(args.scenario, args.rounds, args.seed,
                             fast=not args.oracle, warmup=args.warmup)
    table = obs_prof.render_profile(
        profile, title=f"{args.scenario} "
                       f"[{'oracle' if args.oracle else 'fast'}] "
                       f"{args.rounds} sync round(s)")
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(header + "\n")
            f.write(table + "\n")
        print(f"wrote {args.out}")
    if args.flame:
        with open(args.flame, "w") as f:
            f.write(obs_prof.folded(profile))
        print(f"wrote {args.flame} (folded stacks — load in "
              f"https://speedscope.app)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
