"""Wire codec benchmark: pack/unpack throughput + measured-vs-nominal bytes.

Two measurements (ISSUE 2 tentpole, ROADMAP speed north-star):

  1. **Kernel throughput** — ``pack_bits``/``unpack_bits`` (interpret mode
     everywhere; compiled Pallas additionally when a TPU backend is
     present) across sizes and bit widths, reported as value-side MB/s.
  2. **Byte accounting** — measured ``WireMessage.nbytes`` per compressor
     vs the nominal ``wire_bits_per_scalar`` estimate: the ratio is the
     real header+padding overhead the simulator now accounts for.

Run:  PYTHONPATH=src python benchmarks/wire_bench.py [--tiny]
``--tiny`` (CI smoke): smallest sizes, one repetition, interpret only —
fails fast on any pack/unpack regression.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (Identity, RandD, ScaledSign, TopK,
                                    UniformQuantizer)
from repro.kernels.pack_bits import pack_bits, unpack_bits
from repro.wire import measure_tree_bytes


def _time(fn, reps):
    fn()                                    # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def bench_kernels(sizes, bit_widths, reps, modes):
    print(f"{'mode':10s} {'n':>9s} {'bits':>4s} {'pack MB/s':>10s} "
          f"{'unpack MB/s':>12s}")
    for interpret in modes:
        mode = "interpret" if interpret else "compiled"
        for n in sizes:
            for bits in bit_widths:
                x = jax.random.randint(jax.random.PRNGKey(0), (n,), 0,
                                       2 ** min(bits, 30)).astype(jnp.uint32)
                words = pack_bits(x, bits, interpret=interpret)
                t_pack = _time(lambda: pack_bits(x, bits,
                                                 interpret=interpret), reps)
                t_unpack = _time(lambda: unpack_bits(words, bits, n,
                                                     interpret=interpret),
                                 reps)
                back = unpack_bits(words, bits, n, interpret=interpret)
                assert np.array_equal(np.asarray(back), np.asarray(x)), (
                    f"round-trip broke: n={n} bits={bits} mode={mode}")
                mb = 4.0 * n / 1e6
                print(f"{mode:10s} {n:9d} {bits:4d} {mb / t_pack:10.1f} "
                      f"{mb / t_unpack:12.1f}")


def bench_accounting(n):
    compressors = {
        "identity": Identity(),
        "quant_fine": UniformQuantizer(levels=1000, vmin=-10, vmax=10,
                                       clip=True),
        "quant_coarse": UniformQuantizer(levels=10, vmin=-1, vmax=1,
                                         clip=True),
        "sign": ScaledSign(),
        "top_0.1": TopK(fraction=0.1),
        "rand_0.5": RandD(fraction=0.5),
    }
    print(f"\n{'compressor':14s} {'nominal b/s':>11s} {'measured b/s':>13s} "
          f"{'ratio':>7s}")
    x = jax.random.normal(jax.random.PRNGKey(1), (n,))
    for name, C in compressors.items():
        y = C(jax.random.PRNGKey(2), x)
        measured = measure_tree_bytes(C, y)
        nominal_bs = C.wire_bits_per_scalar()
        measured_bs = 8.0 * measured / n
        print(f"{name:14s} {nominal_bs:11.2f} {measured_bs:13.3f} "
              f"{measured_bs / nominal_bs:7.3f}")


def main(tiny: bool = False):
    t0 = time.time()
    if tiny:
        sizes, bit_widths, reps = [4096, 40000], [1, 4, 10], 1
    else:
        sizes, bit_widths, reps = [65536, 1 << 20, 1 << 22], [1, 4, 8, 16], 5
    modes = [True]
    if jax.default_backend() == "tpu":
        modes.append(False)        # compiled Pallas on the TPU backend
    bench_kernels(sizes, bit_widths, reps, modes)
    bench_accounting(4096 if tiny else 1 << 20)
    us = (time.time() - t0) * 1e6
    print(f"\nwire_bench,{us:.0f},modes={len(modes)}")


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke: small sizes, 1 rep, interpret only")
    main(tiny=p.parse_args().tiny)
