"""Shared benchmark setup: the paper's experimental problem + tuned configs."""
from __future__ import annotations

import os

import jax

from repro.core.baselines import LED, FedAvg, FedProx, FiveGCS
from repro.core.compression import RandD, UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT
from repro.data.logistic import generate, make_local_loss, solve_global

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

# paper §3: ε=50, m_i=500, n=100, N=100, N_e=10; γ, ρ grid-tuned.  The tuned
# point sits in the slow local-training regime where EF wins (EXPERIMENTS.md).
PAPER = dict(n_agents=100, m=500, dim=100, eps=50.0)
TUNED = dict(n_epochs=10, gamma=0.005, rho=20.0)

COMPRESSORS = {
    "quant_fine":   UniformQuantizer(levels=1000, vmin=-10, vmax=10, clip=True),
    "quant_coarse": UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True),
    "rand_0.8":     RandD(fraction=0.8),
    "rand_0.2":     RandD(fraction=0.2),
}


def assert_fast_oracle_equivalent(eng_fast, eng_oracle, msg_bytes, *,
                                  rounds=3, async_deliveries=100):
    """Drive both engines through the same sync trajectory and an async
    stream and assert identical Delivery timelines — the fast engine's
    acceptance contract, shared by ``sim_scale.bench_fast_round`` and
    ``profile_round --check-equivalence`` so the contract lives in ONE
    place.  Delivery is an eq dataclass: ``==`` compares every field,
    including any a future PR adds (engine records always carry finite
    windows, so NaN can't defeat the comparison).  Returns the fast
    engine's RoundResults; both engines come back warm.
    """
    t_f = t_o = 0.0
    results = []
    for r in range(rounds):
        rf = eng_fast.run_round(t_f, msg_bytes)
        ro = eng_oracle.run_round(t_o, msg_bytes)
        assert rf.deliveries == ro.deliveries, \
            f"fast path diverged from the heapq oracle (sync round {r})"
        assert rf.duration == ro.duration and (rf.mask == ro.mask).all()
        t_f += rf.duration
        t_o += ro.duration
        results.append(rf)
    d_f = eng_fast.run_async(0.0, msg_bytes, n_deliveries=async_deliveries)
    d_o = eng_oracle.run_async(0.0, msg_bytes, n_deliveries=async_deliveries)
    assert d_f == d_o, "fast path diverged from the heapq oracle (async)"
    return results


def problem(seed=0, scale=1.0):
    n_agents = int(PAPER["n_agents"] * scale) or 4
    m = int(PAPER["m"] * scale) or 16
    data, _ = generate(jax.random.PRNGKey(seed), n_agents=n_agents, m=m,
                       dim=PAPER["dim"])
    loss = make_local_loss(eps=PAPER["eps"], n_agents=n_agents)
    xbar = solve_global(data, eps=PAPER["eps"])
    return data, loss, xbar, n_agents


def make_algorithm(name, loss, compressor, ef=True, **overrides):
    up, down = EFChannel(compressor, enabled=ef), EFChannel(compressor, enabled=ef)
    kw = dict(TUNED)
    kw.update(overrides)
    rho = kw.pop("rho")
    if name == "fedlt":
        return FedLT(loss=loss, rho=rho, uplink=up, downlink=down, **kw)
    if name == "fedavg":
        return FedAvg(loss=loss, n_epochs=kw["n_epochs"], gamma=0.05,
                      uplink=up, downlink=down)
    if name == "fedprox":
        return FedProx(loss, n_epochs=kw["n_epochs"], gamma=0.05, prox_mu=1.0,
                       uplink=up, downlink=down)
    if name == "led":
        return LED(loss=loss, n_epochs=kw["n_epochs"], gamma=0.01,
                   uplink=up, downlink=down)
    if name == "5gcs":
        return FiveGCS(loss=loss, n_epochs=kw["n_epochs"], gamma=0.05,
                       gamma_p=1.0, uplink=up, downlink=down)
    raise ValueError(name)
