"""Bytes-to-ground vs e_K frontier for in-orbit aggregation (topology table).

Two sweeps over :class:`repro.api.Experiment`, every arm traced and folded
into a run ledger (``results/ledger_plane_agg.jsonl``), with the printed
table rebuilt **exclusively from the ledger entries**
(:func:`repro.obs.report.plane_agg_rows`) — the same no-recomputation
contract as ``table_lossy_ef``:

  * **walker frontier** — the 100-sat seed geometry under ``direct``
    (per-sat uplinks, scheduler-limited participation), ``plane``
    (per-plane convergecast to elected heads), and ``gossip`` (paired
    head merge): how much ground-station incast each topology trades for
    ISL traffic at equal rounds;
  * **mega comparison** — the 1000-sat / 20-plane regime: ``direct``
    (the standard ``mega-1000`` schedule), ``direct-full`` (relay fan-out
    boosted until every satellite ships its own wire — the
    equal-participation baseline), and ``plane`` (20 head wires carry all
    1000 updates).

Headline metric (the tentpole acceptance claim): plane aggregation cuts
**GS bytes per incorporated update** by ≥ 5× versus the
equal-participation direct baseline, with e_K within 1.25× at equal
rounds.

``--smoke`` runs no training at all: it drives the ``plane-agg-walker``
engine rounds on the fast path AND the heapq oracle under obs traces and
exits 1 unless ``repro.obs`` trace-diff is clean — the CI
topology-equivalence gate.

Run:  PYTHONPATH=src python -m benchmarks.table_plane_agg [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.api import Experiment
from repro.core.compression import UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.data.logistic import generate, make_local_loss, solve_global
from repro.obs.ledger import load_ledger
from repro.obs.report import plane_agg_rows
from repro.sim import Engine, get_scenario

from .common import RESULTS_DIR, TUNED

LEDGER = os.path.join(RESULTS_DIR, "ledger_plane_agg.jsonl")

# (arm label, scenario factory) — scenario name or a Scenario instance
WALKER_ARMS = [
    ("direct", "walker-kiruna"),
    ("plane", "plane-agg-walker"),
    ("gossip", "plane-agg-gossip"),
]


def _mega_full():
    # equal-participation direct baseline: boost the relay fan-out until
    # the schedule covers the whole fleet (40 gateways × (1 + 24 relays)
    # = 1000), so the per-update byte comparison is participation-matched
    return dataclasses.replace(get_scenario("mega-1000"),
                               name="mega-1000-full",
                               k_direct=40, n_relay=24)


def MEGA_ARMS():
    return [
        ("direct", get_scenario("mega-1000")),
        ("direct-full", _mega_full()),
        ("plane", get_scenario("mega-1000-plane")),
    ]


def render_row(row: dict) -> str:
    per_upd = (row["bytes_gs"] / row["updates"] if row["updates"]
               else float("inf"))
    return (f"{row['scenario']:18s} {row['arm']:12s} "
            f"[{row['topology']:6s}] e_K={row['error']:.5f}  "
            f"gs={row['bytes_gs'] / 1e3:8.1f}kB  "
            f"isl={row['bytes_isl'] / 1e3:8.1f}kB  "
            f"upd={row['updates']:6d}  gs/upd={per_upd / 1e3:6.2f}kB")


def run_sweep(arms, *, rounds, n_agents, dim, m, seed=0, group="",
              ledger_path=LEDGER):
    """One (arm × scenario) sweep on a shared problem; returns the
    sweep's ledger entries in arm order."""
    data, _ = generate(jax.random.PRNGKey(seed), n_agents=n_agents, m=m,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    err = lambda s: float(optimality_error(s.x, x_star))  # noqa: E731
    run_ids = []
    for arm, scenario in arms:
        alg = FedLT(loss=loss, uplink=EFChannel(C), downlink=EFChannel(C),
                    **TUNED)
        exp = Experiment(scenario, alg, compressor=C, seed=seed,
                         meta=dict(arm=arm, group=group, rounds=rounds,
                                   seed=seed))
        st = exp.init(jnp.zeros((dim,)), n_agents)
        res = exp.run(st, data, rounds, jax.random.PRNGKey(100 + seed),
                      error_fn=err, log_every=max(1, rounds // 5),
                      ledger=ledger_path)
        run_ids.append(res.run_id)
    by_id = {e["run_id"]: e for e in load_ledger(ledger_path)}
    return [by_id[r] for r in run_ids]


def run(quick=False, ledger_path=LEDGER):
    w_rounds = 20 if quick else 60
    m_rounds = 4 if quick else 8
    entries = run_sweep(WALKER_ARMS, rounds=w_rounds, n_agents=100,
                        dim=32, m=40, group="walker",
                        ledger_path=ledger_path)
    entries += run_sweep(MEGA_ARMS(), rounds=m_rounds, n_agents=1000,
                         dim=8, m=16, group="mega",
                         ledger_path=ledger_path)
    rows = plane_agg_rows(entries)
    for row in rows:
        print(render_row(row))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "table_plane_agg.json"), "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(quick=False):
    t0 = time.time()
    rows = run(quick=quick)
    by = {(r["scenario"], r["arm"]): r for r in rows}
    full = by[("mega-1000-full", "direct-full")]
    plane = by[("mega-1000-plane", "plane")]
    per_upd_full = full["bytes_gs"] / max(full["updates"], 1)
    per_upd_plane = plane["bytes_gs"] / max(plane["updates"], 1)
    reduction = per_upd_full / per_upd_plane
    ek_ratio = plane["error"] / full["error"]
    us = (time.time() - t0) * 1e6
    print(f"table_plane_agg,{us:.0f},gs_bytes_per_update_reduction="
          f"{reduction:.1f},ek_ratio_plane_over_direct={ek_ratio:.3f}")
    ok = reduction >= 5.0 and ek_ratio <= 1.25
    print(f"acceptance: reduction>=5x {'PASS' if reduction >= 5.0 else 'FAIL'}"
          f", ek_ratio<=1.25 {'PASS' if ek_ratio <= 1.25 else 'FAIL'}")
    return ok


def smoke(rounds=4) -> bool:
    """Topology-equivalence gate: fast vs heapq-oracle engine rounds on
    ``plane-agg-walker`` must trace-diff clean (round / delivery /
    head_elect event streams identical).  No training, seconds to run."""
    from repro.obs import tracing
    from repro.obs.summary import check, diff

    msg = 120e6 / 8 * 0.01
    traces = []
    for fast in (True, False):
        eng = Engine(get_scenario("plane-agg-walker"), fast=fast)
        with tracing() as trc:
            t = 0.0
            for _ in range(rounds):
                res = eng.run_round(t, msg)
                t += res.duration
            traces.append(trc.records())
    equal, report = diff(traces[0], traces[1])
    bad = check(traces[0]) + check(traces[1])
    if equal and not bad:
        n = len([r for r in traces[0] if r.get("kind") == "delivery"])
        print(f"topology-equivalence OK: {rounds} plane rounds, "
              f"{n} deliveries, fast == oracle")
        return True
    print(report or "\n".join(bad))
    return False


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="20/4-round sweeps (CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast-vs-oracle trace diff only; exit 1 on "
                         "divergence")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke() else 1)
    main(quick=args.quick)
