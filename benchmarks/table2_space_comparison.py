"""Paper Table 2: Fed-LTSat vs space-ified FedAvg/FedProx/LED/5GCS.

All algorithms run in the SAME constellation simulation (orbit-scheduled
10%-ish participation, ISL forwarding) with the SAME agnostic EF channel —
exactly the paper's setup — across four compressors.  Reported: mean ± std
of the asymptotic optimality error over Monte-Carlo runs.

Expected qualitative result (paper Table 2): Fed-LTSat best or near-best in
every column, with orders-of-magnitude margins under quantization.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Experiment
from repro.constellation.orbits import GroundStation, Walker
from repro.core.fedlt import optimality_error
from repro.sim import Engine, Scenario

from .common import COMPRESSORS, RESULTS_DIR, make_algorithm, problem

ALGOS = ["fedlt", "fedavg", "fedprox", "led", "5gcs"]
LABEL = {"fedlt": "Fed-LTSat (this paper)", "fedavg": "FedAvg",
         "fedprox": "FedProx", "led": "LED", "5gcs": "5GCS"}


def run(mc_runs=2, rounds=400, scale=1.0, verbose=True):
    n_sats = int(100 * scale) or 4
    walker = Walker(n_sats=n_sats, n_planes=max(2, n_sats // 10))
    # ~10 participants per round (paper: 10%)
    engine = Engine(Scenario(name="table2", walker=walker,
                             stations=(GroundStation(),),
                             k_direct=4, n_relay=2))

    table = {}
    for comp_name, C in COMPRESSORS.items():
        for algo in ALGOS:
            errs = []
            for mc in range(mc_runs):
                data, loss, xbar, n_agents = problem(seed=mc, scale=scale)
                alg = make_algorithm(algo, loss, C, ef=True)
                exp = Experiment(None, alg, engine=engine, compressor=C)
                st = exp.init(jnp.zeros((xbar.shape[0],)), n_agents)
                res = exp.run(st, data, rounds,
                              jax.random.PRNGKey(200 + mc))
                errs.append(float(optimality_error(res.state.x, xbar)))
            table[(comp_name, algo)] = (float(np.mean(errs)), float(np.std(errs)))
            if verbose:
                m, s = table[(comp_name, algo)]
                print(f"{comp_name:12s} {LABEL[algo]:24s} {m:.4e} ± {s:.1e}")
    return table


def main(quick=False):
    t0 = time.time()
    table = run(mc_runs=1 if quick else 2, rounds=150 if quick else 400,
                scale=0.2 if quick else 1.0)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "table2.json"), "w") as f:
        json.dump({f"{c}|{a}": v for (c, a), v in table.items()}, f, indent=2)
    # derived: in how many compressor columns is Fed-LTSat the best algorithm?
    wins = 0
    for comp in COMPRESSORS:
        best = min(ALGOS, key=lambda a: table[(comp, a)][0])
        wins += best == "fedlt"
    us = (time.time() - t0) * 1e6
    print(f"table2_space_comparison,{us:.0f},fedltsat_wins={wins}/"
          f"{len(COMPRESSORS)}")
    return wins


if __name__ == "__main__":
    main()
