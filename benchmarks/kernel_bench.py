"""Micro-benchmarks: Pallas kernels (interpret) vs pure-jnp oracle on CPU.

On CPU the interpret-mode kernel is NOT expected to be faster — the numbers
recorded here are correctness-path timings plus the analytic TPU roofline
for each kernel (bytes touched / HBM bandwidth), which is what the kernel
is designed to hit on hardware.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quantize_ef import quantize_ef

HBM_BW = 819e9


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6  # µs


def main():
    # quantize+EF: the per-round uplink hot spot
    n = 1 << 20
    msg = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.1
    cache = jnp.zeros((n,))
    us_k = _time(lambda m, c: quantize_ef(m, c, interpret=True), msg, cache)
    us_r = _time(jax.jit(lambda m, c: ref.quantize_ef_ref(
        m, c, levels=255, vmin=-0.25, vmax=0.25)), msg, cache)
    bytes_touched = n * (4 + 4 + 1 + 4)  # msg + cache reads, wire + cache writes
    tpu_floor_us = bytes_touched / HBM_BW * 1e6
    print(f"quantize_ef_pallas_interpret,{us_k:.0f},tpu_roofline_us={tpu_floor_us:.1f}")
    print(f"quantize_ef_jnp_ref,{us_r:.0f},bytes={bytes_touched}")

    # flash attention: prefill hot spot
    b, s, h, d = 1, 1024, 4, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, d), jnp.float32) for kk in ks)
    us_f = _time(lambda *a: flash_attention(*a, causal=True, interpret=True),
                 q, k, v)
    us_fr = _time(jax.jit(lambda *a: ref.flash_attention_ref(*a, causal=True)),
                  q, k, v)
    flops = 4 * b * h * s * s * d / 2
    tpu_us = flops / 197e12 * 1e6
    print(f"flash_attention_pallas_interpret,{us_f:.0f},tpu_compute_us={tpu_us:.1f}")
    print(f"flash_attention_jnp_ref,{us_fr:.0f},flops={flops:.2e}")


if __name__ == "__main__":
    main()
