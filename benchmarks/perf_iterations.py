"""§Perf hillclimbs: the three chosen (arch × shape) pairs + ablations.

Pairs (chosen from the baseline roofline table):
  1. grok-1-314b × train_4k    — most collective-bound & largest model; the
     pair most representative of the paper's technique (compressed federated
     round).  Iterations: uplink compression OFF→ON (the paper's claim at
     system level), MoE dense→capacity, remat grouping.
  2. musicgen-large × decode_32k — worst memory fit (26 GB/chip, MHA cache).
     Iteration: int8 KV cache (the paper's compression idea applied to
     serving state).
  3. mixtral-8x7b × train_4k   — collective-bound MoE+SWA.  Iterations:
     dense→capacity dispatch, compression ablation, remat grouping.

Each variant compiles prod + unrolled R=1/R=2 (exact extrapolated costs).
Results → results/perf/<pair>__<variant>[__unrollN].json
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))
from benchmarks.dryrun_all import run_one as _run  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "results", "perf")

EXPERIMENTS = {
    # pair 1: grok train
    ("grok-1-314b", "train_4k"): {
        "baseline": [],                       # capacity MoE + compressed uplink
        "no_compress": ["--no-compress"],     # ablate the paper's technique
        "dense_moe": ["--moe-dispatch", "dense"],
        "remat8": ["--remat-group", "8"],
    },
    # pair 2: musicgen decode
    ("musicgen-large", "decode_32k"): {
        "baseline": [],
        "kv_int8": ["--kv-int8"],
    },
    # pair 3: mixtral train
    ("mixtral-8x7b", "train_4k"): {
        "baseline": [],
        "no_compress": ["--no-compress"],
        "dense_moe": ["--moe-dispatch", "dense"],
        "remat8": ["--remat-group", "8"],
    },
}


def run_one(arch, shape, extra, tag, timeout=3600):
    import benchmarks.dryrun_all as D
    old = D.OUT_DIR
    D.OUT_DIR = OUT
    try:
        ok = _run(arch, shape, "single", extra=extra, tag=tag, timeout=timeout)
    finally:
        D.OUT_DIR = old
    return ok


def main():
    failures = []
    for (arch, shape), variants in EXPERIMENTS.items():
        for vname, extra in variants.items():
            # production build (memory fits-check) + R1/R2 unrolled (costs)
            if not run_one(arch, shape, extra, vname):
                failures.append((arch, shape, vname, "prod"))
            for r in (1, 2):
                if not run_one(arch, shape,
                               extra + ["--unroll", "--scan-repeats", str(r)],
                               f"{vname}__unroll{r}"):
                    failures.append((arch, shape, vname, f"unroll{r}"))
    print("failures:", failures or "none")


if __name__ == "__main__":
    main()
