"""Paper Table 1: Fed-LT with bi-directional compression, EF on vs off.

Monte-Carlo asymptotic optimality error  e_K = Σ_i ‖x_{i,K} − x̄‖²  for the
two quantizer settings of the paper.  Expected qualitative result (validated
against the paper's Table 1): EF lowers the asymptotic error by ~3–9×, and
the coarse quantizer has a higher floor than the fine one.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedlt import optimality_error

from .common import RESULTS_DIR, make_algorithm, problem

CONFIGS = [
    ("quant L=1000 ±10", dict(levels=1000, vmin=-10.0, vmax=10.0)),
    ("quant L=10 ±1", dict(levels=10, vmin=-1.0, vmax=1.0)),
]


def run(mc_runs=3, rounds=1000, scale=1.0, verbose=True):
    from repro.core.compression import UniformQuantizer

    rows = []
    for label, qkw in CONFIGS:
        C = UniformQuantizer(clip=True, **qkw)
        for ef, alg_name in ((False, "Algorithm 1 (no EF)"),
                             (True, "Algorithm 2 (EF)")):
            errs = []
            for mc in range(mc_runs):
                data, loss, xbar, n_agents = problem(seed=mc, scale=scale)
                alg = make_algorithm("fedlt", loss, C, ef=ef)
                st = alg.init(jnp.zeros((xbar.shape[0],)), n_agents)
                st, _ = jax.jit(lambda s, d: alg.run(
                    s, d, rounds, jax.random.PRNGKey(100 + mc)))(st, data)
                errs.append(float(optimality_error(st.x, xbar)))
            row = dict(config=label, algorithm=alg_name,
                       mean=float(np.mean(errs)), std=float(np.std(errs)))
            rows.append(row)
            if verbose:
                print(f"{label:20s} {alg_name:22s} "
                      f"{row['mean']:.5e} ± {row['std']:.1e}")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "table1.json"), "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(quick=False):
    t0 = time.time()
    rows = run(mc_runs=1 if quick else 3, rounds=300 if quick else 1000,
               scale=0.2 if quick else 1.0)
    # derived metric: EF improvement factor on the coarse quantizer
    coarse = {r["algorithm"]: r["mean"] for r in rows
              if "L=10 " in r["config"]}
    factor = coarse["Algorithm 1 (no EF)"] / coarse["Algorithm 2 (EF)"]
    us = (time.time() - t0) * 1e6
    print(f"table1_error_feedback,{us:.0f},ef_improvement_factor={factor:.2f}")
    return factor


if __name__ == "__main__":
    main()
