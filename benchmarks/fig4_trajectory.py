"""Paper Fig. 4: optimality-error trajectory, EF vs no EF (coarse quantizer).

Writes results/fig4_trajectory.csv with columns round,no_ef,ef.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from repro.core.compression import UniformQuantizer
from repro.core.fedlt import optimality_error

from .common import RESULTS_DIR, make_algorithm, problem


def run(rounds=800, every=10, scale=1.0):
    data, loss, xbar, n_agents = problem(seed=0, scale=scale)
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    curves = {}
    for ef in (False, True):
        alg = make_algorithm("fedlt", loss, C, ef=ef)
        st = alg.init(jnp.zeros((xbar.shape[0],)), n_agents)
        active = jnp.ones((n_agents,), bool)
        step = jax.jit(lambda s, k: alg.round(s, data, active, k)[0])
        keys = jax.random.split(jax.random.PRNGKey(7), rounds)
        errs = []
        for k in range(rounds):
            st = step(st, keys[k])
            if k % every == 0 or k == rounds - 1:
                errs.append((k, float(optimality_error(st.x, xbar))))
        curves[ef] = errs
    return curves


def main(quick=False):
    t0 = time.time()
    curves = run(rounds=200 if quick else 800, scale=0.2 if quick else 1.0)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "fig4_trajectory.csv")
    with open(path, "w") as f:
        f.write("round,no_ef,ef\n")
        for (k, e0), (_, e1) in zip(curves[False], curves[True]):
            f.write(f"{k},{e0:.6e},{e1:.6e}\n")
    final_ratio = curves[False][-1][1] / max(curves[True][-1][1], 1e-30)
    us = (time.time() - t0) * 1e6
    print(f"fig4_trajectory,{us:.0f},final_no_ef_over_ef={final_ratio:.2f}")
    return final_ratio


if __name__ == "__main__":
    main()
