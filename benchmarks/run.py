"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  ``--full`` runs the
paper-scale versions (minutes); the default quick mode validates the same
qualitative claims at reduced scale so CI stays fast.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()
    quick = not args.full

    failures = []

    def section(name, fn):
        print(f"\n# --- {name} ---")
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)

    from . import fig4_trajectory, kernel_bench, sim_scale, table1_error_feedback
    from . import roofline, table2_space_comparison, table_fault_tolerance
    from . import table_lossy_ef, wire_bench

    section("Table 1: error feedback ablation",
            lambda: table1_error_feedback.main(quick=quick))
    section("Lossy-channel table: loss-robust EF vs naive EF vs no EF",
            lambda: table_lossy_ef.main(quick=quick))
    section("Fault-tolerance table: quorum+failover+robust-EF vs naive restart",
            lambda: table_fault_tolerance.main(quick=quick))
    section("Fig 4: error trajectory",
            lambda: fig4_trajectory.main(quick=quick))
    section("Table 2: constellation comparison",
            lambda: table2_space_comparison.main(quick=quick))
    section("Sim scaling: contact plan + 1000-sat engine",
            lambda: sim_scale.main(quick=quick))
    section("Kernel micro-benchmarks", kernel_bench.main)
    section("Wire codec bench: pack throughput + byte accounting",
            lambda: wire_bench.main(tiny=quick))
    section("Roofline (dry-run aggregation)", roofline.main)

    if failures:
        print("\nFAILED sections:", failures)
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
