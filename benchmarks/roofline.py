"""Roofline aggregation: results/dryrun/*.json → EXPERIMENTS.md tables.

Per (arch × shape), single-pod mesh:
  * exact totals via linear extrapolation from the unrolled R=1/R=2 builds:
        cost(R) = base + R·unit  ⇒  total = c1 + (R_real − 1)·(c2 − c1)
  * the three roofline terms (per-chip seconds), dominant bottleneck,
    MODEL_FLOPS ratio, and the production build's memory fits-check.
"""
from __future__ import annotations

import json
import os

from repro.configs import ARCHS

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(name):
    path = os.path.join(OUT_DIR, name + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def extrapolate(arch, shape):
    """Exact per-chip totals at real depth from the R=1/R=2 unrolled builds."""
    c1 = _load(f"{arch}__{shape}__single__unroll1")
    c2 = _load(f"{arch}__{shape}__single__unroll2")
    prod = _load(f"{arch}__{shape}__single")
    if not prod or "skipped" in prod:
        return prod
    if not c1 or not c2 or "skipped" in c1:
        return None
    r_real = ARCHS[arch].scan_repeats

    def ext(key):
        u = c2[key] - c1[key]
        return c1[key] + (r_real - 1) * u

    flops = ext("hlo_flops_per_chip")
    byts = ext("hlo_bytes_per_chip")
    coll = ext("collective_bytes_total")
    res = dict(prod)
    res.update(
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=byts,
        collective_bytes_total=coll,
        t_compute=flops / PEAK_FLOPS,
        t_memory=byts / HBM_BW,
        t_collective=coll / ICI_BW,
        useful_flops_ratio=(prod["model_flops"] / (flops * prod["n_chips"])
                            if flops else None),
        extrapolated=True,
    )
    terms = {k: res[k] for k in ("t_compute", "t_memory", "t_collective")}
    res["bottleneck"] = max(terms, key=terms.get)
    return res


def fits(prod):
    ma = prod.get("memory_analysis", {})
    tot = (ma.get("argument_size_in_bytes", 0) or 0) + \
          (ma.get("temp_size_in_bytes", 0) or 0)
    return tot, tot <= HBM_PER_CHIP


def advice(res):
    b = res["bottleneck"]
    if b == "t_collective":
        return ("cut wire bytes further (int8→int4 quantized collectives) or "
                "overlap the gather with local compute")
    if b == "t_memory":
        return ("raise arithmetic intensity: fuse elementwise chains "
                "(quantize+EF kernel), larger attention blocks, better remat")
    return "increase per-chip work (larger per-agent batch) or cut redundant FLOPs"


def table(markdown=True):
    rows = []
    for arch in sorted(ARCHS):
        for shape in SHAPES:
            res = extrapolate(arch, shape)
            if res is None:
                rows.append((arch, shape, None, "missing"))
                continue
            if "skipped" in res:
                rows.append((arch, shape, None, "skip (full attn @500k)"))
                continue
            prod = _load(f"{arch}__{shape}__single")
            mem, ok = fits(prod)
            multi = _load(f"{arch}__{shape}__multi")
            rows.append((arch, shape, res, dict(
                mem=mem, fits=ok,
                multi_ok=bool(multi) and "skipped" not in (multi or {}))))
    if not markdown:
        return rows
    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck "
             "| useful FLOPs | mem/chip | multi-pod |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch, shape, res, extra in rows:
        if res is None:
            lines.append(f"| {arch} | {shape} | — | — | — | {extra} | — | — | — |")
            continue
        e = extra
        lines.append(
            f"| {arch} | {shape} | {res['t_compute']:.3e} | "
            f"{res['t_memory']:.3e} | {res['t_collective']:.3e} | "
            f"{res['bottleneck'][2:]} | "
            f"{res['useful_flops_ratio']:.2f} | "
            f"{e['mem']/1e9:.1f}GB{'✓' if e['fits'] else '⚠'} | "
            f"{'✓' if e['multi_ok'] else '✗'} |")
    return "\n".join(lines)


def main():
    import time
    t0 = time.time()
    rows = table(markdown=False)
    done = sum(1 for r in rows if r[2] is not None or "skip" in str(r[3]))
    print(table())
    us = (time.time() - t0) * 1e6
    print(f"roofline,{us:.0f},combos_done={done}/40")


if __name__ == "__main__":
    main()
