"""Fault tolerance: quorum deadlines + head failover + loss-robust EF
vs a naive deadline-less baseline (robustness-subsystem table, ISSUE 10).

Sweeps the per-flight crash rate of a :class:`repro.faults.FaultModel`
on a heterogeneous-compute plane-aggregation scenario (15-60 s compute
spread, so round deadlines actually bite; 15 % of head uplinks fail
mid-convergecast, so failover actually runs) and compares two arms of
Fed-LT at EQUAL round counts:

  * **quorum+failover+robust-EF** — rounds close at a 180 s deadline
    once 60 % of the attempted update-weight has landed; stragglers and
    failover collateral revert into their EF residuals
    (``loss_robust=True``) and telescope into later rounds; crashed
    satellites re-sync their residual to zero (the physics — both arms
    share it);
  * **naive restart** — no deadline (the coordinator waits out every
    straggler, including post-failover re-uplinks) and non-robust EF:
    whatever a crash or dead head destroys is discharged from the
    residual and simply vanishes, as if the round were restarted
    without it.

Expected qualitative result (the robustness acceptance claim): at every
crash rate ≥ 5 % the robust arm reaches a strictly lower e_K than the
naive baseline at the same number of rounds — while also spending ~4x
less simulated time (the deadline caps the round length) and no more
uplink bytes, i.e. it strictly dominates on e_K-per-byte.

Every arm runs under a :mod:`repro.obs` trace and is folded into a run
ledger (``results/ledger_fault_tolerance.jsonl``); the printed table and
the dominance gate are rendered **exclusively from the ledger entries**
(:func:`repro.obs.report.fault_tolerance_rows`) — the same
no-recomputation contract as ``table_lossy_ef``.

Run:  PYTHONPATH=src python -m benchmarks.table_fault_tolerance [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Experiment
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.data.logistic import generate, make_local_loss, solve_global
from repro.faults import FaultModel
from repro.obs.ledger import load_ledger
from repro.obs.report import fault_tolerance_rows
from repro.sim import Engine, get_scenario

from .common import COMPRESSORS, TUNED

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
LEDGER = os.path.join(RESULTS_DIR, "ledger_fault_tolerance.jsonl")

ROBUST = "quorum+failover+robust-EF"
NAIVE = "naive restart"
ARMS = [
    # (label, loss_robust, deadline, quorum)
    (ROBUST, True, 180.0, 0.6),
    (NAIVE, False, None, 0.0),
]
HEAD_FAILURE_RATE = 0.15
FAILOVER_TIMEOUT = 60.0


def _scenario():
    """plane-agg-walker with the hetero-compute 15-60 s spread: slow
    planes straggle, so the deadline has something to cut."""
    base = get_scenario("plane-agg-walker")
    spread = 15.0 + 45.0 * (np.arange(base.walker.n_sats) % 5) / 4.0
    return dataclasses.replace(base, name="fault-tolerance-bench",
                               compute_time=spread)


def render_row(row: dict) -> str:
    return (f"crash={row['crash_rate']:4.2f}  {row['arm']:26s} "
            f"e_K={row['error']:.5f}  t_sim={row['t_sim']:9.0f}s  "
            f"lost={row['lost']:5d}  up={row['bytes_up'] / 1e3:7.1f}kB")


def run(crash_rates, rounds=300, n_agents=100, dim=100, m=100, seed=0,
        verbose=True, ledger_path=LEDGER):
    data, _ = generate(jax.random.PRNGKey(seed), n_agents=n_agents, m=m,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)
    C = COMPRESSORS["quant_coarse"]
    err = lambda s: float(optimality_error(s.x, x_star))  # noqa: E731

    # ONE engine for the whole sweep (rounds are pure functions of
    # (scenario, seed, t0)); each arm installs its FaultModel through
    # the facade → Engine.install_faults, which re-derives the blocked
    # masks — fault draws are counter-based, so arms can't contaminate
    # each other any more than channel sweeps can
    engine = Engine(_scenario())
    run_ids = []
    for cr in crash_rates:
        fm = FaultModel(crash_rate=cr,
                        head_failure_rate=HEAD_FAILURE_RATE,
                        failover_timeout=FAILOVER_TIMEOUT)
        for arm, robust, deadline, quorum in ARMS:
            alg = FedLT(loss=loss, uplink=EFChannel(C),
                        downlink=EFChannel(C), **TUNED)
            exp = Experiment(None, alg, engine=engine, compressor=C,
                             faults=fm, deadline=deadline, quorum=quorum,
                             loss_robust=robust,
                             meta=dict(arm=arm, crash_rate=cr,
                                       rounds=rounds, seed=seed,
                                       quorum=quorum))
            st = exp.init(jnp.zeros((dim,)), n_agents)
            res = exp.run(st, data, rounds, jax.random.PRNGKey(100 + seed),
                          error_fn=err, log_every=rounds,
                          ledger=ledger_path)
            run_ids.append(res.run_id)
    # ---- reporting: exclusively from the ledger -------------------------
    by_id = {e["run_id"]: e for e in load_ledger(ledger_path)}
    entries = [by_id[r] for r in run_ids]     # sweep order
    rows = fault_tolerance_rows(entries)
    if verbose:
        for row in rows:
            print(render_row(row))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR,
                           "table_fault_tolerance.json"), "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(quick=False):
    t0 = time.time()
    crash_rates = [0.0, 0.05, 0.1]
    rows = run(crash_rates, rounds=120 if quick else 300)
    # the acceptance gate: at every crash rate >= 5% the robust arm
    # strictly beats the naive baseline on e_K at equal rounds, without
    # spending more uplink bytes (rows come from the ledger, see run())
    by = {(r["crash_rate"], r["arm"]): r for r in rows}
    high = [cr for cr in crash_rates if cr >= 0.05]
    dominates = all(
        by[(cr, ROBUST)]["error"] < by[(cr, NAIVE)]["error"]
        and by[(cr, ROBUST)]["bytes_up"] <= 1.05 * by[(cr, NAIVE)]["bytes_up"]
        for cr in high)
    ratio = (sum(by[(cr, NAIVE)]["error"] / by[(cr, ROBUST)]["error"]
                 for cr in high) / len(high))
    speedup = (sum(by[(cr, NAIVE)]["t_sim"] / by[(cr, ROBUST)]["t_sim"]
                   for cr in high) / len(high))
    us = (time.time() - t0) * 1e6
    print(f"table_fault_tolerance,{us:.0f},robust_dominates={int(dominates)},"
          f"mean_naive_over_robust={ratio:.2f},mean_tsim_speedup={speedup:.2f}")
    return dominates


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="120-round sweep")
    main(quick=ap.parse_args().quick)
