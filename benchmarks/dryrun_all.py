"""Driver: baseline dry-runs for every (arch × shape × mesh) combination.

Per single-pod combo, three subprocess compiles:
  1. production scan build  → lowers+compiles, memory fits-check, artifact
  2. unrolled, scan_repeats=1 ┐ exact per-unit costs; linear extrapolation
  3. unrolled, scan_repeats=2 ┘ total = c1 + (R−1)·(c2−c1)
Per multi-pod combo: the production build only (proves the pod axis shards).

Each run is a separate process because XLA_FLAGS=…device_count=512 must be
set before jax initializes, and compiles are memory-hungry.

Usage:  PYTHONPATH=src python -m benchmarks.dryrun_all [--only arch] [--shapes ...]
Writes results/dryrun/<arch>__<shape>__<mesh>[__variant].json
"""
from __future__ import annotations

import argparse
import itertools
import os
import subprocess
import sys
import time

ARCHS = ["musicgen-large", "granite-20b", "qwen2-vl-7b", "grok-1-314b",
         "mixtral-8x7b", "stablelm-1.6b", "gemma3-27b", "zamba2-2.7b",
         "h2o-danube-3-4b", "rwkv6-3b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run_one(arch, shape, mesh, extra=(), tag="", timeout=3600):
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{arch}__{shape}__{mesh}{('__' + tag) if tag else ''}"
    out = os.path.join(OUT_DIR, name + ".json")
    if os.path.exists(out):
        print(f"[skip done] {name}")
        return True
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", out, *extra]
    t0 = time.time()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                           env={**os.environ, "PYTHONPATH": "src"},
                           cwd=os.path.join(os.path.dirname(__file__), ".."))
    except subprocess.TimeoutExpired:
        print(f"[TIMEOUT {timeout}s] {name}")
        return False
    ok = r.returncode == 0
    print(f"[{'ok' if ok else 'FAIL'} {time.time()-t0:6.0f}s] {name}")
    if not ok:
        err_path = out.replace(".json", ".err")
        with open(err_path, "w") as f:
            f.write(r.stdout[-5000:] + "\n---\n" + r.stderr[-10000:])
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-list of archs")
    ap.add_argument("--shapes", default=None, help="comma-list of shapes")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--skip-unroll", action="store_true")
    args = ap.parse_args()

    archs = args.only.split(",") if args.only else ARCHS
    shapes = args.shapes.split(",") if args.shapes else SHAPES
    meshes = args.meshes.split(",")

    failures = []
    for arch, shape in itertools.product(archs, shapes):
        for mesh in meshes:
            if not run_one(arch, shape, mesh):
                failures.append((arch, shape, mesh, "prod"))
        if "single" in meshes and not args.skip_unroll:
            for r in (1, 2):
                if not run_one(arch, shape, "single",
                               ["--unroll", "--scan-repeats", str(r)],
                               tag=f"unroll{r}"):
                    failures.append((arch, shape, "single", f"unroll{r}"))
    print("\nFailures:", failures if failures else "none")


if __name__ == "__main__":
    main()
