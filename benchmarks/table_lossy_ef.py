"""Loss-robust error feedback over a lossy uplink (channel-subsystem table).

Sweeps the segment-erasure probability of a :class:`repro.channel.
ChannelModel` on the ``walker-kiruna`` scenario and compares three arms of
Fed-LT under coarse quantization:

  * **EF (loss-robust)** — Algorithm 2 + ``SpaceRunner(loss_robust=True)``:
    a destroyed uplink reverts the satellite's EF residual, so the cached
    content telescopes into its next successful transmission;
  * **EF (naive)** — Algorithm 2 with the cache discharged into the lost
    wire (``loss_robust=False``): the bookkeeping believes the wire landed;
  * **no EF** — Algorithm 1 (``EFChannel(enabled=False)``): lost updates
    simply vanish.

Expected qualitative result (the channel-subsystem acceptance claim): the
loss-robust EF arm strictly dominates the no-EF arm at every loss rate ≥
10 %, and beats naive EF as the loss rate grows.  One segment per message
(``seg_bytes`` ≥ message size, ``max_rounds=1``) makes the segment-loss
rate equal the update-loss rate, so the sweep axis is directly
interpretable.

Every arm runs under a :mod:`repro.obs` trace and is folded into a run
ledger (``results/ledger_lossy_ef.jsonl``); the printed table, the JSON
dump, and the derived dominance metrics are rendered **exclusively from
the ledger entries** (:func:`repro.obs.report.lossy_ef_rows`) — there is
no separate in-memory reporting path, so what the ledger records is by
construction what the table claims.  Cross-sweep comparisons come free:

    PYTHONPATH=src python -m repro.obs report --ledger \
        results/ledger_lossy_ef.jsonl --frontier

Run:  PYTHONPATH=src python -m benchmarks.table_lossy_ef [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.api import Experiment
from repro.channel import ChannelModel, SelectiveRepeatARQ
from repro.core.compression import UniformQuantizer
from repro.core.error_feedback import EFChannel
from repro.core.fedlt import FedLT, optimality_error
from repro.data.logistic import generate, make_local_loss, solve_global
from repro.obs.ledger import load_ledger
from repro.obs.report import lossy_ef_rows
from repro.sim import Engine, get_scenario

from .common import RESULTS_DIR, TUNED

ARMS = [
    ("EF (loss-robust)", True, True),
    ("EF (naive)", True, False),
    ("no EF", False, False),
]

LEDGER = os.path.join(RESULTS_DIR, "ledger_lossy_ef.jsonl")


def render_row(row: dict) -> str:
    return (f"p={row['loss_rate']:4.2f}  {row['arm']:18s} "
            f"e_K={row['error']:.5f}  "
            f"lost={row['lost']:5d}/{row['lost'] + row['received']}  "
            f"up={row['bytes_up'] / 1e3:7.1f}kB")


def run(loss_rates, rounds=1500, n_agents=100, dim=100, m=100, seed=0,
        verbose=True, ledger_path=LEDGER):
    data, _ = generate(jax.random.PRNGKey(seed), n_agents=n_agents, m=m,
                       dim=dim)
    loss = make_local_loss(eps=50.0, n_agents=n_agents)
    x_star = solve_global(data, eps=50.0)
    C = UniformQuantizer(levels=10, vmin=-1, vmax=1, clip=True)
    err = lambda s: float(optimality_error(s.x, x_star))  # noqa: E731

    # ONE engine for the whole sweep: rounds are pure functions of
    # (scenario, seed, t0), so arms can't contaminate each other, while
    # the contact plan builds once and the fast path's cached ARQ plans
    # (keyed by the installed channel's identity) amortize across the
    # 1500-round runs instead of being re-derived per (p, arm)
    engine = Engine(get_scenario("walker-kiruna"))
    run_ids = []
    for p in loss_rates:
        # one segment per update + no retransmission → the segment-loss
        # rate IS the update-loss rate (the sweep axis)
        ch = ChannelModel(loss=p, arq=SelectiveRepeatARQ(seg_bytes=4096,
                                                         max_rounds=1))
        for arm, ef, robust in ARMS:
            alg = FedLT(loss=loss, uplink=EFChannel(C, enabled=ef),
                        downlink=EFChannel(C, enabled=ef), **TUNED)
            # the facade installs ch on the shared engine (ChannelCache
            # invalidation included), stamps the self-describing meta
            # (scenario/compressor/channel/topology derived, not retyped),
            # traces the run, and folds it into the ledger
            exp = Experiment(None, alg, engine=engine, compressor=C,
                             channel=ch, loss_robust=robust,
                             meta=dict(arm=arm, loss_rate=p, rounds=rounds,
                                       seed=seed))
            st = exp.init(jnp.zeros((dim,)), n_agents)
            res = exp.run(st, data, rounds, jax.random.PRNGKey(100 + seed),
                          error_fn=err, log_every=rounds, ledger=ledger_path)
            run_ids.append(res.run_id)
    # ---- reporting: exclusively from the ledger -------------------------
    by_id = {e["run_id"]: e for e in load_ledger(ledger_path)}
    entries = [by_id[r] for r in run_ids]     # sweep order
    rows = lossy_ef_rows(entries)
    if verbose:
        for row in rows:
            print(render_row(row))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "table_lossy_ef.json"), "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(quick=False):
    t0 = time.time()
    loss_rates = [0.0, 0.1, 0.2] if quick else [0.0, 0.05, 0.1, 0.2, 0.3]
    rows = run(loss_rates, rounds=500 if quick else 1500)
    # derived metric: does loss-robust EF strictly dominate no-EF at every
    # loss rate >= 10%?  (rows come from the ledger, see run())
    by = {(r["loss_rate"], r["arm"]): r["error"] for r in rows}
    high = [p for p in loss_rates if p >= 0.1]
    dominates = all(by[(p, "EF (loss-robust)")] < by[(p, "no EF")]
                    for p in high)
    ratio = (sum(by[(p, "no EF")] / by[(p, "EF (loss-robust)")]
                 for p in high) / len(high))
    us = (time.time() - t0) * 1e6
    print(f"table_lossy_ef,{us:.0f},ef_dominates={int(dominates)},"
          f"mean_noef_over_ef={ratio:.2f}")
    return dominates


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="3-point sweep, 500 rounds")
    main(quick=ap.parse_args().quick)
