"""Constellation-simulator scaling: contact-plan scheduling vs the seed
per-round propagation path, and engine throughput up to 1000 satellites.

Two claims:

  1. Precomputing the contact plan (O(T·S) once + O(log T) lookups) beats
     the seed scheduler (which re-propagated a 720-step visibility grid on
     EVERY ``select`` call) by ≥ 5× at 100 rounds × 100 satellites.
  2. The discrete-event engine runs a 1000-satellite scenario (sync rounds
     and async deliveries) in seconds of wall-clock.

Prints ``sim_scale,us,speedup=…,sats1000_ok=…`` CSV like the other
benchmark sections.
"""
from __future__ import annotations

import time

import numpy as np

from repro.constellation.links import LinkModel, message_bytes
from repro.constellation.orbits import GroundStation, Walker
from repro.constellation.scheduler import Scheduler, legacy_select
from repro.sim import Engine, Scenario, get_scenario

MSG = message_bytes(10000, 10.0)


def bench_seed_path(rounds: int, walker: Walker, gs: GroundStation,
                    link: LinkModel) -> float:
    t0 = time.perf_counter()
    t = 0.0
    for _ in range(rounds):
        _, d = legacy_select(walker, gs, link, t, MSG)
        t += d
    return time.perf_counter() - t0


def bench_plan_path(rounds: int, walker: Walker, gs: GroundStation) -> float:
    sched = Scheduler(walker, gs)        # plan built lazily inside — timed
    t0 = time.perf_counter()
    t = 0.0
    for _ in range(rounds):
        _, d = sched.select(t, MSG)
        t += d
    return time.perf_counter() - t0


def bench_scale(n_sats: int, rounds: int, async_deliveries: int) -> dict:
    if n_sats >= 1000:
        sc = get_scenario("mega-1000")
    else:
        sc = Scenario(name=f"scale-{n_sats}",
                      walker=Walker(n_sats=n_sats,
                                    n_planes=max(2, n_sats // 10)),
                      stations=(GroundStation(),))
    eng = Engine(sc)
    t0 = time.perf_counter()
    t, active = 0.0, 0
    for _ in range(rounds):
        res = eng.run_round(t, MSG)
        t += res.duration
        active += int(res.mask.sum())
    t_sync = time.perf_counter() - t0
    t0 = time.perf_counter()
    deliveries = eng.run_async(0.0, MSG, n_deliveries=async_deliveries)
    t_async = time.perf_counter() - t0
    return {"n_sats": n_sats, "sync_s": t_sync, "sync_active": active,
            "async_s": t_async, "async_n": len(deliveries)}


def main(quick: bool = False) -> float:
    t_start = time.time()
    rounds = 100      # the claim is defined at 100 rounds × 100 sats —
    walker, gs, link = Walker(), GroundStation(), LinkModel()
    # shorter runs under-amortize the one-off contact-plan build

    t_seed = bench_seed_path(rounds, walker, gs, link)
    t_plan = bench_plan_path(rounds, walker, gs)
    speedup = t_seed / t_plan
    print(f"scheduling {rounds} rounds x {walker.n_sats} sats: "
          f"seed {t_seed:.3f}s  contact-plan {t_plan:.3f}s  "
          f"speedup {speedup:.1f}x")

    sizes = [100, 1000] if quick else [100, 250, 500, 1000]
    sync_rounds = 3 if quick else 10
    async_n = 100 if quick else 300
    ok_1000 = 0
    for n in sizes:
        r = bench_scale(n, sync_rounds, async_n)
        print(f"  {r['n_sats']:5d} sats: {sync_rounds} sync rounds "
              f"{r['sync_s']:.2f}s ({r['sync_active']} updates), "
              f"{r['async_n']} async deliveries {r['async_s']:.2f}s")
        if n >= 1000 and r["async_n"] > 0:
            ok_1000 = 1

    us = (time.time() - t_start) * 1e6
    print(f"sim_scale,{us:.0f},speedup={speedup:.1f},sats1000_ok={ok_1000}")
    return speedup


if __name__ == "__main__":
    main()
